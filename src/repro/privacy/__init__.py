"""Privacy subsystem: measure what the fed runtime leaks, and defend it.

attacks.py   — gradient inversion, activation inversion, membership
               inference against the artifacts that cross the wire.
metrics.py   — PSNR/SSIM, distance correlation per split depth, attack
               AUC/advantage.
defenses.py  — DP-SGD (per-example clip + noise via kernels/dp_clip), a
               pre-codec uplink DP stage, and an RDP accountant.
"""
from repro.privacy.attacks import (ActivationInversionAttack, delta_to_grad,
                                   invert_gradients, make_prefix_fn,
                                   make_shipped_prefix_fn,
                                   membership_inference, membership_scores,
                                   plan_boundary_depths)
from repro.privacy.defenses import (DPUplinkStage, RDPAccountant, dp_epsilon,
                                    make_dp_d_step, make_uplink_stage,
                                    rdp_sampled_gaussian, sigma_for_epsilon)
from repro.privacy.metrics import (attack_advantage, attack_auc,
                                   best_match_psnr, distance_correlation,
                                   psnr, ssim)

__all__ = [
    "ActivationInversionAttack", "delta_to_grad", "invert_gradients",
    "make_prefix_fn", "make_shipped_prefix_fn", "membership_inference",
    "membership_scores",
    "plan_boundary_depths", "DPUplinkStage", "RDPAccountant", "dp_epsilon",
    "make_dp_d_step", "make_uplink_stage", "rdp_sampled_gaussian",
    "sigma_for_epsilon",
    "attack_advantage", "attack_auc", "best_match_psnr",
    "distance_correlation", "psnr", "ssim",
]
