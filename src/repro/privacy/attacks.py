"""Honest-but-curious attacks on the artifacts the fed runtime ships.

The paper's privacy claim is that raw data never leaves the device — only
(a) discriminator parameters/deltas go up the WAN and (b) split-boundary
activations hop the LAN between a client's devices.  Following *Evaluating
Privacy Leakage in Split Learning* (Qiu et al.) and PS-FedGAN (Wijesinghe
et al.), this module measures what each artifact gives away:

  * :func:`invert_gradients` — DLG-style gradient inversion (Zhu et al.
    2019; cosine matching per Geiping et al. 2020): the server knows the
    global D it broadcast, the fakes it shipped, and the uplinked delta;
    it optimizes dummy "real" images until the simulated local gradient
    matches the observed one.  Exact for one SGD local step (delta is
    -lr * grad); directional for Adam/多-step deltas — cosine matching is
    scale-free, which is why it is the default objective.
  * :class:`ActivationInversionAttack` — a decoder trained on auxiliary
    data to invert the smashed activations crossing one
    :class:`~repro.core.split.SplitPlan` boundary (the LAN surface inside
    a client).  :func:`make_shipped_prefix_fn` targets the tensors an
    *executed* split round actually ships — post-boundary-stage
    (codec/DP), via ``core/split.SplitExecution`` — while
    :func:`make_prefix_fn` keeps the clean-prefix probe for depth sweeps.
    Leakage shrinks with split depth — the frontier bench_privacy.py
    plots.
  * :func:`membership_inference` — threshold attack on the trained D
    (Yeom et al. 2018): D's realness logit is systematically higher on its
    own training reals than on held-out reals; AUC/advantage quantify the
    exposure.

All attacks are pure functions of artifacts the threat model grants the
attacker; none touch the victim's raw data except to *score* the attack.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.dcgan import disc_apply, disc_apply_layer, disc_layer_names
from repro.optim.optimizers import adamw
from repro.privacy.metrics import attack_advantage, attack_auc

# loss_fn(params, real_batch, fake_batch) -> scalar  (the D loss the victim
# trains with; core/gan.d_loss_fn partial-applied over the model config)
DLossFn = Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray]


# ---------------------------------------------------------------------------
# gradient inversion of the uplinked discriminator delta
# ---------------------------------------------------------------------------

def flat_grads(tree) -> jnp.ndarray:
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in jax.tree.leaves(tree)])


def delta_to_grad(delta, lr: float):
    """One local SGD step: uplinked delta = -lr * grad, inverted exactly.
    (Adam deltas only preserve direction — feed them to the cosine
    objective as-is instead.)"""
    return jax.tree.map(lambda d: -d.astype(jnp.float32) / lr, delta)


def _total_variation(x: jnp.ndarray) -> jnp.ndarray:
    return (jnp.mean(jnp.abs(x[:, 1:] - x[:, :-1]))
            + jnp.mean(jnp.abs(x[:, :, 1:] - x[:, :, :-1])))


def invert_gradients(loss_fn: DLossFn, d_params, target_grads, fakes,
                     batch_shape: Tuple[int, ...], *, steps: int = 300,
                     lr: float = 0.1, tv_weight: float = 1e-3,
                     key: Optional[jax.Array] = None, x0=None
                     ) -> Tuple[jnp.ndarray, List[float]]:
    """Reconstruct the victim's real batch from an observed D gradient.

    ``target_grads``: the gradient tree the server inferred from the uplink
    (see :func:`delta_to_grad`).  ``batch_shape``: (B, H, W, C) of the batch
    being reconstructed.  Minimizes 1 - cos(sim_grad, target) + TV prior
    with Adam, projecting onto the valid [-1, 1] image box each step.

    Returns (reconstructed batch, matching-loss history).
    """
    key = jax.random.PRNGKey(0) if key is None else key
    tgt = flat_grads(target_grads)
    tgt_norm = jnp.linalg.norm(tgt)

    def match_loss(x):
        g = jax.grad(loss_fn)(d_params, x, fakes)
        gv = flat_grads(g)
        cos = jnp.dot(gv, tgt) / jnp.maximum(
            jnp.linalg.norm(gv) * tgt_norm, 1e-12)
        return (1.0 - cos) + tv_weight * _total_variation(x)

    opt = adamw(0.9, 0.999, 1e-8)
    x = (0.1 * jax.random.normal(key, batch_shape, jnp.float32)
         if x0 is None else jnp.asarray(x0, jnp.float32))
    state = opt.init(x)
    lr_arr = jnp.asarray(lr)

    @jax.jit
    def step(x, state):
        loss, g = jax.value_and_grad(match_loss)(x)
        x, state = opt.update(g, state, x, lr_arr)
        return jnp.clip(x, -1.0, 1.0), state, loss

    history: List[float] = []
    for _ in range(steps):
        x, state, loss = step(x, state)
        history.append(float(loss))
    return x, history


# ---------------------------------------------------------------------------
# activation inversion at a split boundary
# ---------------------------------------------------------------------------

def make_prefix_fn(d_params, c, depth: int):
    """Apply the first ``depth`` discriminator layers: the activation a
    device at that boundary sees. depth=1 => output of conv0, etc."""
    names = disc_layer_names(c)[:depth]

    def prefix(x):
        for n in names:
            x = disc_apply_layer(n, d_params, x, c)
        return x

    return prefix


def plan_boundary_depths(plan) -> List[int]:
    """Layer depths at which this plan's activations cross devices (the
    LAN hops an on-path device can observe)."""
    depths, li = [], 0
    for a, b in zip(plan.portions, plan.portions[1:]):
        li += len(a.layer_names)
        if a.device_id != b.device_id:
            depths.append(li)
    return depths


def make_shipped_prefix_fn(split_exec, d_params, boundary_idx: int, *,
                           key: Optional[jax.Array] = None):
    """Prefix returning what an on-path device ACTUALLY observes at
    ``boundary_idx`` during executed split training: the staged boundary
    tensor — post-codec, post-DP-noise — not a separate clean forward.

    ``split_exec`` is the ``core/split.SplitExecution`` the training step
    runs (``FSLGANTrainer.split_execs[cid]``); feeding this prefix to
    :class:`ActivationInversionAttack` measures the leakage of the split
    round as deployed, so a lossy/noisy boundary stage shows up as a
    weaker reconstruction.  ``key`` seeds a stochastic stage; each call
    folds in a fresh counter — every observation is one LAN crossing with
    its own noise draw, so a decoder can never learn to subtract a single
    reused noise tensor.  Omitted, a default key is derived: a keyless
    probe must never ship noiseless tensors and overstate the leakage of
    the deployed round.
    """
    if key is None and getattr(split_exec, "stochastic", False):
        key = jax.random.PRNGKey(0)
    calls = iter(range(1 << 30))

    def prefix(x):
        k = None if key is None else jax.random.fold_in(key, next(calls))
        return split_exec.forward_boundaries(
            d_params, x, key=k, upto=boundary_idx)[boundary_idx]

    return prefix


def _decoder_init(key, act_shape, out_shape, width: int = 32):
    """Resize-conv decoder from (H', W', C') activations to (H, W, C)."""
    h, cin = act_shape[0], act_shape[2]
    target_h, cout = out_shape[0], out_shape[2]
    sizes, chans = [], []
    while h < target_h:
        h = min(2 * h, target_h)
        sizes.append(h)
        chans.append(width)
    sizes.append(target_h)          # final refinement conv at full res
    chans.append(cout)
    params, keys = [], jax.random.split(key, len(chans))
    for i, (k, ch) in enumerate(zip(keys, chans)):
        fan = 3 * 3 * cin
        params.append({
            "w": jax.random.normal(k, (3, 3, cin, ch), jnp.float32)
            * (2.0 / fan) ** 0.5,
            "b": jnp.zeros((ch,), jnp.float32)})
        cin = ch
    # sizes are static structure, kept apart from the trainable tree
    return params, tuple(sizes)


def _decoder_apply(layers, sizes, a: jnp.ndarray) -> jnp.ndarray:
    x = a.astype(jnp.float32)
    for i, lp in enumerate(layers):
        if i < len(sizes):
            x = jax.image.resize(
                x, (x.shape[0], sizes[i], sizes[i], x.shape[3]), "bilinear")
        x = jax.lax.conv_general_dilated(
            x, lp["w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + lp["b"]
        if i < len(layers) - 1:
            x = jax.nn.leaky_relu(x, 0.2)
    return jnp.tanh(x)


class ActivationInversionAttack:
    """Decoder attack on one split boundary.

    Threat model: an on-path device (or LAN eavesdropper) observes the
    smashed activations ``prefix(x)`` and can query the prefix on auxiliary
    data of the same modality (shadow access — the weakest assumption under
    which Qiu et al.'s attack applies).  ``train`` fits the decoder on
    (prefix(aux), aux) pairs; ``reconstruct`` inverts victim activations.
    """

    def __init__(self, prefix_fn, image_shape: Tuple[int, int, int], *,
                 width: int = 32, seed: int = 0):
        self.prefix = prefix_fn
        self.image_shape = tuple(image_shape)
        probe = prefix_fn(jnp.zeros((1,) + self.image_shape, jnp.float32))
        self.act_shape = tuple(probe.shape[1:])
        self.dec, self.sizes = _decoder_init(
            jax.random.PRNGKey(seed), self.act_shape, self.image_shape,
            width)
        self._opt = adamw(0.9, 0.999, 1e-8)
        self._state = self._opt.init(self.dec)

    def train(self, aux_images: jnp.ndarray, *, steps: int = 200,
              batch: int = 32, lr: float = 2e-3, seed: int = 0
              ) -> List[float]:
        acts = self.prefix(jnp.asarray(aux_images, jnp.float32))
        sizes = self.sizes

        def loss_fn(dec, a, y):
            return jnp.mean((_decoder_apply(dec, sizes, a) - y) ** 2)

        lr_arr = jnp.asarray(lr)

        @jax.jit
        def step(dec, state, a, y):
            loss, g = jax.value_and_grad(loss_fn)(dec, a, y)
            dec, state = self._opt.update(g, state, dec, lr_arr)
            return dec, state, loss

        rng = np.random.default_rng(seed)
        history = []
        for _ in range(steps):
            idx = rng.integers(0, aux_images.shape[0], batch)
            self.dec, self._state, l = step(
                self.dec, self._state, acts[idx],
                jnp.asarray(aux_images[idx], jnp.float32))
            history.append(float(l))
        return history

    def reconstruct(self, victim_images: jnp.ndarray) -> jnp.ndarray:
        """Invert the activations of (unseen) victim inputs."""
        return _decoder_apply(self.dec, self.sizes, self.prefix(
            jnp.asarray(victim_images, jnp.float32)))


# ---------------------------------------------------------------------------
# membership inference against the trained discriminator
# ---------------------------------------------------------------------------

def membership_scores(d_params, x: jnp.ndarray, c) -> np.ndarray:
    """Per-example realness logit — D's confidence the example is from its
    training distribution (the MIA score)."""
    return np.asarray(disc_apply(d_params, jnp.asarray(x, jnp.float32),
                                 c)[:, 0])


def membership_inference(d_params, c, member_x, nonmember_x
                         ) -> Dict[str, float]:
    """Yeom-style threshold attack: returns auc, advantage, threshold."""
    ms = membership_scores(d_params, member_x, c)
    ns = membership_scores(d_params, nonmember_x, c)
    adv, thr = attack_advantage(ms, ns)
    return {"auc": attack_auc(ms, ns), "advantage": adv, "threshold": thr,
            "member_mean": float(ms.mean()),
            "nonmember_mean": float(ns.mean())}
