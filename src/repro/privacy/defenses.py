"""Tunable defenses for the artifacts that leave the device.

The paper's privacy argument is architectural (raw data never leaves the
client); privacy/attacks.py shows the artifacts that DO leave — uplinked
discriminator deltas and split-boundary activations — still leak.  This
module makes the defense side of that trade measurable:

  * **DP-SGD** (Abadi et al. 2016) on the device-side discriminator update:
    per-example L2 clipping + Gaussian noise, fused by the
    ``kernels/dp_clip`` Pallas kernel (or its pure-JAX reference).  NB: the
    per-example gradient is taken on singleton batches, so batch-norm
    statistics are per-example — the standard DP-SGD stance on BN (cross-
    example coupling would break the per-example sensitivity bound).
  * **Uplink DP** — clip-and-noise the whole update delta once per round,
    *before* the transport codec compresses it (a pre-codec stage for
    ``fed/engine.FederationEngine``).  Weaker than DP-SGD (one clip per
    round, not per example) but composes with any codec and costs nothing
    on-device.
  * **RDP accountant** for the (subsampled) Gaussian mechanism (Mironov
    2017; Mironov et al. 2019): integer Rényi orders, converted to an
    (epsilon, delta) spend.  Pure math/numpy — no external dependency.

Config surface: ``RunConfig.privacy`` (config/base.py); the trainer
(core/gan.py) builds the step/stage from it via :func:`make_dp_d_step` and
:func:`make_uplink_stage`.
"""
from __future__ import annotations

import math
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dp_clip.ops import dp_clip_noise_tree
from repro.optim.optimizers import global_norm

# ---------------------------------------------------------------------------
# RDP accountant — subsampled Gaussian mechanism
# ---------------------------------------------------------------------------

DEFAULT_ORDERS: Tuple[int, ...] = tuple(range(2, 33)) + (40, 48, 56, 64, 128)


def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1)
            - math.lgamma(n - k + 1))


def _logsumexp(xs) -> float:
    m = max(xs)
    if m == float("-inf"):
        return m
    return m + math.log(sum(math.exp(x - m) for x in xs))


def rdp_sampled_gaussian(q: float, noise_multiplier: float,
                         order: int) -> float:
    """RDP of one step of the sampled Gaussian mechanism at integer order.

    q: sampling probability; noise_multiplier: sigma (noise stddev / clip).
    q = 1 is the plain Gaussian mechanism: alpha / (2 sigma^2).  For q < 1
    the exact integer-order expression (Mironov et al. 2019, eq. 3):

        RDP(a) = log( sum_k C(a,k) (1-q)^(a-k) q^k exp((k^2-k)/(2 s^2)) )
                 / (a - 1)
    """
    if q == 0.0 or noise_multiplier == float("inf"):
        return 0.0
    if noise_multiplier <= 0.0:
        return float("inf")
    if not 0.0 < q <= 1.0:
        raise ValueError(f"sampling rate {q} outside (0, 1]")
    if order < 2 or int(order) != order:
        raise ValueError(f"integer order >= 2 required, got {order}")
    s2 = float(noise_multiplier) ** 2
    if q == 1.0:
        return order / (2.0 * s2)
    terms = [_log_comb(order, k) + k * math.log(q)
             + (order - k) * math.log1p(-q) + (k * k - k) / (2.0 * s2)
             for k in range(order + 1)]
    return _logsumexp(terms) / (order - 1)


class RDPAccountant:
    """Tracks cumulative RDP over steps; converts to (epsilon, delta).

    One ``step()`` = one application of the mechanism (one DP-SGD batch, or
    one noised uplink round).  RDP composes additively across steps.
    """

    def __init__(self, noise_multiplier: float, sample_rate: float = 1.0,
                 orders: Tuple[int, ...] = DEFAULT_ORDERS):
        self.noise_multiplier = float(noise_multiplier)
        self.sample_rate = float(sample_rate)
        self.orders = tuple(orders)
        self._rdp_per_step = [rdp_sampled_gaussian(self.sample_rate,
                                                   self.noise_multiplier, a)
                              for a in self.orders]
        self.steps = 0

    def step(self, num_steps: int = 1) -> None:
        self.steps += int(num_steps)

    def epsilon(self, delta: float = 1e-5) -> Tuple[float, int]:
        """Best (epsilon, order) over the tracked orders.

        Classic conversion (Mironov 2017 Prop. 3):
        eps = RDP(a) - log(delta) / (a - 1).
        """
        if self.noise_multiplier <= 0.0 or self.steps == 0:
            return (float("inf") if self.steps and
                    self.noise_multiplier <= 0.0 else 0.0,
                    self.orders[0])
        best_eps, best_order = float("inf"), self.orders[0]
        for a, r in zip(self.orders, self._rdp_per_step):
            eps = self.steps * r - math.log(delta) / (a - 1)
            if eps < best_eps:
                best_eps, best_order = eps, a
        return best_eps, best_order


def dp_epsilon(noise_multiplier: float, sample_rate: float, steps: int,
               delta: float = 1e-5) -> float:
    """One-shot epsilon for a finished run (benchmarks/examples)."""
    acct = RDPAccountant(noise_multiplier, sample_rate)
    acct.step(steps)
    return acct.epsilon(delta)[0]


# ---------------------------------------------------------------------------
# DP-SGD device-side discriminator step
# ---------------------------------------------------------------------------

def make_dp_d_step(optimizer, loss_fn, lr: float, clip_norm: float,
                   noise_multiplier: float, *, use_kernel: bool = False,
                   interpret: bool = False):
    """Build the jitted DP-SGD discriminator step.

    ``loss_fn(params, real, fake) -> scalar`` is the batch loss; the step
    re-evaluates it on singleton batches to get per-example gradients
    (vmap over examples), privatizes them through the dp_clip kernel
    (per-example L2 clip to ``clip_norm``, Gaussian noise with stddev
    ``noise_multiplier * clip_norm`` on the SUM), and feeds the mean to the
    optimizer.

    Returns ``dp_step(params, opt, real, fake, key) -> (params, opt, loss)``.
    """
    lr_arr = jnp.asarray(lr)
    noise_scale = float(noise_multiplier) * float(clip_norm)

    def one_example(p, r, f):
        return loss_fn(p, r[None], f[None])

    grad_one = jax.value_and_grad(one_example)

    @jax.jit
    def dp_step(params, opt, real, fake, key):
        losses, per_ex = jax.vmap(grad_one, in_axes=(None, 0, 0))(
            params, real, fake)
        summed = dp_clip_noise_tree(per_ex, clip_norm, noise_scale, key,
                                    use_kernel=use_kernel,
                                    interpret=interpret)
        b = real.shape[0]
        grads = jax.tree.map(lambda g: g / b, summed)
        params, opt = optimizer.update(grads, opt, params, lr_arr)
        return params, opt, jnp.mean(losses)

    return dp_step


# ---------------------------------------------------------------------------
# uplink delta clip-and-noise — a pre-codec transport stage
# ---------------------------------------------------------------------------

class DPUplinkStage:
    """Clip + noise the uplink delta once per round, before the codec.

    The engine calls ``stage(client_id, delta_tree)`` between delta
    computation and codec round-trip (fed/engine.py).  The delta's GLOBAL
    L2 norm is clipped to ``clip_norm`` and elementwise Gaussian noise of
    stddev ``noise_multiplier * clip_norm`` is added, so what the codec
    compresses (and the honest-but-curious server sees) is already
    privatized.  Noise keys are deterministic per (seed, client, round) —
    crc32 of the client id, not Python's salted ``hash``.
    """

    def __init__(self, clip_norm: float, noise_multiplier: float,
                 seed: int = 0):
        self.clip_norm = float(clip_norm)
        self.noise_multiplier = float(noise_multiplier)
        self.seed = int(seed)
        self._round: Dict[str, int] = {}

    def _key(self, cid: str):
        i = self._round.get(cid, 0)
        self._round[cid] = i + 1
        base = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                  zlib.crc32(cid.encode()) & 0x7FFFFFFF)
        return jax.random.fold_in(base, i)

    def __call__(self, cid: str, delta):
        leaves, treedef = jax.tree.flatten(delta)
        norm = global_norm(delta)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(norm, 1e-12))
        sigma = self.noise_multiplier * self.clip_norm
        keys = jax.random.split(self._key(cid), len(leaves))
        out = [((l.astype(jnp.float32) * scale
                 + sigma * jax.random.normal(k, l.shape, jnp.float32))
                .astype(l.dtype))
               for l, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, out)


def make_uplink_stage(priv_cfg) -> Optional[DPUplinkStage]:
    """cfg.privacy -> pre-codec stage, or None when not in uplink mode."""
    if priv_cfg is None or not priv_cfg.enabled or priv_cfg.mode != "uplink":
        return None
    return DPUplinkStage(priv_cfg.clip_norm, priv_cfg.noise_multiplier,
                         priv_cfg.seed)
