"""Tunable defenses for the artifacts that leave the device.

The paper's privacy argument is architectural (raw data never leaves the
client); privacy/attacks.py shows the artifacts that DO leave — uplinked
discriminator deltas and split-boundary activations — still leak.  This
module makes the defense side of that trade measurable:

  * **DP-SGD** (Abadi et al. 2016) on the device-side discriminator update:
    per-example L2 clipping + Gaussian noise, fused by the
    ``kernels/dp_clip`` Pallas kernel (or its pure-JAX reference).  NB: the
    per-example gradient is taken on singleton batches, so batch-norm
    statistics are per-example — the standard DP-SGD stance on BN (cross-
    example coupling would break the per-example sensitivity bound).
  * **Uplink DP** — clip-and-noise the whole update delta once per round,
    *before* the transport codec compresses it (a pre-codec stage for
    ``fed/engine.FederationEngine``).  Weaker than DP-SGD (one clip per
    round, not per example) but composes with any codec and costs nothing
    on-device.
  * **RDP accountant** for the (subsampled) Gaussian mechanism (Mironov
    2017; Mironov et al. 2019): integer Rényi orders, converted to an
    (epsilon, delta) spend.  Pure math/numpy — no external dependency.

Config surface: ``RunConfig.privacy`` (config/base.py); the trainer
(core/gan.py) builds the step/stage from it via :func:`make_dp_d_step` and
:func:`make_uplink_stage`.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import global_norm

# ---------------------------------------------------------------------------
# RDP accountant — subsampled Gaussian mechanism
# ---------------------------------------------------------------------------

INTEGER_ORDERS: Tuple[float, ...] = tuple(range(2, 33)) + (40, 48, 56, 64,
                                                           128)
# dense fractional grid interleaving the integer orders: the optimal
# Rényi order for a given (sigma, q, steps, delta) is rarely an integer,
# so the integer-only grid systematically over-reports epsilon.  Kept
# below 64 — the fractional series converges slowly at very high orders
# and the tail integers cover that regime.
FRACTIONAL_ORDERS: Tuple[float, ...] = tuple(
    round(1.25 + 0.25 * i, 2) for i in range(4 * 31)
    if (1.25 + 0.25 * i) != int(1.25 + 0.25 * i)) + tuple(
    round(x + 0.5, 1) for x in range(32, 64))
DEFAULT_ORDERS: Tuple[float, ...] = tuple(sorted(
    set(INTEGER_ORDERS) | set(FRACTIONAL_ORDERS)))


def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1)
            - math.lgamma(n - k + 1))


def _logsumexp(xs) -> float:
    m = max(xs)
    if m == float("-inf"):
        return m
    return m + math.log(sum(math.exp(x - m) for x in xs))


def _log_add(logx: float, logy: float) -> float:
    """log(exp(logx) + exp(logy)), stable."""
    a, b = max(logx, logy), min(logx, logy)
    if b == float("-inf"):
        return a
    return a + math.log1p(math.exp(b - a))


def _log_sub(logx: float, logy: float) -> float:
    """log(exp(logx) - exp(logy)); requires logx >= logy."""
    if logy == float("-inf"):
        return logx
    if logx < logy:
        raise ValueError("log_sub of a larger value")
    if logx == logy:
        return float("-inf")
    return logx + math.log1p(-math.exp(logy - logx))


def _log_erfc(x: float) -> float:
    """log(erfc(x)), with the asymptotic expansion once erfc underflows."""
    r = math.erfc(x)
    if r > 1e-300:
        return math.log(r)
    return (-math.log(math.pi) / 2 - math.log(x) - x * x
            - 0.5 / (x * x) + 0.625 / x ** 4
            - 37.0 / 24.0 / x ** 6 + 353.0 / 64.0 / x ** 8)


def _rdp_frac(q: float, sigma: float, alpha: float) -> float:
    """Sampled-Gaussian RDP at fractional order (Mironov et al. 2019,
    §3.3): the binomial series over real alpha, each term weighted by
    Gaussian tail masses (log-erfc), accumulated in log space until the
    terms vanish.  Matches the integer closed form at integer alpha."""
    log_a0, log_a1 = float("-inf"), float("-inf")
    i, z0 = 0, sigma ** 2 * math.log(1.0 / q - 1.0) + 0.5
    coef_log, coef_sign = 0.0, 1.0            # log|binom(alpha, i)|, sign
    while True:
        j = alpha - i
        log_t0 = coef_log + i * math.log(q) + j * math.log1p(-q)
        log_t1 = coef_log + j * math.log(q) + i * math.log1p(-q)
        log_e0 = math.log(0.5) + _log_erfc((i - z0) / (math.sqrt(2) * sigma))
        log_e1 = math.log(0.5) + _log_erfc((z0 - j) / (math.sqrt(2) * sigma))
        log_s0 = log_t0 + (i * i - i) / (2.0 * sigma ** 2) + log_e0
        log_s1 = log_t1 + (j * j - j) / (2.0 * sigma ** 2) + log_e1
        if coef_sign > 0:
            log_a0 = _log_add(log_a0, log_s0)
            log_a1 = _log_add(log_a1, log_s1)
        else:
            log_a0 = _log_sub(log_a0, log_s0)
            log_a1 = _log_sub(log_a1, log_s1)
        i += 1
        # next binomial coefficient: binom(a, i) = binom(a, i-1)*(a-i+1)/i
        factor = (alpha - i + 1.0) / i
        if factor == 0.0:
            break
        coef_log += math.log(abs(factor))
        if factor < 0.0:
            coef_sign = -coef_sign
        if max(log_s0, log_s1) < -30.0 and i > alpha:
            break
    return _log_add(log_a0, log_a1) / (alpha - 1.0)


def rdp_sampled_gaussian(q: float, noise_multiplier: float,
                         order: float) -> float:
    """RDP of one step of the sampled Gaussian mechanism at any real
    order > 1 (integer or fractional).

    q: sampling probability; noise_multiplier: sigma (noise stddev / clip).
    q = 1 is the plain Gaussian mechanism: alpha / (2 sigma^2) for any real
    alpha.  For q < 1, integer orders use the exact binomial expression
    (Mironov et al. 2019, eq. 3):

        RDP(a) = log( sum_k C(a,k) (1-q)^(a-k) q^k exp((k^2-k)/(2 s^2)) )
                 / (a - 1)

    and fractional orders the real-alpha series (:func:`_rdp_frac`).
    """
    if q == 0.0 or noise_multiplier == float("inf"):
        return 0.0
    if noise_multiplier <= 0.0:
        return float("inf")
    if not 0.0 < q <= 1.0:
        raise ValueError(f"sampling rate {q} outside (0, 1]")
    if order <= 1:
        raise ValueError(f"order > 1 required, got {order}")
    s2 = float(noise_multiplier) ** 2
    if q == 1.0:
        return order / (2.0 * s2)
    if int(order) != order:
        return _rdp_frac(q, float(noise_multiplier), float(order))
    order = int(order)
    terms = [_log_comb(order, k) + k * math.log(q)
             + (order - k) * math.log1p(-q) + (k * k - k) / (2.0 * s2)
             for k in range(order + 1)]
    return _logsumexp(terms) / (order - 1)


class RDPAccountant:
    """Tracks cumulative RDP over steps; converts to (epsilon, delta).

    One ``step()`` = one application of the mechanism (one DP-SGD batch, or
    one noised uplink round).  RDP composes additively across steps — and
    because it does, the mechanism's noise multiplier may CHANGE between
    steps (``step(n, noise_multiplier=...)``): each batch of steps
    contributes its own per-order RDP to the running total.  This is what
    lets the control plane's sigma controller retune sigma per round while
    the accountant stays exact (per-sigma RDP vectors are cached).
    """

    def __init__(self, noise_multiplier: float, sample_rate: float = 1.0,
                 orders: Tuple[int, ...] = DEFAULT_ORDERS):
        self.noise_multiplier = float(noise_multiplier)
        self.sample_rate = float(sample_rate)
        self.orders = tuple(orders)
        self._rdp_cache: Dict[float, List[float]] = {}
        # warm the default-sigma cache now: a bad (q, sigma) pair raises at
        # construction, not on the first step() mid-training
        self._rdp_for(self.noise_multiplier)
        self._rdp_total = [0.0] * len(self.orders)
        self.steps = 0

    def _rdp_for(self, sigma: float) -> List[float]:
        sigma = float(sigma)
        if sigma not in self._rdp_cache:
            self._rdp_cache[sigma] = [
                rdp_sampled_gaussian(self.sample_rate, sigma, a)
                for a in self.orders]
        return self._rdp_cache[sigma]

    def step(self, num_steps: int = 1,
             noise_multiplier: Optional[float] = None) -> None:
        """Record ``num_steps`` mechanism applications at
        ``noise_multiplier`` (default: the constructor's sigma)."""
        n = int(num_steps)
        if n <= 0:
            # nothing released — and with sigma <= 0 the per-step RDP is
            # inf, where 0 * inf would NaN-poison the running totals
            return
        sigma = (self.noise_multiplier if noise_multiplier is None
                 else float(noise_multiplier))
        r = self._rdp_for(sigma)
        self._rdp_total = [t + n * x for t, x in zip(self._rdp_total, r)]
        self.steps += n

    def epsilon(self, delta: float = 1e-5) -> Tuple[float, int]:
        """Best (epsilon, order) over the tracked orders.

        Classic conversion (Mironov 2017 Prop. 3):
        eps = RDP(a) - log(delta) / (a - 1).
        """
        if self.steps == 0:
            return 0.0, self.orders[0]
        best_eps, best_order = float("inf"), self.orders[0]
        for a, t in zip(self.orders, self._rdp_total):
            eps = t - math.log(delta) / (a - 1)
            if eps < best_eps:
                best_eps, best_order = eps, a
        return best_eps, best_order

    def projected_epsilon(self, extra_steps: int, delta: float = 1e-5,
                          noise_multiplier: Optional[float] = None) -> float:
        """Epsilon this accountant WOULD report after ``extra_steps`` more
        applications at ``noise_multiplier`` — the sigma controller's
        budget-feasibility oracle (nothing is committed)."""
        n = int(extra_steps)
        if self.steps + n == 0:
            return 0.0
        sigma = (self.noise_multiplier if noise_multiplier is None
                 else float(noise_multiplier))
        r = self._rdp_for(sigma)
        # n == 0 must not multiply a (possibly inf) per-step RDP
        return min(t + (n * x if n else 0.0) - math.log(delta) / (a - 1)
                   for a, t, x in zip(self.orders, self._rdp_total, r))


def dp_epsilon(noise_multiplier: float, sample_rate: float, steps: int,
               delta: float = 1e-5) -> float:
    """One-shot epsilon for a finished run (benchmarks/examples)."""
    acct = RDPAccountant(noise_multiplier, sample_rate)
    acct.step(steps)
    return acct.epsilon(delta)[0]


def min_feasible_sigma(feasible, lo: float, hi: float,
                       rel_tol: float = 1e-4) -> float:
    """Smallest sigma in ``[lo, hi]`` satisfying ``feasible(sigma)``, by
    geometric bisection — THE inversion primitive for every RDP epsilon
    curve (``feasible`` must be monotone in sigma: more noise never hurts,
    property-tested via :func:`sigma_for_epsilon`).

    Always returns the bracket's FEASIBLE endpoint, never the midpoint —
    the detail the sigma controller's never-exceed guarantee rests on.
    Returns ``hi`` when even maximum noise is infeasible (the caller's
    clamp-to-most-protection boundary)."""
    lo, hi = float(lo), float(hi)
    if feasible(lo):
        return lo
    if not feasible(hi):
        return hi
    while hi / lo > 1.0 + rel_tol:
        mid = math.sqrt(lo * hi)
        if feasible(mid):
            hi = mid
        else:
            lo = mid
    return hi


def sigma_for_epsilon(epsilon: float, steps: int, delta: float = 1e-5,
                      sample_rate: float = 1.0, lo: float = 1e-3,
                      hi: float = 1e4, rel_tol: float = 1e-4) -> float:
    """Invert the RDP epsilon curve: the smallest noise multiplier whose
    fresh run of ``steps`` sampled-Gaussian applications spends at most
    ``(epsilon, delta)``.

    Epsilon is strictly decreasing in sigma on the fractional-order grid
    (property-tested), so :func:`min_feasible_sigma` converges and the
    returned sigma always satisfies ``dp_epsilon(sigma, ...) <= epsilon``.
    """
    if epsilon <= 0.0:
        raise ValueError(f"epsilon budget must be positive, got {epsilon}")
    if steps <= 0:
        return float(lo)
    return min_feasible_sigma(
        lambda s: dp_epsilon(s, sample_rate, int(steps), delta) <= epsilon,
        lo, hi, rel_tol)


# ---------------------------------------------------------------------------
# DP-SGD device-side discriminator step
# ---------------------------------------------------------------------------

def make_dp_d_step(optimizer, loss_fn, lr: float, clip_norm: float,
                   noise_multiplier: float, *, use_kernel: bool = False,
                   interpret: bool = False):
    """Build the jitted DP-SGD discriminator step.

    ``loss_fn(params, real, fake) -> scalar`` is the batch loss; the step
    re-evaluates it on singleton batches to get per-example gradients
    (vmap over examples), privatizes them through the dp_clip kernel
    (per-example L2 clip to ``clip_norm``, Gaussian noise with stddev
    ``noise_multiplier * clip_norm`` on the SUM), and feeds the mean to the
    optimizer.

    A thin lr-baking wrapper over ``fed/programs.make_local_step`` — the
    DP step definition exists exactly once, so the sequential reference
    and both engine backends can never drift apart.

    Returns ``dp_step(params, opt, real, fake, key) -> (params, opt, loss)``.
    """
    from repro.config import PrivacyConfig
    from repro.fed.programs import make_local_step

    step = make_local_step(
        optimizer, loss_fn,
        PrivacyConfig(enabled=True, mode="dp_sgd", clip_norm=clip_norm,
                      noise_multiplier=noise_multiplier,
                      use_kernel=use_kernel, kernel_interpret=interpret))
    lr_arr = jnp.asarray(lr)

    @jax.jit
    def dp_step(params, opt, real, fake, key):
        return step(params, opt, real, fake, lr_arr, key)

    return dp_step


# ---------------------------------------------------------------------------
# uplink delta clip-and-noise — a pre-codec transport stage
# ---------------------------------------------------------------------------

class DPUplinkStage:
    """Clip + noise the uplink delta once per round, before the codec.

    The engine calls ``stage(client_id, delta_tree)`` between delta
    computation and codec round-trip (fed/engine.py).  The delta's GLOBAL
    L2 norm is clipped to ``clip_norm`` and elementwise Gaussian noise of
    stddev ``noise_multiplier * clip_norm`` is added, so what the codec
    compresses (and the honest-but-curious server sees) is already
    privatized.  Noise keys are deterministic per (seed, client index,
    round): clients are indexed by first appearance — schedule-
    deterministic, and collision-free unlike hashing the id (colliding
    ids would silently share noise tensors, correlating releases the
    accountant prices as independent).
    """

    def __init__(self, clip_norm: float, noise_multiplier: float,
                 seed: int = 0):
        self.clip_norm = float(clip_norm)
        self.noise_multiplier = float(noise_multiplier)
        self.seed = int(seed)
        self._round: Dict[str, int] = {}
        self._index: Dict[str, int] = {}

    def _key(self, cid: str):
        if cid not in self._index:
            self._index[cid] = len(self._index)
        i = self._round.get(cid, 0)
        self._round[cid] = i + 1
        base = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                  self._index[cid])
        return jax.random.fold_in(base, i)

    def __call__(self, cid: str, delta):
        leaves, treedef = jax.tree.flatten(delta)
        norm = global_norm(delta)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(norm, 1e-12))
        sigma = self.noise_multiplier * self.clip_norm
        keys = jax.random.split(self._key(cid), len(leaves))
        out = [((l.astype(jnp.float32) * scale
                 + sigma * jax.random.normal(k, l.shape, jnp.float32))
                .astype(l.dtype))
               for l, k in zip(leaves, keys)]
        return jax.tree.unflatten(treedef, out)


def make_uplink_stage(priv_cfg) -> Optional[DPUplinkStage]:
    """cfg.privacy -> pre-codec stage, or None when not in uplink mode."""
    if priv_cfg is None or not priv_cfg.enabled or priv_cfg.mode != "uplink":
        return None
    return DPUplinkStage(priv_cfg.clip_norm, priv_cfg.noise_multiplier,
                         priv_cfg.seed)
