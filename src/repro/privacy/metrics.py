"""Leakage metrics: how much did an attack actually recover?

Three families, matching the three attack surfaces (privacy/attacks.py):

  * **reconstruction quality** — PSNR and SSIM between recovered and true
    images (gradient/activation inversion).  ``best_match_psnr`` handles
    the permutation ambiguity of batch-level gradient inversion (the
    attacker recovers the batch as a set, not in order).
  * **dependence leakage** — distance correlation (Székely et al. 2007)
    between raw inputs and the smashed activations crossing a split
    boundary: 0 = independent, 1 = deterministic dependence.  This is the
    per-split-depth leakage curve of *Evaluating Privacy Leakage in Split
    Learning*: deeper cuts leak less.
  * **membership exposure** — attack AUC (rank statistic, threshold-free)
    and membership advantage max_t (TPR(t) - FPR(t)) (Yeom et al. 2018).

Everything is numpy/jnp only — no sklearn/scipy in the container.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# reconstruction quality
# ---------------------------------------------------------------------------

def psnr(a: jnp.ndarray, b: jnp.ndarray, data_range: float = 2.0) -> float:
    """Peak signal-to-noise ratio in dB; images in [-1, 1] => range 2."""
    mse = float(jnp.mean((jnp.asarray(a, jnp.float32)
                          - jnp.asarray(b, jnp.float32)) ** 2))
    if mse <= 0.0:
        return float("inf")
    return float(10.0 * np.log10(data_range ** 2 / mse))


def _uniform_filter(x: jnp.ndarray, win: int) -> jnp.ndarray:
    """Mean filter over HxW of (B, H, W, C), VALID windows."""
    c = x.shape[-1]
    k = jnp.ones((win, win, 1, 1), jnp.float32) / float(win * win)
    k = jnp.tile(k, (1, 1, 1, c))
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), k, (1, 1), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c)


def ssim(a: jnp.ndarray, b: jnp.ndarray, data_range: float = 2.0,
         win: int = 7) -> float:
    """Mean structural similarity (Wang et al. 2004), uniform window."""
    a = jnp.asarray(a, jnp.float32)
    b = jnp.asarray(b, jnp.float32)
    c1 = (0.01 * data_range) ** 2
    c2 = (0.03 * data_range) ** 2
    mu_a, mu_b = _uniform_filter(a, win), _uniform_filter(b, win)
    var_a = _uniform_filter(a * a, win) - mu_a * mu_a
    var_b = _uniform_filter(b * b, win) - mu_b * mu_b
    cov = _uniform_filter(a * b, win) - mu_a * mu_b
    num = (2 * mu_a * mu_b + c1) * (2 * cov + c2)
    den = (mu_a ** 2 + mu_b ** 2 + c1) * (var_a + var_b + c2)
    return float(jnp.mean(num / den))


def best_match_psnr(recon: jnp.ndarray, target: jnp.ndarray,
                    data_range: float = 2.0) -> float:
    """Mean over reconstructions of the best PSNR against any target image
    (gradient inversion recovers the batch up to permutation)."""
    scores = []
    for i in range(recon.shape[0]):
        scores.append(max(psnr(recon[i], target[j], data_range)
                          for j in range(target.shape[0])))
    return float(np.mean(scores))


# ---------------------------------------------------------------------------
# dependence leakage at split boundaries
# ---------------------------------------------------------------------------

def _centered_dist(x: jnp.ndarray) -> jnp.ndarray:
    """Double-centered pairwise Euclidean distance matrix of (B, D)."""
    sq = jnp.sum(x * x, axis=1)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)
    d = jnp.sqrt(d2 + 1e-12)
    return (d - jnp.mean(d, axis=0, keepdims=True)
            - jnp.mean(d, axis=1, keepdims=True) + jnp.mean(d))


def distance_correlation(x: jnp.ndarray, y: jnp.ndarray) -> float:
    """Sample distance correlation between two batches (leading axis B).

    Leaves are flattened per example; dCor in [0, 1] measures how much the
    smashed activation y still determines the raw input x.
    """
    b = x.shape[0]
    xa = _centered_dist(jnp.reshape(jnp.asarray(x, jnp.float32), (b, -1)))
    yb = _centered_dist(jnp.reshape(jnp.asarray(y, jnp.float32), (b, -1)))
    dcov2 = jnp.mean(xa * yb)
    dvar_x = jnp.mean(xa * xa)
    dvar_y = jnp.mean(yb * yb)
    den = jnp.sqrt(dvar_x * dvar_y)
    return float(jnp.where(den > 0, jnp.sqrt(jnp.maximum(dcov2, 0.0) /
                                             jnp.maximum(den, 1e-12)), 0.0))


# ---------------------------------------------------------------------------
# membership exposure
# ---------------------------------------------------------------------------

def attack_auc(member_scores, nonmember_scores) -> float:
    """Rank AUC: P(member score > non-member score) + 0.5 P(tie)."""
    m = np.asarray(member_scores, np.float64).reshape(-1)
    n = np.asarray(nonmember_scores, np.float64).reshape(-1)
    gt = (m[:, None] > n[None, :]).sum()
    eq = (m[:, None] == n[None, :]).sum()
    return float((gt + 0.5 * eq) / (len(m) * len(n)))


def attack_advantage(member_scores, nonmember_scores) -> Tuple[float, float]:
    """(advantage, threshold): max_t TPR(t) - FPR(t) over all score cuts."""
    m = np.asarray(member_scores, np.float64).reshape(-1)
    n = np.asarray(nonmember_scores, np.float64).reshape(-1)
    best, best_t = 0.0, float("-inf")
    for t in np.unique(np.concatenate([m, n])):
        adv = float((m >= t).mean() - (n >= t).mean())
        if adv > best:
            best, best_t = adv, float(t)
    return best, best_t
