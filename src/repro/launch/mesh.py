"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``--xla_force_host_platform_device_count`` *before* first jax init.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) = 256 v5e chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # axis_types defaults to Auto on every jax version; the explicit kwarg
    # only exists on jax >= 0.5, so it is deliberately omitted.
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Whatever this host has (1 CPU device in the container) — used by
    smoke tests and examples."""
    n = len(jax.devices())
    mp = model_parallel if n % model_parallel == 0 else 1
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def make_client_mesh(max_devices: int = 0) -> Mesh:
    """1-D mesh over this host's devices with the single axis ``clients``
    — what the federation runtime shards the vectorized program's stacked
    client axis along (sharding/specs.stacked_shardings).  On CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before first
    jax init to get N > 1.  ``max_devices`` > 0 caps the mesh size."""
    import numpy as np
    devs = jax.devices()
    n = len(devs) if max_devices <= 0 else min(len(devs), int(max_devices))
    return Mesh(np.array(devs[:n]), ("clients",))


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
