"""Multi-host initialisation for real pods.

On a real v5e pod each host runs the same program; JAX discovers its local
devices and the coordinator stitches the global mesh. This container has no
TPU, so these helpers are exercised only by the dry-run (fake devices) and
documented for real deployments (scripts/launch_pod.sh).
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def maybe_initialize_distributed(coordinator: Optional[str] = None,
                                 num_processes: Optional[int] = None,
                                 process_id: Optional[int] = None) -> bool:
    """Initialise jax.distributed from args or the standard env vars
    (COORDINATOR_ADDRESS / NUM_PROCESSES / PROCESS_ID). Returns True if
    distributed mode was initialised."""
    coordinator = coordinator or os.environ.get("COORDINATOR_ADDRESS")
    if not coordinator:
        return False
    num_processes = num_processes or int(os.environ.get("NUM_PROCESSES", "1"))
    process_id = process_id if process_id is not None \
        else int(os.environ.get("PROCESS_ID", "0"))
    jax.distributed.initialize(coordinator_address=coordinator,
                               num_processes=num_processes,
                               process_id=process_id)
    return True


def is_primary() -> bool:
    return jax.process_index() == 0


def log_topology() -> str:
    info = (f"process {jax.process_index()}/{jax.process_count()} "
            f"local_devices={jax.local_device_count()} "
            f"global_devices={jax.device_count()}")
    if is_primary():
        print(info, flush=True)
    return info
