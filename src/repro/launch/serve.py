"""Serving launcher: batched prefill + decode with a simple request queue.

Demonstrates the production serving path at smoke scale (``--smoke``):
requests arrive with different prompt lengths, are padded into a batch,
prefilled in one pass, then decoded token-by-token with greedy sampling.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --smoke \
      --requests 4 --gen-tokens 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig, reduce_for_smoke
from repro.configs.registry import get_config
from repro.data import synthetic_tokens
from repro.models.transformer import lm_init
from repro.runtime import make_decode_step, make_prefill_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (L,) int32
    generated: List[int] = None


def serve_batch(cfg: RunConfig, requests: List[Request], gen_tokens: int,
                seed: int = 0, verbose: bool = True):
    m = cfg.model
    key = jax.random.PRNGKey(seed)
    params = lm_init(key, m, jnp.dtype(cfg.parallel.param_dtype))
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    max_len = max(len(r.prompt) for r in requests)
    cache_len = max_len + gen_tokens
    cfg2 = cfg.override({"shape.seq_len": cache_len})
    prefill = jax.jit(make_prefill_step(cfg2))

    batch_tokens = np.zeros((len(requests), max_len), np.int32)
    for i, r in enumerate(requests):
        batch_tokens[i, max_len - len(r.prompt):] = r.prompt  # left-pad
    batch = {"tokens": jnp.asarray(batch_tokens)}
    if m.encdec.enabled:
        batch["enc_embeds"] = 0.1 * jax.random.normal(
            key, (len(requests), m.encdec.encoder_seq, m.d_model))

    t0 = time.time()
    logits, state, index = prefill(params, batch)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    prefill_s = time.time() - t0

    for r in requests:
        r.generated = []
    t0 = time.time()
    idx = int(index)
    for step in range(gen_tokens):
        for i, r in enumerate(requests):
            r.generated.append(int(next_tok[i]))
        logits, state = decode(params, next_tok, state,
                               jnp.asarray(idx + step, jnp.int32))
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    decode_s = time.time() - t0
    if verbose:
        tps = gen_tokens * len(requests) / max(decode_s, 1e-9)
        print(f"prefill: {prefill_s:.2f}s for {len(requests)}x{max_len} tokens")
        print(f"decode:  {decode_s:.2f}s for {gen_tokens} steps "
              f"({tps:.1f} tok/s batch throughput)")
        for r in requests:
            print(f"  req {r.rid}: prompt[-5:]={r.prompt[-5:].tolist()} "
                  f"-> {r.generated[:10]}...")
    return requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--gen-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, "decode_32k")
    if args.smoke:
        cfg = reduce_for_smoke(cfg, seq_len=64, batch=args.requests)
    rng = np.random.default_rng(0)
    reqs = [Request(i, synthetic_tokens(1, int(rng.integers(8, 33)),
                                        cfg.model.vocab_size, seed=i)[0])
            for i in range(args.requests)]
    serve_batch(cfg, reqs, args.gen_tokens)


if __name__ == "__main__":
    main()
