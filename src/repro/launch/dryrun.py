import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) pair this lowers + compiles the real
step function (train_step / prefill / decode) against ShapeDtypeStruct
stand-ins on the production mesh — 256 fake host devices for the single-pod
(16,16) mesh and 512 for the multi-pod (2,16,16) mesh — then prints
``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs/bytes for
§Roofline), and writes a JSON artifact per combination under
``experiments/dryrun/``.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import RunConfig
from repro.configs.registry import (ASSIGNED_ARCHS, SHAPES, SkippedShape,
                                    get_config)
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch import specs as S
from repro.roofline import analyze_compiled
from repro.runtime import make_decode_step, make_prefill_step, make_train_step
from repro.sharding.specs import (make_activation_policy,
                                  set_activation_policy)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def lower_one(cfg: RunConfig, mesh, donate: bool = True):
    """Build + lower + compile the step for cfg on mesh. Returns (lowered,
    compiled, seconds)."""
    rules = S.make_rules(cfg, mesh)
    set_activation_policy(make_activation_policy(mesh, rules))
    try:
        pshapes = S.param_shapes(cfg)
        psh = S.param_shardings(cfg, mesh, pshapes)
        ins = S.input_specs(cfg)
        bsh = S.batch_shardings(cfg, mesh, ins)
        rep = NamedSharding(mesh, P())
        mode = cfg.shape.mode
        t0 = time.time()
        with mesh:
            if mode == "train":
                oshapes = S.opt_shapes(cfg, pshapes)
                osh = S.opt_shardings(cfg, mesh, pshapes)
                step = make_train_step(cfg)
                jitted = jax.jit(
                    step,
                    in_shardings=(psh, osh, bsh, rep),
                    out_shardings=(psh, osh, rep),
                    donate_argnums=(0, 1))
                lowered = jitted.lower(
                    pshapes, oshapes, ins,
                    jax.ShapeDtypeStruct((), jnp.int32))
            elif mode == "prefill":
                stepf = make_prefill_step(cfg)
                jitted = jax.jit(stepf, in_shardings=(psh, bsh))
                lowered = jitted.lower(pshapes, ins)
            else:  # decode
                stepf = make_decode_step(cfg)
                jitted = jax.jit(
                    stepf,
                    in_shardings=(psh, bsh["token"], bsh["state"],
                                  bsh["index"]),
                    out_shardings=(rep, bsh["state"]),
                    donate_argnums=(2,))
                lowered = jitted.lower(pshapes, ins["token"], ins["state"],
                                       ins["index"])
            compiled = lowered.compile()
        return lowered, compiled, time.time() - t0
    finally:
        set_activation_policy(None)


def run_pair(arch: str, shape: str, multi_pod: bool,
             out_dir: str = OUT_DIR, verbose: bool = True,
             probes: bool = True) -> Optional[Dict[str, Any]]:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{arch}_{shape}_{mesh_name}.json")
    try:
        cfg = get_config(arch, shape)
    except SkippedShape as e:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "skipped", "reason": str(e)}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
        if verbose:
            print(f"[skip] {arch} x {shape}: {e}")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        lowered, compiled, secs = lower_one(cfg, mesh)
    except Exception as e:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[FAIL] {arch} x {shape} ({mesh_name}): {e}")
        return rec

    mem = compiled.memory_analysis()
    # tokens per step for MODEL_FLOPS = 6*N*D (decode: 1 token per seq)
    b, seq = S.batch_tokens(cfg)
    if cfg.shape.mode == "decode":
        tokens = b
    else:
        tokens = b * seq
    n_active = cfg.model.active_param_count()
    factor = 6.0 if cfg.shape.mode == "train" else 2.0
    model_flops = factor * n_active * tokens
    rep = analyze_compiled(
        compiled, arch=arch, shape=shape, mesh_name=mesh_name,
        chips=mesh_chips(mesh), model_flops=model_flops)
    # loop-aware terms via probe differencing (cost_analysis counts while
    # bodies once — see roofline/probes.py)
    try:
        if not probes:
            raise RuntimeError("probes disabled (--no-probes)")
        from repro.roofline.probes import probe_costs
        from repro.roofline.hw import TPU_V5E as hw
        pc = probe_costs(cfg, mesh)
        rep.hlo_flops = pc["flops"]["total"]
        rep.hlo_bytes = pc["bytes"]["total"]
        rep.collective_bytes = pc["coll"]["total"]
        rep.compute_term_s = rep.hlo_flops / hw.peak_flops_bf16
        rep.memory_term_s = rep.hlo_bytes / hw.hbm_bw
        rep.collective_term_s = rep.collective_bytes / hw.ici_bw_per_link
        probe_terms = pc
    except Exception as e:  # probes are best-effort; keep raw numbers
        probe_terms = {"error": f"{type(e).__name__}: {e}"}
    rec = rep.to_dict()
    rec.update(status="ok", compile_s=secs, mode=cfg.shape.mode,
               params=cfg.model.param_count(),
               active_params=n_active, tokens_per_step=tokens,
               probe_terms=probe_terms)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    if verbose:
        print(f"[ok] {arch:22s} {shape:12s} {mesh_name:10s} "
              f"compile={secs:6.1f}s flops/chip={rep.hlo_flops:.3e} "
              f"coll={rep.collective_bytes:.3e}B dom={rep.dominant} "
              f"mem(args+tmp)={(rep.arg_bytes_per_device + rep.temp_bytes_per_device)/2**30:.2f}GiB")
        print(f"     memory_analysis: {mem}")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="compile-proof only (skip probe cost accounting)")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    pairs = []
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = SHAPES if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                out_path = os.path.join(args.out,
                                        f"{a}_{s}_{mesh_name}.json")
                if args.skip_existing and os.path.exists(out_path):
                    with open(out_path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached] {a} x {s} ({mesh_name})")
                        continue
                rec = run_pair(a, s, mp, args.out,
                               probes=not args.no_probes)
                if rec and rec.get("status") == "error":
                    failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
