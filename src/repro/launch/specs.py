"""ShapeDtypeStruct stand-ins for every model input + sharding trees.

``input_specs(cfg)`` returns the abstract arguments the dry-run lowers
against — weak-type-correct, shardable, zero allocation. ``shardings(cfg,
mesh)`` returns the matching NamedSharding trees for params / optimizer /
inputs / decode state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import AUDIO, RunConfig
from repro.models.transformer import (decode_state_shapes, decode_state_specs,
                                      lm_param_shapes, lm_specs)
from repro.optim import make_optimizer
from repro.sharding.specs import (AxisRules, Lg, default_rules, logical_spec,
                                  tree_shardings)


def _dt(name: str):
    return jnp.dtype(name)


def batch_tokens(cfg: RunConfig) -> Tuple[int, int]:
    """(global_batch, token_len) for the configured shape, respecting
    whisper's 448-position decoder cap."""
    s = cfg.shape
    seq = s.seq_len
    if cfg.model.encdec.enabled:
        seq = min(seq, cfg.model.encdec.max_target_positions)
    return s.global_batch, seq


def input_specs(cfg: RunConfig) -> Dict[str, Any]:
    """Abstract inputs for the configured (arch, shape) mode."""
    m = cfg.model
    b, seq = batch_tokens(cfg)
    mode = cfg.shape.mode
    if mode in ("train", "prefill"):
        specs = {"tokens": jax.ShapeDtypeStruct((b, seq), jnp.int32)}
        if mode == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, seq), jnp.int32)
        if m.encdec.enabled:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, m.encdec.encoder_seq, m.d_model),
                _dt(cfg.parallel.compute_dtype))
        return specs
    if mode == "decode":
        state = decode_state_shapes(m, b, cfg.shape.seq_len,
                                    _dt(cfg.parallel.cache_dtype))
        return {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
                "state": state,
                "index": jax.ShapeDtypeStruct((), jnp.int32)}
    raise ValueError(mode)


def param_shapes(cfg: RunConfig):
    return lm_param_shapes(cfg.model, _dt(cfg.parallel.param_dtype))


def opt_shapes(cfg: RunConfig, pshapes=None):
    pshapes = pshapes if pshapes is not None else param_shapes(cfg)
    opt = make_optimizer(cfg.optim)
    return jax.eval_shape(opt.init, pshapes)


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def make_rules(cfg: RunConfig, mesh) -> AxisRules:
    return default_rules(mesh, cfg.parallel)


def param_shardings(cfg: RunConfig, mesh, pshapes=None):
    pshapes = pshapes if pshapes is not None else param_shapes(cfg)
    rules = make_rules(cfg, mesh)
    return tree_shardings(mesh, rules, pshapes, lm_specs(cfg.model))


def opt_shardings(cfg: RunConfig, mesh, pshapes=None):
    """Adam m/v shard like params; scalar step replicated."""
    pshapes = pshapes if pshapes is not None else param_shapes(cfg)
    psh = param_shardings(cfg, mesh, pshapes)
    oshapes = opt_shapes(cfg, pshapes)
    rep = NamedSharding(mesh, P())

    out = {}
    for k, v in oshapes.items():
        if k in ("m", "v", "mom"):
            out[k] = psh
        else:
            out[k] = rep
    return out


def batch_shardings(cfg: RunConfig, mesh, specs=None):
    specs = specs if specs is not None else input_specs(cfg)
    rules = make_rules(cfg, mesh)

    def shard_one(s):
        logical = ["batch"] + [None] * (len(s.shape) - 1)
        return NamedSharding(mesh, logical_spec(mesh, rules, s.shape, logical))

    if "state" in specs:
        rep = NamedSharding(mesh, P())
        state_sh = tree_shardings(
            mesh, rules, specs["state"],
            decode_state_specs(cfg.model))
        return {"token": shard_one(specs["token"]),
                "state": state_sh,
                "index": rep}
    return {k: shard_one(v) for k, v in specs.items()}
