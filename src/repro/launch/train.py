"""Training launcher.

Two modes:
  * ``--smoke``  reduced config on the host devices — runs real steps on
    synthetic data and prints losses (what CI exercises).
  * full config — builds the production mesh (requires the real pod or the
    dry-run device-count env) and runs the same loop.

FSL mode (``--fsl``) trains per-client replicas with FedAvg every
``fsl.local_steps`` steps — the paper's cadence applied to an LM.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.launch.distributed import log_topology, maybe_initialize_distributed
from repro.config import RunConfig, reduce_for_smoke
from repro.configs.registry import get_config
from repro.data import synthetic_lm_batch
from repro.models.transformer import lm_init
from repro.optim import make_optimizer
from repro.runtime import make_fsl_train_step, make_train_step


def train_loop(cfg: RunConfig, steps: int, fsl_clients: int = 0,
               ckpt_dir: str = "", log_every: int = 1, seed: int = 0):
    m = cfg.model
    key = jax.random.PRNGKey(seed)
    dt = jnp.dtype(cfg.parallel.param_dtype)
    params = lm_init(key, m, dt)
    opt = make_optimizer(cfg.optim)
    opt_state = opt.init(params)
    b, seq = cfg.shape.global_batch, cfg.shape.seq_len
    if m.encdec.enabled:
        seq = min(seq, m.encdec.max_target_positions)

    fsl = fsl_clients > 0
    if fsl:
        step_fn = jax.jit(make_fsl_train_step(cfg, fsl_clients))
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (fsl_clients, *x.shape)),
            params)
        opt_state = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (fsl_clients, *x.shape)),
            opt_state)
    else:
        step_fn = jax.jit(make_train_step(cfg))

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    history = []
    t0 = time.time()
    for i in range(steps):
        batch = synthetic_lm_batch(b * max(1, fsl_clients), seq,
                                   m.vocab_size, seed=seed + i)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if m.encdec.enabled:
            batch["enc_embeds"] = 0.1 * jax.random.normal(
                jax.random.fold_in(key, i),
                (batch["tokens"].shape[0], m.encdec.encoder_seq, m.d_model),
                jnp.dtype(cfg.parallel.compute_dtype))
        if fsl:
            batch = jax.tree.map(
                lambda x: x.reshape(fsl_clients, b, *x.shape[1:]), batch)
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             jnp.asarray(i, jnp.int32))
        loss = float(metrics["loss"])
        history.append(loss)
        if i % log_every == 0:
            print(f"step {i:5d} loss={loss:.4f} "
                  f"aux={float(metrics['aux_loss']):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
        if mgr and (i + 1) % 50 == 0:
            mgr.save(i + 1, params)
    return params, history


def main():
    if maybe_initialize_distributed():
        log_topology()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--smoke-seq", type=int, default=64)
    ap.add_argument("--smoke-batch", type=int, default=4)
    ap.add_argument("--fsl", type=int, default=0,
                    help="train N federated client replicas (FSL mode)")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch, args.shape)
    if args.smoke:
        cfg = reduce_for_smoke(cfg, seq_len=args.smoke_seq,
                               batch=args.smoke_batch)
    _, history = train_loop(cfg, args.steps, args.fsl, args.ckpt)
    print(f"final loss {history[-1]:.4f} (from {history[0]:.4f})")


if __name__ == "__main__":
    main()
