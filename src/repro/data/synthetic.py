"""Deterministic synthetic datasets (the container is offline — DESIGN.md §8).

``synthetic_mnist`` draws class-conditional 28x28 digit-like blobs: each of
the 10 classes is a fixed mixture of 3 gaussian strokes, so (a) classes are
visually distinct, (b) a generator must actually learn per-class structure,
and (c) non-IID federated partitions (by label) are meaningful.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def _class_prototype(label: int, size: int = 28) -> np.ndarray:
    rng = np.random.default_rng(1234 + label)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size
    img = np.zeros((size, size), np.float32)
    for _ in range(3):
        cx, cy = rng.uniform(0.25, 0.75, 2)
        sx, sy = rng.uniform(0.05, 0.18, 2)
        rot = rng.uniform(0, np.pi)
        dx, dy = xx - cx, yy - cy
        rx = dx * np.cos(rot) + dy * np.sin(rot)
        ry = -dx * np.sin(rot) + dy * np.cos(rot)
        img += np.exp(-(rx ** 2 / (2 * sx ** 2) + ry ** 2 / (2 * sy ** 2)))
    return img / img.max()


_PROTOS: Dict[int, np.ndarray] = {}


def synthetic_mnist(n: int, seed: int = 0, size: int = 28
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """-> images (N, size, size, 1) float32 in [-1, 1], labels (N,) int32."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n).astype(np.int32)
    imgs = np.empty((n, size, size, 1), np.float32)
    for lbl in range(10):
        if (lbl, size) not in _PROTOS:
            _PROTOS[(lbl, size)] = _class_prototype(lbl, size)
        sel = labels == lbl
        k = int(sel.sum())
        if k == 0:
            continue
        base = _PROTOS[(lbl, size)][None]
        # per-sample jitter: shift + intensity + noise
        shift = rng.integers(-2, 3, (k, 2))
        amp = rng.uniform(0.8, 1.2, (k, 1, 1)).astype(np.float32)
        noise = rng.normal(0, 0.05, (k, size, size)).astype(np.float32)
        batch = np.repeat(base, k, 0)
        for i in range(k):
            batch[i] = np.roll(np.roll(batch[i], shift[i, 0], 0),
                               shift[i, 1], 1)
        batch = np.clip(batch * amp + noise, 0, 1)
        imgs[sel, :, :, 0] = batch * 2.0 - 1.0
    return imgs, labels


def synthetic_tokens(n_seqs: int, seq_len: int, vocab: int, seed: int = 0
                     ) -> np.ndarray:
    """Markov-ish token streams so an LM has learnable structure."""
    rng = np.random.default_rng(seed)
    # block-structured transition: token t+1 ~ near t with high prob
    toks = np.empty((n_seqs, seq_len), np.int32)
    cur = rng.integers(0, vocab, n_seqs)
    for t in range(seq_len):
        toks[:, t] = cur
        jump = rng.random(n_seqs) < 0.1
        step = rng.integers(1, 17, n_seqs)
        cur = np.where(jump, rng.integers(0, vocab, n_seqs),
                       (cur + step) % vocab)
    return toks


def synthetic_lm_batch(batch: int, seq_len: int, vocab: int, seed: int = 0
                       ) -> Dict[str, np.ndarray]:
    toks = synthetic_tokens(batch, seq_len + 1, vocab, seed)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}
