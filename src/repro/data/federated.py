"""Federated data partitioning: IID and Dirichlet non-IID label skew.

The paper notes (Fig 4) that multiple discriminators "preserve the
heterogeneity of the data distributions" — the Dirichlet partitioner is how
that heterogeneity is produced in the reproduction.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def partition_iid(data: np.ndarray, num_clients: int, seed: int = 0
                  ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(data))
    parts = np.array_split(idx, num_clients)
    return {f"c{i}": data[p] for i, p in enumerate(parts)}


def partition_dirichlet(data: np.ndarray, labels: np.ndarray,
                        num_clients: int, alpha: float = 0.5, seed: int = 0
                        ) -> Dict[str, np.ndarray]:
    """Label-skewed split: client k's label distribution ~ Dir(alpha)."""
    rng = np.random.default_rng(seed)
    by_label: Dict[int, np.ndarray] = {
        int(l): np.where(labels == l)[0] for l in np.unique(labels)}
    client_idx: List[List[int]] = [[] for _ in range(num_clients)]
    for l, idx in by_label.items():
        idx = rng.permutation(idx)
        props = rng.dirichlet([alpha] * num_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, chunk in enumerate(np.split(idx, cuts)):
            client_idx[k].extend(chunk.tolist())
    out = {}
    for k in range(num_clients):
        sel = np.asarray(sorted(client_idx[k]), int)
        if len(sel) == 0:                 # guarantee non-empty clients
            sel = np.asarray([int(rng.integers(0, len(data)))])
        out[f"c{k}"] = data[sel]
    return out
