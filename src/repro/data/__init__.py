from repro.data.synthetic import (  # noqa: F401
    synthetic_mnist, synthetic_tokens, synthetic_lm_batch,
)
from repro.data.federated import partition_iid, partition_dirichlet  # noqa: F401
from repro.data.pipeline import BatchIterator  # noqa: F401
