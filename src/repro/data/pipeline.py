"""Minimal deterministic batch iterator with epoch shuffling."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


class BatchIterator:
    """Iterate (optionally dict-of-arrays) data in shuffled minibatches."""

    def __init__(self, data, batch_size: int, seed: int = 0,
                 drop_last: bool = True):
        self.data = data
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.drop_last = drop_last
        self._n = (len(next(iter(data.values()))) if isinstance(data, dict)
                   else len(data))

    def __len__(self) -> int:
        return self._n // self.batch_size if self.drop_last else \
            -(-self._n // self.batch_size)

    def epoch(self) -> Iterator:
        idx = self.rng.permutation(self._n)
        stop = self._n - (self._n % self.batch_size if self.drop_last else 0)
        for s in range(0, stop, self.batch_size):
            sel = idx[s:s + self.batch_size]
            if isinstance(self.data, dict):
                yield {k: v[sel] for k, v in self.data.items()}
            else:
                yield self.data[sel]
