"""Discrete-event primitives for the federation engine.

The engine advances a *virtual* clock: client compute times come from the
paper's analytic model (``core/simulate.plan_epoch_time``), WAN transfer
times from ``fed/transport.LinkModel``.  Events are totally ordered by
(time, seq) — seq breaks ties deterministically in insertion order, so runs
are reproducible regardless of float coincidences.

Event kinds:
  FINISH  client finished local compute (+ encode); uplink starts
  ARRIVE  the client's update landed at the server; aggregation may fire

Availability traces model client churn (devices going offline between
rounds, SplitFed's straggler reality): a trace answers "is this client up
for round r?".
"""
from __future__ import annotations

import heapq
import zlib
from dataclasses import dataclass, field
from typing import Any, Iterator, List, Optional

import numpy as np

FINISH = "finish"
ARRIVE = "arrive"


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: str = field(compare=False)
    client_id: str = field(compare=False)
    payload: Any = field(compare=False, default=None)


class EventQueue:
    """Min-heap of events with a deterministic tie-break sequence."""

    def __init__(self) -> None:
        self._heap: List[Event] = []
        self._seq = 0

    def push(self, time: float, kind: str, client_id: str,
             payload: Any = None) -> Event:
        ev = Event(float(time), self._seq, kind, client_id, payload)
        self._seq += 1
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        while self._heap:
            yield heapq.heappop(self._heap)


# ---------------------------------------------------------------------------
# availability traces
# ---------------------------------------------------------------------------

class AvailabilityTrace:
    def available(self, client_id: str, round_idx: int) -> bool:
        raise NotImplementedError


class AlwaysAvailable(AvailabilityTrace):
    def available(self, client_id: str, round_idx: int) -> bool:
        return True


class BernoulliAvailability(AvailabilityTrace):
    """Each (client, round) is up independently with probability ``prob``.

    Deterministic in (seed, client_id, round): the draw is keyed by a hash
    of both, not by call order — the engine may probe clients in any order.
    """

    def __init__(self, prob: float, seed: int = 0):
        self.prob = float(prob)
        self.seed = int(seed)

    def available(self, client_id: str, round_idx: int) -> bool:
        if self.prob >= 1.0:
            return True
        # crc32, not hash(): str hashing is salted per process and would
        # break run-to-run reproducibility of the trace
        key = zlib.crc32(f"{self.seed}/{client_id}/{round_idx}".encode())
        return float(np.random.default_rng(key).uniform()) < self.prob


def make_availability(prob: float, seed: int = 0) -> AvailabilityTrace:
    return AlwaysAvailable() if prob >= 1.0 else \
        BernoulliAvailability(prob, seed)
