"""Client programs: the per-client local round as data, compiled two ways.

The paper's protocol has exactly one client-side job — run ``local_steps``
discriminator batches from the downloaded params — but the repo used to
encode it three divergent ways (sequential loop, engine callback,
vectorized vmap), each supporting a different subset of
scheduling x backend x privacy.  This module makes the local round a
first-class *program* so every combination exists:

  * :func:`make_local_step` builds ONE step definition — plain SGD/Adam or
    DP-SGD (per-example clip + Gaussian noise via ``kernels/dp_clip``,
    per-example grads from singleton-batch vmap) — selected orthogonally
    from the backend.  A ``core/split.SplitExecution`` swaps the gradient
    computation for the staged split forward/backward (boundary stages on
    every crossing tensor), again orthogonally: split x privacy x backend
    all compose.
  * :class:`LocalProgram` compiles that step two ways:
      - **loop**    — per-client Python loop over jitted steps (the seed's
                      dispatch pattern; bit-exact reference numerics), and
      - **vectorized** — the whole multi-client round as one jitted
                      program: vmap over clients, scan over batches, with
                      the DP stage *inside* the scanned step.
  * :class:`RoundExecutor` binds a program to one engine round: data
    sampling, per-client hyperparameters (``lr_scale`` / ``local_steps``
    schedules), opt-state lookup and RNG plumbing.  Execution is pure —
    optimizer states are returned in :class:`ClientResult`, never written
    back; the engine decides which clients participated and only those
    states are committed (``RoundReport.opt_states``).

RNG contract: DP noise keys depend only on (round key, client id,
execution index, batch index), so the looped and vectorized backends draw
identical noise at a fixed seed — the basis of the pinned
looped-DP == vectorized-DP test (tests/test_fed_runtime.py).

Stacked-tree utilities (:func:`stack_trees` / :func:`unstack_tree` /
:func:`fedavg_stacked`) and the :func:`sequential_d_rounds` reference lived
in the former ``fed/vectorized.py``, which this module absorbs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# loss_fn(params, real_batch, fake_batch) -> scalar loss
LossFn = Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray]

# The executor's real dispatch paths.  config.FED_BACKENDS additionally
# accepts "auto" — resolved by the trainer's first-round dispatch probe
# (core/gan.FSLGANTrainer._resolve_auto_backend) before any RoundExecutor
# is built, so "auto" never reaches this module.
BACKENDS = ("loop", "vectorized")


# ---------------------------------------------------------------------------
# stacked-tree utilities (absorbed from fed/vectorized.py)
# ---------------------------------------------------------------------------

def stack_trees(trees: Sequence) -> Any:
    """[tree_0 .. tree_{C-1}] -> one tree with a leading client axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_tree(stacked, num: int) -> List[Any]:
    """Inverse of :func:`stack_trees`."""
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(num)]


def fedavg_stacked(stacked_tree, weights, *, use_kernel: bool = False,
                   interpret: bool = False):
    """Weighted average over the leading client axis of a stacked tree.

    ``use_kernel`` routes each leaf through the fedavg Pallas kernel
    (one HBM pass per element); the default is a fused tensordot, which XLA
    emits the same roofline-bound loop for on CPU.
    """
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)

    if use_kernel:
        from repro.kernels.fedavg.ops import fedavg_flat

        def avg(leaf):
            c = leaf.shape[0]
            flat = leaf.reshape(c, -1).astype(jnp.float32)
            out = fedavg_flat(flat, w, interpret=interpret)
            return out.reshape(leaf.shape[1:]).astype(leaf.dtype)
    else:
        def avg(leaf):
            acc = jnp.tensordot(w, leaf.astype(jnp.float32), axes=(0, 0))
            return acc.astype(leaf.dtype)

    return jax.tree.map(avg, stacked_tree)


def sequential_d_rounds(d_step, params_list: Sequence, opt_list: Sequence,
                        reals: jnp.ndarray, fakes: jnp.ndarray):
    """Reference semantics of the vectorized round: the seed's per-client
    Python loop over the same (C, T, B, ...) batches.  Used by the pinned
    equivalence test and the benchmark baseline."""
    out_p, out_o, out_l = [], [], []
    for i, (p, o) in enumerate(zip(params_list, opt_list)):
        losses = []
        for t in range(reals.shape[1]):
            p, o, l = d_step(p, o, reals[i, t], fakes[i, t])
            losses.append(l)
        out_p.append(p)
        out_o.append(o)
        out_l.append(jnp.stack(losses))
    return out_p, out_o, jnp.stack(out_l)


# ---------------------------------------------------------------------------
# the one step definition: plain vs DP-SGD, selected orthogonally
# ---------------------------------------------------------------------------

def make_local_step(optimizer, loss_fn: LossFn, privacy=None, *,
                    force_ref: bool = False, split_exec=None):
    """Build ``step(params, opt, real, fake, lr, key) -> (params, opt,
    loss)`` — the single client-side step both backends compile.

    ``privacy`` is a ``config.PrivacyConfig`` (or None).  When it selects
    ``dp_sgd``, the step takes per-example gradients on singleton batches
    (vmap over examples, so batchnorm statistics are per-example — the
    standard DP-SGD stance on BN), privatizes them through
    ``kernels/dp_clip`` and feeds the mean to the optimizer; otherwise it
    is the plain batch step.

    ``split_exec`` (``core/split.SplitExecution``, or None) selects HOW the
    gradient is computed, orthogonally to privacy: None differentiates the
    monolithic ``loss_fn``; a SplitExecution runs the staged split
    forward/backward — every boundary tensor through the plan's boundary
    stage — which is bit-exact with the monolithic gradient under the
    identity stage.  ``key`` feeds the stage noise (and DP-SGD noise);
    with neither, it is ignored.

    ``force_ref`` pins the pure-JAX dp_clip reference regardless of
    ``privacy.use_kernel`` — the vectorized backend sets it because the
    Pallas kernel is a per-call primitive, and inside the scanned/vmapped
    program XLA fuses the reference to the same thing.
    """
    dp = (privacy is not None and getattr(privacy, "enabled", False)
          and privacy.mode == "dp_sgd")
    if not dp:
        if split_exec is None:
            def step(params, opt, real, fake, lr, key):
                del key
                loss, grads = jax.value_and_grad(loss_fn)(params, real,
                                                          fake)
                params, opt = optimizer.update(grads, opt, params, lr)
                return params, opt, loss
        else:
            def step(params, opt, real, fake, lr, key):
                loss, grads = split_exec.value_and_grad(params, real, fake,
                                                        key)
                params, opt = optimizer.update(grads, opt, params, lr)
                return params, opt, loss
        return step

    from repro.kernels.dp_clip.ops import dp_clip_noise_tree
    clip = float(privacy.clip_norm)
    noise_scale = float(privacy.noise_multiplier) * clip
    use_kernel = bool(privacy.use_kernel) and not force_ref
    interpret = bool(privacy.kernel_interpret)

    if split_exec is None:
        def one_example(p, r, f):
            return loss_fn(p, r[None], f[None])

        grad_one = jax.value_and_grad(one_example)

        def per_example_vg(params, real, fake, key):
            del key
            return jax.vmap(grad_one, in_axes=(None, 0, 0))(params, real,
                                                            fake)
    else:
        def per_example_vg(params, real, fake, key):
            # each example's staged pass draws its own boundary-stage
            # noise; dp_clip's noise key (`key` itself) is never folded
            # with these, so the two noise sources stay independent
            def one(r, f, i):
                return split_exec.value_and_grad(
                    params, r[None], f[None], jax.random.fold_in(key, i))
            return jax.vmap(one)(real, fake, jnp.arange(real.shape[0]))

    def step(params, opt, real, fake, lr, key):
        losses, per_ex = per_example_vg(params, real, fake, key)
        summed = dp_clip_noise_tree(per_ex, clip, noise_scale, key,
                                    use_kernel=use_kernel,
                                    interpret=interpret)
        b = real.shape[0]
        grads = jax.tree.map(lambda g: g / b, summed)
        params, opt = optimizer.update(grads, opt, params, lr)
        return params, opt, jnp.mean(losses)

    return step


# ---------------------------------------------------------------------------
# LocalProgram: one step, two compilations
# ---------------------------------------------------------------------------

class LocalProgram:
    """The per-client local round as data: step fn + backend compilations.

    Both backends run the SAME step definition; only the dispatch differs:

      * ``run_looped``     — T jitted step calls for one client (the seed's
        dispatch pattern; with privacy disabled this is bit-exact with the
        seed trainer's ``_d_step`` loop);
      * ``run_vectorized`` — one jitted program for C clients: vmap over
        the stacked client axis, scan over the T batch axis, per-client
        learning rates / noise keys as vectors and a (C, T) step mask for
        heterogeneous ``local_steps`` schedules.

    ``split`` maps client ids to ``core/split.SplitExecution`` objects:
    those clients' steps execute THROUGH the split (staged segment
    forward/backward, boundary stages on every crossing tensor).  Steps are
    compiled per *split signature* — the tuple of boundary depths + stage —
    since plans sharing a signature share the staged program; the
    vectorized backend batches clients per signature group
    (``RoundExecutor``).  Unlisted clients run the monolithic step
    (signature ``None``), so split and unsplit clients coexist in one
    round.
    """

    def __init__(self, optimizer, loss_fn: LossFn, base_lr: float, *,
                 privacy=None, split=None):
        self.optimizer = optimizer
        self.loss_fn = loss_fn
        self.base_lr = float(base_lr)
        self.privacy = privacy
        self.split = dict(split or {})
        self.is_dp = (privacy is not None
                      and getattr(privacy, "enabled", False)
                      and privacy.mode == "dp_sgd")
        # does the step consume its PRNG key? (DP-SGD noise and/or a
        # stochastic boundary stage) — the trainer derives round keys iff so
        self.needs_key = self.is_dp or any(
            ex.stochastic for ex in self.split.values())
        self._exec_by_sig = {}
        for ex in self.split.values():
            self._exec_by_sig.setdefault(ex.signature, ex)
        self._step_cache: Dict[Any, Any] = {}
        self._vrun_cache: Dict[Any, Any] = {}
        # the monolithic step stays a public attribute (seed-compatible)
        self.step = self._step(None)

    # ------------------------------------------------------------------
    def rebind_sigma(self, noise_multiplier: float) -> None:
        """Rebind the DP-SGD noise multiplier between rounds (the sigma
        controller's lever).  The noise scale is a compile-time constant of
        the step, so the per-signature caches are cleared and both backends
        recompile on next dispatch; the (round, client, exec, batch)
        noise-key scheme is untouched, so the rebound run stays
        deterministic per schedule.  The controller's hysteresis bounds how
        often this fires."""
        import dataclasses
        if not self.is_dp or \
                float(noise_multiplier) == self.privacy.noise_multiplier:
            return
        self.privacy = dataclasses.replace(
            self.privacy, noise_multiplier=float(noise_multiplier))
        self._step_cache.clear()
        self._vrun_cache.clear()
        self.step = self._step(None)

    # ------------------------------------------------------------------
    # per-signature compilation
    # ------------------------------------------------------------------
    def signature_for(self, cid: str):
        """Compilation key for one client: its plan's boundary-depth/stage
        signature, or None for the monolithic step.  Pipelined split
        executions (``pipeline_microbatches > 1``) carry K inside the
        signature, so their micro-batched steps compile — and the
        vectorized backend groups — separately from sequential ones."""
        ex = self.split.get(cid)
        return ex.signature if ex is not None else None

    def _step(self, sig):
        if sig not in self._step_cache:
            self._step_cache[sig] = jax.jit(make_local_step(
                self.optimizer, self.loss_fn, self.privacy,
                split_exec=self._exec_by_sig.get(sig)))
        return self._step_cache[sig]

    def _vrun(self, sig):
        if sig not in self._vrun_cache:
            self._vrun_cache[sig] = self._compile_vectorized(
                make_local_step(self.optimizer, self.loss_fn, self.privacy,
                                force_ref=True,
                                split_exec=self._exec_by_sig.get(sig)))
        return self._vrun_cache[sig]

    # ------------------------------------------------------------------
    @staticmethod
    def _compile_vectorized(step):
        def per_client(params, opt, reals, fakes, lr, key, mask):
            ts = jnp.arange(reals.shape[0])

            def body(carry, xs):
                p, o = carry
                real, fake, t, m = xs
                p2, o2, loss = step(p, o, real, fake, lr,
                                    jax.random.fold_in(key, t))
                # masked (padded) steps carry state through unchanged, so
                # clients with shorter local_steps schedules stop early
                # inside the shared scan length
                keep = lambda new, old: jax.tree.map(  # noqa: E731
                    lambda a, b: jnp.where(m, a, b), new, old)
                return (keep(p2, p), keep(o2, o)), jnp.where(m, loss, 0.0)

            (params, opt), losses = jax.lax.scan(
                body, (params, opt), (reals, fakes, ts, mask))
            return params, opt, losses

        return jax.jit(jax.vmap(per_client))

    # ------------------------------------------------------------------
    def run_looped(self, params, opt, reals, fakes, *,
                   lr: Optional[float] = None, key=None,
                   cid: Optional[str] = None
                   ) -> Tuple[Any, Any, List[float]]:
        """One client's round: T jitted steps over (T, B, ...) batches.
        ``cid`` selects the client's split-signature step (monolithic when
        omitted or unlisted)."""
        lr_arr = jnp.float32(self.base_lr if lr is None else lr)
        if key is None:
            key = jax.random.PRNGKey(0)
        step = self._step(self.signature_for(cid) if cid is not None
                          else None)
        losses: List[float] = []
        for t in range(reals.shape[0]):
            params, opt, l = step(params, opt, reals[t], fakes[t],
                                  lr_arr, jax.random.fold_in(key, t))
            losses.append(float(l))
        return params, opt, losses

    def run_vectorized(self, stacked_params, stacked_opt, reals, fakes, *,
                       lrs=None, keys=None, mask=None, signature=None):
        """C clients' rounds as ONE jitted program.

        ``reals``/``fakes``: (C, T, B, ...).  ``lrs``: (C,) per-client
        learning rates; ``keys``: (C,) PRNG keys (DP/stage noise);
        ``mask``: (C, T) bool — False entries are padding steps that leave
        the client's state untouched.  ``signature`` selects the split
        program; every stacked client must share it (``RoundExecutor``
        groups by signature).  Returns stacked (params, opt) and (C, T)
        losses (0.0 at masked slots).
        """
        c, t = reals.shape[0], reals.shape[1]
        if lrs is None:
            lrs = jnp.full((c,), self.base_lr, jnp.float32)
        if keys is None:
            keys = jnp.stack([jax.random.PRNGKey(0)] * c)
        if mask is None:
            mask = jnp.ones((c, t), bool)
        return self._vrun(signature)(
            stacked_params, stacked_opt, reals, fakes,
            jnp.asarray(lrs, jnp.float32), keys, jnp.asarray(mask, bool))


# ---------------------------------------------------------------------------
# RoundExecutor: a program bound to one engine round
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ClientHyper:
    """Per-client local-round hyperparameters (cfg.fed schedules)."""
    lr_scale: float = 1.0
    local_steps: int = 0          # 0 => the round's default


@dataclass
class ClientResult:
    """Pure output of one client execution — nothing is written back."""
    client_id: str
    params: Any
    opt_state: Any                # None for legacy bare-callable programs
    info: Dict[str, Any] = field(default_factory=dict)


class RoundExecutor:
    """What the engine schedules: ``run(cids, start_params)`` executes the
    listed clients' local rounds (one jitted program under the vectorized
    backend, jitted per-step loops otherwise) and returns pure
    :class:`ClientResult` objects.

    ``sample(cid, steps) -> (reals, fakes)`` is called once per execution
    in schedule order, so the host-RNG stream is identical across backends
    (and, with the loop backend under sync scheduling, identical to the
    seed's sequential loop).  Optimizer state reads go through a per-round
    overlay so async re-cycles of the same client chain correctly without
    mutating the trainer's committed state.
    """

    def __init__(self, program: LocalProgram, *, backend: str,
                 sample: Callable[[str, int], Tuple[jnp.ndarray, jnp.ndarray]],
                 opt_lookup: Callable[[str], Any], default_steps: int,
                 hyper: Optional[Dict[str, ClientHyper]] = None,
                 round_key=None, mesh=None, cohort_of=None):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"expected one of {BACKENDS}")
        self.program = program
        self.backend = backend
        self.sample = sample
        self.opt_lookup = opt_lookup
        self.default_steps = int(default_steps)
        self.hyper = hyper or {}
        self.round_key = round_key
        # client-axis mesh (launch/mesh.make_client_mesh): when set, the
        # vectorized dispatch places every stacked input on the mesh's
        # `clients` axis before calling the jitted program, so client
        # shards execute on separate devices.  None = single-device
        # placement (bit-exact default).
        self.mesh = mesh
        # cohort assigner (e.g. Roster.cohort_of_cid) folded into the
        # noise-key chain: (round, cohort, client, execution).  None means
        # cohort 0 for everyone — a uniform chain either way, so keys stay
        # reproducible across backends and topologies.
        self.cohort_of = cohort_of
        self._opt_overlay: Dict[str, Any] = {}
        self._exec_idx: Dict[str, int] = {}
        # stable roster index for noise-key derivation: folding in a hash
        # of the id (e.g. crc32) would hand colliding client ids identical
        # noise tensors — correlated releases the accountant would still
        # price as independent.  Unlisted clients get indices past the
        # roster in first-execution order, which is schedule-deterministic
        # (both backends execute the same schedule).
        self._cid_index: Dict[str, int] = {cid: i
                                           for i, cid in enumerate(self.hyper)}

    # ------------------------------------------------------------------
    def steps_for(self, cid: str) -> int:
        h = self.hyper.get(cid)
        return (h.local_steps or self.default_steps) if h \
            else self.default_steps

    def lr_for(self, cid: str) -> float:
        h = self.hyper.get(cid)
        return self.program.base_lr * (h.lr_scale if h else 1.0)

    def _key_for(self, cid: str):
        """Noise key for this execution: (round key, cohort, client roster
        index, exec index) — the roster's ``(round, cohort, client_id)``
        chain plus the execution counter for async re-cycles.
        Deterministic per schedule, identical across backends and
        aggregation topologies, collision-free across clients (cohort is
        folded in *before* the roster index, and the index is already
        unique across cohorts, so distinct clients can never collide)."""
        if self.round_key is None:
            return None
        if cid not in self._cid_index:
            self._cid_index[cid] = len(self._cid_index)
        i = self._exec_idx.get(cid, 0)
        self._exec_idx[cid] = i + 1
        cohort = int(self.cohort_of(cid)) if self.cohort_of else 0
        base = jax.random.fold_in(self.round_key, cohort)
        base = jax.random.fold_in(base, self._cid_index[cid])
        return jax.random.fold_in(base, i)

    def _opt_for(self, cid: str):
        if cid in self._opt_overlay:
            return self._opt_overlay[cid]
        return self.opt_lookup(cid)

    def _shard_stacked(self, trees):
        """Place stacked per-client inputs on the `clients` mesh axis.

        Every leaf's dim 0 is the client axis; other dims replicate.  A
        client count that doesn't divide the mesh replicates instead
        (sharding/specs.logical_spec policy), so ragged last groups still
        run — just without the multi-device split."""
        from repro.sharding.specs import client_axis_rules, stacked_shardings
        rules = client_axis_rules(self.mesh)
        return tuple(
            jax.device_put(t, stacked_shardings(self.mesh, t, rules=rules))
            for t in trees)

    # ------------------------------------------------------------------
    def run(self, cids: List[str], start_params) -> List[ClientResult]:
        if not cids:
            return []
        if self.backend == "vectorized":
            return self._run_vectorized(cids, start_params)
        out = []
        for cid in cids:
            steps = self.steps_for(cid)
            reals, fakes = self.sample(cid, steps)
            params, opt, losses = self.program.run_looped(
                start_params, self._opt_for(cid), reals, fakes,
                lr=self.lr_for(cid), key=self._key_for(cid), cid=cid)
            self._opt_overlay[cid] = opt
            out.append(ClientResult(cid, params, opt,
                                    {"losses": losses, "steps": steps}))
        return out

    def _run_vectorized(self, cids: List[str], start_params
                        ) -> List[ClientResult]:
        steps = [self.steps_for(cid) for cid in cids]
        t_max = max(steps)
        reals_l, fakes_l, mask_l = [], [], []
        for cid, s in zip(cids, steps):
            # sample exactly `s` batches (same host-RNG draws as the loop
            # backend); padding slots are zeros under a False mask
            r, f = self.sample(cid, s)
            if s < t_max:
                pad = lambda x: jnp.concatenate(  # noqa: E731
                    [x, jnp.zeros((t_max - s,) + x.shape[1:], x.dtype)])
                r, f = pad(r), pad(f)
            reals_l.append(r)
            fakes_l.append(f)
            mask_l.append([True] * s + [False] * (t_max - s))
        keys = [self._key_for(cid) for cid in cids]
        if keys[0] is None:
            keys = [jax.random.PRNGKey(0)] * len(cids)
        # one jitted dispatch per split signature (monolithic clients are
        # the None group).  Sampling and key derivation above already ran
        # in schedule order, so grouping only reorders the DISPATCH — the
        # host-RNG stream stays identical to the loop backend.
        sig_groups: Dict[Any, List[int]] = {}
        for i, cid in enumerate(cids):
            sig_groups.setdefault(self.program.signature_for(cid),
                                  []).append(i)
        out: List[Optional[ClientResult]] = [None] * len(cids)
        for sig, idxs in sig_groups.items():
            stacked_p = stack_trees([start_params] * len(idxs))
            stacked_o = stack_trees([self._opt_for(cids[i]) for i in idxs])
            stacked_r = jnp.stack([reals_l[i] for i in idxs])
            stacked_f = jnp.stack([fakes_l[i] for i in idxs])
            stacked_k = jnp.stack([keys[i] for i in idxs])
            stacked_m = jnp.asarray([mask_l[i] for i in idxs], bool)
            if self.mesh is not None:
                stacked_p, stacked_o, stacked_r, stacked_f, stacked_k, \
                    stacked_m = self._shard_stacked(
                        (stacked_p, stacked_o, stacked_r, stacked_f,
                         stacked_k, stacked_m))
            new_p, new_o, losses = self.program.run_vectorized(
                stacked_p, stacked_o, stacked_r, stacked_f,
                lrs=[self.lr_for(cids[i]) for i in idxs],
                keys=stacked_k, mask=stacked_m, signature=sig)
            for j, i in enumerate(idxs):
                cid, s = cids[i], steps[i]
                p = jax.tree.map(lambda x: x[j], new_p)
                o = jax.tree.map(lambda x: x[j], new_o)
                self._opt_overlay[cid] = o
                out[i] = ClientResult(
                    cid, p, o,
                    {"losses": [float(l) for l in losses[j, :s]],
                     "steps": s})
        return out


class CallableProgram:
    """Adapter: a legacy ``local_update(cid, params) -> (params, info)``
    callable as a program.  Opt state is opaque to the engine (None), so
    no ``RoundReport.opt_states`` entries are produced."""

    def __init__(self, fn):
        self.fn = fn

    def run(self, cids: List[str], start_params) -> List[ClientResult]:
        out = []
        for cid in cids:
            params, info = self.fn(cid, start_params)
            out.append(ClientResult(cid, params, None, info))
        return out


def as_program(obj):
    """Engine glue: accept a RoundExecutor-like program or a bare callable."""
    if hasattr(obj, "run"):
        return obj
    if callable(obj):
        return CallableProgram(obj)
    raise TypeError(f"not a client program: {obj!r}")
