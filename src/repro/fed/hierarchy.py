"""Two-tier hierarchical aggregation: edge cohorts pre-reduce before the WAN.

Flat FedAvg uplinks every client's update across the WAN — per-round WAN
bytes grow linearly in the participant count.  The two-tier topology
interposes edge aggregators: each cohort of clients uplinks over a cheap
LAN/MAN hop to its edge, the edge pre-reduces the cohort's updates with the
same weighted mean the server would apply (routed through the fedavg Pallas
kernel when ``fed.kernel_aggregation`` is on), and only ONE tree per cohort
crosses the WAN.  WAN bytes drop by the cohort fan-in factor, and — because
FedAvg is a weighted mean — the weighted-mean-of-weighted-means with cohort
weights equal to the member weight sums reproduces the flat aggregate
exactly (up to float reassociation, which is why the engine pin is
tolerance-based, not bit-exact).

The engine stays the single owner of virtual time and byte accounting;
this module only knows how to group clients and reduce a cohort.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.fed.programs import fedavg_stacked, stack_trees

__all__ = ["CohortReduction", "HierarchicalAggregator", "assign_cohorts"]


def assign_cohorts(client_ids: Sequence[str], num_cohorts: int,
                   cohort_of=None) -> Dict[int, List[str]]:
    """Group client ids into cohorts.

    With ``cohort_of`` (e.g. ``Roster.cohort_of_cid``) membership follows
    the roster's contiguous population ranges; otherwise ids are split into
    ``num_cohorts`` contiguous, balanced slices in schedule order — the
    deterministic default for materialized client lists."""
    n = max(1, int(num_cohorts))
    out: Dict[int, List[str]] = {}
    if cohort_of is not None:
        for cid in client_ids:
            out.setdefault(int(cohort_of(cid)) % n, []).append(cid)
        return out
    ids = list(client_ids)
    span = -(-len(ids) // n) if ids else 1
    for i, cid in enumerate(ids):
        out.setdefault(i // span, []).append(cid)
    return out


@dataclass(frozen=True)
class CohortReduction:
    """One edge's pre-reduced contribution to the WAN hop."""
    cohort: int
    aggregate: object            # weighted-mean tree over the cohort
    weight: float                # sum of member weights (server-side weight)
    members: Tuple[str, ...]     # client ids reduced into the aggregate


class HierarchicalAggregator:
    """Edge-tier reducer: weighted FedAvg over each cohort's updates.

    ``use_kernel``/``interpret`` mirror the engine's aggregation-policy
    knobs so the edge reduce exercises the same fedavg Pallas kernel as the
    server-side reduce it replaces."""

    def __init__(self, num_cohorts: int, *, use_kernel: bool = False,
                 interpret: bool = False, cohort_of=None):
        if num_cohorts < 1:
            raise ValueError(f"num_cohorts must be >= 1, got {num_cohorts}")
        self.num_cohorts = int(num_cohorts)
        self.use_kernel = bool(use_kernel)
        self.interpret = bool(interpret)
        self.cohort_of = cohort_of

    def group(self, client_ids: Sequence[str]) -> Dict[int, List[str]]:
        return assign_cohorts(client_ids, self.num_cohorts, self.cohort_of)

    def reduce_cohort(self, cohort: int, members: Sequence[str],
                      trees: Sequence, weights: Sequence[float]
                      ) -> CohortReduction:
        """Pre-reduce one cohort: weighted mean of its members' trees,
        weight = sum of member weights (so the server's cohort-level
        weighted mean equals the flat client-level one)."""
        if not trees:
            raise ValueError(f"cohort {cohort} has no member updates")
        agg = fedavg_stacked(stack_trees(list(trees)), list(weights),
                             use_kernel=self.use_kernel,
                             interpret=self.interpret)
        return CohortReduction(int(cohort), agg, float(sum(weights)),
                               tuple(members))

    def reduce_all(self, updates: Dict[str, Tuple[object, float]]
                   ) -> List[CohortReduction]:
        """Reduce a full round: ``updates`` maps client id ->
        (tree, weight); returns one reduction per non-empty cohort, in
        cohort order."""
        grouped = self.group(list(updates.keys()))
        out: List[CohortReduction] = []
        for c in sorted(grouped):
            members = grouped[c]
            trees = [updates[m][0] for m in members]
            weights = [updates[m][1] for m in members]
            out.append(self.reduce_cohort(c, members, trees, weights))
        return out

    def reduce_all_streaming(self, updates: Dict[str, Tuple[object, float]],
                             template, *, codec_name: str
                             ) -> List[CohortReduction]:
        """Compressed-domain round reduce: ``updates`` maps client id ->
        (encoded wire payload from ``Codec.encode_tree``, weight).  Each
        cohort folds its members' WIRE payloads through one
        :class:`repro.fed.aggregate.StreamingAggregator` — the edge tier
        never stacks decoded member trees; live decoded state per cohort
        is the single fp32 accumulator.  ``aggregate`` is the cohort's
        weighted MEAN in the wire's domain (the delta domain for lossy
        codecs — the engine rebases onto the global tree)."""
        from repro.fed.aggregate import StreamingAggregator
        grouped = self.group(list(updates.keys()))
        out: List[CohortReduction] = []
        for c in sorted(grouped):
            members = grouped[c]
            agg = StreamingAggregator(codec_name,
                                      use_kernel=self.use_kernel,
                                      interpret=self.interpret)
            agg.init(template)
            for m in members:
                enc, w = updates[m]
                agg.fold(enc, w)
            out.append(CohortReduction(int(c), agg.finalize(),
                                       float(agg.wsum), tuple(members)))
        return out
