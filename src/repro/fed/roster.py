"""Lazy client roster: 10k-1M clients priced per round, never materialized.

The engine's :class:`~repro.fed.engine.ClientSpec` list is the *materialized*
roster — fine for a handful of simulated clients, impossible at the paper's
"many user devices" scale.  A :class:`Roster` represents the whole population
by three things only:

  * **deterministic sampling** — each round's participants are drawn with a
    ``(round, cohort)`` fold_in key chain, so resampling any round is
    reproducible across processes and backends without storing a single
    per-client record.  Per-client keys extend the chain with the client id
    (``client_key``) — the DP noise / availability stream of client ``i`` in
    round ``r`` is a pure function of ``(seed, r, cohort, i)``.
  * **amplified privacy accounting** — sampling ``m`` of ``n`` clients per
    round is subsampling at rate ``q = m/n``; the roster wires ``q`` into
    the subsampled-RDP accountant (``privacy/defenses``) so population
    growth buys epsilon down analytically.
  * **analytic pricing** — availability and finish-time distributions are
    closed-form (Bernoulli thinning; lognormal compute times with the sync
    barrier at the max-order statistic quantile), so rounds-per-second vs
    population is a formula, not a simulation over a million specs.

Cohorts are contiguous index ranges (``population / cohorts`` clients per
edge aggregator), matching :class:`~repro.fed.hierarchy.
HierarchicalAggregator`'s contiguous grouping: participants of cohort ``c``
pre-reduce at edge ``c`` before the WAN hop.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from statistics import NormalDist
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.fed.transport import LinkModel

__all__ = ["Roster", "RoundSample"]

# fold_in salt separating the static per-client stream (compute times)
# from the per-round sampling chain — both hang off the same seed key
_STATIC_SALT = 0x5eed


def _sample_indices(key, n: int, k: int) -> np.ndarray:
    """``k`` distinct indices in ``[0, n)``, deterministic in ``(key, n,
    k)``.  Small ``n`` uses ``jax.random.choice`` without replacement; for
    huge populations (where choice's internal permutation costs O(n)) a
    deterministic rejection loop draws batches of ints and keeps the first
    ``k`` distinct values in draw order — O(k) work independent of ``n``."""
    k = min(int(k), int(n))
    if k <= 0:
        return np.empty((0,), np.int64)
    if n <= (1 << 13) or 4 * k >= n:
        return np.asarray(
            jax.random.choice(key, n, (k,), replace=False), np.int64)
    out: List[int] = []
    seen: set = set()
    attempt = 0
    while len(out) < k:
        draw = np.asarray(jax.random.randint(
            jax.random.fold_in(key, attempt), (2 * k,), 0, n), np.int64)
        for v in draw.tolist():
            if v not in seen:
                seen.add(v)
                out.append(v)
                if len(out) == k:
                    break
        attempt += 1
    return np.asarray(out, np.int64)


@dataclass(frozen=True)
class RoundSample:
    """One round's sampled participants (the only materialized clients)."""
    round_index: int
    client_ids: Tuple[int, ...]            # global indices into [0, pop)
    cohorts: Tuple[int, ...]               # cohort per participant
    by_cohort: Dict[int, Tuple[int, ...]] = field(default_factory=dict)

    @property
    def num_participants(self) -> int:
        return len(self.client_ids)


class Roster:
    """A population of ``population`` clients sampled ``participants`` per
    round, split into ``cohorts`` contiguous edge cohorts."""

    def __init__(self, population: int, *, participants: int,
                 cohorts: int = 1, seed: int = 0,
                 availability: float = 1.0,
                 compute_time_s: float = 30.0,
                 compute_log_sigma: float = 0.35):
        if population < 1:
            raise ValueError(f"population must be >= 1, got {population}")
        if not 1 <= participants <= population:
            raise ValueError(
                f"participants must be in [1, population={population}], "
                f"got {participants}")
        if not 1 <= cohorts <= participants:
            raise ValueError(
                f"cohorts must be in [1, participants={participants}], "
                f"got {cohorts}")
        if not 0.0 < availability <= 1.0:
            raise ValueError(
                f"availability must be in (0, 1], got {availability}")
        self.population = int(population)
        self.participants = int(participants)
        self.cohorts = int(cohorts)
        self.seed = int(seed)
        self.availability = float(availability)
        # lognormal finish-time model: median compute_time_s, shape
        # compute_log_sigma (0 = deterministic clients)
        self.compute_time_s = float(compute_time_s)
        self.compute_log_sigma = float(compute_log_sigma)
        self._base_key = jax.random.PRNGKey(self.seed)
        # contiguous cohort ranges: cohort c owns [c*span, min((c+1)*span, n))
        self._span = -(-self.population // self.cohorts)   # ceil div

    # ------------------------------------------------------------------
    # deterministic key chain: (round, cohort, client_id)
    # ------------------------------------------------------------------
    def round_key(self, round_index: int):
        return jax.random.fold_in(self._base_key, int(round_index))

    def cohort_key(self, round_index: int, cohort: int):
        return jax.random.fold_in(self.round_key(round_index), int(cohort))

    def client_key(self, round_index: int, cohort: int, client_id: int):
        """The per-(round, cohort, client) key DP noise and availability
        draws derive from — the roster's whole RNG contract."""
        return jax.random.fold_in(
            self.cohort_key(round_index, cohort), int(client_id))

    def cohort_of(self, client_id: int) -> int:
        return int(client_id) // self._span

    def cohort_range(self, cohort: int) -> Tuple[int, int]:
        lo = int(cohort) * self._span
        return lo, min(lo + self._span, self.population)

    # ------------------------------------------------------------------
    # per-round participant sampling
    # ------------------------------------------------------------------
    def _quota(self, cohort: int) -> int:
        """Participants drawn from this cohort: ``participants`` split as
        evenly as the cohort count allows (earlier cohorts take the
        remainder), capped by the cohort's size."""
        base, rem = divmod(self.participants, self.cohorts)
        want = base + (1 if cohort < rem else 0)
        lo, hi = self.cohort_range(cohort)
        return min(want, hi - lo)

    def sample_round(self, round_index: int) -> RoundSample:
        """The round's participants: per cohort, ``quota`` distinct clients
        drawn under the ``(round, cohort)`` key.  Pure — sampling the same
        round twice (any process, any backend) returns the same clients."""
        ids: List[int] = []
        cohorts: List[int] = []
        by_cohort: Dict[int, Tuple[int, ...]] = {}
        for c in range(self.cohorts):
            lo, hi = self.cohort_range(c)
            local = _sample_indices(
                self.cohort_key(round_index, c), hi - lo, self._quota(c))
            members = tuple(int(lo + i) for i in local)
            by_cohort[c] = members
            ids.extend(members)
            cohorts.extend([c] * len(members))
        return RoundSample(int(round_index), tuple(ids), tuple(cohorts),
                           by_cohort)

    # ------------------------------------------------------------------
    # privacy: subsampling amplification
    # ------------------------------------------------------------------
    @property
    def sample_rate(self) -> float:
        """Per-round participation fraction q = m/n — the subsampled-RDP
        accountant's amplification rate.  (Our per-cohort draw is without
        replacement; Poisson-q is the standard, slightly conservative
        model for it at q << 1.)"""
        return self.participants / self.population

    def accountant(self, noise_multiplier: float):
        """A subsampled-RDP accountant at this roster's q — epsilon per
        round shrinks as the population grows at fixed participants."""
        from repro.privacy.defenses import RDPAccountant
        return RDPAccountant(noise_multiplier, sample_rate=self.sample_rate)

    def amplified_epsilon(self, noise_multiplier: float, rounds: int,
                          delta: float = 1e-5) -> float:
        from repro.privacy.defenses import dp_epsilon
        return dp_epsilon(noise_multiplier, self.sample_rate, int(rounds),
                          delta)

    # ------------------------------------------------------------------
    # analytic availability / finish-time pricing
    # ------------------------------------------------------------------
    @property
    def expected_participants(self) -> float:
        """Bernoulli availability thins the sampled set: E = m * p."""
        return self.participants * self.availability

    def compute_time(self, client_id: int) -> float:
        """Client ``i``'s persistent compute time: a lognormal draw under
        the static (round-independent) chain — the same client is fast or
        slow in every round, deterministically."""
        k = jax.random.fold_in(
            jax.random.fold_in(self._base_key, _STATIC_SALT), int(client_id))
        z = float(jax.random.normal(k, ()))
        return self.compute_time_s * math.exp(self.compute_log_sigma * z)

    def finish_quantile(self, q: float) -> float:
        """Inverse CDF of one client's compute time (lognormal)."""
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        z = NormalDist().inv_cdf(q)
        return self.compute_time_s * math.exp(self.compute_log_sigma * z)

    def barrier_compute_s(self) -> float:
        """The sync barrier waits for the slowest available participant:
        E[max of m iid draws] at the standard ``m/(m+1)`` order-statistic
        quantile — closed form, no per-client simulation."""
        m = max(1.0, self.expected_participants)
        return self.finish_quantile(m / (m + 1.0))

    def round_time_s(self, update_bytes: int, *, down_bytes: int = 0,
                     uplink: Optional[LinkModel] = None,
                     downlink: Optional[LinkModel] = None,
                     edge_uplink: Optional[LinkModel] = None,
                     hierarchical: bool = False) -> float:
        """One sync round's virtual wall time: downlink + the barrier
        compute quantile + the uplink hop(s).  Hierarchical rounds uplink
        ``update_bytes`` to the edge and one pre-reduced aggregate per
        cohort across the WAN (cohort fan-in never serializes per client —
        edges forward one tree each, concurrently)."""
        up = uplink or LinkModel()
        down = downlink or LinkModel()
        t = down.transfer_time(int(down_bytes)) + self.barrier_compute_s()
        if hierarchical:
            edge = edge_uplink or LinkModel(0.005, 200e6)
            return (t + edge.transfer_time(int(update_bytes))
                    + up.transfer_time(int(update_bytes)))
        return t + up.transfer_time(int(update_bytes))

    def rounds_per_second(self, update_bytes: int, **kw) -> float:
        return 1.0 / max(self.round_time_s(update_bytes, **kw), 1e-12)

    def wan_bytes_per_round(self, update_bytes: int, *,
                            hierarchical: bool = False) -> int:
        """Expected uplink bytes crossing the WAN per round: every
        available participant under flat FedAvg, one pre-reduced tree per
        cohort under the two-tier hierarchy — the fan-in cut
        (participants / cohorts) the bench gates on."""
        if hierarchical:
            return int(self.cohorts * int(update_bytes))
        return int(round(self.expected_participants)) * int(update_bytes)

    # ------------------------------------------------------------------
    # engine glue: materialize ONLY the sampled participants
    # ------------------------------------------------------------------
    def specs_for_round(self, round_index: int, *, weight: float = 1.0,
                        local_steps: int = 0) -> List:
        """ClientSpecs for this round's sample — the engine sees ``m``
        clients, never the population.  Ids are ``v<global index>`` so
        cohort membership survives the string round-trip
        (:meth:`cohort_of_cid`)."""
        from repro.fed.engine import ClientSpec
        return [ClientSpec(f"v{i}", float(weight), self.compute_time(i),
                           local_steps=int(local_steps))
                for i in self.sample_round(round_index).client_ids]

    def cohort_of_cid(self, cid: str) -> int:
        """Cohort of a ``v<idx>`` client id (0 for foreign ids)."""
        if isinstance(cid, str) and cid[:1] == "v" and cid[1:].isdigit():
            return self.cohort_of(int(cid[1:]))
        return 0
