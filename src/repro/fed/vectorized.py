"""Vectorized multi-client local training.

The seed simulates C clients with a Python loop: C x T dispatches of the
jitted D-step per round.  For homogeneous-shape clients (every replica is
the same architecture — the paper's setting) the whole round is one program:

    vmap over clients ( scan over local batches ( D-step ) )

i.e. a single jitted call consuming stacked per-client parameter/optimizer
trees and (C, T, B, ...) batch tensors.  XLA then batches the per-client
convolutions into one pass over the stacked leading axis — the Python-loop
dispatch overhead (the dominant cost at paper scale) disappears.

The aggregation hot path stays on-device too: ``fedavg_stacked`` averages
the already-stacked trees, optionally through the fedavg Pallas kernel
(kernels/fedavg) so the whole round never leaves the accelerator.

Cross-references: paper §3 (per-client D training + FedAvg), ROADMAP
"Federation runtime" open item, ``core/simulate.py`` for the wall-time
model this speeds past.
"""
from __future__ import annotations

from typing import Any, Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp

# loss_fn(params, real_batch, fake_batch) -> scalar loss
LossFn = Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray]


def stack_trees(trees: Sequence) -> Any:
    """[tree_0 .. tree_{C-1}] -> one tree with a leading client axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_tree(stacked, num: int) -> List[Any]:
    """Inverse of :func:`stack_trees`."""
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(num)]


def make_multi_client_d_step(optimizer, loss_fn: LossFn, lr: float):
    """Build the jitted vectorized round.

    Returns ``run(stacked_params, stacked_opt, reals, fakes)`` where
    ``reals``/``fakes`` are (C, T, B, ...) — T local batches for each of C
    clients — and the result is ``(stacked_params, stacked_opt, losses)``
    with ``losses`` of shape (C, T).  One XLA program; no Python per-client
    or per-batch loop.
    """
    lr_arr = jnp.asarray(lr)

    def one_step(params, opt, real, fake):
        loss, grads = jax.value_and_grad(loss_fn)(params, real, fake)
        params, opt = optimizer.update(grads, opt, params, lr_arr)
        return params, opt, loss

    def per_client(params, opt, reals, fakes):
        def body(carry, xs):
            p, o = carry
            p, o, loss = one_step(p, o, xs[0], xs[1])
            return (p, o), loss

        (params, opt), losses = jax.lax.scan(body, (params, opt),
                                             (reals, fakes))
        return params, opt, losses

    @jax.jit
    def run(stacked_params, stacked_opt, reals, fakes):
        return jax.vmap(per_client)(stacked_params, stacked_opt,
                                    reals, fakes)

    return run


def sequential_d_rounds(d_step, params_list: Sequence, opt_list: Sequence,
                        reals: jnp.ndarray, fakes: jnp.ndarray):
    """Reference semantics of the vectorized round: the seed's per-client
    Python loop over the same (C, T, B, ...) batches.  Used by the pinned
    equivalence test and the benchmark baseline."""
    out_p, out_o, out_l = [], [], []
    for i, (p, o) in enumerate(zip(params_list, opt_list)):
        losses = []
        for t in range(reals.shape[1]):
            p, o, l = d_step(p, o, reals[i, t], fakes[i, t])
            losses.append(l)
        out_p.append(p)
        out_o.append(o)
        out_l.append(jnp.stack(losses))
    return out_p, out_o, jnp.stack(out_l)


def fedavg_stacked(stacked_tree, weights, *, use_kernel: bool = False,
                   interpret: bool = False):
    """Weighted average over the leading client axis of a stacked tree.

    ``use_kernel`` routes each leaf through the fedavg Pallas kernel
    (one HBM pass per element); the default is a fused tensordot, which XLA
    emits the same roofline-bound loop for on CPU.
    """
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)

    if use_kernel:
        from repro.kernels.fedavg.ops import fedavg_flat

        def avg(leaf):
            c = leaf.shape[0]
            flat = leaf.reshape(c, -1).astype(jnp.float32)
            out = fedavg_flat(flat, w, interpret=interpret)
            return out.reshape(leaf.shape[1:]).astype(leaf.dtype)
    else:
        def avg(leaf):
            acc = jnp.tensordot(w, leaf.astype(jnp.float32), axes=(0, 0))
            return acc.astype(leaf.dtype)

    return jax.tree.map(avg, stacked_tree)
