"""Compressed-domain streaming aggregation over encoded uplinks.

The decode-then-fedavg server reduce stages one decoded fp32 tree per
client before averaging — O(C) server memory and an extra full
materialization per uplink.  This module folds each uplink's WIRE
payload (``Codec.encode_tree`` output) straight into one fp32
accumulator through the fused ``kernels/agg_fuse`` ops:

  * :class:`StreamingAggregator` — ``init / fold / finalize``: the
    engine folds each landed uplink as it arrives and holds O(1) state
    in the cohort size (one accumulator tree + a weight sum).  ``fold``
    also measures the codec's relative L2 error against the raw delta
    in the SAME traversal, so the per-client error metric no longer
    costs a second decode pass.
  * :func:`codec_rel_error` — the fold's error measurement alone, for
    executed-but-late stragglers whose update never folds.
  * :func:`decode_enc` / :func:`fused_decode_apply` — one-traversal
    decode (+ rebase) of a single encoded uplink, used by the async
    path at ARRIVE time so FINISH events queue wire payloads instead of
    decoded trees.
  * :func:`batched_reduce` — the vectorized-backend form: per-leaf wire
    stacks reduced in one fused kernel call (dense codecs) or one
    vmapped decode over the stacked client axis (top-k), sharded with
    ``sharding.stacked_shardings`` when a client mesh is attached.

Weighted mean of rebased updates equals base + weighted mean of deltas
exactly in real arithmetic but only to fma-level in float, so every
stream-vs-decode pin is tolerance-based, never bit-exact.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.agg_fuse.ops import (dequant_acc_flat, dequant_reduce_flat,
                                        scatter_acc_flat)
from repro.fed.transport import apply_delta

__all__ = ["StreamingAggregator", "batched_reduce", "codec_rel_error",
           "decode_enc", "fused_decode_apply"]

EncTree = List[Tuple[Any, Any]]          # per-leaf (wire, meta), leaves order


def _norm(name: str) -> str:
    return "none" if name in ("none", "", "identity") else name


def decode_enc(codec_name: str, enc: EncTree, template):
    """Decode one encoded uplink back to a tree in ``template``'s
    structure — leaf-wise identical to ``Codec.roundtrip``'s decode."""
    name = _norm(codec_name)
    leaves = []
    for (wire, meta), t in zip(enc, jax.tree.leaves(template)):
        if name == "none":
            leaves.append(wire)          # identity: the leaf itself
        elif name == "topk":
            vals, idx = wire
            leaves.append(jnp.zeros((t.size,), jnp.float32).at[idx]
                          .set(vals).reshape(t.shape))
        elif name == "int8":
            leaves.append((wire.astype(jnp.float32) * meta).reshape(t.shape))
        else:                            # fp16 (or any plain cast wire)
            leaves.append(wire.astype(jnp.float32).reshape(t.shape))
    return jax.tree.unflatten(jax.tree.structure(template), leaves)


def fused_decode_apply(codec_name: str, base, enc: EncTree):
    """Decode an encoded DELTA uplink and rebase it onto ``base`` in one
    traversal — what the async path applies per arrival."""
    return apply_delta(base, decode_enc(codec_name, enc, base))


def codec_rel_error(codec_name: str, enc: EncTree, delta) -> float:
    """Relative global-L2 error of the encoded uplink vs the raw delta —
    the decode-free form of ``transport.tree_rel_error`` (top-k never
    densifies: the error splits into on-support and dropped mass)."""
    name = _norm(codec_name)
    if name == "none" or delta is None:
        return 0.0
    num = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    for (wire, meta), d in zip(enc, jax.tree.leaves(delta)):
        f = d.astype(jnp.float32).reshape(-1)
        den += jnp.sum(f * f)
        if name == "topk":
            vals, idx = wire
            dv = f[idx]
            num += jnp.sum(f * f) - jnp.sum(dv * dv) \
                + jnp.sum((vals - dv) ** 2)
        else:
            dec = wire.astype(jnp.float32).reshape(-1)
            if name == "int8":
                dec = dec * meta
            num += jnp.sum((dec - f) ** 2)
    return math.sqrt(max(float(num), 0.0)) / max(math.sqrt(float(den)),
                                                 1e-12)


class StreamingAggregator:
    """O(1)-memory weighted mean over encoded uplinks.

    ``init(template)`` allocates one zero fp32 accumulator per leaf;
    ``fold(enc, weight)`` adds ``weight * dequant(enc)`` through the
    fused kernels (sparse top-k wires scatter straight into the dense
    accumulator); ``finalize()`` divides by the folded weight sum and
    restores leaf shapes/dtypes.  Live decoded-tree count is always 1 —
    the accumulator — independent of how many uplinks folded.
    """

    def __init__(self, codec_name: str, *, use_kernel: bool = False,
                 interpret: bool = False):
        self.codec_name = _norm(codec_name)
        self.use_kernel = bool(use_kernel)
        self.interpret = bool(interpret)
        self._acc: Optional[List[jnp.ndarray]] = None
        self._template = None
        self.wsum = 0.0
        self.folds = 0

    def init(self, template) -> None:
        """``template``: any tree with the uplink's structure and leaf
        shapes (the global tree works for both delta and param wires)."""
        self._template = template
        self._acc = [jnp.zeros((l.size,), jnp.float32)
                     for l in jax.tree.leaves(template)]
        self.wsum = 0.0
        self.folds = 0

    def fold(self, enc: EncTree, weight: float,
             delta=None) -> Optional[float]:
        """Fold one encoded uplink with fedavg weight ``weight``.  When
        the raw ``delta`` tree is passed, the codec's relative L2 error
        is measured in the same per-leaf sweep and returned."""
        assert self._acc is not None, "fold() before init()"
        w = float(weight)
        name = self.codec_name
        want_err = delta is not None and name != "none"
        dleaves = jax.tree.leaves(delta) if want_err else [None] * len(enc)
        num = jnp.zeros((), jnp.float32)
        den = jnp.zeros((), jnp.float32)
        for i, ((wire, meta), d) in enumerate(zip(enc, dleaves)):
            if name == "topk":
                vals, idx = wire
                self._acc[i] = scatter_acc_flat(
                    self._acc[i], vals, idx, w,
                    use_kernel=self.use_kernel, interpret=self.interpret)
                if want_err:
                    f = d.astype(jnp.float32).reshape(-1)
                    dv = f[idx]
                    den += jnp.sum(f * f)
                    num += jnp.sum(f * f) - jnp.sum(dv * dv) \
                        + jnp.sum((vals - dv) ** 2)
                continue
            scale = meta if name == "int8" else 1.0
            flat = wire.reshape(-1)
            self._acc[i] = dequant_acc_flat(
                self._acc[i], flat, scale, w,
                use_kernel=self.use_kernel, interpret=self.interpret)
            if want_err:
                f = d.astype(jnp.float32).reshape(-1)
                dec = flat.astype(jnp.float32)
                if name == "int8":
                    dec = dec * meta
                den += jnp.sum(f * f)
                num += jnp.sum((dec - f) ** 2)
        self.wsum += w
        self.folds += 1
        if delta is None:
            return None
        if name == "none":
            return 0.0
        return math.sqrt(max(float(num), 0.0)) \
            / max(math.sqrt(float(den)), 1e-12)

    def finalize(self):
        """Weighted mean tree (template structure/shapes/dtypes), or
        None when nothing folded."""
        if self._acc is None or self.folds == 0 or self.wsum <= 0.0:
            return None
        inv = 1.0 / self.wsum
        leaves = [(a * inv).reshape(t.shape).astype(t.dtype)
                  for a, t in zip(self._acc,
                                  jax.tree.leaves(self._template))]
        return jax.tree.unflatten(jax.tree.structure(self._template), leaves)


@functools.partial(jax.jit, static_argnames=("n",))
def _topk_batched_mean(vals: jnp.ndarray, idx: jnp.ndarray,
                       weights: jnp.ndarray, n: int) -> jnp.ndarray:
    """vmapped per-tensor decode over the stacked client axis, then the
    weighted mean — the top-k leaves' batched form."""
    w = (weights / jnp.sum(weights)).astype(jnp.float32)
    dense = jax.vmap(
        lambda v, ix: jnp.zeros((n,), jnp.float32).at[ix].set(v))(vals, idx)
    return jnp.sum(dense * w[:, None], axis=0)


def batched_reduce(codec_name: str, encs: Sequence[EncTree],
                   weights: Sequence[float], template, *,
                   use_kernel: bool = False, interpret: bool = False,
                   mesh=None):
    """Weighted mean over a whole round's encoded uplinks, one fused
    call per leaf: dense wires stack at WIRE dtype into
    ``dequant_reduce_flat``; top-k wires batch through the vmapped
    decode.  With ``mesh``, stacked leaves land on the ``clients`` mesh
    axis via ``stacked_shardings`` before the reduce."""
    assert encs, "batched_reduce over no uplinks"
    name = _norm(codec_name)
    w = jnp.asarray(list(weights), jnp.float32)
    put = lambda a: a                                       # noqa: E731
    if mesh is not None:
        from repro.sharding.specs import (client_axis_rules,
                                          stacked_shardings)
        rules = client_axis_rules(mesh)
        put = lambda a: jax.device_put(                     # noqa: E731
            a, stacked_shardings(mesh, a, rules=rules))
    tleaves = jax.tree.leaves(template)
    out = []
    for i, t in enumerate(tleaves):
        if name == "topk":
            vals = put(jnp.stack([e[i][0][0] for e in encs]))
            idx = put(jnp.stack([e[i][0][1] for e in encs]))
            out.append(_topk_batched_mean(vals, idx, w, int(t.size))
                       .reshape(t.shape).astype(t.dtype))
            continue
        wires = put(jnp.stack([e[i][0].reshape(-1) for e in encs]))
        if name == "int8":
            scales = jnp.stack([jnp.asarray(e[i][1], jnp.float32)
                                for e in encs])
        else:
            scales = jnp.ones((len(encs),), jnp.float32)
        out.append(dequant_reduce_flat(wires, scales, w,
                                       use_kernel=use_kernel,
                                       interpret=interpret)
                   .reshape(t.shape).astype(t.dtype))
    return jax.tree.unflatten(jax.tree.structure(template), out)
