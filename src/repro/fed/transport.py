"""Wire model for the federation runtime: links, payloads, codecs.

What actually crosses the network in the paper's protocol (§3) is small and
asymmetric:

  * **downlink** (server -> client): batches of generated fakes — the server
    never ships G itself, only its outputs (the privacy argument);
  * **uplink** (client -> server): the trained discriminator parameters
    (or parameter *deltas* when a lossy codec is enabled).

PS-FedGAN (PAPERS.md) shows this partially-shared state dominates both the
communication cost and the privacy surface, so the runtime makes it
first-class: every transfer is priced by a :class:`LinkModel` and counted in
a :class:`TrafficLedger`; uplink trees can be run through pluggable
compression codecs (fp16 / int8 quantize-dequantize / top-k sparsification
with error feedback).

LAN hops *inside* one client's split chain are a third budget: when
training executes through the split (``core/split.SplitExecution``), the
measured per-boundary payloads are recorded here too (``TrafficLedger``
``lan`` column) and priced by ``core/simulate.plan_epoch_time``; the
:class:`LinkModel`\\ s in this module price the WAN between the server and
each client.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def tree_bytes(tree) -> int:
    """Total payload bytes of a pytree at its native dtypes."""
    return int(sum(l.size * jnp.dtype(l.dtype).itemsize
                   for l in jax.tree.leaves(tree)))


def delta_tree(params, base):
    """The uplinked update delta ``params - base``, leafwise in fp32.

    The ONE definition of delta arithmetic on the wire: the engine's codec
    path and the sequential reference loop both use this +
    :func:`apply_delta`, so their bit-exact pin is structural rather than
    two hand-kept copies."""
    return jax.tree.map(
        lambda p, b: p.astype(jnp.float32) - b.astype(jnp.float32),
        params, base)


def apply_delta(base, delta):
    """Rebase a (possibly privatized/compressed) fp32 delta onto ``base``,
    cast back to the base dtypes — inverse of :func:`delta_tree`."""
    return jax.tree.map(
        lambda b, d: (b.astype(jnp.float32)
                      + d.astype(jnp.float32)).astype(b.dtype),
        base, delta)


def tree_rel_error(approx, exact) -> float:
    """Relative global-L2 error of ``approx`` vs ``exact`` — the engine's
    per-round measurement of what a lossy codec cost the update (the
    bytes-vs-delta-error frontier the codec controller walks)."""
    num = 0.0
    den = 0.0
    for a, e in zip(jax.tree.leaves(approx), jax.tree.leaves(exact)):
        d = np.asarray(a, np.float64) - np.asarray(e, np.float64)
        num += float(np.sum(d * d))
        den += float(np.sum(np.asarray(e, np.float64) ** 2))
    return math.sqrt(num) / max(math.sqrt(den), 1e-12)


def predict_codec_bytes(name: str, leaf_sizes: Sequence[int], *,
                        dtype_bytes: int = 4, topk_frac: float = 0.01) -> int:
    """Analytic wire bytes of one uplink round-trip per codec — a pure
    function of the tree's leaf sizes, so the codec controller can rank
    candidates by cost WITHOUT spending a probe round on each (only the
    delta ERROR needs live measurement)."""
    if name in ("none", "", "identity"):
        return int(sum(leaf_sizes) * dtype_bytes)
    if name == "fp16":
        return int(sum(leaf_sizes) * 2)
    if name == "int8":
        return int(sum(n + 4 for n in leaf_sizes))
    if name == "topk":
        return int(sum(8 * min(n, max(1, int(math.ceil(topk_frac * n))))
                       for n in leaf_sizes))
    raise ValueError(f"unknown codec {name!r}")


def fake_batch_bytes(batch: int, image_shape: Tuple[int, ...],
                     dtype_bytes: int = 4) -> int:
    """Downlink bytes for one batch of generated fakes."""
    n = batch
    for s in image_shape:
        n *= s
    return int(n * dtype_bytes)


@dataclass(frozen=True)
class LinkModel:
    """One-way link: fixed latency plus serialization at ``bandwidth_bps``."""
    latency_s: float = 0.050
    bandwidth_bps: float = 10e6

    def transfer_time(self, nbytes: int) -> float:
        return self.latency_s + 8.0 * nbytes / max(self.bandwidth_bps, 1.0)


@dataclass
class TrafficLedger:
    """Per-round, per-client byte accounting (benchmarks read this).

    Four budgets: WAN uplink (D params/deltas — under hierarchical
    aggregation, keyed by ``cohort<k>`` since only the pre-reduced edge
    aggregates cross the WAN), WAN downlink (fake batches), the LAN
    *inside* each client's split chain — the measured per-boundary
    payloads of executed split training
    (``core/split.SplitExecution.step_wire_bytes``), zero when the client
    trains unsplit — and the client→edge tier: bytes each client uplinks
    to its edge aggregator before the cohort pre-reduce (empty on the
    flat path).
    """
    up_bytes: Dict[str, int] = field(default_factory=dict)
    down_bytes: Dict[str, int] = field(default_factory=dict)
    lan_bytes: Dict[str, int] = field(default_factory=dict)
    edge_bytes: Dict[str, int] = field(default_factory=dict)
    # observability hook: called as observer(client_id, up, down, lan) on
    # every record (repro.obs feeds per-client wire counters from it);
    # None — the default — keeps the ledger a plain accumulator
    observer: Optional[Callable[[str, int, int, int], None]] = \
        field(default=None, repr=False, compare=False)
    # separate hook for the edge tier — keeps the 4-arg observer
    # signature stable for installed observers that predate hierarchy
    edge_observer: Optional[Callable[[str, int], None]] = \
        field(default=None, repr=False, compare=False)

    def record(self, client_id: str, *, up: int = 0, down: int = 0,
               lan: int = 0) -> None:
        self.up_bytes[client_id] = self.up_bytes.get(client_id, 0) + int(up)
        self.down_bytes[client_id] = (self.down_bytes.get(client_id, 0)
                                      + int(down))
        if lan:
            self.lan_bytes[client_id] = (self.lan_bytes.get(client_id, 0)
                                         + int(lan))
        if self.observer is not None:
            self.observer(client_id, int(up), int(down), int(lan))

    def record_edge(self, client_id: str, nbytes: int) -> None:
        """Client→edge uplink bytes (the pre-reduce hop)."""
        self.edge_bytes[client_id] = (self.edge_bytes.get(client_id, 0)
                                      + int(nbytes))
        if self.edge_observer is not None:
            self.edge_observer(client_id, int(nbytes))

    @property
    def total_up(self) -> int:
        return sum(self.up_bytes.values())

    @property
    def total_down(self) -> int:
        return sum(self.down_bytes.values())

    @property
    def total_lan(self) -> int:
        return sum(self.lan_bytes.values())

    @property
    def total_edge(self) -> int:
        return sum(self.edge_bytes.values())


# ---------------------------------------------------------------------------
# Codecs — quantize-dequantize transforms over uplink parameter trees
# ---------------------------------------------------------------------------

class Codec:
    """Lossy round-trip over an uplink tree.

    ``encodes_delta`` controls what the engine feeds it: raw parameters
    (identity — keeps the sync path bit-exact) or the update delta
    ``params - global`` (all lossy codecs: compressing deltas is the
    standard trick — they are near-zero-mean and tolerate quantization).

    ``roundtrip(tree)`` returns ``(decoded_tree, wire_bytes)``.  Stateful
    codecs (top-k with error feedback) carry a residual across calls, so the
    engine keeps ONE codec instance PER CLIENT.

    ``encode(x)`` / ``decode(wire, meta, dtype)`` are the per-tensor
    *buffer* entry points the pipelined split executor ships hops with:
    ``encode`` returns the actual wire arrays (the quantized buffer plus
    whatever side metadata decoding needs) instead of a decoded
    round-trip, and ``decode(*encode(x), x.dtype) == roundtrip(x)[0]``
    leaf-wise for every stateless codec (pinned in tests).  Both are
    jit-compatible pure functions of the input tensor.
    """
    name = "none"
    encodes_delta = False

    def roundtrip(self, tree) -> Tuple[Any, int]:
        raise NotImplementedError

    def encode(self, x: jnp.ndarray) -> Tuple[Any, Any]:
        """One tensor -> (wire buffer(s), decode metadata)."""
        return x, None

    def decode(self, wire, meta, dtype=jnp.float32) -> jnp.ndarray:
        """Inverse of ``encode``: reconstruct what the receiver computes
        on (identical to the ``roundtrip`` decode for this tensor)."""
        del meta
        return wire.astype(dtype)

    def encode_tree(self, tree) -> Tuple[List[Tuple[Any, Any]], int]:
        """Whole-tree wire form: per-leaf ``(wire, meta)`` in
        ``jax.tree.leaves`` order plus the priced wire bytes — exactly
        the bytes ``roundtrip`` reports, so the engine's byte accounting
        is identical whichever reduce consumes the uplink.  Stateful
        codecs (top-k error feedback) advance their residual here just
        like ``roundtrip`` does.  The compressed-domain server reduce
        (``fed/aggregate``) folds these payloads without decoding."""
        return [(l, None) for l in jax.tree.leaves(tree)], tree_bytes(tree)


class IdentityCodec(Codec):
    """No compression; wire bytes = native tree bytes."""
    name = "none"
    encodes_delta = False

    def roundtrip(self, tree) -> Tuple[Any, int]:
        return tree, tree_bytes(tree)


class FP16Codec(Codec):
    """Cast leaves to fp16 on the wire, back to native dtype on arrival."""
    name = "fp16"
    encodes_delta = True

    def roundtrip(self, tree) -> Tuple[Any, int]:
        dec = jax.tree.map(
            lambda l: l.astype(jnp.float16).astype(l.dtype), tree)
        nbytes = sum(l.size * 2 for l in jax.tree.leaves(tree))
        return dec, int(nbytes)

    def encode(self, x: jnp.ndarray) -> Tuple[Any, Any]:
        return x.astype(jnp.float16), None

    def decode(self, wire, meta, dtype=jnp.float32) -> jnp.ndarray:
        del meta
        return wire.astype(dtype)

    def encode_tree(self, tree) -> Tuple[List[Tuple[Any, Any]], int]:
        leaves = jax.tree.leaves(tree)
        return ([(l.astype(jnp.float16), None) for l in leaves],
                int(sum(l.size * 2 for l in leaves)))


class Int8Codec(Codec):
    """Per-leaf symmetric int8 quantization: q = round(x / s), s = amax/127.

    Wire cost: 1 byte per element + one fp32 scale per leaf.
    """
    name = "int8"
    encodes_delta = True

    def roundtrip(self, tree) -> Tuple[Any, int]:
        def qdq(l):
            x = l.astype(jnp.float32)
            amax = jnp.max(jnp.abs(x))
            # zero-range (all-constant-zero) delta: any positive scale maps
            # q=0 back to exact zeros; 1.0 avoids the subnormal division a
            # tiny epsilon scale would do
            scale = jnp.where(amax > 0, amax / 127.0, 1.0)
            q = jnp.clip(jnp.round(x / scale), -127, 127)
            return (q * scale).astype(l.dtype)

        dec = jax.tree.map(qdq, tree)
        nbytes = sum(l.size + 4 for l in jax.tree.leaves(tree))
        return dec, int(nbytes)

    def encode(self, x: jnp.ndarray) -> Tuple[Any, Any]:
        f = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(f))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(f / scale), -127, 127).astype(jnp.int8)
        return q, scale

    def decode(self, wire, meta, dtype=jnp.float32) -> jnp.ndarray:
        # int8 buffer * fp32 scale, matching roundtrip's q * scale in
        # fp32 before the final cast
        return (wire.astype(jnp.float32) * meta).astype(dtype)

    def encode_tree(self, tree) -> Tuple[List[Tuple[Any, Any]], int]:
        leaves = jax.tree.leaves(tree)
        return ([self.encode(l) for l in leaves],
                int(sum(l.size + 4 for l in leaves)))


class TopKCodec(Codec):
    """Magnitude top-k sparsification with error feedback (Stich et al.).

    Keeps the ``frac`` largest-|x| entries per leaf; the dropped mass is
    carried in a residual and added back before the next round's selection,
    so nothing is lost permanently — only delayed.  Wire cost: 8 bytes per
    kept entry (fp32 value + int32 index).
    """
    name = "topk"
    encodes_delta = True

    def __init__(self, frac: float = 0.01, error_feedback: bool = True):
        self.frac = float(frac)
        self.error_feedback = bool(error_feedback)
        self._residual: Optional[Any] = None

    def roundtrip(self, tree) -> Tuple[Any, int]:
        if self.error_feedback and self._residual is not None:
            tree = jax.tree.map(lambda l, r: l + r.astype(l.dtype),
                                tree, self._residual)

        kept_entries = 0

        def sparsify(l):
            nonlocal kept_entries
            flat = l.astype(jnp.float32).reshape(-1)
            # clamp k into [1, n]: frac >= 1 (or tiny leaves) means keep
            # everything — lax.top_k raises on k > n
            k = min(flat.size, max(1, int(math.ceil(self.frac * flat.size))))
            kept_entries += k
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            mask = jnp.zeros_like(flat).at[idx].set(1.0)
            return (flat * mask).reshape(l.shape).astype(l.dtype)

        dec = jax.tree.map(sparsify, tree)
        if self.error_feedback:
            self._residual = jax.tree.map(
                lambda l, d: l.astype(jnp.float32) - d.astype(jnp.float32),
                tree, dec)
        return dec, int(kept_entries * 8)

    def encode(self, x: jnp.ndarray) -> Tuple[Any, Any]:
        """Stateless (no error feedback) per-tensor buffer encode: the
        kept values + their flat indices — exactly the 8-bytes-per-entry
        wire payload ``roundtrip`` prices."""
        flat = x.astype(jnp.float32).reshape(-1)
        k = min(flat.size, max(1, int(math.ceil(self.frac * flat.size))))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return (flat[idx], idx.astype(jnp.int32)), x.shape

    def decode(self, wire, meta, dtype=jnp.float32) -> jnp.ndarray:
        vals, idx = wire
        n = 1
        for s in meta:
            n *= int(s)
        return jnp.zeros((n,), jnp.float32).at[idx].set(vals) \
            .reshape(meta).astype(dtype)

    def encode_tree(self, tree) -> Tuple[List[Tuple[Any, Any]], int]:
        """Stateful whole-tree encode: adds the carried residual before
        selection and advances it — exactly ``roundtrip``'s error
        feedback, but the dropped mass is the with-residual leaf with
        its kept entries zeroed (top-k indices are distinct), so no
        densified decode is ever built."""
        if self.error_feedback and self._residual is not None:
            tree = jax.tree.map(lambda l, r: l + r.astype(l.dtype),
                                tree, self._residual)
        enc: List[Tuple[Any, Any]] = []
        res_leaves = []
        kept_entries = 0
        for l in jax.tree.leaves(tree):
            flat = l.astype(jnp.float32).reshape(-1)
            k = min(flat.size, max(1, int(math.ceil(self.frac * flat.size))))
            kept_entries += k
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            idx = idx.astype(jnp.int32)
            enc.append(((flat[idx], idx), l.shape))
            res_leaves.append(flat.at[idx].set(0.0).reshape(l.shape))
        if self.error_feedback:
            self._residual = jax.tree.unflatten(jax.tree.structure(tree),
                                                res_leaves)
        return enc, int(kept_entries * 8)


def make_codec(name: str, *, topk_frac: float = 0.01,
               error_feedback: bool = True) -> Codec:
    """Factory keyed by ``config.FedConfig.codec``."""
    if name in ("none", "", "identity"):
        return IdentityCodec()
    if name == "fp16":
        return FP16Codec()
    if name == "int8":
        return Int8Codec()
    if name == "topk":
        return TopKCodec(topk_frac, error_feedback)
    raise ValueError(f"unknown codec {name!r}")
