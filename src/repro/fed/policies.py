"""Pluggable server aggregation policies.

  SyncFedAvg   the paper's rule: barrier on all surviving clients, weighted
               average (delegates to ``core/fedavg`` — or to the fedavg
               Pallas kernel when ``use_kernel`` is set).
  FedAsync     Xie et al.: apply every update the moment it arrives,
               down-weighted by staleness
                   global <- (1 - a_t) * global + a_t * params,
                   a_t = alpha * (1 + staleness) ** -staleness_power.
  FedBuff      Nguyen et al.: buffer K updates (staleness-discounted
               weights), aggregate the buffer, mix with server_lr.

The engine calls ``on_update`` for every arriving update (in virtual-time
order) and ``on_round_end`` once per round; a policy returns the possibly
updated global tree plus whether it advanced the global model version
(which is what staleness counts).  Policies are orthogonal to how the
client side was compiled (fed/programs.py backends): an update's params
look the same whether the local round ran as a per-client loop or inside
the batched vmap program.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp


def _fedavg(trees, weights=None):
    # deferred: repro.core.__init__ re-exports core.gan, which imports
    # fed.engine — a top-level import here would close that cycle and make
    # `import repro.fed` order-dependent
    from repro.core.fedavg import fedavg
    return fedavg(trees, weights)


@dataclass
class ClientUpdate:
    client_id: str
    params: Any                 # decoded (post-codec) discriminator tree
    weight: float               # FedAvg weight (client example count)
    staleness: int = 0          # global versions advanced since download
    recv_time: float = 0.0      # virtual arrival time at the server


def _mix(global_tree, update_tree, rate: float):
    """fp32 convex blend, cast back to the global tree's dtypes."""
    r = jnp.float32(rate)
    return jax.tree.map(
        lambda g, u: ((1.0 - r) * g.astype(jnp.float32)
                      + r * u.astype(jnp.float32)).astype(g.dtype),
        global_tree, update_tree)


class AggregationPolicy:
    """Base: buffer everything, do nothing until told."""
    name = "base"

    def on_update(self, global_tree, up: ClientUpdate
                  ) -> Tuple[Any, bool]:
        return global_tree, False

    def on_round_end(self, global_tree) -> Any:
        return global_tree

    def reset(self) -> None:
        pass


class SyncFedAvg(AggregationPolicy):
    """Barrier aggregation — the seed trainer's exact rule.

    Updates are buffered in arrival order (== participation order under the
    sync engine), and the round-end average calls the same host ``fedavg``
    with the same ordering and weights as the seed loop, so the no-dropout
    sync path is bit-for-bit identical.  ``use_kernel`` swaps in the Pallas
    streaming-average kernel for the aggregation hot path.
    """
    name = "sync"

    def __init__(self, weighted: bool = True, use_kernel: bool = False,
                 interpret: bool = False):
        self.weighted = weighted
        self.use_kernel = use_kernel
        self.interpret = interpret
        self._buffer: List[ClientUpdate] = []

    def on_update(self, global_tree, up: ClientUpdate) -> Tuple[Any, bool]:
        self._buffer.append(up)
        return global_tree, False

    def on_round_end(self, global_tree) -> Any:
        if not self._buffer:
            return global_tree
        trees = [u.params for u in self._buffer]
        weights = ([u.weight for u in self._buffer] if self.weighted
                   else None)
        self._buffer = []
        if self.use_kernel:
            from repro.kernels.fedavg.ops import fedavg_trees
            return fedavg_trees(trees, weights, interpret=self.interpret)
        return _fedavg(trees, weights)


class FedAsync(AggregationPolicy):
    """Staleness-weighted immediate application (FedAsync)."""
    name = "fedasync"

    def __init__(self, alpha: float = 0.6, staleness_power: float = 0.5):
        self.alpha = float(alpha)
        self.staleness_power = float(staleness_power)

    def rate(self, staleness: int) -> float:
        return self.alpha * (1.0 + staleness) ** (-self.staleness_power)

    def on_update(self, global_tree, up: ClientUpdate) -> Tuple[Any, bool]:
        return _mix(global_tree, up.params, self.rate(up.staleness)), True


class FedBuff(AggregationPolicy):
    """Buffered async aggregation: fire once K updates are waiting.

    Buffered updates are weighted by ``weight * (1+staleness)^-power`` and
    averaged; the server blends the buffer mean in at ``server_lr`` (1.0 ==
    replace, the FedBuff default).  A non-empty buffer at round end is
    flushed rather than discarded so no client work is silently dropped.
    """
    name = "fedbuff"

    def __init__(self, buffer_size: int = 2, server_lr: float = 1.0,
                 staleness_power: float = 0.5):
        self.buffer_size = max(1, int(buffer_size))
        self.server_lr = float(server_lr)
        self.staleness_power = float(staleness_power)
        self._buffer: List[ClientUpdate] = []

    def _flush(self, global_tree):
        ws = [u.weight * (1.0 + u.staleness) ** (-self.staleness_power)
              for u in self._buffer]
        mean = _fedavg([u.params for u in self._buffer], ws)
        self._buffer = []
        return _mix(global_tree, mean, self.server_lr)

    def on_update(self, global_tree, up: ClientUpdate) -> Tuple[Any, bool]:
        self._buffer.append(up)
        if len(self._buffer) >= self.buffer_size:
            return self._flush(global_tree), True
        return global_tree, False

    def on_round_end(self, global_tree) -> Any:
        if self._buffer:
            return self._flush(global_tree)
        return global_tree

    def reset(self) -> None:
        self._buffer = []


def make_policy(fed_cfg, *, weighted: bool = True) -> AggregationPolicy:
    """Factory keyed by ``config.FedConfig.mode``."""
    if fed_cfg.mode == "sync":
        return SyncFedAvg(weighted, fed_cfg.kernel_aggregation,
                          fed_cfg.kernel_interpret)
    if fed_cfg.mode == "fedasync":
        return FedAsync(fed_cfg.fedasync_alpha, fed_cfg.staleness_power)
    if fed_cfg.mode == "fedbuff":
        return FedBuff(fed_cfg.buffer_size,
                       staleness_power=fed_cfg.staleness_power)
    raise ValueError(f"unknown fed mode {fed_cfg.mode!r}")
