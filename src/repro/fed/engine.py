"""Discrete-event federation round engine.

Replaces the seed's sequential client loop with an explicit runtime: each
round, every available client

  1. downloads the server's fake batches      (downlink, LinkModel-priced),
  2. runs local split-discriminator training  (compute, priced by the
     paper's analytic model ``core/simulate.plan_epoch_time``),
  3. uplinks its discriminator update through a compression codec
     (``fed/transport``), and
  4. the server aggregates per its policy     (``fed/policies``).

The engine schedules **client programs** (``fed/programs``): an object
whose ``run(cids, start_params)`` executes the listed clients' local
rounds and returns pure :class:`~repro.fed.programs.ClientResult` objects
(params + opt state + info, nothing written back).  Legacy bare
``local_update(cid, params) -> (params, info)`` callables are adapted
automatically.

Two scheduling modes:

  * **sync** — barrier semantics with **batched dispatch**: all clients
    that can possibly meet the deadline are handed to the program as ONE
    ``run`` call (one jitted vmap program under the vectorized backend; a
    roster-order loop — host-RNG identical to the seed trainer — under the
    loop backend).  A ``deadline_s`` drops straggler updates whose virtual
    finish time exceeds it (their LAN+WAN+compute work is still counted —
    the cost of a dropped client is real).
  * **async (fedasync | fedbuff)** — a FINISH/ARRIVE event queue: local
    training executes per-arrival when the client's compute finishes *on
    the global snapshot it downloaded*, the update lands after its uplink
    delay, and staleness = how many global versions advanced in between.
    Fast clients can cycle ``async_cycles`` times per round.

Optimizer-state purity: executions stash their resulting opt state with
the (virtual) arrival; only updates that actually land inside the deadline
commit to ``RoundReport.opt_states``.  A dropped straggler therefore
leaves no trace in training state — its opt state is never ahead of the
re-broadcast params (regression-pinned in tests/test_fed_runtime.py).

The wall-clock the engine advances is *virtual* (the paper's Fig-2 time
model extended with WAN transfers); the actual tensor math runs on
whatever accelerator hosts the process, exactly like the seed.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.fed.aggregate import (StreamingAggregator, batched_reduce,
                                 codec_rel_error, decode_enc,
                                 fused_decode_apply)
from repro.fed.events import ARRIVE, FINISH, EventQueue, make_availability
from repro.fed.hierarchy import HierarchicalAggregator
from repro.fed.policies import ClientUpdate, make_policy
from repro.fed.programs import as_program
from repro.fed.transport import (LinkModel, TrafficLedger, apply_delta,
                                 delta_tree, make_codec, tree_bytes,
                                 tree_rel_error)

# legacy program shape: local_update(client_id, start_params)
#   -> (trained_params, info_dict)
LocalUpdateFn = Callable[[str, Any], Tuple[Any, Dict[str, Any]]]


@dataclass(frozen=True)
class ClientSpec:
    """Static per-client facts the scheduler needs."""
    client_id: str
    weight: float                 # FedAvg weight (example count)
    compute_time_s: float         # one local round (core/simulate)
    lr_scale: float = 1.0         # per-client LR schedule (cfg.fed)
    local_steps: int = 0          # per-client round length (0 = default)


@dataclass
class RoundReport:
    global_params: Any
    participated: List[str] = field(default_factory=list)
    unavailable: List[str] = field(default_factory=list)
    stragglers: List[str] = field(default_factory=list)
    round_time_s: float = 0.0
    clock_s: float = 0.0          # engine clock after this round
    traffic: TrafficLedger = field(default_factory=TrafficLedger)
    client_infos: List[Tuple[str, Dict[str, Any]]] = field(
        default_factory=list)            # in execution order
    staleness: Dict[str, int] = field(default_factory=dict)   # last per client
    staleness_events: List[int] = field(default_factory=list)  # every arrival
    version: int = 0
    # final opt state per client whose update landed (participated) —
    # the caller commits exactly these; dropped work leaves no state
    opt_states: Dict[str, Any] = field(default_factory=dict)
    # measured per-client virtual finish times (sync: download + compute +
    # uplink; async: last arrival offset).  Provably-late stragglers that
    # never ran record their known lower bound (download + compute) — the
    # deadline controller reads this distribution.
    finish_s: Dict[str, float] = field(default_factory=dict)
    # measured relative L2 error the codec round-trip cost each client's
    # delta this round (0.0 under the identity codec) — with the uplink
    # bytes, one point on the bytes-vs-error frontier the codec controller
    # walks.
    codec_error: Dict[str, float] = field(default_factory=dict)
    # content digest of the as-aggregated global_params, stamped by the
    # engine's digester hook (set_digester) the moment aggregation lands —
    # BEFORE any health action touches the tree, so a rolled-back round
    # still records what the aggregate actually was
    global_digest: Optional[str] = None
    # peak count of decoded fp32 update trees live at the server during
    # aggregation: the decode reduce stages one per landed client (O(C));
    # the compressed-domain stream reduce holds only the accumulator
    # (O(1) in cohort size — asserted in tests, recorded in the bench)
    peak_live_trees: int = 0

    @property
    def mean_staleness(self) -> float:
        if not self.staleness_events:
            return 0.0
        return sum(self.staleness_events) / len(self.staleness_events)


class FederationEngine:
    def __init__(self, fed_cfg, specs: List[ClientSpec], *,
                 weighted: bool = True, uplink_stage=None, cohort_of=None):
        self.cfg = fed_cfg
        self.roster = [s.client_id for s in specs]
        self.specs = {s.client_id: s for s in specs}
        self.weighted = bool(weighted)
        self.policy = make_policy(fed_cfg, weighted=weighted)
        # server reduce strategy (config.SERVER_REDUCES): "decode" stages
        # decoded trees through the policy (bit-exact reference);
        # "stream"/"batched" aggregate wire payloads in the compressed
        # domain (fed/aggregate) — on the sync paths, where the reduce is
        # a plain weighted mean.  Async always decodes per-arrival, but
        # under "stream"/"batched" the ARRIVE queue carries wire payloads
        # instead of decoded trees (O(1) live decoded state).
        self.server_reduce = str(getattr(fed_cfg, "server_reduce", "decode"))
        # optional client mesh for the "batched" reduce: stacked wire
        # leaves land on the `clients` axis before the fused reduce
        self.mesh = None
        # pre-codec uplink transform (privacy/defenses.DPUplinkStage):
        # applied to the update delta BEFORE compression, so the codec —
        # and everything downstream of it — only ever sees the privatized
        # delta.  None = no transform (the default, bit-exact path).
        self.uplink_stage = uplink_stage
        self.codec_name = fed_cfg.codec
        self.topk_frac = fed_cfg.topk_frac
        self.codecs = {cid: make_codec(fed_cfg.codec,
                                       topk_frac=fed_cfg.topk_frac,
                                       error_feedback=fed_cfg.error_feedback)
                       for cid in self.roster}
        # the live straggler deadline: seeded from config, retuned between
        # rounds by the control plane (set_deadline) without touching cfg
        self.deadline_s = float(fed_cfg.deadline_s)
        self.uplink = LinkModel(fed_cfg.wan_latency_s, fed_cfg.uplink_bps)
        self.downlink = LinkModel(fed_cfg.wan_latency_s, fed_cfg.downlink_bps)
        # two-tier edge aggregation (fed/hierarchy): cohorts >= 2 on the
        # sync path routes client updates over the cheap edge link, pre-
        # reduces per cohort, and uplinks ONE tree per cohort across the
        # WAN.  0/1 keeps the flat, bit-exact single-tier path.
        cohorts = int(getattr(fed_cfg, "hierarchy_cohorts", 0))
        self.edge_link = LinkModel(
            float(getattr(fed_cfg, "edge_latency_s", 0.005)),
            float(getattr(fed_cfg, "edge_uplink_bps", 200e6)))
        self.hierarchy: Optional[HierarchicalAggregator] = None
        if cohorts >= 2 and fed_cfg.mode == "sync":
            self.hierarchy = HierarchicalAggregator(
                cohorts, use_kernel=fed_cfg.kernel_aggregation,
                interpret=fed_cfg.kernel_interpret, cohort_of=cohort_of)
        self.availability = make_availability(fed_cfg.availability,
                                              fed_cfg.availability_seed)
        self.clock = 0.0
        self.round_idx = 0
        self.version = 0
        self.ledger = TrafficLedger()      # cumulative across rounds
        self._lan_by: Dict[str, int] = {}  # this round's LAN bytes/client
        # observability (repro.obs): optional tracer + per-client split
        # timelines; None/empty means no spans are emitted — pricing,
        # scheduling and numerics are identical either way
        self.tracer = None
        self._trace_batch_cap = 0
        self._timelines: Dict[str, Any] = {}
        # optional content-digest hook (repro.obs.digest.tree_digest):
        # stamps RoundReport.global_digest on the as-aggregated tree
        self._digester = None
        self.last_report: Optional[RoundReport] = None

    # ------------------------------------------------------------------
    def set_codec(self, name: str, topk_frac: Optional[float] = None) -> None:
        """Swap the uplink codec for subsequent rounds (codec controller).
        Rebuilds per-client codec instances, which clears any top-k error-
        feedback residual — the residual belongs to the OLD codec's lossy
        stream and must not be replayed into the new one."""
        frac = self.topk_frac if topk_frac is None else float(topk_frac)
        if name == self.codec_name and frac == self.topk_frac:
            return
        self.codec_name, self.topk_frac = name, frac
        self.codecs = {cid: make_codec(name, topk_frac=frac,
                                       error_feedback=self.cfg.error_feedback)
                       for cid in self.roster}

    def set_deadline(self, deadline_s: float) -> None:
        """Retune the sync straggler deadline (deadline controller)."""
        self.deadline_s = float(deadline_s)

    def set_mesh(self, mesh) -> None:
        """Attach a client device mesh for the "batched" server reduce:
        per-leaf wire stacks are placed with
        ``sharding.stacked_shardings`` before the fused dequant-reduce.
        ``None`` (the default) keeps every reduce single-device."""
        self.mesh = mesh

    def set_tracer(self, tracer, *, batch_cap: int = 0) -> None:
        """Attach a :class:`repro.obs.Tracer`; subsequent rounds emit
        virtual-clock spans (round -> download -> client-execution ->
        split-segment/boundary -> uplink -> aggregate).  ``batch_cap``
        bounds how many batches per client get per-phase split spans
        (0 = all).  ``None`` detaches."""
        self.tracer = tracer
        self._trace_batch_cap = int(batch_cap)

    def set_digester(self, fn) -> None:
        """Attach a content-digest function ``tree -> str`` (typically
        :func:`repro.obs.digest.tree_digest`); each subsequent round stamps
        ``RoundReport.global_digest`` with the digest of the as-aggregated
        global tree.  Purely observational — the tree itself is untouched.
        ``None`` detaches."""
        self._digester = fn

    # ------------------------------------------------------------------
    def _codec_roundtrip(self, cid: str, base_tree, params
                         ) -> Tuple[Any, int, float]:
        """Uplink params through the client's codec; lossy codecs compress
        the delta vs the tree the client downloaded (``base_tree``).  An
        ``uplink_stage`` (DP clip+noise) runs on the delta first, so lossy
        codecs compress — and the server only decodes — the privatized
        update.  Returns ``(decoded, wire_bytes, rel_error)`` where
        ``rel_error`` is the measured relative L2 error the CODEC cost the
        (possibly privatized) delta."""
        codec = self.codecs[cid]
        if codec.encodes_delta or self.uplink_stage is not None:
            delta = delta_tree(params, base_tree)
            if self.uplink_stage is not None:
                delta = self.uplink_stage(cid, delta)
            dec, nbytes = codec.roundtrip(delta)
            err = tree_rel_error(dec, delta) if codec.encodes_delta else 0.0
            return apply_delta(base_tree, dec), nbytes, err
        dec, nbytes = codec.roundtrip(params)
        return dec, nbytes, 0.0

    def _encode_uplink(self, cid: str, base_tree, params
                       ) -> Tuple[Any, int, Any, bool]:
        """Wire-level form of :meth:`_codec_roundtrip`: encode the uplink
        WITHOUT decoding it.  Returns ``(enc, wire_bytes, delta,
        is_delta)`` — ``enc`` is the ``Codec.encode_tree`` payload the
        compressed-domain reduce folds, ``delta`` the raw (possibly
        privatized) delta for error measurement (None when the codec is
        lossless), ``is_delta`` whether the wire is in the delta domain.
        Prices IDENTICAL bytes, applies the same ``uplink_stage``, and
        advances stateful codec residuals exactly like the decode path."""
        codec = self.codecs[cid]
        if codec.encodes_delta or self.uplink_stage is not None:
            delta = delta_tree(params, base_tree)
            if self.uplink_stage is not None:
                delta = self.uplink_stage(cid, delta)
            enc, nbytes = codec.encode_tree(delta)
            return (enc, nbytes,
                    delta if codec.encodes_delta else None, True)
        enc, nbytes = codec.encode_tree(params)
        return enc, nbytes, None, False

    def _split_roster(self) -> Tuple[List[str], List[str]]:
        up, down = [], []
        for cid in self.roster:
            (up if self.availability.available(cid, self.round_idx)
             else down).append(cid)
        return up, down

    # ------------------------------------------------------------------
    def run_round(self, global_tree, program, *, down_bytes: int = 0,
                  down_bytes_by_client: Optional[Dict[str, int]] = None,
                  lan_bytes_by_client: Optional[Dict[str, int]] = None,
                  timeline_by_client: Optional[Dict[str, Any]] = None
                  ) -> RoundReport:
        """One FL round.  ``program``: a client program (``fed/programs``)
        or a legacy bare callable.  ``down_bytes``: server->client fake
        payload; ``down_bytes_by_client`` overrides it per client (clients
        on a longer ``local_steps`` schedule download more fake batches,
        so their downlink time and bytes must be priced accordingly).
        ``lan_bytes_by_client``: measured split-boundary bytes of one local
        round (``core/split.SplitExecution.step_wire_bytes`` x steps) —
        recorded per *execution*, straggler or not, because the LAN traffic
        happens whether or not the update lands.
        ``timeline_by_client``: one batch's ordered split phases per client
        (``core/split.SplitExecution.round_timeline`` output) — only read
        when a tracer is attached, to subdivide client-execution spans."""
        program = as_program(program)
        down_by = dict(down_bytes_by_client or {})
        db = lambda cid: down_by.get(cid, down_bytes)  # noqa: E731
        self._lan_by = dict(lan_bytes_by_client or {})
        self._timelines = dict(timeline_by_client or {})
        if self.cfg.mode != "sync":
            rep = self._run_async(global_tree, program, db)
        elif self.hierarchy is not None:
            rep = self._run_sync_hier(global_tree, program, db)
        else:
            rep = self._run_sync(global_tree, program, db)
        self.round_idx += 1
        if self._digester is not None:
            rep.global_digest = self._digester(rep.global_params)
        for cid in rep.traffic.up_bytes:
            self.ledger.record(cid, up=rep.traffic.up_bytes[cid])
        for cid in rep.traffic.down_bytes:
            self.ledger.record(cid, down=rep.traffic.down_bytes[cid])
        for cid in rep.traffic.lan_bytes:
            self.ledger.record(cid, lan=rep.traffic.lan_bytes[cid])
        for cid in rep.traffic.edge_bytes:
            self.ledger.record_edge(cid, rep.traffic.edge_bytes[cid])
        # kept for post-round inspection (peak_live_trees assertions, the
        # agg bench) — the trainer consumes the returned report directly
        self.last_report = rep
        return rep

    # ------------------------------------------------------------------
    # span emission (repro.obs).  Spans are recorded retroactively from
    # the round's priced times once they are all known — the discrete-
    # event engine schedules whole client windows, it never "waits".
    # ------------------------------------------------------------------
    def _emit_exec_span(self, tr, parent, cid: str, start: float,
                        compute_dur: float, args: Dict[str, Any]) -> int:
        """Client-execution span [start, start+compute_dur], subdivided
        into per-batch split-segment / boundary-crossing phases when a
        timeline is known for this client."""
        sid = tr.record(f"exec {cid}", cat="client", track=cid,
                        v_start=start, v_end=start + compute_dur,
                        parent=parent, args=args)
        tl = self._timelines.get(cid)
        if not tl:
            return sid
        phases, batch_time = tl
        if batch_time <= 0.0 or not phases:
            return sid
        steps = self.specs[cid].local_steps \
            or max(1, int(round(compute_dur / batch_time)))
        n = steps if self._trace_batch_cap <= 0 \
            else min(steps, self._trace_batch_cap)
        for b in range(n):
            off = start + b * batch_time
            bid = tr.record(f"batch {b}", cat="batch", track=cid,
                            v_start=off, v_end=off + batch_time, parent=sid)
            for ph in phases:
                tr.record(ph["name"], cat=ph["cat"], track=ph["track"],
                          v_start=off + ph["t0"], v_end=off + ph["t1"],
                          parent=bid, args=ph["args"])
        return sid

    def _emit_sync_spans(self, rep: RoundReport, t0: float,
                         down_t: Dict[str, float]) -> None:
        tr = self.tracer
        rnd = tr.record(
            f"round {self.round_idx}", cat="round", track="server",
            v_start=t0, v_end=t0 + rep.round_time_s,
            args={"mode": "sync", "participated": len(rep.participated),
                  "stragglers": len(rep.stragglers),
                  "codec": self.codec_name, "deadline_s": self.deadline_s})
        for cid, dt in down_t.items():
            spec = self.specs[cid]
            tr.record(f"down {cid}", cat="downlink", track=cid,
                      v_start=t0, v_end=t0 + dt, parent=rnd,
                      args={"bytes": rep.traffic.down_bytes.get(cid, 0)})
            args: Dict[str, Any] = {}
            if cid in rep.stragglers:
                args["dropped"] = True
            # ran iff the codec round-tripped its update this round
            if cid not in rep.codec_error:
                args["executed"] = False   # provably-late lower bound
                self._emit_exec_span(tr, rnd, cid, t0 + dt,
                                     spec.compute_time_s, args)
                continue
            self._emit_exec_span(tr, rnd, cid, t0 + dt,
                                 spec.compute_time_s, args)
            fin = rep.finish_s[cid]
            up_dur = max(0.0, fin - dt - spec.compute_time_s)
            tr.record(f"up {cid}", cat="uplink", track=cid,
                      v_start=t0 + fin - up_dur, v_end=t0 + fin, parent=rnd,
                      args={"bytes": rep.traffic.up_bytes.get(cid, 0),
                            "codec": self.codec_name,
                            "landed": cid in rep.participated})
        tr.record("aggregate", cat="aggregate", track="server",
                  v_start=t0 + rep.round_time_s, v_end=t0 + rep.round_time_s,
                  parent=rnd,
                  args={"num_updates": len(rep.participated),
                        "version": rep.version})

    def _emit_async_spans(self, rep: RoundReport, t0: float, last_t: float,
                          events: List[Dict[str, Any]]) -> None:
        tr = self.tracer
        rnd = tr.record(
            f"round {self.round_idx}", cat="round", track="server",
            v_start=t0, v_end=last_t,
            args={"mode": self.cfg.mode,
                  "participated": len(rep.participated),
                  "stragglers": len(rep.stragglers),
                  "codec": self.codec_name})
        for ev in events:
            cid = ev.get("cid", "")
            if ev["kind"] == "down":
                tr.record(f"down {cid}", cat="downlink", track=cid,
                          v_start=ev["t0"], v_end=ev["t1"], parent=rnd,
                          args={"bytes": ev["bytes"],
                                "cycle": ev["cycle"]})
            elif ev["kind"] == "exec":
                self._emit_exec_span(tr, rnd, cid, ev["t0"],
                                     ev["t1"] - ev["t0"],
                                     {"cycle": ev["cycle"]})
            elif ev["kind"] == "up":
                tr.record(f"up {cid}", cat="uplink", track=cid,
                          v_start=ev["t0"], v_end=ev["t1"], parent=rnd,
                          args={"bytes": ev["bytes"],
                                "codec": self.codec_name})
            else:                          # arrive -> server-side apply
                tr.record(f"aggregate {cid}", cat="aggregate",
                          track="server", v_start=ev["t"], v_end=ev["t"],
                          parent=rnd,
                          args={"staleness": ev["staleness"],
                                "landed": ev["landed"]})

    # ------------------------------------------------------------------
    def _run_sync(self, global_tree, program, db) -> RoundReport:
        rep = RoundReport(global_params=global_tree)
        participants, rep.unavailable = self._split_roster()
        t0 = self.clock
        deadline = self.deadline_s
        down_t = {cid: self.downlink.transfer_time(db(cid))
                  for cid in participants}
        finishes: List[float] = []

        # batched dispatch: every client that can possibly meet the
        # deadline executes in ONE program.run call (one jitted vmap
        # program under the vectorized backend); provably-late clients
        # never run, so no work — and no host RNG — is spent on them
        runnable: List[str] = []
        for cid in participants:
            if deadline and down_t[cid] + self.specs[cid].compute_time_s \
                    > deadline:
                rep.stragglers.append(cid)
                rep.traffic.record(cid, down=db(cid))
                # never ran: record the known lower bound on its finish so
                # the measured round-time distribution still covers it
                rep.finish_s[cid] = (down_t[cid]
                                     + self.specs[cid].compute_time_s)
            else:
                runnable.append(cid)
        results = program.run(runnable, global_tree)

        # compressed-domain reduce ("stream"/"batched"): landed uplinks
        # fold as WIRE payloads — no per-client decoded tree is staged
        reduce_mode = self.server_reduce
        agg: Optional[StreamingAggregator] = None
        staged: List[Tuple[Any, float]] = []      # batched: (enc, weight)
        is_delta = False
        if reduce_mode != "decode":
            agg = StreamingAggregator(self.codec_name,
                                      use_kernel=self.cfg.kernel_aggregation,
                                      interpret=self.cfg.kernel_interpret)
            agg.init(global_tree)

        for res in results:
            cid = res.client_id
            spec = self.specs[cid]
            if reduce_mode == "decode":
                decoded, up_b, cerr = self._codec_roundtrip(
                    cid, global_tree, res.params)
            else:
                enc, up_b, delta, is_delta = self._encode_uplink(
                    cid, global_tree, res.params)
            finish = down_t[cid] + spec.compute_time_s \
                + self.uplink.transfer_time(up_b)
            rep.traffic.record(cid, up=up_b, down=db(cid),
                               lan=self._lan_by.get(cid, 0))
            rep.client_infos.append((cid, res.info))
            rep.finish_s[cid] = finish
            if reduce_mode == "decode":
                rep.codec_error[cid] = cerr
            if deadline and finish > deadline:
                if reduce_mode != "decode":
                    # ran but never folds: measure the codec's cost
                    # without decoding the dropped update
                    rep.codec_error[cid] = codec_rel_error(
                        self.codec_name, enc, delta)
                rep.stragglers.append(cid)     # ran, but its update is late
                continue                       # nothing commits — not even
                                               # its optimizer state
            rep.participated.append(cid)
            if res.opt_state is not None:
                rep.opt_states[cid] = res.opt_state
            rep.staleness[cid] = 0
            rep.staleness_events.append(0)
            finishes.append(finish)
            if reduce_mode == "stream":
                # fold now; the rel error rides the same traversal
                err = agg.fold(enc, spec.weight if self.weighted else 1.0,
                               delta=delta)
                rep.codec_error[cid] = 0.0 if err is None else err
            elif reduce_mode == "batched":
                staged.append((enc, spec.weight if self.weighted else 1.0))
                rep.codec_error[cid] = codec_rel_error(
                    self.codec_name, enc, delta)
            else:
                self.policy.on_update(
                    global_tree, ClientUpdate(cid, decoded, spec.weight,
                                              0, self.clock + finish))

        if reduce_mode == "decode":
            new_global = self.policy.on_round_end(global_tree)
            rep.peak_live_trees = len(rep.participated)
        else:
            if reduce_mode == "stream":
                mean = agg.finalize()
            elif staged:
                mean = batched_reduce(
                    self.codec_name, [e for e, _ in staged],
                    [w for _, w in staged], global_tree,
                    use_kernel=self.cfg.kernel_aggregation,
                    interpret=self.cfg.kernel_interpret, mesh=self.mesh)
            else:
                mean = None
            if mean is None:
                new_global = global_tree
            else:
                new_global = apply_delta(global_tree, mean) if is_delta \
                    else mean
            rep.peak_live_trees = 1 if rep.participated else 0
        if rep.participated:
            self.version += 1
        # the sync barrier releases at the slowest survivor — or at the
        # deadline when stragglers were waited out that long
        rep.round_time_s = max(finishes) if finishes else 0.0
        if deadline and rep.stragglers:
            rep.round_time_s = max(rep.round_time_s, deadline)
        self.clock += rep.round_time_s
        rep.clock_s = self.clock
        rep.global_params = new_global
        rep.version = self.version
        if self.tracer is not None:
            self._emit_sync_spans(rep, t0, down_t)
        return rep

    # ------------------------------------------------------------------
    def _run_sync_hier(self, global_tree, program, db) -> RoundReport:
        """Sync round through the two-tier edge hierarchy.

        Client updates travel the cheap edge link (``edge_bytes``); each
        cohort's edge pre-reduces its members' decoded updates with the
        same weighted FedAvg the server applies, and only ONE aggregate
        per cohort crosses the WAN (``up_bytes`` keyed ``cohort<k>``).
        Weighted-mean-of-weighted-means equals the flat aggregate up to
        float reassociation, so this path pins against :meth:`_run_sync`
        at tolerance, never bit-exact.  A cohort barrier releases at its
        slowest surviving member; the round at the slowest cohort."""
        rep = RoundReport(global_params=global_tree)
        participants, rep.unavailable = self._split_roster()
        t0 = self.clock
        deadline = self.deadline_s
        down_t = {cid: self.downlink.transfer_time(db(cid))
                  for cid in participants}

        runnable: List[str] = []
        for cid in participants:
            if deadline and down_t[cid] + self.specs[cid].compute_time_s \
                    > deadline:
                rep.stragglers.append(cid)
                rep.traffic.record(cid, down=db(cid))
                rep.finish_s[cid] = (down_t[cid]
                                     + self.specs[cid].compute_time_s)
            else:
                runnable.append(cid)
        results = program.run(runnable, global_tree)

        # per-client: codec over the EDGE hop, deadline at edge arrival.
        # Under the compressed-domain reduce the edge tier stages WIRE
        # payloads, not decoded member trees — each cohort folds them
        # through one streaming accumulator (hierarchy.
        # reduce_all_streaming), so live decoded state at the edge is
        # O(1) in the cohort size.
        reduce_mode = self.server_reduce
        is_delta = False
        landed: Dict[str, Tuple[Any, float]] = {}   # cid -> (payload, w)
        edge_finish: Dict[str, float] = {}
        for res in results:
            cid = res.client_id
            spec = self.specs[cid]
            if reduce_mode == "decode":
                payload, up_b, cerr = self._codec_roundtrip(
                    cid, global_tree, res.params)
            else:
                payload, up_b, delta, is_delta = self._encode_uplink(
                    cid, global_tree, res.params)
                cerr = codec_rel_error(self.codec_name, payload, delta)
            finish = down_t[cid] + spec.compute_time_s \
                + self.edge_link.transfer_time(up_b)
            rep.traffic.record(cid, down=db(cid),
                               lan=self._lan_by.get(cid, 0))
            rep.traffic.record_edge(cid, up_b)
            rep.client_infos.append((cid, res.info))
            rep.finish_s[cid] = finish
            rep.codec_error[cid] = cerr
            if deadline and finish > deadline:
                rep.stragglers.append(cid)
                continue
            rep.participated.append(cid)
            if res.opt_state is not None:
                rep.opt_states[cid] = res.opt_state
            rep.staleness[cid] = 0
            rep.staleness_events.append(0)
            landed[cid] = (payload, spec.weight)
            edge_finish[cid] = finish

        # per-cohort: edge pre-reduce, then ONE WAN uplink per cohort
        if reduce_mode == "decode":
            reductions = self.hierarchy.reduce_all(landed)
        else:
            reductions = self.hierarchy.reduce_all_streaming(
                landed, global_tree, codec_name=self.codec_name)
        cohort_finishes: List[float] = []
        cohort_trace: List[Dict[str, Any]] = []
        for red in reductions:
            aggregate = red.aggregate
            if reduce_mode != "decode" and is_delta:
                # stream reduce yields the cohort's mean DELTA; rebase it
                # so the WAN payload and the server update are the same
                # full tree the decode path ships
                aggregate = apply_delta(global_tree, aggregate)
            wan_b = tree_bytes(aggregate)
            ready = max(edge_finish[m] for m in red.members)
            finish = ready + self.uplink.transfer_time(wan_b)
            ckey = f"cohort{red.cohort}"
            rep.traffic.record(ckey, up=wan_b)
            cohort_finishes.append(finish)
            cohort_trace.append({"cohort": red.cohort, "ready": ready,
                                 "finish": finish, "bytes": wan_b,
                                 "members": list(red.members)})
            self.policy.on_update(
                global_tree, ClientUpdate(ckey, aggregate, red.weight,
                                          0, self.clock + finish))
        # decode: every landed member tree + the buffered cohort
        # aggregates are live at once; stream: cohort aggregates + ONE
        # accumulator, independent of cohort size
        rep.peak_live_trees = len(landed) + len(reductions) \
            if reduce_mode == "decode" else \
            (len(reductions) + 1 if reductions else 0)

        new_global = self.policy.on_round_end(global_tree)
        if rep.participated:
            self.version += 1
        rep.round_time_s = max(cohort_finishes) if cohort_finishes else 0.0
        if deadline and rep.stragglers:
            rep.round_time_s = max(rep.round_time_s, deadline)
        self.clock += rep.round_time_s
        rep.clock_s = self.clock
        rep.global_params = new_global
        rep.version = self.version
        if self.tracer is not None:
            self._emit_hier_spans(rep, t0, down_t, cohort_trace)
        return rep

    def _emit_hier_spans(self, rep: RoundReport, t0: float,
                         down_t: Dict[str, float],
                         cohort_trace: List[Dict[str, Any]]) -> None:
        """Round span -> per-client down/exec/edge-up spans -> one cohort
        span per edge (cat="cohort": pre-reduce ready time to WAN
        arrival) -> aggregate."""
        tr = self.tracer
        rnd = tr.record(
            f"round {self.round_idx}", cat="round", track="server",
            v_start=t0, v_end=t0 + rep.round_time_s,
            args={"mode": "sync", "hierarchy": True,
                  "cohorts": len(cohort_trace),
                  "participated": len(rep.participated),
                  "stragglers": len(rep.stragglers),
                  "codec": self.codec_name, "deadline_s": self.deadline_s})
        for cid, dt in down_t.items():
            spec = self.specs[cid]
            tr.record(f"down {cid}", cat="downlink", track=cid,
                      v_start=t0, v_end=t0 + dt, parent=rnd,
                      args={"bytes": rep.traffic.down_bytes.get(cid, 0)})
            args: Dict[str, Any] = {}
            if cid in rep.stragglers:
                args["dropped"] = True
            if cid not in rep.codec_error:
                args["executed"] = False
                self._emit_exec_span(tr, rnd, cid, t0 + dt,
                                     spec.compute_time_s, args)
                continue
            self._emit_exec_span(tr, rnd, cid, t0 + dt,
                                 spec.compute_time_s, args)
            fin = rep.finish_s[cid]
            up_dur = max(0.0, fin - dt - spec.compute_time_s)
            tr.record(f"edge-up {cid}", cat="uplink", track=cid,
                      v_start=t0 + fin - up_dur, v_end=t0 + fin, parent=rnd,
                      args={"bytes": rep.traffic.edge_bytes.get(cid, 0),
                            "tier": "edge", "codec": self.codec_name,
                            "landed": cid in rep.participated})
        for ct in cohort_trace:
            tr.record(f"cohort {ct['cohort']}", cat="cohort",
                      track=f"edge{ct['cohort']}",
                      v_start=t0 + ct["ready"], v_end=t0 + ct["finish"],
                      parent=rnd,
                      args={"members": len(ct["members"]),
                            "wan_bytes": ct["bytes"]})
        tr.record("aggregate", cat="aggregate", track="server",
                  v_start=t0 + rep.round_time_s, v_end=t0 + rep.round_time_s,
                  parent=rnd,
                  args={"num_updates": len(cohort_trace),
                        "version": rep.version})

    # ------------------------------------------------------------------
    def _run_async(self, global_tree, program, db) -> RoundReport:
        rep = RoundReport(global_params=global_tree)
        participants, rep.unavailable = self._split_roster()
        t0 = self.clock
        deadline = self.deadline_s
        down_t = {cid: self.downlink.transfer_time(db(cid))
                  for cid in participants}
        queue = EventQueue()
        # (snapshot tree, version at download) per in-flight client
        snapshots: Dict[str, Tuple[Any, int]] = {}
        tev: List[Dict[str, Any]] = []     # trace records (tracer attached)

        for cid in participants:
            snapshots[cid] = (global_tree, self.version)
            rep.traffic.record(cid, down=db(cid))
            queue.push(t0 + down_t[cid] + self.specs[cid].compute_time_s,
                       FINISH, cid, payload={"cycle": 1})
            if self.tracer is not None:
                tev.append({"kind": "down", "cid": cid, "t0": t0,
                            "t1": t0 + down_t[cid], "bytes": db(cid),
                            "cycle": 1})

        # under the compressed-domain reduce, in-flight ARRIVE payloads
        # carry WIRE encodings; the decode happens per-arrival (one
        # fused decode+rebase traversal), so live decoded trees stay at
        # 1 no matter how many uplinks are in flight
        stream = self.server_reduce != "decode"
        live_payloads = 0
        peak_payloads = 0
        last_t = t0
        while queue:
            ev = queue.pop()
            last_t = max(last_t, ev.time)
            cid = ev.client_id
            spec = self.specs[cid]
            if ev.kind == FINISH:
                snap_tree, snap_ver = snapshots[cid]
                res = program.run([cid], snap_tree)[0]
                if stream:
                    enc, up_b, delta, is_delta = self._encode_uplink(
                        cid, snap_tree, res.params)
                    cerr = codec_rel_error(self.codec_name, enc, delta)
                    # the snapshot rides along: it is what the uplink's
                    # delta rebases onto, and snapshots[cid] may advance
                    # before this arrival is processed
                    payload = {"enc": enc, "is_delta": is_delta,
                               "snap_tree": snap_tree}
                else:
                    decoded, up_b, cerr = self._codec_roundtrip(
                        cid, snap_tree, res.params)
                    payload = {"decoded": decoded}
                    live_payloads += 1
                    peak_payloads = max(peak_payloads, live_payloads)
                rep.traffic.record(cid, up=up_b,
                                   lan=self._lan_by.get(cid, 0))
                rep.client_infos.append((cid, res.info))
                rep.codec_error[cid] = cerr
                # the opt state rides with the arrival: it only commits if
                # the update actually lands inside the deadline
                up_t = self.uplink.transfer_time(up_b)
                payload.update({"snap_ver": snap_ver,
                                "cycle": ev.payload["cycle"],
                                "opt_state": res.opt_state})
                queue.push(ev.time + up_t, ARRIVE, cid, payload=payload)
                if self.tracer is not None:
                    tev.append({"kind": "exec", "cid": cid,
                                "t0": ev.time - spec.compute_time_s,
                                "t1": ev.time,
                                "cycle": ev.payload["cycle"]})
                    tev.append({"kind": "up", "cid": cid, "t0": ev.time,
                                "t1": ev.time + up_t, "bytes": up_b})
                continue
            # ARRIVE
            if not stream:
                live_payloads -= 1
            rep.finish_s[cid] = ev.time - t0      # last arrival per client
            if deadline and ev.time - t0 > deadline:
                rep.stragglers.append(cid)
                if self.tracer is not None:
                    tev.append({"kind": "arrive", "cid": cid, "t": ev.time,
                                "staleness":
                                    self.version - ev.payload["snap_ver"],
                                "landed": False})
                continue
            staleness = self.version - ev.payload["snap_ver"]
            if self.tracer is not None:
                tev.append({"kind": "arrive", "cid": cid, "t": ev.time,
                            "staleness": staleness, "landed": True})
            rep.staleness[cid] = staleness
            rep.staleness_events.append(staleness)
            if stream:
                if ev.payload["is_delta"]:
                    update_tree = fused_decode_apply(
                        self.codec_name, ev.payload["snap_tree"],
                        ev.payload["enc"])
                else:
                    update_tree = decode_enc(self.codec_name,
                                             ev.payload["enc"],
                                             ev.payload["snap_tree"])
            else:
                update_tree = ev.payload["decoded"]
            global_tree, bumped = self.policy.on_update(
                global_tree,
                ClientUpdate(cid, update_tree, spec.weight,
                             staleness, ev.time))
            if bumped:
                self.version += 1
            if cid not in rep.participated:
                rep.participated.append(cid)
            if ev.payload["opt_state"] is not None:
                rep.opt_states[cid] = ev.payload["opt_state"]
            cycle = ev.payload["cycle"]
            if cycle < self.cfg.async_cycles:
                snapshots[cid] = (global_tree, self.version)
                rep.traffic.record(cid, down=db(cid))
                queue.push(ev.time + down_t[cid] + spec.compute_time_s,
                           FINISH, cid, payload={"cycle": cycle + 1})
                if self.tracer is not None:
                    tev.append({"kind": "down", "cid": cid, "t0": ev.time,
                                "t1": ev.time + down_t[cid],
                                "bytes": db(cid), "cycle": cycle + 1})

        global_tree = self.policy.on_round_end(global_tree)
        self.version += 1 if rep.participated else 0
        rep.peak_live_trees = (1 if rep.client_infos else 0) if stream \
            else peak_payloads
        rep.round_time_s = last_t - t0
        self.clock = last_t
        rep.clock_s = self.clock
        rep.global_params = global_tree
        rep.version = self.version
        if self.tracer is not None:
            self._emit_async_spans(rep, t0, last_t, tev)
        return rep
