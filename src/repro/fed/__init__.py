"""Event-driven federation runtime (paper §3 made first-class).

  transport   links, byte accounting, compression codecs
  events      event queue + client availability traces
  policies    FedAvg / FedAsync / FedBuff aggregation
  engine      discrete-event round engine (sync + async scheduling)
  vectorized  single-program multi-client local training + kernel FedAvg
"""
from repro.fed.engine import (ClientSpec, FederationEngine,  # noqa: F401
                              RoundReport)
from repro.fed.events import (AlwaysAvailable,  # noqa: F401
                              BernoulliAvailability, EventQueue,
                              make_availability)
from repro.fed.policies import (AggregationPolicy, ClientUpdate,  # noqa: F401
                                FedAsync, FedBuff, SyncFedAvg, make_policy)
from repro.fed.transport import (Codec, FP16Codec, IdentityCodec,  # noqa: F401
                                 Int8Codec, LinkModel, TopKCodec,
                                 TrafficLedger, fake_batch_bytes, make_codec,
                                 tree_bytes)
from repro.fed.vectorized import (fedavg_stacked,  # noqa: F401
                                  make_multi_client_d_step,
                                  sequential_d_rounds, stack_trees,
                                  unstack_tree)
