"""Event-driven federation runtime (paper §3 made first-class).

  transport   links, byte accounting, compression codecs
  events      event queue + client availability traces
  policies    FedAvg / FedAsync / FedBuff aggregation
  programs    the client-side local round as data: one step definition
              (plain | DP-SGD), two compilations (loop | vectorized),
              per-client lr/steps schedules, pure round execution
  engine      discrete-event round engine (sync + async scheduling) that
              schedules programs
"""
from repro.fed.engine import (ClientSpec, FederationEngine,  # noqa: F401
                              RoundReport)
from repro.fed.events import (AlwaysAvailable,  # noqa: F401
                              BernoulliAvailability, EventQueue,
                              make_availability)
from repro.fed.policies import (AggregationPolicy, ClientUpdate,  # noqa: F401
                                FedAsync, FedBuff, SyncFedAvg, make_policy)
from repro.fed.programs import (BACKENDS, CallableProgram,  # noqa: F401
                                ClientHyper, ClientResult, LocalProgram,
                                RoundExecutor, as_program, fedavg_stacked,
                                make_local_step, sequential_d_rounds,
                                stack_trees, unstack_tree)
from repro.fed.transport import (Codec, FP16Codec, IdentityCodec,  # noqa: F401
                                 Int8Codec, LinkModel, TopKCodec,
                                 TrafficLedger, apply_delta, delta_tree,
                                 fake_batch_bytes, make_codec, tree_bytes)
