from repro.checkpoint.io import (  # noqa: F401
    CheckpointManager, load_pytree, save_pytree,
)
