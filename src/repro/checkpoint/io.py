"""Pytree checkpointing to .npz (no external deps).

Trees are flattened to path-keyed arrays; bfloat16 leaves are bit-cast to
uint16 with a dtype sidecar since numpy has no native bfloat16.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "//"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        out.append((_SEP.join(keys), leaf))
    return out


def save_pytree(path: str, tree, extra: Optional[Dict[str, Any]] = None
                ) -> None:
    arrays: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            dtypes[key] = "bfloat16"
            arr = arr.view(np.uint16)
        arrays[key] = arr
    meta = {"dtypes": dtypes, "extra": extra or {}}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, __meta__=np.frombuffer(
            json.dumps(meta).encode(), np.uint8), **arrays)
    os.replace(tmp, path)


def load_pytree(path: str, like=None) -> Tuple[Any, Dict[str, Any]]:
    """Load; if `like` is given, restore into its tree structure."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        arrays = {k: z[k] for k in z.files if k != "__meta__"}
    for k, dt in meta["dtypes"].items():
        if dt == "bfloat16":
            arrays[k] = arrays[k].view(jnp.bfloat16)
    if like is None:
        return arrays, meta["extra"]
    keys = [k for k, _ in _flatten_with_paths(like)]
    missing = [k for k in keys if k not in arrays]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    leaves = [jnp.asarray(arrays[k]) for k in keys]
    treedef = jax.tree.structure(like)
    return jax.tree.unflatten(treedef, leaves), meta["extra"]


class CheckpointManager:
    """Step-indexed checkpoints with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> str:
        p = self._path(step)
        save_pytree(p, tree, {**(extra or {}), "step": step})
        self._gc()
        return p

    def steps(self) -> List[int]:
        pat = re.compile(r"ckpt_(\d+)\.npz$")
        out = []
        for f in os.listdir(self.dir):
            m = pat.match(f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, like=None, step: Optional[int] = None):
        steps = self.steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        step = step if step is not None else steps[-1]
        return load_pytree(self._path(step), like)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            os.remove(self._path(s))
