"""Probe-differencing cost accounting.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE, so the scanned
production module under-reports FLOPs/bytes/collectives by the trip counts
(verified: a 10-iteration scan of a matmul reports 1x the matmul flops).

We therefore lower small UNROLLED probes at full width and difference them.
Cost is modelled as

    F(d, nmb) = A + B*nmb + C*d + D*d*nmb        (train)
    F(d)      = A + C*d                          (prefill / decode)

where d = number of layer *periods*, nmb = number of microbatches:
  A  fixed (optimizer on embed/head, bookkeeping)
  B  per-microbatch embed/loss fwd+bwd
  C  per-period optimizer update (+ per-period fixed)
  D  per-period per-microbatch fwd+bwd

Probes (each compiles in seconds because there is no while loop):
  train:   (d=1, m=1), (d=2, m=1), (d=1, m=2), (d=2, m=2)
  serve:   (d=1), (d=2)
plus tail probes (d=1+tail) when depth % period != 0 (recurrentgemma).
Every probe keeps the production per-microbatch token count, so D is exact
for the production batch geometry. The derived totals feed §Roofline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import RunConfig
from repro.models.blocks import period_of, split_periods
from repro.roofline.analysis import (collective_bytes_from_hlo,
                                     cost_analysis_dict)


def _probe_cfg(cfg: RunConfig, depth_periods: int, nmb: int,
               include_tail: bool = False) -> RunConfig:
    period_len = len(period_of(cfg.model))
    n_full, rem = split_periods(cfg.model)
    depth = depth_periods * period_len + (len(rem) if include_tail else 0)
    pmb_batch = cfg.shape.global_batch // max(1, cfg.parallel.microbatches)
    d = cfg.to_dict()
    d["model"]["num_layers"] = depth
    d["parallel"]["scan_layers"] = False
    d["parallel"]["unroll_microbatches"] = True
    d["parallel"]["microbatches"] = nmb
    if cfg.shape.mode == "train":
        d["shape"]["global_batch"] = pmb_batch * nmb
    return RunConfig.from_dict(d)


def _measure(cfg: RunConfig, mesh) -> Dict[str, float]:
    """Lower+compile one probe, return flops/bytes/collective bytes."""
    from repro.launch.dryrun import lower_one  # late import (env ordering)
    lowered, compiled, _ = lower_one(cfg, mesh)
    ca = cost_analysis_dict(compiled)
    coll = collective_bytes_from_hlo(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total"])}


def probe_costs(cfg: RunConfig, mesh) -> Dict[str, Dict[str, float]]:
    """Derive production-trip-count cost terms for cfg on mesh.

    Returns {"flops": {...}, "bytes": {...}, "coll": {...}} with keys
    A, B, C, D, total.
    """
    n_full, rem = split_periods(cfg.model)
    nmb = max(1, cfg.parallel.microbatches)
    train = cfg.shape.mode == "train"

    f11 = _measure(_probe_cfg(cfg, 1, 1), mesh)
    f21 = _measure(_probe_cfg(cfg, 2, 1), mesh)
    if train:
        f12 = _measure(_probe_cfg(cfg, 1, 2), mesh)
        f22 = _measure(_probe_cfg(cfg, 2, 2), mesh)
    tail = None
    if rem:
        t11 = _measure(_probe_cfg(cfg, 1, 1, include_tail=True), mesh)
        if train:
            t12 = _measure(_probe_cfg(cfg, 1, 2, include_tail=True), mesh)

    out: Dict[str, Dict[str, float]] = {}
    for key in ("flops", "bytes", "coll"):
        if train:
            D = f22[key] - f21[key] - f12[key] + f11[key]
            C = f21[key] - f11[key] - D
            B = f12[key] - f11[key] - D
            A = f11[key] - B - C - D
            total = A + B * nmb + C * n_full + D * n_full * nmb
            if rem:
                # tail delta vs the d=1 probe: m=1 gives C_t + D_t,
                # m=2 gives C_t + 2*D_t  =>  solve both tail terms
                Dt = (t12[key] - f12[key]) - (t11[key] - f11[key])
                Ct = (t11[key] - f11[key]) - Dt
                total += Ct + Dt * nmb
        else:
            D = 0.0
            B = 0.0
            C = f21[key] - f11[key]
            A = f11[key] - C
            total = A + C * n_full
            if rem:
                total += t11[key] - f11[key]
        # differencing can go slightly negative on near-zero terms
        # (compiler noise between probes); clamp — costs are nonnegative.
        out[key] = {"A": A, "B": B, "C": C, "D": D,
                    "total": max(total, 0.0)}
    return out
