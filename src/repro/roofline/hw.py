"""Target-hardware constants (TPU v5e, per chip)."""
from dataclasses import dataclass


@dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops_bf16: float     # FLOP/s
    hbm_bw: float              # bytes/s
    ici_bw_per_link: float     # bytes/s per link
    hbm_bytes: float
    vmem_bytes: float


TPU_V5E = HwSpec(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw_per_link=50e9,
    hbm_bytes=16 * 1024 ** 3,
    vmem_bytes=128 * 1024 ** 2,
)
