"""Roofline terms from a compiled dry-run artifact.

    compute_term    = per_chip_HLO_FLOPs / peak_FLOP/s
    memory_term     = per_chip_HLO_bytes_accessed / HBM_bw
    collective_term = per_chip_collective_bytes / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()``. The compiled module is
the post-GSPMD *per-device* program, so its totals are already per chip
(verified against a hand-computed sharded matmul). Collective bytes are NOT
in cost_analysis: they are summed from the optimized HLO text, one entry per
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
using the op's *output* tensor bytes as the wire-bytes convention
(documented in EXPERIMENTS.md §Roofline; ring-algorithm factors of
2(n-1)/n are ignored uniformly so comparisons between iterations are
apples-to-apples).
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.roofline.hw import TPU_V5E, HwSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """compiled.cost_analysis() returns a dict on jax >= 0.5 but a
    one-element list of dicts on 0.4.x — normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}

# e.g.  %ag = bf16[2,1024,512]{2,1,0} all-gather(...)
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum output-tensor bytes per collective kind from optimized HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for m in _SHAPE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        out[kind] += _shape_bytes(dtype, dims)
        out["count"] += 1
    # tuple-result collectives (multiple operands) — grab tuple elements
    tuple_re = re.compile(
        r"=\s*\(([^)]*)\)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(")
    elem_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for m in tuple_re.finditer(hlo_text):
        kind = m.group(2)
        for e in elem_re.finditer(m.group(1)):
            out[kind] += _shape_bytes(e.group(1), e.group(2))
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0            # 6*N*D (or 6*N_active*D for MoE)
    compute_term_s: float = 0.0
    memory_term_s: float = 0.0
    collective_term_s: float = 0.0
    arg_bytes_per_device: float = 0.0
    temp_bytes_per_device: float = 0.0
    out_bytes_per_device: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_term_s, "memory": self.memory_term_s,
                 "collective": self.collective_term_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS (global 6ND, divided per chip) / per-chip HLO FLOPs."""
        if not self.hlo_flops:
            return 0.0
        return (self.model_flops / self.chips) / self.hlo_flops

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["useful_flops_ratio"] = self.useful_flops_ratio
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)


def kernel_terms(compiled, hw: HwSpec = TPU_V5E) -> Dict[str, float]:
    """Roofline terms of ONE compiled kernel/program — the light-weight
    form the obs profiler (``repro.obs.profile``) feeds from jit artifacts:
    flops / bytes from ``cost_analysis`` plus the compute and memory terms
    against ``hw``.  No HLO parsing (single-device kernels have no
    collectives)."""
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes_accessed": byts,
            "compute_term_s": flops / hw.peak_flops_bf16,
            "memory_term_s": byts / hw.hbm_bw,
            "arithmetic_intensity": flops / byts if byts else 0.0}


def fused_boundary_terms(batch: int, features: int, *,
                         codec: str = "int8", hw: HwSpec = TPU_V5E,
                         compiled=None) -> Dict[str, float]:
    """Roofline terms for the fused boundary kernel
    (``kernels/boundary_fuse``): codec qdq + per-example clip + noise
    over one flattened ``(batch, features)`` crossing tensor.

    The analytic model follows the kernel's phase structure: the input
    streams from HBM once per grid phase (2 phases for ``fp16``/``none``,
    3 for ``int8`` — the extra amax pass), the noise tile is read once
    and the output written once, all fp32:

        bytes = (phases + 2) * 4 * B * N

    FLOP count is ~2 per element per phase (qdq multiply-round, square +
    accumulate, scale-and-fma) — small against the byte traffic; the
    fused stage is memory-bound by construction, which is exactly why
    fusing three traversals into one pays.  Pass ``compiled`` (a lowered
    ``fused_boundary_flat`` jit artifact) to merge the XLA-measured
    ``kernel_terms`` under ``measured_*`` keys.
    """
    phases = 3 if codec == "int8" else 2
    n = float(batch) * float(features)
    flops = 2.0 * phases * n
    byts = (phases + 2) * 4.0 * n
    out = {"codec": codec, "batch": float(batch),
           "features": float(features), "phases": float(phases),
           "flops": flops, "bytes_accessed": byts,
           "compute_term_s": flops / hw.peak_flops_bf16,
           "memory_term_s": byts / hw.hbm_bw,
           "arithmetic_intensity": flops / byts,
           # what fusing saves vs three separate traversals (codec pass +
           # clip-norm pass + scale/noise pass, each read+write)
           "unfused_bytes_accessed": 3.0 * 2.0 * 4.0 * n}
    if compiled is not None:
        out.update({f"measured_{k}": v
                    for k, v in kernel_terms(compiled, hw).items()})
    return out


_WIRE_BYTES = {"none": 4.0, "fp16": 2.0, "int8": 1.0}


def agg_fuse_terms(num_clients: int, n: int, *, codec: str = "int8",
                   hw: HwSpec = TPU_V5E, compiled=None) -> Dict[str, float]:
    """Roofline terms for the fused dequant-reduce aggregation kernel
    (``kernels/agg_fuse``): ``num_clients`` compressed client wires of
    ``n`` elements -> one fp32 weighted mean, per-client scales applied
    inside the grid and the running sum held in a persistent VMEM
    accumulator.

    The fused kernel streams each wire from HBM exactly once at its WIRE
    dtype width (1 B ``int8``, 2 B ``fp16``, 4 B ``none``), reads the
    tiny ``(C, 2)`` weight*scale coefficient tile, and writes the fp32
    aggregate once:

        bytes = wire_b * C * N + 8 * C + 4 * N

    The decode-then-reduce baseline pays the same wire reads plus a full
    fp32 materialization per client (decode writes ``4*C*N``) that the
    reduce then reads back (``4*C*N``) — the ``unfused_bytes_accessed``
    key quantifies that, and its ratio to ``bytes_accessed`` is the
    memory-bound speedup ceiling the ``agg`` bench section measures.
    FLOPs are ~3 per element (dequant multiply, weight multiply,
    accumulate) — negligible against the traffic, so the reduce is
    memory-bound and fusion pays the full traversal saving.  Pass
    ``compiled`` (a lowered ``dequant_reduce_flat`` jit artifact) to
    merge XLA-measured ``kernel_terms`` under ``measured_*`` keys.
    """
    wire_b = _WIRE_BYTES.get(codec, 4.0)
    c, nn = float(num_clients), float(n)
    flops = 3.0 * c * nn
    byts = wire_b * c * nn + 8.0 * c + 4.0 * nn
    out = {"codec": codec, "num_clients": c, "n": nn,
           "wire_bytes_per_elem": wire_b,
           "flops": flops, "bytes_accessed": byts,
           "compute_term_s": flops / hw.peak_flops_bf16,
           "memory_term_s": byts / hw.hbm_bw,
           "arithmetic_intensity": flops / byts,
           # decode-then-reduce: wire reads + fp32 decode writes + fp32
           # reduce read-back + aggregate write
           "unfused_bytes_accessed": wire_b * c * nn + 8.0 * c * nn
                                     + 4.0 * nn}
    if compiled is not None:
        out.update({f"measured_{k}": v
                    for k, v in kernel_terms(compiled, hw).items()})
    return out


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float,
                     hw: HwSpec = TPU_V5E,
                     hlo_text: Optional[str] = None) -> RooflineReport:
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes_from_hlo(text)
    mem = compiled.memory_analysis()
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        collective_bytes=float(coll["total"]), collectives=coll,
        model_flops=model_flops,
        compute_term_s=flops / hw.peak_flops_bf16,
        memory_term_s=byts / hw.hbm_bw,
        # per-chip wire bytes: collectives are already per-participant in
        # the SPMD module (shapes are per-shard), links per chip ~= 4 on a
        # 2D torus; use one link as the conservative convention.
        collective_term_s=float(coll["total"]) / hw.ici_bw_per_link,
        arg_bytes_per_device=getattr(mem, "argument_size_in_bytes", 0),
        temp_bytes_per_device=getattr(mem, "temp_size_in_bytes", 0),
        out_bytes_per_device=getattr(mem, "output_size_in_bytes", 0),
    )
    return rep
