from repro.sharding.specs import (  # noqa: F401
    LOGICAL, AxisRules, Lg, default_rules, is_lg, logical_spec,
    mesh_axis_size, spec_for_param, tree_shardings,
)
