"""Logical-axis sharding rules.

Parameters and activations are annotated with *logical* axis names; a single
rules table maps them to mesh axes. Mapping is skipped (replicated) whenever
the dimension size does not divide the mesh-axis extent — GSPMD then
propagates a layout instead of failing to shard.

Logical axes
------------
  embed    d_model dim                -> FSDP over ("pod","data") when enabled
  mlp      ffn hidden / fused q_dim   -> tensor-parallel over "model"
  kv       fused kv_dim               -> "model" when divisible
  experts  MoE expert dim             -> expert-parallel over "model"
  vocab    vocabulary dim             -> "model"
  batch    global batch               -> ("pod","data")
  seq      sequence (activations)     -> "model" when sequence_parallel
  layers/stack/conv/...               -> replicated
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

LOGICAL = ("embed", "mlp", "kv", "experts", "vocab", "batch", "seq",
           "heads", "state", "layers", "window", "clients", None)


@dataclass
class AxisRules:
    """Map from logical axis name -> mesh axis (or tuple of axes)."""
    rules: Dict[str, MeshAxes] = field(default_factory=dict)
    fsdp: bool = True
    tensor_parallel: bool = True
    sequence_parallel: bool = True

    def mesh_axes_for(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.rules.get(logical)


def default_rules(mesh: Mesh, parallel=None) -> AxisRules:
    """Production layout: batch/FSDP over (pod,data), TP/EP over model."""
    axes = list(mesh.axis_names)
    data_axes: Tuple[str, ...] = tuple(a for a in ("pod", "data") if a in axes)
    model = "model" if "model" in axes else None
    fsdp = parallel.fsdp if parallel is not None else True
    tp = parallel.tensor_parallel if parallel is not None else True
    sp = parallel.sequence_parallel if parallel is not None else True
    rules: Dict[str, MeshAxes] = {
        "batch": data_axes or None,
        "embed": data_axes if fsdp else None,
        "mlp": model if tp else None,
        "kv": model if tp else None,
        "heads": model if tp else None,
        "experts": model if tp else None,
        "vocab": model if tp else None,
        "seq": model if sp else None,
        "state": None,
        "layers": None,
        "window": None,
    }
    return AxisRules(rules=rules, fsdp=fsdp, tensor_parallel=tp,
                     sequence_parallel=sp)


def mesh_axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def logical_spec(mesh: Mesh, rules: AxisRules, shape: Sequence[int],
                 logical: Sequence[Optional[str]]) -> P:
    """Build a PartitionSpec, dropping any axis that doesn't divide evenly."""
    assert len(shape) == len(logical), (shape, logical)
    out = []
    used: set = set()
    for dim, name in zip(shape, logical):
        ax = rules.mesh_axes_for(name)
        if ax is None:
            out.append(None)
            continue
        ax_t = (ax,) if isinstance(ax, str) else tuple(ax)
        ax_t = tuple(a for a in ax_t if a not in used)
        if not ax_t or dim % mesh_axis_size(mesh, ax_t) != 0:
            out.append(None)
            continue
        used.update(ax_t)
        out.append(ax_t[0] if len(ax_t) == 1 else ax_t)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_for_param(mesh: Mesh, rules: AxisRules, arr_or_shape,
                   logical: Sequence[Optional[str]]) -> NamedSharding:
    shape = getattr(arr_or_shape, "shape", arr_or_shape)
    return NamedSharding(mesh, logical_spec(mesh, rules, shape, logical))


# ---------------------------------------------------------------------------
# Activation-sharding policy hook
#
# Model code is mesh-agnostic; the runtime installs a policy that maps
# logical activation axes to with_sharding_constraint calls. Without a
# policy, constrain() is the identity and GSPMD propagates layouts freely.
# ---------------------------------------------------------------------------

_ACTIVATION_POLICY = None


def set_activation_policy(fn) -> None:
    """fn(x, logical_axes: tuple) -> x, or None to clear."""
    global _ACTIVATION_POLICY
    _ACTIVATION_POLICY = fn


def constrain(x, logical_axes):
    if _ACTIVATION_POLICY is None:
        return x
    return _ACTIVATION_POLICY(x, logical_axes)


def make_activation_policy(mesh: Mesh, rules: "AxisRules"):
    def policy(x, logical_axes):
        spec = logical_spec(mesh, rules, x.shape, logical_axes)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return policy


class Lg(tuple):
    """A tuple of logical axis names used as a *leaf* in spec trees."""
    def __new__(cls, *names):
        return super().__new__(cls, names)


def is_lg(x) -> bool:
    return isinstance(x, Lg)


def tree_shardings(mesh: Mesh, rules: AxisRules, params_tree, logical_tree):
    """Zip a tree of arrays/ShapeDtypeStructs with a matching tree of Lg leaves."""
    flat_p, tdef_p = jax.tree.flatten(params_tree)
    flat_l, tdef_l = jax.tree.flatten(logical_tree, is_leaf=is_lg)
    if tdef_p != jax.tree.structure(jax.tree.unflatten(tdef_l, flat_l)):
        # Structures must match one-to-one; a mismatch is a modelling bug.
        raise ValueError(
            f"param/spec tree mismatch:\n  params: {tdef_p}\n  specs:  {tdef_l}")
    shardings = [spec_for_param(mesh, rules, p, l) for p, l in zip(flat_p, flat_l)]
    return jax.tree.unflatten(tdef_p, shardings)


# ---------------------------------------------------------------------------
# Stacked client-axis sharding (federation runtime)
#
# The vectorized client program (fed/programs.LocalProgram.run_vectorized)
# stacks every per-client tree/batch along a leading `clients` axis.  These
# helpers place those stacked trees on a 1-D `clients` mesh
# (launch/mesh.make_client_mesh): dim 0 is the `clients` logical axis, all
# other dims replicate, and — per logical_spec's policy — a client count
# that does not divide the mesh replicates instead of failing.
# ---------------------------------------------------------------------------

def client_axis_rules(mesh: Mesh, axis: str = "clients") -> AxisRules:
    """Rules mapping the `clients` logical axis onto ``axis`` of ``mesh``
    (replicated when the mesh has no such axis)."""
    ax = axis if axis in mesh.axis_names else None
    return AxisRules(rules={"clients": ax})


def stacked_shardings(mesh: Mesh, tree, *, axis: str = "clients",
                      rules: Optional[AxisRules] = None):
    """NamedShardings for a stacked per-client tree: every leaf's leading
    dim is the `clients` logical axis, the rest replicate.  Works for
    parameter/optimizer stacks and (C, T, B, ...) batch arrays alike."""
    rules = client_axis_rules(mesh, axis) if rules is None else rules
    logical = jax.tree.map(
        lambda l: Lg(*(("clients",) + (None,) * (l.ndim - 1))), tree)
    return tree_shardings(mesh, rules, tree, logical)
