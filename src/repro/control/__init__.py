"""Closed-loop control plane: per-round controllers over measured feedback.

feedback.py    — :class:`RoundFeedback` (one typed record per round, fed by
                 every measuring layer) + :class:`ControlKnobs` (everything
                 a controller may turn).
controllers.py — the :class:`Controller` protocol, the codec / sigma /
                 split / deadline controllers, :class:`ControllerSuite`,
                 and the config-keyed factory :func:`make_controllers`.

The trainer (core/gan.py) emits a ``RoundFeedback`` after every round and,
under ``cfg.control.mode='adaptive'``, consults the suite between rounds —
``knobs = suite(feedback_history, knobs)`` — applying the diff to the
engine (codec, deadline), the privacy stack (sigma), and the split planner
(strategy, per-boundary stages).  ``mode='frozen'`` (default) applies
nothing and stays bit-exact with the static build.
"""
from repro.control.controllers import (CodecController, Controller,
                                       ControllerSuite, DeadlineController,
                                       SigmaController, SplitController,
                                       make_controllers)
from repro.control.feedback import (ControlKnobs, RoundFeedback,
                                    knobs_from_config)

__all__ = [
    "CodecController", "Controller", "ControllerSuite", "ControlKnobs",
    "DeadlineController", "RoundFeedback", "SigmaController",
    "SplitController", "knobs_from_config", "make_controllers",
]
