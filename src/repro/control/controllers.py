"""Per-round controllers: measured feedback in, knob decisions out.

Each controller is a pure function over the round history —
``controller(history, knobs) -> knobs`` — behind the :class:`Controller`
protocol; :class:`ControllerSuite` chains them in a fixed order.  Purity is
the point: a controller holds tuning constants, never engine state, so
decisions are replayable from the feedback log alone and a controller can
be unit-tested against a synthetic history.

  * :class:`CodecController`    — walks the bytes-vs-delta-error frontier
    cheapest-codec-first (wire bytes are ANALYTIC per codec —
    ``fed/transport.predict_codec_bytes`` — only the error needs live
    probing), committing to the cheapest codec whose measured error fits
    the budget.  Probing cheapest-first is what makes the adaptive run's
    total bytes <= the best static codec's: every probe is cheaper than
    the codec it ends up committing to.
  * :class:`SigmaController`    — replays the accountant's spend from the
    feedback log and bisects the RDP epsilon curve
    (``RDPAccountant.projected_epsilon``) for the smallest sigma that keeps
    the whole remaining horizon inside the ``(epsilon, delta)`` budget.
    Solved fresh every round, so early over-estimates self-correct and the
    budget is never exceeded (pinned).
  * :class:`SplitController`    — replans device selection when measured
    load imbalance drifts past a threshold, and assigns the leaky stage
    only to boundary indices whose measured dCor exceeds the leakage
    threshold (SplitEasy / split-leakage motivation: noise what the attack
    actually reads).
  * :class:`DeadlineController` — sets the sync straggler deadline at a
    quantile of the measured per-client finish-time distribution.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

from repro.control.feedback import ControlKnobs, RoundFeedback
from repro.fed.transport import predict_codec_bytes
from repro.privacy.defenses import RDPAccountant, min_feasible_sigma


class Controller(Protocol):
    """One knob's decision rule: pure over the feedback history."""
    name: str

    def __call__(self, history: List[RoundFeedback],
                 knobs: ControlKnobs) -> ControlKnobs: ...


class ControllerSuite:
    """Chains controllers in order; each sees the previous one's knobs."""

    def __init__(self, controllers: Sequence[Controller]):
        self.controllers = list(controllers)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(c.name for c in self.controllers)

    def __call__(self, history: List[RoundFeedback],
                 knobs: ControlKnobs) -> ControlKnobs:
        for c in self.controllers:
            knobs = c(history, knobs)
        return knobs


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------

class CodecController:
    """Cheapest-first frontier walk over the candidate codecs.

    Candidates are ranked by their ANALYTIC wire bytes for this uplink tree
    (``predict_codec_bytes``); each round the controller walks that ranking
    and picks the first codec that is either unprobed (probe it — its error
    is the one unknown) or measured within ``error_budget`` (commit).  A
    committed codec whose error later drifts over budget is walked past
    automatically.  ``target_uplink_s`` short-circuits to lossless when the
    measured bandwidth ships the native tree inside the target.
    """
    name = "codec"

    def __init__(self, candidates: Sequence[str], error_budget: float,
                 leaf_sizes: Sequence[int], *, topk_frac: float = 0.01,
                 target_uplink_s: float = 0.0):
        self.error_budget = float(error_budget)
        self.target_uplink_s = float(target_uplink_s)
        self.topk_frac = float(topk_frac)
        self.bytes_of = {
            name: predict_codec_bytes(name, leaf_sizes,
                                      topk_frac=self.topk_frac)
            for name in dict.fromkeys(candidates)}   # dedup, keep order
        self.ranked = sorted(self.bytes_of, key=self.bytes_of.get)

    def __call__(self, history: List[RoundFeedback],
                 knobs: ControlKnobs) -> ControlKnobs:
        # latest measured error per codec ("none" is lossless by
        # construction); rounds with no landed uplink measure nothing.
        # Round 0 has no history: the walk below starts probing at the
        # cheapest candidate immediately.
        seen: Dict[str, float] = {"none": 0.0}
        for fb in history:
            if not math.isnan(fb.codec_error):
                seen[fb.codec] = fb.codec_error
        bps = history[-1].uplink_bps if history else 0.0
        if (self.target_uplink_s > 0 and bps > 0 and "none" in self.bytes_of
                and 8.0 * self.bytes_of["none"] / bps <= self.target_uplink_s):
            return knobs.replace(codec="none", topk_frac=self.topk_frac)
        for cand in self.ranked:
            if cand not in seen or seen[cand] <= self.error_budget:
                return knobs.replace(codec=cand, topk_frac=self.topk_frac)
        # every candidate measured over budget: best-effort WITHIN the
        # user's candidate list — the most expensive (least lossy) one,
        # never a codec the config deliberately excluded
        return knobs.replace(codec=self.ranked[-1],
                             topk_frac=self.topk_frac)


# ---------------------------------------------------------------------------
# sigma
# ---------------------------------------------------------------------------

class SigmaController:
    """Spend a total ``(epsilon_budget, delta)`` over ``horizon_rounds``.

    Replays the realized spend — (dp_steps, sigma) per past round — into a
    fresh accountant, then bisects ``projected_epsilon`` for the smallest
    sigma under which the REMAINING rounds (at the projected steps/round)
    still land inside the budget.  Because every round re-solves with the
    realized spend, and the bisection only ever returns budget-feasible
    sigmas, the cumulative epsilon never crosses the budget (pinned
    against the accountant in tests) — provided the budget is REACHABLE
    (at least the horizon's spend at ``sigma_max``; an unreachable budget
    clamps to ``sigma_max``, the most noise it can buy, and overspends by
    construction) and the round length never exceeds the projection
    (steps/round is projected as the max of the hint and every observed
    round, so only growing a round PAST the historical maximum can
    overshoot).  Shrinking sigma by less than ``rel_change`` is skipped
    (hysteresis) to bound DP-SGD recompiles; noise INCREASES are always
    applied — hysteresis must never relax the budget.
    """
    name = "sigma"

    def __init__(self, epsilon_budget: float, horizon_rounds: int,
                 delta: float = 1e-5, sample_rate: float = 1.0, *,
                 steps_per_round_hint: int = 1, sigma_min: float = 1e-2,
                 sigma_max: float = 1e4, rel_change: float = 0.05):
        self.budget = float(epsilon_budget)
        self.horizon = int(horizon_rounds)
        self.delta = float(delta)
        self.sample_rate = float(sample_rate)
        self.steps_hint = max(1, int(steps_per_round_hint))
        self.sigma_min = float(sigma_min)
        self.sigma_max = float(sigma_max)
        self.rel_change = float(rel_change)

    def _solve(self, acct: RDPAccountant, steps: int) -> float:
        # the shared property-tested inverter; infeasible budgets clamp to
        # sigma_max (maximum protection) by its contract
        return min_feasible_sigma(
            lambda s: acct.projected_epsilon(steps, self.delta, s)
            <= self.budget,
            self.sigma_min, self.sigma_max)

    def __call__(self, history: List[RoundFeedback],
                 knobs: ControlKnobs) -> ControlKnobs:
        if self.budget <= 0 or self.horizon <= 0:
            return knobs
        acct = RDPAccountant(max(knobs.sigma, self.sigma_min),
                             self.sample_rate)
        # project with the LARGEST round seen (or hinted): a conservative
        # steps/round keeps the feasibility check sound when round lengths
        # fluctuate below their historical maximum
        steps_per_round = self.steps_hint
        for fb in history:
            if fb.dp_steps > 0:
                acct.step(fb.dp_steps, noise_multiplier=fb.sigma)
                steps_per_round = max(steps_per_round, fb.dp_steps)
        remaining = max(1, self.horizon - len(history))
        sigma = self._solve(acct, remaining * steps_per_round)
        if (sigma < knobs.sigma
                and (knobs.sigma - sigma) / knobs.sigma < self.rel_change):
            return knobs                   # hysteresis: only skip DECREASES
        return knobs.replace(sigma=sigma)


# ---------------------------------------------------------------------------
# split
# ---------------------------------------------------------------------------

class SplitController:
    """Replan the split when the measurements drift.

    Load rule: when max/mean measured device load exceeds
    ``imbalance_threshold``, switch the selection strategy to
    ``replan_strategy`` (the paper's sorted_multi winner) — a plan-level
    regroup, re-run through ``core/selection``.

    Leakage rule: per boundary INDEX, take the worst measured dCor across
    clients; indices above ``dcor_threshold`` get ``leaky_stage`` (dp
    clip+noise by default), the rest keep the config's base stage — noise
    goes only where the attack actually reads.
    """
    name = "split"

    def __init__(self, *, imbalance_threshold: float = 2.0,
                 dcor_threshold: float = 0.5,
                 replan_strategy: str = "sorted_multi",
                 leaky_stage: str = "dp", base_stage: str = "identity"):
        self.imbalance_threshold = float(imbalance_threshold)
        self.dcor_threshold = float(dcor_threshold)
        self.replan_strategy = replan_strategy
        self.leaky_stage = leaky_stage
        self.base_stage = base_stage or "identity"

    def __call__(self, history: List[RoundFeedback],
                 knobs: ControlKnobs) -> ControlKnobs:
        if not history:
            return knobs
        last = history[-1]
        loads = list(last.device_loads.values())
        if len(loads) > 1:
            mean = sum(loads) / len(loads)
            if (mean > 0 and max(loads) / mean > self.imbalance_threshold
                    and knobs.split_strategy != self.replan_strategy):
                knobs = knobs.replace(split_strategy=self.replan_strategy)
        if last.boundary_dcor:
            worst: Dict[int, float] = {}
            for dcors in last.boundary_dcor.values():
                for b, v in enumerate(dcors):
                    worst[b] = max(worst.get(b, 0.0), float(v))
            stage_map = {b: (self.leaky_stage if v > self.dcor_threshold
                             else self.base_stage)
                         for b, v in worst.items()}
            # all-base == the uniform config stage: normalize to None so a
            # no-leak round never registers as a knob change (a map diff
            # triggers a full split-program regroup + engine reprice)
            if all(s == self.base_stage for s in stage_map.values()):
                stage_map = None
            old_map = (dict(knobs.stage_by_boundary)
                       if knobs.stage_by_boundary is not None else None)
            if stage_map != old_map:
                knobs = knobs.replace(stage_by_boundary=stage_map)
        return knobs


# ---------------------------------------------------------------------------
# deadline
# ---------------------------------------------------------------------------

class DeadlineController:
    """Sync straggler deadline from the measured finish-time distribution.

    Takes the ``quantile`` of all per-client virtual finish times over the
    last ``window`` rounds and stretches it by ``slack`` — clients inside
    the bulk of the distribution land, tail stragglers are cut.  Needs
    ``warmup`` rounds of feedback before the first decision; small
    (<5% relative) retunes are skipped.

    Pipelining-aware: when the pipelined split executor changes K between
    rounds, historical finish times were measured under a different
    overlap schedule.  Each round's times are rescaled by
    ``fb.pipeline_speedup / current.pipeline_speedup`` — the analytic
    sequential/pipelined ratio the schedule emitted (finish time scales
    inversely with it) — so the quantile is taken over a distribution
    expressed in *current-schedule* seconds.  With K fixed the ratio is
    1 everywhere and the controller is bit-identical to before.
    """
    name = "deadline"

    def __init__(self, *, quantile: float = 0.9, slack: float = 1.25,
                 warmup: int = 1, window: int = 5):
        self.quantile = float(quantile)
        self.slack = float(slack)
        self.warmup = int(warmup)
        self.window = int(window)

    def __call__(self, history: List[RoundFeedback],
                 knobs: ControlKnobs) -> ControlKnobs:
        if len(history) < self.warmup:
            return knobs
        cur = getattr(history[-1], "pipeline_speedup", 1.0) or 1.0
        times = sorted(
            t * (getattr(fb, "pipeline_speedup", 1.0) or 1.0) / cur
            for fb in history[-self.window:]
            for t in fb.client_finish_s.values())
        if not times:
            return knobs
        idx = min(len(times) - 1,
                  max(0, int(math.ceil(self.quantile * len(times))) - 1))
        deadline = times[idx] * self.slack
        if knobs.deadline_s > 0 and \
                abs(deadline - knobs.deadline_s) / knobs.deadline_s < 0.05:
            return knobs
        return knobs.replace(deadline_s=deadline)


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------

def make_controllers(cfg, *, leaf_sizes: Sequence[int],
                     steps_per_round_hint: int = 1) -> ControllerSuite:
    """cfg (RunConfig) -> the suite named by ``cfg.control.controllers``.

    ``leaf_sizes``: leaf element counts of the uplinked tree (codec byte
    prediction); ``steps_per_round_hint``: expected DP releases per round
    before the first feedback arrives (sigma controller).
    """
    ctl = cfg.control
    order = {"codec": 0, "sigma": 1, "split": 2, "deadline": 3}
    built: List[Controller] = []
    for name in sorted(dict.fromkeys(ctl.controllers), key=order.get):
        if name == "codec":
            built.append(CodecController(
                ctl.codec_candidates, ctl.error_budget, leaf_sizes,
                topk_frac=cfg.fed.topk_frac,
                target_uplink_s=ctl.target_uplink_s))
        elif name == "sigma":
            built.append(SigmaController(
                ctl.epsilon_budget, ctl.horizon_rounds, cfg.privacy.delta,
                cfg.privacy.sample_rate,
                steps_per_round_hint=steps_per_round_hint,
                sigma_min=ctl.sigma_min, sigma_max=ctl.sigma_max,
                rel_change=ctl.sigma_rel_change))
        elif name == "split":
            built.append(SplitController(
                imbalance_threshold=ctl.imbalance_threshold,
                dcor_threshold=ctl.dcor_threshold,
                replan_strategy=ctl.replan_strategy,
                leaky_stage=ctl.leaky_stage,
                base_stage=cfg.split.boundary_stage))
        elif name == "deadline":
            built.append(DeadlineController(
                quantile=ctl.deadline_quantile, slack=ctl.deadline_slack,
                warmup=ctl.warmup_rounds))
    return ControllerSuite(built)
