"""Typed per-round feedback and knob records — the control plane's wire.

Every layer of the engine already *measures*: the :class:`~repro.fed.
transport.TrafficLedger` counts WAN/LAN bytes, the engine prices per-client
virtual finish times and the codec's delta error, the accountant tracks the
(epsilon, delta) spend, the split execution measures per-device load and the
privacy subsystem's dCor probes measure per-boundary leakage.  This module
gives all of that ONE typed record per round — :class:`RoundFeedback` —
instead of ad-hoc trainer metric dicts, and one typed record for the knobs a
controller may turn — :class:`ControlKnobs`.

The contract: controllers are pure functions
``(history: list[RoundFeedback], knobs: ControlKnobs) -> ControlKnobs``
(see controllers.py).  The trainer assembles a ``RoundFeedback`` after every
round (``control.mode='frozen'`` included — measurement is free; only knob
*application* is gated) and applies knob diffs before the next one.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Mapping, Optional, Tuple


@dataclass(frozen=True)
class ControlKnobs:
    """Everything a controller may turn between rounds.

    Seeded from the static config (:func:`knobs_from_config`); the frozen
    mode never changes it, so the static path stays bit-exact.
    """
    codec: str = "none"                # uplink codec (fed/transport)
    topk_frac: float = 0.01
    sigma: float = 0.0                 # DP noise multiplier (both modes)
    deadline_s: float = 0.0            # sync straggler deadline (0 = off)
    split_strategy: str = "sorted_multi"   # core/selection replanning
    # per-boundary stage override: boundary index -> stage name; None keeps
    # the uniform cfg.split.boundary_stage.  Plans with more boundaries
    # than the map fall back to the config stage at the unlisted indices.
    stage_by_boundary: Optional[Mapping[int, str]] = None

    def replace(self, **kw) -> "ControlKnobs":
        return replace(self, **kw)


@dataclass(frozen=True)
class RoundFeedback:
    """One round's measurements, as the controllers consume them.

    Which controller reads what:

      * codec controller    — ``codec``/``up_bytes``/``codec_error``
                              (the bytes-vs-delta-error frontier) +
                              ``uplink_bps`` (measured bandwidth);
      * sigma controller    — ``sigma``/``dp_steps``/``dp_epsilon``
                              (replays the accountant's spend);
      * split controller    — ``device_loads`` (imbalance drift) +
                              ``boundary_dcor`` (leakage drift);
      * deadline controller — ``client_finish_s`` (the measured round-time
                              distribution) + ``stragglers``.
    """
    round_index: int
    backend: str
    # knobs in force during this round
    codec: str
    sigma: float
    deadline_s: float
    split_strategy: str
    # measured wire (TrafficLedger, this round)
    up_bytes: int
    down_bytes: int
    lan_bytes: int
    codec_error: float                 # mean rel-L2 delta error (nan: none ran)
    uplink_bps: float
    # measured time (virtual clock)
    round_time_s: float
    clock_s: float
    client_finish_s: Mapping[str, float] = field(default_factory=dict)
    # participation
    num_clients: int = 0
    stragglers: int = 0
    # training + privacy
    d_loss: float = float("nan")
    g_loss: float = float("nan")
    dp_epsilon: float = float("nan")   # cumulative spend after this round
    dp_steps: int = 0                  # mechanism releases this round
    # split measurements.  boundary_dcor is the RAW (pre-stage) smashed
    # activation's dCor — the boundary's intrinsic leak, a stable control
    # signal regardless of what stage currently protects it (post-stage
    # leakage is the attack suite's measurement, not the controller's).
    device_loads: Mapping[str, float] = field(default_factory=dict)
    boundary_dcor: Mapping[str, Tuple[float, ...]] = field(
        default_factory=dict)          # per split client, per boundary idx
    # pipelined split execution (core/pipeline): micro-batches per batch
    # in force this round, and the mean analytic sequential/pipelined
    # per-batch ratio across split clients (1.0 when not pipelined).
    # The deadline controller rescales historical finish times by this
    # ratio when K changes between rounds.
    pipeline_microbatches: int = 1
    pipeline_speedup: float = 1.0
    # backend="auto": dispatch probe wall-times (µs per backend) from
    # the round that ran the probe; empty otherwise
    backend_probe_us: Mapping[str, float] = field(default_factory=dict)
    # population-scale topology in force this round: client->edge bytes
    # (the pre-reduce hop; 0 on the flat path), edge cohorts (0/1 = flat
    # single-tier), and `clients`-mesh shards the vectorized dispatch
    # placed stacked inputs across (1 = single-device).
    edge_bytes: int = 0
    cohorts: int = 0
    shards: int = 1

    def summary(self) -> Dict[str, object]:
        """Compact printable view (the demos use this as schema docs)."""
        return {
            "round": self.round_index,
            "codec": self.codec,
            "sigma": self.sigma,
            "deadline_s": round(self.deadline_s, 3),
            "split_strategy": self.split_strategy,
            "up_bytes": self.up_bytes,
            "lan_bytes": self.lan_bytes,
            "edge_bytes": self.edge_bytes,
            "codec_error": self.codec_error,
            "round_time_s": round(self.round_time_s, 3),
            "num_clients": self.num_clients,
            "stragglers": self.stragglers,
            "dp_epsilon": self.dp_epsilon,
            "device_loads": dict(self.device_loads),
            "boundary_dcor": {k: tuple(round(v, 3) for v in vs)
                              for k, vs in self.boundary_dcor.items()},
        }


def knobs_from_config(cfg) -> ControlKnobs:
    """The static config as the initial knob state (frozen mode keeps it)."""
    return ControlKnobs(
        codec=cfg.fed.codec,
        topk_frac=cfg.fed.topk_frac,
        sigma=cfg.privacy.noise_multiplier,
        deadline_s=cfg.fed.deadline_s,
        split_strategy=cfg.split.strategy or cfg.fsl.selection,
        stage_by_boundary=None)
