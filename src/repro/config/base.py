"""Configuration system for the FSL-GAN framework.

Plain dataclasses (no external deps) with:
  - nested to_dict / from_dict round-tripping,
  - dotted-path CLI overrides (``--set model.d_model=512``),
  - validation hooks,
  - derived-quantity helpers (param counts, per-family feature flags).

Every assigned architecture is expressed as a :class:`RunConfig`; reduced
"smoke" variants are produced by :func:`reduce_for_smoke`.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field, fields
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------

DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"
DCGAN = "dcgan"

FAMILIES = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO, DCGAN)

# Attention kinds
ATTN_FULL = "full"            # causal full attention
ATTN_SLIDING = "sliding"      # sliding-window causal attention
ATTN_NONE = "none"            # attention-free (e.g. RWKV)

# ---------------------------------------------------------------------------
# Valid knob names — the single source of truth.
#
# Runtime factories (fed/transport.make_codec, core/split.make_boundary_stage,
# core/selection.STRATEGIES, fed/programs.BACKENDS) key off these same names;
# validating HERE means a typo'd config fails at construction with the list of
# valid options instead of deep inside a jitted program.
# ---------------------------------------------------------------------------

CODECS = ("none", "fp16", "int8", "topk")
# "+"-composed names chain stages in order (codec round-trip, then
# clip+noise); core/split.make_boundary_stage fuses the fusable ones
# (fp16+dp, int8+dp) into the single-traversal kernels/boundary_fuse op.
BOUNDARY_STAGES = ("identity", "fp16", "int8", "topk", "dp",
                   "fp16+dp", "int8+dp", "topk+dp")
SELECTION_STRATEGIES = ("random_single", "random_multi", "sorted_single",
                        "sorted_multi")
FED_MODES = ("sync", "fedasync", "fedbuff")
# "auto" probes loop vs vectorized dispatch once on the first round and
# pins the faster one (core/gan.FSLGANTrainer); fed/programs.BACKENDS
# stays ("loop", "vectorized") — the executor never sees "auto".
FED_BACKENDS = ("loop", "vectorized", "auto")
# server-side reduce over landed uplinks (fed/engine + fed/aggregate):
# "decode" stages one decoded fp32 tree per client then FedAvgs (the
# bit-exact reference); "stream" folds each WIRE payload into one fp32
# accumulator via kernels/agg_fuse as it lands (O(1) server memory);
# "batched" stacks wire payloads per leaf and reduces them in one fused
# call (vmapped decode for top-k), sharded when fed.shard_clients is on.
SERVER_REDUCES = ("decode", "stream", "batched")
PRIVACY_MODES = ("dp_sgd", "uplink")
CONTROL_MODES = ("frozen", "adaptive")
CONTROLLERS = ("codec", "sigma", "split", "deadline")
OBS_TRACE_CLOCKS = ("virtual", "wall", "both")
OBS_SINKS = ("trace", "metrics", "feedback", "alerts", "digests")
# what a fatal health verdict does to the run (obs/health.py)
HEALTH_POLICIES = ("record", "warn", "abort", "rollback")


def _check_name(section: str, field_name: str, value: str,
                valid: Tuple[str, ...], *, aliases: Tuple[str, ...] = ()
                ) -> None:
    """Construction-time name validation with the valid options spelled out."""
    if value in valid or value in aliases:
        return
    raise ValueError(
        f"{section}.{field_name}={value!r} is not a valid option; "
        f"expected one of {list(valid)}")


@dataclass
class MoEConfig:
    """Mixture-of-Experts settings (DeepSeek-V2-Lite, OLMoE)."""
    num_experts: int = 0                  # routed experts
    num_shared_experts: int = 0           # always-on experts (DeepSeek)
    top_k: int = 0
    d_ff_expert: int = 0                  # per-expert hidden dim
    router_aux_coef: float = 0.01         # load-balance loss coefficient
    router_jitter: float = 0.0
    capacity_factor: float = 0.0          # 0 => dropless (dense one-hot dispatch)

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 0                 # compressed KV latent dim (512 for V2-Lite)
    q_lora_rank: int = 0                  # 0 => full-rank queries (V2-Lite)
    rope_head_dim: int = 64               # decoupled rope sub-dim per head
    v_head_dim: int = 0                   # value head dim (defaults to head_dim)

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass
class RWKVConfig:
    """RWKV-6 ("Finch") settings."""
    head_dim: int = 64
    decay_lora: int = 64                  # lora rank of data-dependent decay
    token_shift_lora: int = 32            # lora rank of ddlerp token-shift
    gate_lora: int = 64

    @property
    def enabled(self) -> bool:
        return self.head_dim > 0


@dataclass
class RGLRUConfig:
    """RecurrentGemma RG-LRU + local-attention hybrid settings."""
    lru_width: int = 0                    # recurrent width (d_model if 0)
    conv_width: int = 4                   # temporal conv1d width in recurrent block
    window: int = 2048                    # local-attention window
    pattern: Tuple[str, ...] = ()         # e.g. ("rglru","rglru","attn") repeated

    @property
    def enabled(self) -> bool:
        return bool(self.pattern)


@dataclass
class EncDecConfig:
    """Encoder-decoder (whisper) settings; the conv/mel frontend is a stub."""
    encoder_layers: int = 0
    encoder_seq: int = 1500               # whisper: 30 s -> 1500 frames after conv
    max_target_positions: int = 448

    @property
    def enabled(self) -> bool:
        return self.encoder_layers > 0


@dataclass
class DCGANConfig:
    """The paper's own model: DCGAN with 3 conv blocks (Radford et al. 2016)."""
    image_size: int = 28
    channels: int = 1
    latent_dim: int = 100
    base_filters: int = 64
    conv_blocks: int = 3

    @property
    def enabled(self) -> bool:
        return self.conv_blocks > 0


@dataclass
class ModelConfig:
    name: str = "unnamed"
    family: str = DENSE
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                     # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    max_seq_len: int = 8192
    # flags
    attention: str = ATTN_FULL
    sliding_window: int = 0               # used when attention == ATTN_SLIDING
    qk_norm: bool = False                 # Qwen3
    qkv_bias: bool = False                # Qwen2
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"                     # mlp activation (silu => SwiGLU)
    # family sub-configs
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    rwkv: RWKVConfig = field(default_factory=RWKVConfig)
    rglru: RGLRUConfig = field(default_factory=RGLRUConfig)
    encdec: EncDecConfig = field(default_factory=EncDecConfig)
    dcgan: DCGANConfig = field(default_factory=DCGANConfig)
    # provenance
    source: str = ""                      # citation bracket from the assignment

    # ------------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0 and self.num_heads > 0:
            self.head_dim = self.d_model // self.num_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def gqa_groups(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count N (used for MODEL_FLOPS = 6*N*D)."""
        if self.family == DCGAN:
            return _dcgan_params(self.dcgan)
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        # attention
        if self.mla.enabled:
            rk = self.mla.kv_lora_rank
            rh = self.mla.rope_head_dim
            vh = self.mla.v_head_dim or self.head_dim
            nh = self.num_heads
            qd = nh * (self.head_dim + rh)
            per_layer += d * qd                       # q proj (full rank, V2-Lite)
            per_layer += d * (rk + rh)                # compressed kv + rope k
            per_layer += rk * nh * (self.head_dim + vh)  # kv up-proj
            per_layer += nh * vh * d                  # o proj
        elif self.family == SSM:
            # RWKV-6 time-mix: r,k,v,g,o projections + small loras + decay
            per_layer += 5 * d * d
            per_layer += d * (self.rwkv.decay_lora * 2)
            per_layer += 5 * d * self.rwkv.token_shift_lora * 2
        else:
            per_layer += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                per_layer += self.q_dim + 2 * self.kv_dim
        # mlp
        if self.moe.enabled:
            e = self.moe
            ff = e.d_ff_expert
            per_layer += (e.num_experts + e.num_shared_experts) * 3 * d * ff
            per_layer += d * e.num_experts            # router
        elif self.family == SSM:
            per_layer += 2 * d * self.d_ff            # rwkv channel-mix (k,v) + r gate
            per_layer += d * d
        else:
            mult = 3 if self.act == "silu" else 2     # swiglu has gate+up+down
            per_layer += mult * d * self.d_ff
        # rglru hybrid replaces some attn layers with LRU blocks
        if self.rglru.enabled:
            lw = self.rglru.lru_width or d
            n_rec = sum(1 for p in self._layer_pattern() if p == "rglru")
            n_att = L - n_rec
            att_params = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            rec_params = 2 * d * lw + lw * d + 2 * lw * self.rglru.conv_width + 2 * lw
            per_layer = 0  # recompute fully below
            mlp = 3 * d * self.d_ff
            total_layers = n_att * (att_params + mlp) + n_rec * (rec_params + mlp)
            norms = L * 2 * d + d
            return emb + total_layers + norms
        norms = L * 2 * d + d
        total = emb + L * per_layer + norms
        if self.encdec.enabled:
            # encoder layers (full self-attn + mlp) + decoder cross-attn
            enc_l = (d * self.q_dim * 2 + 2 * d * self.kv_dim + 2 * d * self.d_ff)
            total += self.encdec.encoder_layers * enc_l
            total += L * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts count)."""
        if not self.moe.enabled:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        e = self.moe
        inactive = (e.num_experts - e.top_k) * 3 * d * e.d_ff_expert * L
        return int(self.param_count() - inactive)

    def _layer_pattern(self) -> List[str]:
        if not self.rglru.enabled:
            return ["attn"] * self.num_layers
        pat = list(self.rglru.pattern)
        out: List[str] = []
        while len(out) < self.num_layers:
            out.extend(pat)
        return out[: self.num_layers]


def _dcgan_params(c: DCGANConfig) -> int:
    # generator: project latent -> (f*4, 7, 7) then 2 deconv blocks -> image
    f = c.base_filters
    g = c.latent_dim * f * 4 * 7 * 7 + (f * 4) * (f * 2) * 25 + (f * 2) * f * 25 + f * c.channels * 25
    # discriminator: conv_blocks convs + classifier
    d = c.channels * f * 25 + f * f * 2 * 25 + f * 2 * f * 4 * 25 + f * 4 * 7 * 7
    return int(g + d)


# ---------------------------------------------------------------------------
# Parallelism / runtime
# ---------------------------------------------------------------------------

@dataclass
class ParallelConfig:
    # mesh
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: str = "pod"
    # strategies
    fsdp: bool = True                     # shard params over `data` too
    tensor_parallel: bool = True          # shard heads/ffn over `model`
    expert_parallel: bool = True          # shard experts over `model`
    sequence_parallel: bool = True        # shard residuals over `model` on seq dim
    # training memory knobs
    microbatches: int = 1                 # gradient-accumulation steps
    remat: str = "full"                   # "none" | "full" | "dots"
    scan_layers: bool = True              # False => unrolled (probe mode)
    unroll_microbatches: bool = False     # True => python loop (probe mode)
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    accum_dtype: str = "float32"          # gradient-accumulation dtype
    cache_dtype: str = "bfloat16"         # KV/decode-state dtype
    # attention kernel dispatch
    use_flash_kernel: bool = False        # Pallas kernels opt-in (tests turn on)


@dataclass
class OptimConfig:
    name: str = "adamw"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    schedule: str = "constant"            # "constant" | "cosine" | "linear"
    warmup_steps: int = 0
    total_steps: int = 1000
    state_dtype: str = ""                 # "" => same as param dtype


@dataclass
class FSLConfig:
    """Paper knobs: clients, devices-per-client, selection, averaging cadence."""
    num_clients: int = 5
    devices_per_client: int = 4
    selection: str = "sorted_multi"       # random_single|random_multi|sorted_single|sorted_multi
    local_steps: int = 1                  # FedAvg cadence (1 == per-step sync)
    lan_latency_s: float = 0.050          # paper: 50 ms per LAN hop
    weighted_average: bool = True         # weight FedAvg by client example counts
    heterogeneity: str = "paper"          # device-pool preset (see core/devices.py)
    seed: int = 0

    def __post_init__(self) -> None:
        _check_name("fsl", "selection", self.selection, SELECTION_STRATEGIES)


@dataclass
class FedConfig:
    """Federation runtime knobs (fed/ subsystem): what crosses the wire,
    how it is compressed, and how/when the server aggregates.

    ``mode='sync'`` with ``codec='none'``, ``backend='loop'``, full
    availability and no deadline reproduces the paper's sequential
    simulation bit-for-bit (pinned test).
    """
    mode: str = "sync"                 # sync | fedasync | fedbuff
    # client-program backend (fed/programs.py): how the local round is
    # compiled.  "loop" = per-client jitted steps (seed dispatch, bit-exact
    # reference); "vectorized" = one jitted vmap-over-clients /
    # scan-over-batches program per dispatch.  Orthogonal to scheduling
    # and privacy — every mode x backend x privacy cell is supported.
    backend: str = "loop"              # loop | vectorized
    # per-client local-round schedules, keyed by client id; unlisted
    # clients use the defaults (lr_scale 1.0 / the round's
    # batches_per_client).  Threaded through both backends.
    client_lr_scales: Dict[str, float] = field(default_factory=dict)
    client_local_steps: Dict[str, int] = field(default_factory=dict)
    # uplink compression (discriminator params / deltas)
    codec: str = "none"                # none | fp16 | int8 | topk
    topk_frac: float = 0.01            # fraction of entries topk keeps
    error_feedback: bool = True        # topk residual carry-over
    # transport (WAN between server and clients; LAN inside a client is
    # priced by core/simulate.py)
    uplink_bps: float = 10e6           # client -> server
    downlink_bps: float = 50e6         # server -> client
    wan_latency_s: float = 0.050
    # scheduling
    deadline_s: float = 0.0            # sync: drop updates landing later (0=off)
    availability: float = 1.0          # per-round client up-probability
    availability_seed: int = 0
    async_cycles: int = 1              # local rounds per client per epoch (async)
    # async aggregation
    fedasync_alpha: float = 0.6        # server mixing rate
    staleness_power: float = 0.5       # alpha_t = alpha * (1+staleness)^-power
    buffer_size: int = 2               # fedbuff aggregation threshold K
    # aggregation hot path
    kernel_aggregation: bool = False   # use the fedavg Pallas kernel
    kernel_interpret: bool = False     # Pallas interpret mode (CPU tests)
    # server reduce strategy (SERVER_REDUCES above).  "decode" is the
    # bit-exact staging reference; "stream"/"batched" aggregate in the
    # compressed domain (pinned vs "decode" at fma-level tolerance —
    # mean(base + d_c) reassociates vs base + mean(d_c) in float).
    server_reduce: str = "decode"
    # population scale: map the vectorized backend's stacked client axis
    # onto a `clients` device mesh (launch/mesh.make_client_mesh +
    # sharding/specs.stacked_shardings).  Off (default) keeps every
    # dispatch single-device — the bit-exact unsharded path.  Testable on
    # CPU via XLA_FLAGS=--xla_force_host_platform_device_count=N.
    shard_clients: bool = False
    # two-tier aggregation: >= 2 groups sync-round clients into that many
    # edge cohorts, each pre-reducing its clients' updates (the fedavg
    # kernel when kernel_aggregation) BEFORE the WAN hop — only cohort
    # aggregates cross the WAN.  0/1 = flat FedAvg (bit-exact default).
    hierarchy_cohorts: int = 0
    # the client -> edge-aggregator link (LAN/MAN: faster + nearer than
    # the WAN); the WAN LinkModels above then price only edge -> server
    edge_uplink_bps: float = 200e6
    edge_latency_s: float = 0.005

    def __post_init__(self) -> None:
        _check_name("fed", "mode", self.mode, FED_MODES)
        _check_name("fed", "backend", self.backend, FED_BACKENDS)
        _check_name("fed", "codec", self.codec, CODECS,
                    aliases=("", "identity"))
        _check_name("fed", "server_reduce", self.server_reduce,
                    SERVER_REDUCES)
        if self.hierarchy_cohorts < 0:
            raise ValueError(
                f"fed.hierarchy_cohorts must be >= 0, got "
                f"{self.hierarchy_cohorts}")
        if self.edge_uplink_bps <= 0.0:
            raise ValueError(
                f"fed.edge_uplink_bps must be > 0, got "
                f"{self.edge_uplink_bps}")


@dataclass
class SplitConfig:
    """Executed split training (core/split.SplitExecution).

    ``enabled=False`` keeps the seed behavior: the SplitPlan only *prices*
    the round (analytic 50 ms hops) while training runs the monolithic D.
    ``enabled=True`` compiles each client's plan into the local step itself:
    forward/backward run device-segment by device-segment, every boundary
    tensor (activation fwd, activation-grad bwd) passes through the
    ``boundary_stage``, and round time + LAN bytes are priced from the
    measured per-boundary payloads instead of the hop constant.
    """
    enabled: bool = False
    # planner strategy override; "" uses cfg.fsl.selection
    strategy: str = ""
    # what crosses each LAN boundary: identity | fp16 | int8 | topk | dp
    boundary_stage: str = "identity"
    topk_frac: float = 0.01            # topk stage keep fraction
    stage_clip: float = 1.0            # dp stage: per-example L2 clip
    stage_sigma: float = 0.0           # dp stage: noise multiplier
    seed: int = 0                      # stage noise stream (dp stage)
    # LAN serialization rate for measured-bytes pricing (latency comes
    # from lan_latency_s below, falling back to cfg.fsl.lan_latency_s)
    lan_bandwidth_bps: float = 100e6
    # per-hop LAN latency override for the split chain; 0.0 inherits
    # cfg.fsl.lan_latency_s (the paper's 50 ms) end-to-end
    lan_latency_s: float = 0.0
    # 1F1B pipelined local step: micro-batches per batch (1 = sequential
    # executor, bit-exact with the pre-pipeline step; K > 1 overlaps
    # device segments, clamped per step to a divisor of the batch size)
    pipeline_microbatches: int = 1
    # compile the K-micro-batch loop as ONE lax.scan instead of K unrolled
    # staged chains (trace size O(1) in K; tolerance-pinned against the
    # unrolled loop).  Off (default) keeps the unrolled reference path.
    pipeline_scan: bool = False
    # fuse composed codec+dp stages into kernels/boundary_fuse (the
    # unfused ComposedBoundaryStage remains the pinned reference)
    fuse_boundary: bool = True
    use_kernel: bool = False           # Pallas path for the fused stage
    kernel_interpret: bool = False     # interpret mode (CPU) for it

    def __post_init__(self) -> None:
        _check_name("split", "boundary_stage", self.boundary_stage,
                    BOUNDARY_STAGES, aliases=("", "none"))
        if self.strategy:
            _check_name("split", "strategy", self.strategy,
                        SELECTION_STRATEGIES)
        if self.pipeline_microbatches < 1:
            raise ValueError(
                f"split.pipeline_microbatches must be >= 1, got "
                f"{self.pipeline_microbatches}")
        if self.lan_latency_s < 0.0:
            raise ValueError(
                f"split.lan_latency_s must be >= 0.0, got "
                f"{self.lan_latency_s}")


@dataclass
class PrivacyConfig:
    """Privacy subsystem knobs (privacy/ + kernels/dp_clip).

    ``enabled=False`` leaves every training path byte-identical to the
    non-private build (pinned test).  Two defense placements:

      * ``mode='dp_sgd'`` — per-example clip + Gaussian noise inside the
        device-side D step (Abadi et al. 2016), accounted per batch;
      * ``mode='uplink'`` — clip + noise the whole update delta once per
        round, as a pre-codec transport stage (fed/engine.py), accounted
        per round.
    """
    enabled: bool = False
    mode: str = "dp_sgd"               # dp_sgd | uplink
    clip_norm: float = 1.0             # per-example (dp_sgd) / per-delta L2
    noise_multiplier: float = 0.0      # sigma; noise stddev = sigma * clip
    delta: float = 1e-5                # accountant's delta target
    # accountant's per-step Poisson-sampling probability q.  The data
    # loader samples uniformly with replacement, so set q >= batch/|data|
    # to claim amplification honestly; the default 1.0 claims none.
    sample_rate: float = 1.0
    seed: int = 0                      # DP noise stream
    use_kernel: bool = False           # dp_clip Pallas kernel for clip+noise
    kernel_interpret: bool = False     # Pallas interpret mode (CPU tests)

    def __post_init__(self) -> None:
        _check_name("privacy", "mode", self.mode, PRIVACY_MODES)


@dataclass
class ControlConfig:
    """Closed-loop control plane (src/repro/control/): per-round controllers
    that turn measured :class:`~repro.control.RoundFeedback` into knob
    decisions between rounds.

    ``mode='frozen'`` (default) keeps every knob at its static config value
    — bit-exact with the pre-control build (pinned test); feedback is still
    emitted.  ``mode='adaptive'`` runs the controllers named in
    ``controllers`` each round:

      * ``codec``    — uplink codec from measured bandwidth + the observed
                       bytes-vs-delta-error frontier (fed/transport);
      * ``sigma``    — DP noise multiplier inverted from the RDP epsilon
                       curve to spend ``(epsilon_budget, privacy.delta)``
                       over ``horizon_rounds`` without ever exceeding it;
      * ``split``    — re-plan device selection / per-boundary stages when
                       measured load imbalance or boundary dCor drifts;
      * ``deadline`` — sync straggler deadline from the measured per-client
                       round-time distribution.
    """
    mode: str = "frozen"               # frozen | adaptive
    controllers: Tuple[str, ...] = ()  # subset of CONTROLLERS; () = none
    # codec controller
    codec_candidates: Tuple[str, ...] = ("topk", "int8", "fp16", "none")
    error_budget: float = 0.05         # max relative L2 delta error on uplink
    target_uplink_s: float = 0.0       # prefer lossless if it fits (0 = off)
    # sigma controller
    epsilon_budget: float = 0.0        # total epsilon to spend (0 = off)
    horizon_rounds: int = 0            # rounds the budget must cover
    sigma_min: float = 1e-2
    sigma_max: float = 1e4
    sigma_rel_change: float = 0.05     # ignore smaller rebinds (dp_sgd:
                                       # bounds per-round recompilation)
    # split controller
    imbalance_threshold: float = 2.0   # max/mean device load before replan
    dcor_threshold: float = 0.5        # boundary dCor above this gets noised
    replan_strategy: str = "sorted_multi"
    leaky_stage: str = "dp"            # stage assigned to leaky boundaries
    probe_batch: int = 16              # examples per boundary-dCor probe
    # deadline controller
    deadline_quantile: float = 0.9     # of the measured finish distribution
    deadline_slack: float = 1.25
    warmup_rounds: int = 1             # rounds of feedback before deciding

    def __post_init__(self) -> None:
        _check_name("control", "mode", self.mode, CONTROL_MODES)
        for c in self.controllers:
            _check_name("control", "controllers", c, CONTROLLERS)
        for name in self.codec_candidates:
            _check_name("control", "codec_candidates", name, CODECS)
        _check_name("control", "replan_strategy", self.replan_strategy,
                    SELECTION_STRATEGIES)
        _check_name("control", "leaky_stage", self.leaky_stage,
                    BOUNDARY_STAGES)


@dataclass
class HealthConfig:
    """Numeric-health monitors (obs/health.py): per-round verdicts over the
    freshly-aggregated global state and the ``RoundFeedback`` history.

    ``enabled=False`` (default) runs no monitor — nothing is scanned and
    training is untouched.  Enabled, every round is checked for non-finite
    global params / losses (fatal) and for heuristic drift (warn): D/G
    loss-ratio blowup, update-norm spikes, codec-error spikes, epsilon
    overspend and straggler-rate runaway.  Every verdict is a typed
    :class:`~repro.obs.HealthAlert` recorded to ``alerts.jsonl`` and the
    metric registry; what a FATAL verdict additionally does is ``policy``:

      * ``record``   — log only; training continues on the poisoned state
                       (monitors-on stays bit-exact with monitors-off);
      * ``warn``     — log + a Python warning;
      * ``abort``    — raise :class:`~repro.obs.HealthAbort`;
      * ``rollback`` — restore the last healthy global params + optimizer
                       state (one poisoned round degrades gracefully
                       instead of killing the run).  Non-recoverable fatal
                       alerts (epsilon overspend: the noise was already
                       released) degrade to ``warn``.
    """
    enabled: bool = False
    policy: str = "record"             # record | warn | abort | rollback
    window: int = 4                    # trailing rounds for spike baselines
    min_history: int = 2               # rounds before heuristic monitors arm
    loss_ratio_max: float = 50.0       # max(d/g, g/d) above this -> warn
    update_norm_factor: float = 10.0   # spike vs trailing median -> warn
    codec_error_factor: float = 10.0   # spike vs trailing median -> warn
    epsilon_budget: float = 0.0        # 0 = off; spend above this -> fatal
    straggler_rate_max: float = 0.5    # windowed straggler rate -> warn

    def __post_init__(self) -> None:
        _check_name("obs.health", "policy", self.policy, HEALTH_POLICIES)


@dataclass
class ObsConfig:
    """Flight recorder (src/repro/obs/): tracing, metrics, and profiling.

    ``enabled=False`` (default) records nothing and leaves every training
    path untouched — obs-off runs stay bit-exact with the pre-obs build
    (pinned test).  ``enabled=True`` attaches a :class:`~repro.obs.
    FlightRecorder` to the trainer:

      * spans for round -> download -> client-execution -> split-segment ->
        boundary-crossing -> uplink -> aggregate on the engine's virtual
        clock (plus wall-clock host spans), exported as Chrome-trace JSON;
      * a typed metric registry fed from each round's ``RoundFeedback``,
        snapshotted to ``metrics.jsonl``;
      * the full ``RoundFeedback`` + knob-decision history as JSONL, enough
        to replay the run through the pure controllers offline
        (``repro.obs.replay``) and reproduce the knob sequence bit-exactly.

    ``profile_kernels`` additionally times jit compiles and the fedavg /
    dp_clip kernels (roofline terms); it is gated off by default because
    profiling runs extra compilations — measurement only, numerics are
    never touched either way.
    """
    enabled: bool = False
    out_dir: str = "obs_runs"          # per-run dir created under this root
    run_id: str = ""                   # "" => derived from config + counter
    # which sinks are live when enabled; subset of OBS_SINKS
    sinks: Tuple[str, ...] = ("trace", "metrics", "feedback", "alerts",
                              "digests")
    trace_clock: str = "virtual"       # virtual | wall | both (export clocks)
    # cap batches whose segment/boundary phases are traced per client per
    # round (0 = no cap); rounds beyond the cap still get client spans
    trace_batches: int = 0
    profile_kernels: bool = False      # jit + kernel timing -> profile.json
    # numeric-health monitors (obs/health.py).  Orthogonal to ``enabled``:
    # health checks run whenever health.enabled is set, recorder or not —
    # a run can watch its own numerics without persisting anything.
    health: HealthConfig = field(default_factory=HealthConfig)

    def __post_init__(self) -> None:
        _check_name("obs", "trace_clock", self.trace_clock, OBS_TRACE_CLOCKS)
        for s in self.sinks:
            _check_name("obs", "sinks", s, OBS_SINKS)


@dataclass
class ShapeConfig:
    name: str = "train_4k"
    seq_len: int = 4096
    global_batch: int = 256
    mode: str = "train"                   # "train" | "prefill" | "decode"


# The four assigned input shapes.
INPUT_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass
class RunConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    optim: OptimConfig = field(default_factory=OptimConfig)
    fsl: FSLConfig = field(default_factory=FSLConfig)
    fed: FedConfig = field(default_factory=FedConfig)
    split: SplitConfig = field(default_factory=SplitConfig)
    privacy: PrivacyConfig = field(default_factory=PrivacyConfig)
    control: ControlConfig = field(default_factory=ControlConfig)
    obs: ObsConfig = field(default_factory=ObsConfig)
    shape: ShapeConfig = field(default_factory=lambda: INPUT_SHAPES["train_4k"])
    seed: int = 0

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, default=str)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunConfig":
        return _from_dict(cls, d)

    def override(self, dotted: Dict[str, Any]) -> "RunConfig":
        """Apply {'model.d_model': 512, ...} style overrides, returning a copy."""
        d = self.to_dict()
        for path, val in dotted.items():
            cur = d
            parts = path.split(".")
            for p in parts[:-1]:
                cur = cur[p]
            if parts[-1] not in cur:
                raise KeyError(f"unknown config key {path!r}")
            cur[parts[-1]] = _coerce(cur[parts[-1]], val)
        return RunConfig.from_dict(d)

    def validate(self) -> "RunConfig":
        m = self.model
        if m.family != DCGAN:
            if m.family != SSM and m.num_heads % max(1, m.num_kv_heads) != 0:
                raise ValueError("num_heads must be divisible by num_kv_heads")
            if m.moe.enabled and m.moe.top_k > m.moe.num_experts:
                raise ValueError("top_k > num_experts")
        if self.shape.mode == "decode" and m.family in (DENSE, MOE, VLM) \
                and self.shape.seq_len > 65536 and m.attention != ATTN_SLIDING:
            raise ValueError(
                f"{m.name}: long-context decode requires sub-quadratic attention "
                "(set model.attention='sliding')")
        return self


def _coerce(old: Any, new: Any) -> Any:
    if isinstance(new, str) and old is not None and not isinstance(old, str):
        t = type(old)
        if t is bool:
            return new.lower() in ("1", "true", "yes")
        return t(new)
    return new


def _from_dict(cls: Any, d: Dict[str, Any]) -> Any:
    kwargs = {}
    for f in fields(cls):
        if f.name not in d:
            continue
        v = d[f.name]
        if dataclasses.is_dataclass(f.type) if isinstance(f.type, type) else False:
            kwargs[f.name] = _from_dict(f.type, v)
        elif f.name in _NESTED.get(cls, {}):
            kwargs[f.name] = _from_dict(_NESTED[cls][f.name], v)
        elif isinstance(v, list):
            kwargs[f.name] = tuple(v)
        else:
            kwargs[f.name] = v
    return cls(**kwargs)


_NESTED = {
    ModelConfig: {"moe": MoEConfig, "mla": MLAConfig, "rwkv": RWKVConfig,
                  "rglru": RGLRUConfig, "encdec": EncDecConfig, "dcgan": DCGANConfig},
    ObsConfig: {"health": HealthConfig},
    RunConfig: {"model": ModelConfig, "parallel": ParallelConfig,
                "optim": OptimConfig, "fsl": FSLConfig, "fed": FedConfig,
                "split": SplitConfig, "privacy": PrivacyConfig,
                "control": ControlConfig, "obs": ObsConfig,
                "shape": ShapeConfig},
}


# ---------------------------------------------------------------------------
# Smoke reduction
# ---------------------------------------------------------------------------

def reduce_for_smoke(cfg: RunConfig, *, seq_len: int = 64, batch: int = 2) -> RunConfig:
    """Reduced variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    d = cfg.to_dict()
    m = d["model"]
    m["num_layers"] = 2
    scale = max(1, m["d_model"] // 256)
    m["d_model"] = min(m["d_model"], 256)
    m["num_heads"] = max(1, min(m["num_heads"], 4))
    m["num_kv_heads"] = max(1, min(m["num_kv_heads"], m["num_heads"],
                                   max(1, m["num_kv_heads"])))
    if m["num_heads"] % m["num_kv_heads"]:
        m["num_kv_heads"] = 1
    m["head_dim"] = m["d_model"] // m["num_heads"]
    m["d_ff"] = min(m["d_ff"], 512)
    m["vocab_size"] = min(m["vocab_size"], 512)
    m["max_seq_len"] = max(seq_len * 2, 128)
    if m["moe"]["num_experts"]:
        m["moe"]["num_experts"] = 4
        m["moe"]["num_shared_experts"] = min(1, m["moe"]["num_shared_experts"])
        m["moe"]["top_k"] = 2
        m["moe"]["d_ff_expert"] = min(m["moe"]["d_ff_expert"] or 128, 128)
    if m["mla"]["kv_lora_rank"]:
        m["mla"]["kv_lora_rank"] = 64
        m["mla"]["rope_head_dim"] = 16
        m["mla"]["v_head_dim"] = m["head_dim"]
    if m["rwkv"]["head_dim"] and d["model"]["family"] == SSM:
        m["rwkv"]["head_dim"] = 32
        m["rwkv"]["decay_lora"] = 16
        m["rwkv"]["token_shift_lora"] = 8
        m["rwkv"]["gate_lora"] = 16
    if m["rglru"]["pattern"]:
        m["rglru"]["lru_width"] = m["d_model"]
        m["rglru"]["window"] = min(m["rglru"]["window"], seq_len)
    if m["encdec"]["encoder_layers"]:
        m["encdec"]["encoder_layers"] = 2
        m["encdec"]["encoder_seq"] = 32
    d["shape"] = {"name": "smoke", "seq_len": seq_len, "global_batch": batch,
                  "mode": d["shape"]["mode"]}
    d["parallel"]["microbatches"] = 1
    d["parallel"]["param_dtype"] = "float32"
    d["parallel"]["compute_dtype"] = "float32"
    out = RunConfig.from_dict(d)
    return out
