from repro.config.base import (  # noqa: F401
    ATTN_FULL, ATTN_NONE, ATTN_SLIDING, AUDIO, BOUNDARY_STAGES, CODECS,
    CONTROL_MODES, CONTROLLERS, DCGAN, DENSE, FAMILIES, FED_BACKENDS,
    FED_MODES, HEALTH_POLICIES, HYBRID, INPUT_SHAPES, MOE, OBS_SINKS,
    OBS_TRACE_CLOCKS, PRIVACY_MODES, SELECTION_STRATEGIES, SSM, VLM,
    ControlConfig, DCGANConfig, EncDecConfig, FedConfig, FSLConfig,
    HealthConfig, MLAConfig, ModelConfig, MoEConfig, ObsConfig, OptimConfig,
    ParallelConfig, PrivacyConfig, RGLRUConfig, RWKVConfig, RunConfig,
    ShapeConfig, SplitConfig, reduce_for_smoke,
)
