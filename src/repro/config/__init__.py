from repro.config.base import (  # noqa: F401
    ATTN_FULL, ATTN_NONE, ATTN_SLIDING, AUDIO, DCGAN, DENSE, FAMILIES, HYBRID,
    INPUT_SHAPES, MOE, SSM, VLM, DCGANConfig, EncDecConfig, FedConfig,
    FSLConfig, MLAConfig, ModelConfig, MoEConfig, OptimConfig, ParallelConfig,
    PrivacyConfig, RGLRUConfig, RWKVConfig, RunConfig, ShapeConfig,
    SplitConfig, reduce_for_smoke,
)
