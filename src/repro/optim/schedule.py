"""Learning-rate schedules (hand-rolled; no optax in this environment)."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(name: str, base_lr: float, warmup_steps: int = 0,
                  total_steps: int = 1000, final_frac: float = 0.1):
    """Returns step -> lr (jnp scalar). Supports constant/linear/cosine."""
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, (step + 1) / jnp.maximum(warmup_steps, 1))
        if name == "constant":
            decay = 1.0
        elif name == "linear":
            t = jnp.clip((step - warmup_steps)
                         / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
            decay = 1.0 - (1.0 - final_frac) * t
        elif name == "cosine":
            t = jnp.clip((step - warmup_steps)
                         / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
            decay = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        else:
            raise ValueError(f"unknown schedule {name!r}")
        return base_lr * warm * decay
    return sched
