"""Optimizers as pure (init, update) pairs over parameter pytrees.

AdamW and SGD+momentum, with global-norm clipping and a state-dtype knob
(bf16 moments for the ZeRO-style memory accounting of the biggest archs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def adamw(beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.0,
          grad_clip=0.0, state_dtype=None) -> Optimizer:
    def init(params):
        def zeros_like(p):
            dt = state_dtype or p.dtype
            return jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(zeros_like, params),
                "v": jax.tree.map(zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1 - beta1 ** t
        bc2 = 1 - beta2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = beta1 * m.astype(jnp.float32) + (1 - beta1) * g32
            v32 = beta2 * v.astype(jnp.float32) + (1 - beta2) * g32 * g32
            mh = m32 / bc1
            vh = v32 / bc2
            delta = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * delta
            return (newp.astype(p.dtype), m32.astype(m.dtype),
                    v32.astype(v.dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}

    return Optimizer(init=init, update=update)


def sgd(momentum=0.9, grad_clip=0.0, state_dtype=None) -> Optimizer:
    def init(params):
        def zeros_like(p):
            dt = state_dtype or p.dtype
            return jnp.zeros(p.shape, dt)
        return {"mom": jax.tree.map(zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        if grad_clip:
            grads, _ = clip_by_global_norm(grads, grad_clip)

        def upd(g, mo, p):
            m32 = momentum * mo.astype(jnp.float32) + g.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * m32
            return (newp.astype(p.dtype), m32.astype(mo.dtype))

        out = jax.tree.map(upd, grads, state["mom"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mom": new_m, "step": state["step"] + 1}

    return Optimizer(init=init, update=update)


def make_optimizer(cfg) -> Optimizer:
    """cfg: OptimConfig."""
    sd = jnp.dtype(cfg.state_dtype) if cfg.state_dtype else None
    if cfg.name in ("adam", "adamw"):
        return adamw(cfg.beta1, cfg.beta2, cfg.eps,
                     cfg.weight_decay if cfg.name == "adamw" else 0.0,
                     cfg.grad_clip, sd)
    if cfg.name == "sgd":
        return sgd(cfg.beta1, cfg.grad_clip, sd)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
