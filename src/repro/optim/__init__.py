from repro.optim.optimizers import (  # noqa: F401
    Optimizer, adamw, sgd, make_optimizer, global_norm, clip_by_global_norm,
)
from repro.optim.schedule import make_schedule  # noqa: F401
