"""The flight recorder: one run directory holding everything the engine
measured — enough to replay the run's control decisions offline.

Layout of one run directory (``<cfg.obs.out_dir>/<run_id>/``):

  * ``manifest.json``   — the full RunConfig plus the controller inputs
    (``leaf_sizes``, ``steps_per_round_hint``) that ``control.
    make_controllers`` needs to rebuild the exact live suite;
  * ``feedback.jsonl``  — one serialized :class:`RoundFeedback` per round,
    appended eagerly (a killed run still leaves a readable log);
  * ``knobs.jsonl``     — the :class:`ControlKnobs` in force during each
    round (the controller's decision sequence — what replay must
    reproduce bit-exactly);
  * ``metrics.jsonl``   — one metric-registry snapshot per round;
  * ``trace.json``      — the Chrome-trace export, written at ``flush()``;
  * ``alerts.jsonl``    — typed :class:`~repro.obs.health.HealthAlert`
    records, one per tripped health check (PR 7);
  * ``digests.jsonl``   — one :class:`~repro.obs.digest.RoundDigest` per
    round: the committed global state, content-addressed, which is what
    lets ``repro.obs.diff`` check bit-exactness claims across runs from
    artifacts alone.

Serialization is plain JSON via Python's repr-based float formatting,
which round-trips every finite float bit-exactly — the foundation of the
replay pin (``repro.obs.replay``).  NaN fields (a round with no codec
error, no DP) serialize as JSON ``NaN`` tokens, which Python's loader
accepts; the logs are an internal format, read back by :func:`load_run`.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

from repro.control.feedback import ControlKnobs, RoundFeedback
from repro.obs.digest import RoundDigest, digest_from_dict, digest_to_dict
from repro.obs.health import HealthAlert, alert_from_dict, alert_to_dict
from repro.obs.metrics import (JsonlSink, MetricsRegistry, load_jsonl,
                               observe_round)
from repro.obs.trace import Tracer

MANIFEST = "manifest.json"
FEEDBACK = "feedback.jsonl"
KNOBS = "knobs.jsonl"
METRICS = "metrics.jsonl"
TRACE = "trace.json"
PROFILE = "profile.json"
ALERTS = "alerts.jsonl"
DIGESTS = "digests.jsonl"


# ---------------------------------------------------------------------------
# serde — RoundFeedback / ControlKnobs <-> JSON objects
# ---------------------------------------------------------------------------

def feedback_to_dict(fb: RoundFeedback) -> Dict[str, Any]:
    return asdict(fb)


def feedback_from_dict(d: Dict[str, Any]) -> RoundFeedback:
    d = dict(d)
    # JSON lists -> the tuples the dataclass held
    d["boundary_dcor"] = {k: tuple(v)
                          for k, v in d.get("boundary_dcor", {}).items()}
    return RoundFeedback(**d)


def knobs_to_dict(k: ControlKnobs) -> Dict[str, Any]:
    d = asdict(k)
    if k.stage_by_boundary is not None:
        d["stage_by_boundary"] = dict(k.stage_by_boundary)
    return d


def knobs_from_dict(d: Dict[str, Any]) -> ControlKnobs:
    d = dict(d)
    sbb = d.get("stage_by_boundary")
    if sbb is not None:
        # JSON object keys are strings; the live map is keyed by boundary
        # index — restore ints or the replay comparison would never match
        d["stage_by_boundary"] = {int(b): s for b, s in sbb.items()}
    return ControlKnobs(**d)


# ---------------------------------------------------------------------------
# recorder
# ---------------------------------------------------------------------------

class FlightRecorder:
    """Owns the run directory, the tracer, and the metric registry.

    ``sinks`` selects what gets persisted (``trace`` / ``metrics`` /
    ``feedback`` / ``alerts`` / ``digests``); the in-memory tracer and
    registry always run so demos can render from them even without
    persistence.
    """

    def __init__(self, run_dir: str, *, run_id: Optional[str] = None,
                 sinks=("trace", "metrics", "feedback", "alerts", "digests"),
                 trace_clock: str = "virtual", trace_batches: int = 0):
        self.run_dir = run_dir
        self.run_id = run_id or os.path.basename(run_dir)
        self.sinks = tuple(sinks)
        self.trace_clock = trace_clock
        self.trace_batches = int(trace_batches)
        os.makedirs(run_dir, exist_ok=True)
        self.tracer = Tracer(self.run_id)
        self.registry = MetricsRegistry()
        self.feedback: List[RoundFeedback] = []
        self.knob_log: List[ControlKnobs] = []
        self.alerts: List[HealthAlert] = []
        self.digests: List[RoundDigest] = []
        self._fb_sink = (JsonlSink(self.path(FEEDBACK))
                         if "feedback" in self.sinks else None)
        self._knob_sink = (JsonlSink(self.path(KNOBS))
                           if "feedback" in self.sinks else None)
        self._metric_sink = (JsonlSink(self.path(METRICS))
                             if "metrics" in self.sinks else None)
        self._alert_sink = (JsonlSink(self.path(ALERTS))
                            if "alerts" in self.sinks else None)
        self._digest_sink = (JsonlSink(self.path(DIGESTS))
                             if "digests" in self.sinks else None)
        # flush() idempotence: count of spans already exported, so a
        # second flush with no new spans is a no-op (see flush docstring)
        self._flushed_spans = 0
        self._trace_path: Optional[str] = None

    # ------------------------------------------------------------------
    @classmethod
    def from_config(cls, cfg, *, run_id: Optional[str] = None
                    ) -> "FlightRecorder":
        """Build from ``cfg.obs`` (a full RunConfig).  ``run_id`` defaults
        to ``cfg.obs.run_id`` or, failing that, a name derived from the
        model + pid (unique enough for side-by-side local runs)."""
        obs = cfg.obs
        rid = run_id or obs.run_id \
            or f"{cfg.model.name or 'run'}-{os.getpid()}"
        return cls(os.path.join(obs.out_dir, rid), run_id=rid,
                   sinks=obs.sinks, trace_clock=obs.trace_clock,
                   trace_batches=obs.trace_batches)

    def path(self, name: str) -> str:
        return os.path.join(self.run_dir, name)

    def wants(self, sink: str) -> bool:
        return sink in self.sinks

    # ------------------------------------------------------------------
    def set_manifest(self, cfg, *, leaf_sizes, steps_per_round_hint: int,
                     extra: Optional[Dict[str, Any]] = None) -> None:
        """Persist the config + controller inputs: everything
        ``replay_run`` needs to rebuild the live controller suite."""
        manifest = {"run_id": self.run_id,
                    "config": cfg.to_dict(),
                    "leaf_sizes": [int(s) for s in leaf_sizes],
                    "steps_per_round_hint": int(steps_per_round_hint)}
        if extra:
            manifest.update(extra)
        with open(self.path(MANIFEST), "w") as f:
            json.dump(manifest, f, indent=2, default=str)

    def on_round(self, fb: RoundFeedback, knobs: ControlKnobs) -> None:
        """Record one completed round: the feedback the engine measured and
        the knobs that were in force while it ran."""
        self.feedback.append(fb)
        self.knob_log.append(knobs)
        observe_round(self.registry, fb)
        if self._fb_sink is not None:
            self._fb_sink.write(feedback_to_dict(fb))
        if self._knob_sink is not None:
            self._knob_sink.write(knobs_to_dict(knobs))
        if self._metric_sink is not None:
            self._metric_sink.write({"round": fb.round_index,
                                     "metrics": self.registry.snapshot()})

    def on_alert(self, alert: HealthAlert) -> None:
        """Record one tripped health check (``repro.obs.health``) to the
        ``alerts.jsonl`` sink and the metrics registry."""
        self.alerts.append(alert)
        self.registry.counter(
            "health_alerts", help="health alerts, all checks").inc()
        self.registry.counter(
            f"health_alerts_{alert.check}",
            help=f"health alerts from the {alert.check} check").inc()
        if self._alert_sink is not None:
            self._alert_sink.write(alert_to_dict(alert))

    def on_digest(self, digest: RoundDigest) -> None:
        """Record one round's committed-state content digest
        (``repro.obs.digest``) to the ``digests.jsonl`` sink."""
        self.digests.append(digest)
        if self._digest_sink is not None:
            self._digest_sink.write(digest_to_dict(digest))

    def write_profile(self, profile: Dict[str, Any]) -> str:
        path = self.path(PROFILE)
        with open(path, "w") as f:
            json.dump(profile, f, indent=2, default=str)
        return path

    # ------------------------------------------------------------------
    def flush(self) -> Optional[str]:
        """Export the Chrome trace (when the trace sink is on); returns its
        path.  Explicitly IDEMPOTENT: a flush with no spans recorded since
        the previous flush re-exports nothing and returns the cached path —
        so ``benchmarks/_obs.py:finish`` flushing and its caller flushing
        again (the old double-flush path) costs one export, not two, and a
        reader mid-inspecting ``trace.json`` never sees it rewritten
        gratuitously.  Call after every epoch or once at the end."""
        if "trace" not in self.sinks or not self.tracer.spans:
            return self._trace_path
        if len(self.tracer.spans) == self._flushed_spans:
            return self._trace_path
        self._trace_path = self.tracer.export_chrome(
            self.path(TRACE), self.trace_clock)
        self._flushed_spans = len(self.tracer.spans)
        return self._trace_path

    def close(self) -> None:
        self.flush()
        for s in (self._fb_sink, self._knob_sink, self._metric_sink,
                  self._alert_sink, self._digest_sink):
            if s is not None:
                s.close()

    def render_summary(self) -> str:
        return self.registry.render()


# ---------------------------------------------------------------------------
# loader
# ---------------------------------------------------------------------------

@dataclass
class RunRecord:
    """One recorded run, loaded back from disk."""
    run_dir: str
    manifest: Dict[str, Any] = field(default_factory=dict)
    feedback: List[RoundFeedback] = field(default_factory=list)
    knobs: List[ControlKnobs] = field(default_factory=list)
    metrics: List[Dict[str, Any]] = field(default_factory=list)
    alerts: List[HealthAlert] = field(default_factory=list)
    digests: List[RoundDigest] = field(default_factory=list)

    @property
    def num_rounds(self) -> int:
        return len(self.feedback)


def load_run(run_dir: str) -> RunRecord:
    rec = RunRecord(run_dir=run_dir)
    mpath = os.path.join(run_dir, MANIFEST)
    if os.path.exists(mpath):
        with open(mpath) as f:
            rec.manifest = json.load(f)
    fpath = os.path.join(run_dir, FEEDBACK)
    if os.path.exists(fpath):
        rec.feedback = [feedback_from_dict(d) for d in load_jsonl(fpath)]
    kpath = os.path.join(run_dir, KNOBS)
    if os.path.exists(kpath):
        rec.knobs = [knobs_from_dict(d) for d in load_jsonl(kpath)]
    mpath = os.path.join(run_dir, METRICS)
    if os.path.exists(mpath):
        rec.metrics = load_jsonl(mpath)
    apath = os.path.join(run_dir, ALERTS)
    if os.path.exists(apath):
        rec.alerts = [alert_from_dict(d) for d in load_jsonl(apath)]
    dpath = os.path.join(run_dir, DIGESTS)
    if os.path.exists(dpath):
        rec.digests = [digest_from_dict(d) for d in load_jsonl(dpath)]
    return rec
