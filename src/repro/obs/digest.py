"""Per-round content digests of the global training state.

A digest makes a bit-exactness claim checkable *across runs from artifacts
alone*: two runs whose ``digests.jsonl`` rows match round for round held
byte-identical global state at every round boundary — no need to hold both
runs in memory, or even run them on the same day.  The recorder writes one
:class:`RoundDigest` per round; ``repro.obs.diff`` aligns and compares
them, and localizes the first diverging round.

Two comparison granularities, because the repo pins two kinds of equality:

  * **hash** (:func:`tree_digest`) — a blake2b over every leaf's
    dtype, shape and raw bytes, path-tagged so structure matters.  Equal
    hashes == bit-identical trees.  This is the artifact form of the
    BIT-EXACT pins (obs-on == obs-off, engine loop == seed sequential,
    frozen == static).
  * **sketch** (:func:`tree_sketch`) — a tiny float summary (L2 norm,
    sum, absmax, leaf count) serialized at full precision.  Hashes can't
    measure *distance*; the sketch is what lets loop-vs-vectorized — a
    TOLERANCE pin since PR 3 (different XLA programs, ~1e-5 fp32 drift) —
    be checked across runs too, and lets ``diff.py`` report the magnitude
    of a numeric divergence instead of just its existence.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import asdict, dataclass
from typing import Any, Dict, Tuple

import jax
import numpy as np


def tree_digest(tree: Any) -> str:
    """Content hash of a pytree: blake2b over each leaf's path, dtype,
    shape and raw bytes (dict keys traverse sorted, so the walk order is
    deterministic).  Equal digests <=> bit-identical trees."""
    h = hashlib.blake2b(digest_size=16)
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        h.update(jax.tree_util.keystr(path).encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def tree_sketch(tree: Any) -> Tuple[float, float, float, int]:
    """``(l2, sum, absmax, leaves)`` over the tree's inexact leaves —
    the tolerance-comparable companion to :func:`tree_digest`."""
    sq, total, mx, n = 0.0, 0.0, 0.0, 0
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(leaf)
        n += 1
        if not np.issubdtype(arr.dtype, np.inexact):
            continue
        a64 = arr.astype(np.float64)
        sq += float(np.sum(a64 * a64))
        total += float(np.sum(a64))
        if arr.size:
            mx = max(mx, float(np.max(np.abs(a64))))
    return (math.sqrt(sq), total, mx, n)


@dataclass(frozen=True)
class RoundDigest:
    """One round's committed global state, content-addressed.

    ``global_digest`` hashes the broadcast global discriminator (what every
    replica equals after the round), ``opt_digest`` the per-client
    optimizer states that committed, ``gan_digest`` the server generator
    (params + opt).  ``aggregated_digest`` is the engine's as-aggregated
    global tree BEFORE any health action — under ``policy='rollback'`` a
    poisoned round records the NaN'd aggregate there while the committed
    ``global_digest`` equals the restored (last healthy) state, which is
    exactly the graceful-degradation pin."""
    round_index: int
    global_digest: str
    opt_digest: str = ""
    gan_digest: str = ""
    aggregated_digest: str = ""
    rolled_back: bool = False
    # tolerance-comparable sketch of the committed global discriminator
    global_sketch: Tuple[float, float, float, int] = (0.0, 0.0, 0.0, 0)

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)


def digest_to_dict(d: RoundDigest) -> Dict[str, Any]:
    return asdict(d)


def digest_from_dict(d: Dict[str, Any]) -> RoundDigest:
    d = dict(d)
    d["global_sketch"] = tuple(d.get("global_sketch", (0.0, 0.0, 0.0, 0)))
    return RoundDigest(**d)


def state_digest(d_params: Any, d_opt: Any, g_params: Any, g_opt: Any,
                 *, round_index: int, aggregated: str = "",
                 rolled_back: bool = False) -> RoundDigest:
    """Digest one trainer round's committed state (the single assembly
    point the trainer and the in-memory recompute tests share)."""
    return RoundDigest(
        round_index=round_index,
        global_digest=tree_digest(d_params),
        opt_digest=tree_digest(d_opt),
        gan_digest=tree_digest((g_params, g_opt)),
        aggregated_digest=aggregated,
        rolled_back=rolled_back,
        global_sketch=tree_sketch(d_params))
