"""Per-round numeric-health monitors over the live training loop.

The paper's setting — long-running GAN training on user devices — is
exactly where a NaN'd discriminator or a silently-diverged replica poisons
the global model with nobody watching.  The flight recorder (PR 6)
*collects*; this module *detects*: after every round the trainer hands the
:class:`HealthMonitor` the round's :class:`~repro.control.feedback.
RoundFeedback` plus the aggregated global tree, and gets back a list of
typed :class:`HealthAlert` records.  What happens next is policy
(``cfg.obs.health.policy``), applied by the trainer:

  ==========  =============================================================
  policy      effect
  ==========  =============================================================
  record      alerts go to ``alerts.jsonl`` + the metrics registry, nothing
              else — the training trajectory stays bit-exact with monitors
              off (monitors only read state, never write it)
  warn        record + ``warnings.warn`` per alert
  abort       fatal alerts raise :class:`HealthAbort`; warn-severity alerts
              behave as ``warn``
  rollback    fatal *recoverable* alerts restore the last healthy global /
              optimizer state so one poisoned round degrades gracefully;
              non-recoverable fatals (epsilon overspend — rolling back
              params does not unspend the privacy budget) degrade to warn
  ==========  =============================================================

Checks (:data:`HEALTH_CHECKS`) and what trips them:

  * ``nonfinite_params`` — jitted tree-scan counts NaN/Inf in the
    aggregated global params (fatal, recoverable);
  * ``nonfinite_loss``   — D or G loss went NaN/Inf (fatal, recoverable);
  * ``loss_ratio``       — D/G loss ratio outside ``loss_ratio_max``
    either way: the classic mode-collapse / overpowered-D heuristic (warn);
  * ``update_norm``      — this round's global-update L2 exceeds
    ``update_norm_factor`` x the window median (divergence onset) (warn);
  * ``codec_error_spike``— measured codec delta-error jumped
    ``codec_error_factor`` x above its window median (warn);
  * ``epsilon_overspend``— cumulative DP spend crossed
    ``epsilon_budget`` (> 0 enables) (fatal, NOT recoverable);
  * ``straggler_runaway``— straggler rate exceeded ``straggler_rate_max``
    for a full window of rounds (warn).

Windowed checks need ``min_history`` prior rounds before they arm — a
fresh run's first rounds are legitimately noisy.
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.control.feedback import RoundFeedback

HEALTH_CHECKS = ("nonfinite_params", "nonfinite_loss", "loss_ratio",
                 "update_norm", "codec_error_spike", "epsilon_overspend",
                 "straggler_runaway")

SEV_WARN = "warn"
SEV_FATAL = "fatal"


class HealthAbort(RuntimeError):
    """Raised by the trainer under ``policy='abort'`` on a fatal alert."""

    def __init__(self, alert: "HealthAlert"):
        super().__init__(f"health abort at round {alert.round_index}: "
                         f"{alert.check}: {alert.message}")
        self.alert = alert


@dataclass(frozen=True)
class HealthAlert:
    """One tripped health check — the typed record ``alerts.jsonl`` holds.

    ``recoverable`` says whether restoring the last healthy snapshot
    actually fixes the condition: a NaN'd aggregate is recoverable, an
    overspent epsilon budget is not (the spend is monotone)."""
    round_index: int
    check: str                      # one of HEALTH_CHECKS
    severity: str                   # "warn" | "fatal"
    value: float                    # the measured quantity
    threshold: float                # what it was compared against
    message: str
    recoverable: bool = True

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)


def alert_to_dict(a: HealthAlert) -> Dict[str, Any]:
    return asdict(a)


def alert_from_dict(d: Dict[str, Any]) -> HealthAlert:
    return HealthAlert(**d)


# ---------------------------------------------------------------------------
# jitted tree scans — one fused pass each over the global tree
# ---------------------------------------------------------------------------

@jax.jit
def _tree_nonfinite(tree) -> jnp.ndarray:
    """Count of non-finite entries across all inexact leaves."""
    total = jnp.zeros((), jnp.int32)
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact):
            total = total + jnp.sum(~jnp.isfinite(leaf), dtype=jnp.int32)
    return total


@jax.jit
def _tree_l2(tree) -> jnp.ndarray:
    """Global L2 norm across all inexact leaves."""
    sq = jnp.zeros((), jnp.float32)
    for leaf in jax.tree_util.tree_leaves(tree):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact):
            sq = sq + jnp.sum(jnp.square(leaf.astype(jnp.float32)))
    return jnp.sqrt(sq)


@jax.jit
def _tree_update_l2(new, base) -> jnp.ndarray:
    """L2 norm of ``new - base`` (this round's aggregate update)."""
    sq = jnp.zeros((), jnp.float32)
    for n, b in zip(jax.tree_util.tree_leaves(new),
                    jax.tree_util.tree_leaves(base)):
        if jnp.issubdtype(jnp.asarray(n).dtype, jnp.inexact):
            d = n.astype(jnp.float32) - b.astype(jnp.float32)
            sq = sq + jnp.sum(jnp.square(d))
    return jnp.sqrt(sq)


def _median(xs: Sequence[float]) -> float:
    s = sorted(xs)
    n = len(s)
    if n == 0:
        return float("nan")
    mid = n // 2
    return s[mid] if n % 2 else 0.5 * (s[mid - 1] + s[mid])


# ---------------------------------------------------------------------------
# monitor
# ---------------------------------------------------------------------------

class HealthMonitor:
    """Stateful window-keeper over the checks above.

    Read-only with respect to training: every check consumes measurements
    (the feedback record, the aggregated tree) and produces alerts — it
    never touches params, optimizer state, or RNG, which is why
    ``policy='record'`` is bit-exact with monitors off.  The windows
    (update norms, codec errors, straggler flags) live here rather than in
    ``RoundFeedback`` so the feedback schema stays purely *measured*.
    """

    def __init__(self, cfg):
        """``cfg`` is a :class:`repro.config.HealthConfig`."""
        self.cfg = cfg
        self._update_norms: List[float] = []
        self._codec_errors: List[float] = []
        self._straggler_hot: List[bool] = []
        # NaN doubles as the schema's "not measured" marker; a loss only
        # counts as *gone* NaN after it has ever been finite.
        self._loss_seen = {"d_loss": False, "g_loss": False}

    # ------------------------------------------------------------------
    def check_round(self, fb: RoundFeedback, *, params: Any = None,
                    update_base: Any = None) -> List[HealthAlert]:
        """Run every armed check against one completed round.

        ``params`` is the round's aggregated global tree (NaN scan +
        update norm); ``update_base`` the round-*start* global tree the
        update is measured against.  Both optional — feedback-only checks
        still run when the trees are not provided (e.g. offline over a
        loaded run).
        """
        c = self.cfg
        r = fb.round_index
        alerts: List[HealthAlert] = []

        # -- fatal: non-finite aggregate / losses --------------------------
        if params is not None:
            bad = int(_tree_nonfinite(params))
            if bad:
                alerts.append(HealthAlert(
                    r, "nonfinite_params", SEV_FATAL, float(bad), 0.0,
                    f"{bad} non-finite entries in aggregated global params"))
        for name, val in (("d_loss", fb.d_loss), ("g_loss", fb.g_loss)):
            if math.isfinite(val):
                self._loss_seen[name] = True
            elif not math.isnan(val) or self._loss_seen[name]:
                # Inf always flags; NaN only once the signal has been live
                alerts.append(HealthAlert(
                    r, "nonfinite_loss", SEV_FATAL, float(val), 0.0,
                    f"{name} is non-finite ({val!r})"))

        # -- warn: loss-ratio window ---------------------------------------
        if c.loss_ratio_max > 0 and math.isfinite(fb.d_loss) \
                and math.isfinite(fb.g_loss) and fb.d_loss > 0 \
                and fb.g_loss > 0:
            ratio = max(fb.d_loss / fb.g_loss, fb.g_loss / fb.d_loss)
            if ratio > c.loss_ratio_max:
                alerts.append(HealthAlert(
                    r, "loss_ratio", SEV_WARN, ratio, c.loss_ratio_max,
                    f"D/G loss ratio {ratio:.2f} exceeds "
                    f"{c.loss_ratio_max:.2f} (mode-collapse heuristic)"))

        # -- warn: update-norm spike vs window median ----------------------
        if params is not None and update_base is not None:
            norm = float(_tree_update_l2(params, update_base))
            window = self._update_norms[-c.window:]
            if len(window) >= c.min_history and math.isfinite(norm):
                med = _median(window)
                if med > 0 and norm > c.update_norm_factor * med:
                    alerts.append(HealthAlert(
                        r, "update_norm", SEV_WARN, norm,
                        c.update_norm_factor * med,
                        f"global update norm {norm:.4g} is "
                        f"{norm / med:.1f}x the window median {med:.4g}"))
            if math.isfinite(norm):
                self._update_norms.append(norm)

        # -- warn: codec-error spike vs window median ----------------------
        if not math.isnan(fb.codec_error):
            window = self._codec_errors[-c.window:]
            if len(window) >= c.min_history:
                med = _median(window)
                if med > 0 and fb.codec_error > c.codec_error_factor * med:
                    alerts.append(HealthAlert(
                        r, "codec_error_spike", SEV_WARN, fb.codec_error,
                        c.codec_error_factor * med,
                        f"codec error {fb.codec_error:.4g} is "
                        f"{fb.codec_error / med:.1f}x the window median"))
            self._codec_errors.append(fb.codec_error)

        # -- fatal (non-recoverable): epsilon overspend --------------------
        if c.epsilon_budget > 0 and not math.isnan(fb.dp_epsilon) \
                and fb.dp_epsilon > c.epsilon_budget:
            alerts.append(HealthAlert(
                r, "epsilon_overspend", SEV_FATAL, fb.dp_epsilon,
                c.epsilon_budget,
                f"cumulative epsilon {fb.dp_epsilon:.4g} exceeds budget "
                f"{c.epsilon_budget:.4g}", recoverable=False))

        # -- warn: straggler-rate runaway over a full window ---------------
        rate = (fb.stragglers / fb.num_clients) if fb.num_clients else 0.0
        self._straggler_hot.append(rate > c.straggler_rate_max)
        window = self._straggler_hot[-c.window:]
        if len(window) >= max(c.min_history, c.window) and all(window):
            alerts.append(HealthAlert(
                r, "straggler_runaway", SEV_WARN, rate,
                c.straggler_rate_max,
                f"straggler rate above {c.straggler_rate_max:.0%} for "
                f"{len(window)} consecutive rounds"))

        return alerts


def worst(alerts: Sequence[HealthAlert]) -> Optional[HealthAlert]:
    """The most severe alert (fatal beats warn; ties keep first)."""
    if not alerts:
        return None
    return max(alerts, key=lambda a: (a.severity == SEV_FATAL,
                                      -alerts.index(a)))
