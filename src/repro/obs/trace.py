"""Two-clock span tracing for the federated split engine.

The engine advances a *virtual* clock (the paper's analytic time model:
download + segment compute + LAN hops + uplink), while the tensor math runs
on the host in *wall* time.  A :class:`Span` therefore carries both clocks:
``v_start``/``v_end`` in virtual seconds (NaN when the span is wall-only)
and ``wall_start``/``wall_end`` in host seconds (NaN when the span was
placed retroactively from priced times — the engine knows a client's whole
virtual timeline the moment it schedules it, so most spans are recorded
with :meth:`Tracer.record` rather than timed live).

Hierarchy is explicit: every span holds its parent's id, so round ->
client-execution -> split-segment -> boundary-crossing nests exactly the
way the engine composed the round, and a trace viewer shows the LAN hops
inside the compute window they actually occupy.

:func:`to_chrome` exports the Chrome-trace / Perfetto JSON object model
(``{"traceEvents": [...]}``, "X" complete events, one pid per clock, one
tid lane per track), loadable in ``ui.perfetto.dev`` or
``chrome://tracing``; :func:`validate_chrome_trace` is the schema check CI
runs on the exported file.
"""
from __future__ import annotations

import json
import math
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

NAN = float("nan")

# Chrome-trace pids: one synthetic "process" per clock, so both timelines
# coexist in one file without colliding timestamps.
PID_VIRTUAL = 1
PID_WALL = 2

TRACE_CLOCKS = ("virtual", "wall", "both")


@dataclass(frozen=True)
class Span:
    """One named interval on one track, on one or both clocks."""
    span_id: int
    parent_id: Optional[int]
    name: str
    cat: str                      # coarse kind: round|client|segment|...
    track: str                    # viewer lane (client id, device id, server)
    v_start: float = NAN          # virtual seconds (engine clock)
    v_end: float = NAN
    wall_start: float = NAN       # host seconds since tracer start
    wall_end: float = NAN
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def v_dur(self) -> float:
        return self.v_end - self.v_start

    @property
    def has_virtual(self) -> bool:
        return math.isfinite(self.v_start) and math.isfinite(self.v_end)

    @property
    def has_wall(self) -> bool:
        return math.isfinite(self.wall_start) and math.isfinite(self.wall_end)


class Tracer:
    """Append-only span log with explicit parents and a wall-span stack.

    Two recording styles, matching how the engine knows about time:

      * :meth:`record` — a span whose VIRTUAL interval is already priced
        (the engine computes a client's download/compute/uplink times when
        it schedules the client, not as they "happen"); parent defaults to
        the innermost open wall span so retroactive virtual spans still
        nest under the host phase that produced them.
      * :meth:`span` — a context manager that measures the WALL interval
        of the enclosed host work (``program.run``, codec round-trips, jit
        compiles) and maintains the nesting stack.

    ``set_virtual_offset`` re-bases subsequent virtual times: the trainer
    calls it when it rebuilds the engine (whose virtual clock restarts at
    0) so one recording's virtual timeline stays monotone across rebuilds.
    """

    def __init__(self, run_id: str = "run"):
        self.run_id = run_id
        self.spans: List[Span] = []
        self._stack: List[int] = []
        self._next_id = 0
        self._wall0 = time.perf_counter()
        self._v_offset = 0.0

    # ------------------------------------------------------------------
    def _now(self) -> float:
        return time.perf_counter() - self._wall0

    def set_virtual_offset(self, offset_s: float) -> None:
        self._v_offset = float(offset_s)

    @property
    def virtual_offset(self) -> float:
        return self._v_offset

    def last_virtual_end(self) -> float:
        """Latest virtual end across all spans (0.0 when none) — what the
        trainer re-bases a fresh engine's clock to."""
        ends = [s.v_end for s in self.spans if s.has_virtual]
        return max(ends) if ends else 0.0

    # ------------------------------------------------------------------
    def record(self, name: str, *, cat: str, track: str,
               v_start: float, v_end: float,
               parent: Optional[int] = None,
               args: Optional[Dict[str, Any]] = None,
               wall_start: float = NAN, wall_end: float = NAN) -> int:
        """Append a virtually-timed span; returns its id (for children)."""
        if parent is None and self._stack:
            parent = self._stack[-1]
        sid = self._next_id
        self._next_id += 1
        self.spans.append(Span(
            sid, parent, name, cat, track,
            v_start=self._v_offset + float(v_start),
            v_end=self._v_offset + float(v_end),
            wall_start=wall_start, wall_end=wall_end,
            args=dict(args or {})))
        return sid

    @contextmanager
    def span(self, name: str, *, cat: str = "host", track: str = "host",
             args: Optional[Dict[str, Any]] = None) -> Iterator[int]:
        """Wall-clocked span around host work; nests via the stack."""
        parent = self._stack[-1] if self._stack else None
        sid = self._next_id
        self._next_id += 1
        self._stack.append(sid)
        t0 = self._now()
        try:
            yield sid
        finally:
            self._stack.pop()
            self.spans.append(Span(
                sid, parent, name, cat, track,
                wall_start=t0, wall_end=self._now(),
                args=dict(args or {})))

    # ------------------------------------------------------------------
    def children(self, span_id: Optional[int]) -> List[Span]:
        return [s for s in self.spans if s.parent_id == span_id]

    def by_cat(self, cat: str) -> List[Span]:
        return [s for s in self.spans if s.cat == cat]

    def by_id(self, span_id: int) -> Span:
        for s in self.spans:
            if s.span_id == span_id:
                return s
        raise KeyError(span_id)

    # ------------------------------------------------------------------
    def to_chrome(self, clock: str = "virtual") -> Dict[str, Any]:
        """Chrome-trace object: X events in microseconds, pid per clock."""
        if clock not in TRACE_CLOCKS:
            raise ValueError(f"clock={clock!r}; expected one of "
                             f"{list(TRACE_CLOCKS)}")
        tids: Dict[str, int] = {}

        def tid(track: str) -> int:
            if track not in tids:
                tids[track] = len(tids) + 1
            return tids[track]

        events: List[Dict[str, Any]] = []
        want_v = clock in ("virtual", "both")
        want_w = clock in ("wall", "both")
        for s in self.spans:
            # args must be JSON-finite: a trace with NaN breaks strict
            # Chrome-trace parsers, so non-finite values are stringified
            args = {k: (v if not isinstance(v, float) or math.isfinite(v)
                        else repr(v)) for k, v in s.args.items()}
            args["span_id"] = s.span_id
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            if want_v and s.has_virtual:
                events.append({
                    "name": s.name, "cat": s.cat, "ph": "X",
                    "pid": PID_VIRTUAL, "tid": tid(s.track),
                    "ts": s.v_start * 1e6,
                    "dur": max(0.0, s.v_dur) * 1e6,
                    "args": args})
            if want_w and s.has_wall:
                events.append({
                    "name": s.name, "cat": s.cat, "ph": "X",
                    "pid": PID_WALL, "tid": tid(s.track),
                    "ts": s.wall_start * 1e6,
                    "dur": max(0.0, s.wall_end - s.wall_start) * 1e6,
                    "args": args})
        meta: List[Dict[str, Any]] = []
        for pid, pname, on in ((PID_VIRTUAL, "virtual clock", want_v),
                               (PID_WALL, "wall clock", want_w)):
            if not on:
                continue
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": pname}})
            for track, t in sorted(tids.items(), key=lambda kv: kv[1]):
                meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                             "tid": t, "args": {"name": track}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms",
                "otherData": {"run_id": self.run_id, "clock": clock}}

    def export_chrome(self, path: str, clock: str = "virtual") -> str:
        obj = self.to_chrome(clock)
        validate_chrome_trace(obj)
        with open(path, "w") as f:
            # allow_nan=False: a file Perfetto rejects must fail HERE
            json.dump(obj, f, allow_nan=False)
        return path


def validate_chrome_trace(obj: Any) -> int:
    """Chrome-trace JSON-object-format schema check; returns the number of
    "X" complete events.  Raises ``ValueError`` on any violation — this is
    what CI runs against the exported file."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be an object with a traceEvents list")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    n_complete = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"event {i} missing required key {k!r}")
        if not isinstance(ev["ph"], str) or len(ev["ph"]) != 1:
            raise ValueError(f"event {i}: ph must be a 1-char phase code")
        if ev["ph"] == "X":
            n_complete += 1
            for k in ("ts", "dur"):
                v = ev.get(k)
                if not isinstance(v, (int, float)) or not math.isfinite(v):
                    raise ValueError(
                        f"event {i}: X event needs finite numeric {k!r}")
            if ev["dur"] < 0:
                raise ValueError(f"event {i}: negative dur")
    if n_complete == 0:
        raise ValueError("trace contains no complete ('X') events")
    return n_complete
