"""Cross-run divergence diffing: load two recorded runs, align their
rounds, and localize the FIRST place they part ways.

"These two runs should have been identical — where did they split?" is
the question every reproducibility bug starts with.  With the recorder's
artifacts the answer is mechanical:

  * ``knobs.jsonl``    — the controller's decision each round.  The first
    knob mismatch is a **controller** divergence: the runs were steered
    differently.  :func:`diff_runs` additionally replays each run's own
    feedback through its own manifest-rebuilt suite (``repro.obs.replay``)
    to say whether each side's decisions are still a pure function of its
    history — separating "the controller changed" from "the controller
    faithfully reacted to different measurements";
  * ``digests.jsonl``  — the committed global state, content-addressed.
    A digest mismatch at EQUAL knobs is a **numeric** divergence: same
    steering, different bits (a kernel change, a nondeterministic op, a
    different backend).  The digest sketches give its magnitude;
  * ``feedback.jsonl`` — the measurements.  A feedback mismatch at equal
    knobs and equal digests is a **measurement** divergence: the training
    state agreed but the environment readings (timing model, wire pricing)
    did not.

Fields compare exactly (the JSONL round-trips floats bit-exactly; that is
the recorder's foundation) with NaN == NaN — NaN is the schema's "not
measured" marker, and two unmeasured fields agree.
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.recorder import RunRecord, load_run

# divergence kinds, most specific wins (knobs checked before digests
# before feedback — steering differences explain everything downstream)
KIND_CONTROLLER = "controller"
KIND_NUMERIC = "numeric"
KIND_MEASUREMENT = "measurement"


@dataclass(frozen=True)
class DiffEntry:
    """One field that disagreed at one aligned round."""
    round_index: int
    field: str                   # e.g. "knobs.codec", "digest.global_digest"
    kind: str                    # controller | numeric | measurement
    a: Any
    b: Any

    def __str__(self) -> str:
        return (f"round {self.round_index} [{self.kind}] {self.field}: "
                f"{self.a!r} != {self.b!r}")


@dataclass
class RunDiff:
    """The full comparison of two recorded runs."""
    dir_a: str
    dir_b: str
    rounds_a: int = 0
    rounds_b: int = 0
    config_diffs: List[Tuple[str, Any, Any]] = field(default_factory=list)
    entries: List[DiffEntry] = field(default_factory=list)
    # replay self-consistency per side (None: replay not possible — no
    # manifest, e.g. a feedback-sink-off run)
    replay_ok_a: Optional[bool] = None
    replay_ok_b: Optional[bool] = None

    @property
    def identical(self) -> bool:
        return not self.entries and self.rounds_a == self.rounds_b

    @property
    def first_divergence(self) -> Optional[DiffEntry]:
        """The earliest mismatch; ties within a round break by kind
        (controller < numeric < measurement — upstream explains
        downstream)."""
        if not self.entries:
            return None
        order = {KIND_CONTROLLER: 0, KIND_NUMERIC: 1, KIND_MEASUREMENT: 2}
        return min(self.entries,
                   key=lambda e: (e.round_index, order[e.kind]))

    @property
    def kind(self) -> Optional[str]:
        """The first divergence's classification (None: identical)."""
        fd = self.first_divergence
        return fd.kind if fd is not None else None

    def report(self) -> str:
        lines = [f"diff {self.dir_a} vs {self.dir_b}",
                 f"  rounds: {self.rounds_a} vs {self.rounds_b}"]
        for path, a, b in self.config_diffs:
            lines.append(f"  config {path}: {a!r} != {b!r}")
        if self.identical:
            lines.append("  identical")
            return "\n".join(lines)
        fd = self.first_divergence
        if fd is not None:
            lines.append(f"  FIRST DIVERGENCE: {fd}")
        if self.replay_ok_a is not None or self.replay_ok_b is not None:
            lines.append(f"  replay self-consistent: "
                         f"a={self.replay_ok_a} b={self.replay_ok_b}")
        for e in self.entries[:20]:
            lines.append(f"  {e}")
        if len(self.entries) > 20:
            lines.append(f"  ... {len(self.entries) - 20} more")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# comparison primitives
# ---------------------------------------------------------------------------

def _eq(a: Any, b: Any) -> bool:
    """Exact equality with NaN == NaN (recursively through containers —
    the feedback maps hold float values)."""
    if isinstance(a, float) and isinstance(b, float):
        if math.isnan(a) and math.isnan(b):
            return True
        return a == b
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() and all(_eq(a[k], b[k]) for k in a)
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return len(a) == len(b) and all(_eq(x, y) for x, y in zip(a, b))
    return a == b


def _flat_config_diffs(ca: Dict[str, Any], cb: Dict[str, Any],
                       prefix: str = "") -> List[Tuple[str, Any, Any]]:
    out: List[Tuple[str, Any, Any]] = []
    for key in sorted(set(ca) | set(cb)):
        path = f"{prefix}{key}"
        if path.startswith("obs."):
            continue        # run_id / out_dir always differ between runs
        va, vb = ca.get(key), cb.get(key)
        if isinstance(va, dict) and isinstance(vb, dict):
            out.extend(_flat_config_diffs(va, vb, prefix=f"{path}."))
        elif not _eq(va, vb):
            out.append((path, va, vb))
    return out


def _dataclass_field_diffs(r: int, a: Any, b: Any, prefix: str, kind: str
                           ) -> List[DiffEntry]:
    da, db = asdict(a), asdict(b)
    return [DiffEntry(r, f"{prefix}.{k}", kind, da[k], db[k])
            for k in da if not _eq(da[k], db.get(k))]


def _replay_consistent(rec: RunRecord) -> Optional[bool]:
    if not rec.manifest or not rec.knobs:
        return None
    from repro.obs.replay import replay_run
    try:
        return replay_run(rec.run_dir).matches
    except Exception:
        return None


# ---------------------------------------------------------------------------
# the diff
# ---------------------------------------------------------------------------

def diff_runs(dir_a: str, dir_b: str, *,
              compare_feedback: bool = True) -> RunDiff:
    """Align two recorded runs round by round and report every mismatch,
    classified (see module docstring).  ``first_divergence`` answers the
    headline question; ``entries`` holds the full field-level fallout.

    Knob fields diverging at round r classify everything as *controller*
    from r on; a digest mismatch while knobs still agreed is *numeric*;
    feedback-only disagreement (set ``compare_feedback=False`` to skip,
    e.g. when comparing runs across machines whose timing models
    legitimately differ) is *measurement*.
    """
    ra, rb = load_run(dir_a), load_run(dir_b)
    out = RunDiff(dir_a=dir_a, dir_b=dir_b,
                  rounds_a=ra.num_rounds, rounds_b=rb.num_rounds)
    if ra.manifest and rb.manifest:
        out.config_diffs = _flat_config_diffs(
            ra.manifest.get("config", {}), rb.manifest.get("config", {}))

    n = min(ra.num_rounds, rb.num_rounds)
    knobs_diverged = False
    for r in range(n):
        # 1) steering: the knobs in force during round r
        if r < len(ra.knobs) and r < len(rb.knobs):
            kd = _dataclass_field_diffs(r, ra.knobs[r], rb.knobs[r],
                                        "knobs", KIND_CONTROLLER)
            if kd:
                knobs_diverged = True
            out.entries.extend(kd)
        # 2) numerics: the committed state digest
        if r < len(ra.digests) and r < len(rb.digests):
            da, db = ra.digests[r], rb.digests[r]
            kind = KIND_CONTROLLER if knobs_diverged else KIND_NUMERIC
            for f in ("global_digest", "opt_digest", "gan_digest",
                      "rolled_back"):
                va, vb = getattr(da, f), getattr(db, f)
                if not _eq(va, vb):
                    out.entries.append(
                        DiffEntry(r, f"digest.{f}", kind, va, vb))
        # 3) measurements: the feedback record
        if compare_feedback and r < len(ra.feedback) \
                and r < len(rb.feedback):
            kind = KIND_CONTROLLER if knobs_diverged else KIND_MEASUREMENT
            out.entries.extend(_dataclass_field_diffs(
                r, ra.feedback[r], rb.feedback[r], "feedback", kind))

    # numeric state diverging is upstream of the *next* round's
    # measurements, but a digest mismatch in round r with agreeing
    # feedback IN round r stays classified per-stream above; the
    # first_divergence tie-break (controller < numeric < measurement)
    # already surfaces the right cause.
    out.replay_ok_a = _replay_consistent(ra)
    out.replay_ok_b = _replay_consistent(rb)
    return out


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        description="diff two flight-recorder run directories")
    p.add_argument("run_a")
    p.add_argument("run_b")
    p.add_argument("--no-feedback", action="store_true",
                   help="skip feedback (measurement) comparison")
    args = p.parse_args(argv)
    d = diff_runs(args.run_a, args.run_b,
                  compare_feedback=not args.no_feedback)
    print(d.report())
    return 0 if d.identical else 1


if __name__ == "__main__":          # pragma: no cover
    raise SystemExit(main())
