"""Typed metric registry + JSONL sink for the federated split engine.

Three instrument kinds, mirroring the usual telemetry taxonomy:

  * :class:`Counter`   — monotone totals (wire bytes, straggler drops).
  * :class:`Gauge`     — last-value-wins per-round readings (codec error,
    per-boundary dCor, epsilon spend, losses).
  * :class:`Histogram` — distributions (client finish times): fixed
    log-spaced buckets plus exact count/sum/min/max.

:func:`observe_round` is the single choke point that turns one
``RoundFeedback`` into registry updates — `RoundFeedback` assembly feeds
this instead of each caller hand-rolling ad-hoc dicts.  The
:class:`JsonlSink` appends one snapshot object per round so a run's
metric history is greppable/plottable without rerunning anything.
"""
from __future__ import annotations

import json
import math
from bisect import bisect_right
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

METRIC_KINDS = ("counter", "gauge", "histogram")


@dataclass
class Counter:
    name: str
    help: str = ""
    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment")
        self.value += amount

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": "counter", "value": self.value}


@dataclass
class Gauge:
    name: str
    help: str = ""
    value: float = math.nan

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": "gauge", "value": self.value}


def _log_buckets(lo: float = 1e-3, hi: float = 1e3,
                 per_decade: int = 2) -> Tuple[float, ...]:
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


@dataclass
class Histogram:
    """Fixed-bucket histogram (upper bounds, +inf implicit) with exact
    count/sum/min/max so means survive coarse buckets."""
    name: str
    help: str = ""
    bounds: Tuple[float, ...] = field(default_factory=_log_buckets)
    counts: List[int] = field(default_factory=list)
    count: int = 0
    sum: float = 0.0
    min: float = math.inf
    max: float = -math.inf

    def __post_init__(self) -> None:
        if not self.counts:
            self.counts = [0] * (len(self.bounds) + 1)

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect_right(self.bounds, v)] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper bound of the covering bucket)."""
        if not self.count:
            return math.nan
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target and c:
                return self.bounds[i] if i < len(self.bounds) else self.max
        return self.max

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": "histogram", "count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "bounds": list(self.bounds), "counts": list(self.counts)}


class MetricsRegistry:
    """Get-or-create registry; re-registering with a different kind is an
    error (one name, one instrument, one meaning for the whole run)."""

    def __init__(self) -> None:
        self._metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name=name, help=help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  bounds: Optional[Tuple[float, ...]] = None) -> Histogram:
        kw = {"bounds": bounds} if bounds is not None else {}
        return self._get(Histogram, name, help, **kw)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __getitem__(self, name: str):
        return self._metrics[name]

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        return {n: self._metrics[n].snapshot() for n in self.names()}

    def render(self, *, prefix: str = "") -> str:
        """Human-readable dump for demos — one metric per line."""
        lines = []
        for n in self.names():
            if prefix and not n.startswith(prefix):
                continue
            m = self._metrics[n]
            if isinstance(m, Histogram):
                lines.append(
                    f"{n:<42s} hist  n={m.count} mean={m.mean:.4g} "
                    f"min={m.min:.4g} max={m.max:.4g} p90~{m.quantile(0.9):.4g}")
            elif isinstance(m, Counter):
                lines.append(f"{n:<42s} count {m.value:.6g}")
            else:
                lines.append(f"{n:<42s} gauge {m.value:.6g}")
        return "\n".join(lines)


class JsonlSink:
    """Append-only JSONL writer: one JSON object per line, flushed eagerly
    so a killed run still leaves a readable log."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a")

    def write(self, obj: Mapping[str, Any]) -> None:
        self._f.write(json.dumps(obj) + "\n")
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def load_jsonl(path: str) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def observe_round(registry: MetricsRegistry, fb) -> None:
    """Feed one ``RoundFeedback`` into the registry — the single choke
    point that replaces the old ad-hoc per-demo field printing."""
    registry.counter("fed.rounds", "rounds completed").inc()
    registry.counter("wire.up_bytes", "uplinked bytes, cumulative") \
        .inc(fb.up_bytes)
    registry.counter("wire.down_bytes", "downlinked bytes, cumulative") \
        .inc(fb.down_bytes)
    registry.counter("wire.lan_bytes", "intra-client LAN bytes, cumulative") \
        .inc(fb.lan_bytes)
    registry.counter("fed.straggler_drops", "clients past deadline, "
                     "cumulative").inc(fb.stragglers)
    registry.gauge("fed.round_time_s", "latest round makespan") \
        .set(fb.round_time_s)
    registry.gauge("fed.clock_s", "virtual clock after latest round") \
        .set(fb.clock_s)
    registry.gauge("codec.rel_error", "latest uplink codec relative error") \
        .set(fb.codec_error)
    registry.gauge("gan.d_loss", "latest discriminator loss").set(fb.d_loss)
    registry.gauge("gan.g_loss", "latest generator loss").set(fb.g_loss)
    registry.gauge("privacy.epsilon", "cumulative epsilon spend") \
        .set(fb.dp_epsilon)
    finish = registry.histogram("fed.client_finish_s",
                                "per-client finish times, all rounds")
    for t in fb.client_finish_s.values():
        if math.isfinite(t):
            finish.observe(t)
    # boundary_dcor: client id -> (dcor at boundary 0, 1, ...)
    for cid, dcors in sorted(fb.boundary_dcor.items()):
        for b, d in enumerate(dcors):
            registry.gauge(
                f"privacy.dcor.{cid}.b{b}",
                f"latest raw-activation dCor, client {cid} boundary {b}") \
                .set(d)
