"""Bench regression gating: compare fresh ``BENCH_*.json`` output against
the committed baselines, noise-aware, and fail loudly on regression.

The committed ``benchmarks/BENCH_*.json`` files are trajectory snapshots —
deterministic quantities (wire bytes, virtual round times, span counts,
boolean acceptance gates like ``replay_ok``/``budget_ok``) plus noisy
wall-clock timings.  Until now nothing *watched* them: a PR could silently
double the adaptive codec's uplink bytes or flip ``frontier_ok`` and the
only guard was a human reading a JSON diff.  This module is the gate:

    python -m repro.obs.regress --bench-dir benchmarks --baseline-git HEAD

re-reads the fresh files, pulls the committed baselines out of git, and
evaluates a per-metric rule table (:data:`RULES`): each rule gives a
wildcard path, a direction (``lower`` / ``higher`` is better, ``equal``
must match within tolerance, ``true`` must stay truthy) and a relative
tolerance.  Wall-clock rules are flagged ``noisy`` and get a separate —
CLI-overridable — tolerance, because CI CPUs jitter 2x without meaning
anything (``--noisy-rel-tol``).

Two safety valves keep the gate honest rather than brittle:

  * **config gate** — when the fresh file's ``config`` block differs from
    the baseline's (different BENCH_FAST shape, different client count),
    value rules are *skipped* (the numbers aren't comparable) while
    boolean rules still apply (an acceptance property must hold at any
    size);
  * **missing paths** — a value rule matching nothing is reported but
    only a missing *boolean* gate fails (deleting ``replay_ok`` from the
    bench is itself a regression).

Exit status is nonzero iff any rule fails; ``--report`` writes the
markdown table CI uploads as an artifact.
"""
from __future__ import annotations

import fnmatch
import json
import math
import os
import subprocess
import sys
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# rule table
# ---------------------------------------------------------------------------

DIR_LOWER = "lower"     # lower is better: fresh <= base * (1 + tol)
DIR_HIGHER = "higher"   # higher is better: fresh >= base * (1 - tol)
DIR_EQUAL = "equal"     # must match: |fresh - base| <= tol * max(|base|,1e-12)
DIR_TRUE = "true"       # boolean acceptance gate: fresh must stay truthy


@dataclass(frozen=True)
class Rule:
    """One metric's regression contract."""
    path: str               # '/'-joined wildcard path into the JSON
    direction: str          # lower | higher | equal | true
    rel_tol: float = 0.0
    noisy: bool = False     # wall-clock: tolerance overridable via CLI


RULES: Dict[str, Tuple[Rule, ...]] = {
    "BENCH_control.json": (
        # acceptance booleans — must hold at any bench size
        Rule("codec/frontier_ok", DIR_TRUE),
        Rule("codec/adaptive_bytes_le_best_static", DIR_TRUE),
        Rule("codec/adaptive_error_ok", DIR_TRUE),
        Rule("codec/adaptive/replay_ok", DIR_TRUE),
        Rule("sigma/budget_ok", DIR_TRUE),
        Rule("deadline/faster", DIR_TRUE),
        # deterministic trajectory values (virtual clock / priced wire)
        Rule("codec/adaptive/up_bytes", DIR_LOWER, 0.01),
        Rule("codec/static/*/up_bytes", DIR_EQUAL, 0.01),
        Rule("sigma/adaptive_epsilon", DIR_LOWER, 0.01),
        Rule("deadline/adaptive_round_s", DIR_LOWER, 0.05),
    ),
    "BENCH_fed_runtime.json": (
        Rule("codecs/*/up_mbytes", DIR_LOWER, 0.01),
        Rule("codecs/*/down_mbytes", DIR_EQUAL, 0.01),
        Rule("codecs/*/round_time_s", DIR_EQUAL, 0.01),
        Rule("scheduling/*/round_time_s", DIR_EQUAL, 0.01),
        Rule("scheduling/*/trace_spans", DIR_EQUAL, 0.0),
        Rule("scheduling/*/stragglers", DIR_EQUAL, 0.0),
        # pipelined split execution: acceptance gates hold at any size;
        # virtual round times are deterministic (priced LAN model)
        Rule("pipeline/speedup_ok", DIR_TRUE),
        Rule("pipeline/numerics_ok", DIR_TRUE),
        Rule("pipeline/boundary_fuse/fused_matches", DIR_TRUE),
        Rule("pipeline/k*/round_time_s", DIR_EQUAL, 0.01),
        # population scale: acceptance gates (size-independent booleans),
        # deterministic roster resampling, and the analytic byte/epsilon
        # model — all exact; roster sampling wall-clock is noisy
        Rule("scale/analytic_wan_cut_ok", DIR_TRUE),
        Rule("scale/deterministic", DIR_TRUE),
        Rule("scale/epsilon_monotone_ok", DIR_TRUE),
        Rule("scale/hier_round/wan_cut_ok", DIR_TRUE),
        Rule("scale/hier_round/wan_up_bytes_hier", DIR_LOWER, 0.01),
        Rule("scale/hier_round/wan_cut", DIR_HIGHER, 0.01),
        Rule("scale/populations/*/wan_bytes_flat", DIR_EQUAL, 0.0),
        Rule("scale/populations/*/wan_bytes_hier", DIR_EQUAL, 0.0),
        Rule("scale/populations/*/amplified_epsilon_100r",
             DIR_LOWER, 0.01),
        Rule("scale/populations/*/rounds_per_s_hier", DIR_HIGHER, 0.01),
        # compressed-domain streaming aggregation: numerics + speedup are
        # acceptance gates at any size; wire bytes and peak live decoded
        # tree counts are deterministic (exact); timings are wall-clock
        Rule("agg/c*/numerics_ok", DIR_TRUE),
        Rule("agg/c64/speedup_ok", DIR_TRUE),
        Rule("agg/c*/wire_bytes", DIR_EQUAL, 0.0),
        Rule("agg/c*/peak_trees_decode", DIR_EQUAL, 0.0),
        Rule("agg/c*/peak_trees_stream", DIR_EQUAL, 0.0),
        # wall-clock: CI CPUs jitter wildly — wide default, overridable
        Rule("agg/c*/*_us", DIR_LOWER, 1.0, noisy=True),
        Rule("dispatch/*_us", DIR_LOWER, 1.0, noisy=True),
        Rule("codecs/*/us_per_epoch", DIR_LOWER, 1.0, noisy=True),
        Rule("scheduling/*/us_per_epoch", DIR_LOWER, 1.0, noisy=True),
        Rule("pipeline/k*/us_per_epoch", DIR_LOWER, 1.0, noisy=True),
        Rule("pipeline/boundary_fuse/*_us", DIR_LOWER, 1.0, noisy=True),
        Rule("scale/populations/*/sample_us", DIR_LOWER, 1.0, noisy=True),
        Rule("scale/sharded/*_us", DIR_LOWER, 1.0, noisy=True),
    ),
    "BENCH_privacy.json": (
        # deterministic fixed-prefix probes
        Rule("split_depth_dcor/*", DIR_EQUAL, 0.10),
        Rule("strategy_boundaries/*/min_depth", DIR_EQUAL, 0.0),
        Rule("strategy_boundaries/*/mean_depth", DIR_EQUAL, 0.0),
    ),
}


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

@dataclass
class Check:
    """One evaluated (rule, path) pair."""
    file: str
    path: str
    rule: Rule
    baseline: Any = None
    fresh: Any = None
    status: str = "pass"    # pass | fail | skip | missing
    note: str = ""

    @property
    def failed(self) -> bool:
        return self.status == "fail"


def _flatten(obj: Any, prefix: str = "") -> Dict[str, Any]:
    """Scalar leaves of a JSON tree as '/'-joined paths (list entries
    indexed numerically)."""
    out: Dict[str, Any] = {}
    if isinstance(obj, dict):
        for k, v in obj.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = obj
    return out


def _match(rule: Rule, paths) -> List[str]:
    return sorted(p for p in paths if fnmatch.fnmatchcase(p, rule.path))


def _num(x: Any) -> Optional[float]:
    if isinstance(x, bool) or not isinstance(x, (int, float)):
        return None
    return float(x)


def _eval_one(rule: Rule, base: Any, fresh: Any, tol: float) -> Tuple[str, str]:
    """-> (status, note) for one matched path."""
    if rule.direction == DIR_TRUE:
        return ("pass", "") if fresh else ("fail", "gate is falsy")
    b, f = _num(base), _num(fresh)
    if b is None or f is None:
        return "skip", "non-numeric"
    if math.isnan(b) and math.isnan(f):
        return "pass", "both NaN"
    if math.isinf(b) and math.isinf(f) and (b > 0) == (f > 0):
        return "pass", "both infinite"       # e.g. epsilon with no DP
    if rule.direction == DIR_LOWER:
        ok = f <= b * (1.0 + tol) + 1e-12
        return ("pass", "") if ok else (
            "fail", f"{f:.6g} > baseline {b:.6g} (+{tol:.0%})")
    if rule.direction == DIR_HIGHER:
        ok = f >= b * (1.0 - tol) - 1e-12
        return ("pass", "") if ok else (
            "fail", f"{f:.6g} < baseline {b:.6g} (-{tol:.0%})")
    # equal
    ok = abs(f - b) <= tol * max(abs(b), 1e-12)
    return ("pass", "") if ok else (
        "fail", f"{f:.6g} != baseline {b:.6g} (tol {tol:.0%})")


def evaluate(fresh: Dict[str, Any], baseline: Dict[str, Any],
             rules: Tuple[Rule, ...], *, file: str = "",
             noisy_rel_tol: Optional[float] = None) -> List[Check]:
    """Run one file's rule table.  When the two ``config`` blocks differ
    the numbers aren't comparable — value rules are skipped, boolean
    gates still apply (the config gate; see module docstring)."""
    fb, bb = _flatten(fresh), _flatten(baseline)
    cfg_differs = fresh.get("config") != baseline.get("config")
    checks: List[Check] = []
    for rule in rules:
        tol = rule.rel_tol
        if rule.noisy and noisy_rel_tol is not None:
            tol = noisy_rel_tol
        matched = _match(rule, set(fb) | set(bb))
        if not matched:
            status = "fail" if rule.direction == DIR_TRUE else "missing"
            checks.append(Check(file, rule.path, rule, status=status,
                                note="no matching paths"))
            continue
        for p in matched:
            c = Check(file, p, rule, baseline=bb.get(p), fresh=fb.get(p))
            if p not in fb or p not in bb:
                missing = "fresh" if p not in fb else "baseline"
                c.status = ("fail" if rule.direction == DIR_TRUE
                            else "missing")
                c.note = f"path absent in {missing}"
            elif cfg_differs and rule.direction != DIR_TRUE:
                c.status, c.note = "skip", "config blocks differ"
            else:
                c.status, c.note = _eval_one(rule, bb[p], fb[p], tol)
            checks.append(c)
    return checks


# ---------------------------------------------------------------------------
# baseline sources + report
# ---------------------------------------------------------------------------

def git_baseline(bench_dir: str, name: str, ref: str
                 ) -> Optional[Dict[str, Any]]:
    """The committed version of ``<bench_dir>/<name>`` at ``ref`` (None:
    not in git at that ref)."""
    try:
        out = subprocess.run(
            ["git", "-C", bench_dir, "show", f"{ref}:./{name}"],
            capture_output=True, text=True, check=True).stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, json.JSONDecodeError,
            FileNotFoundError):
        return None


def run_gate(bench_dir: str, *, baseline_git: Optional[str] = None,
             noisy_rel_tol: Optional[float] = None) -> List[Check]:
    """Evaluate every known bench file present in ``bench_dir``.

    ``baseline_git=None`` compares each file against itself — trivially
    green on an unmodified tree, which makes the local no-op invocation a
    self-test of the rule table.  CI runs the benches (overwriting the
    files), then gates with ``baseline_git='HEAD'``.
    """
    checks: List[Check] = []
    for name, rules in sorted(RULES.items()):
        path = os.path.join(bench_dir, name)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            fresh = json.load(f)
        if baseline_git is None:
            baseline = fresh
        else:
            baseline = git_baseline(bench_dir, name, baseline_git)
            if baseline is None:
                checks.append(Check(name, "<file>", Rule(name, DIR_EQUAL),
                                    status="missing",
                                    note=f"no baseline at {baseline_git}"))
                continue
        checks.extend(evaluate(fresh, baseline, rules, file=name,
                               noisy_rel_tol=noisy_rel_tol))
    return checks


def markdown_report(checks: List[Check]) -> str:
    failed = [c for c in checks if c.failed]
    lines = ["# Bench regression report", "",
             f"**{'REGRESSION' if failed else 'PASS'}** — "
             f"{len(failed)} failed / {len(checks)} checks", ""]
    lines += ["| file | path | direction | baseline | fresh | status |",
              "|---|---|---|---|---|---|"]
    # failures first, then everything else
    for c in sorted(checks, key=lambda c: (not c.failed, c.file, c.path)):
        mark = {"pass": "ok", "fail": "**FAIL**", "skip": "skip",
                "missing": "missing"}[c.status]
        note = f" ({c.note})" if c.note and c.status != "pass" else ""
        lines.append(f"| {c.file} | `{c.path}` | {c.rule.direction} "
                     f"| {c.baseline!r} | {c.fresh!r} | {mark}{note} |")
    return "\n".join(lines) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        description="gate fresh BENCH_*.json output against committed "
                    "baselines")
    p.add_argument("--bench-dir", default="benchmarks",
                   help="directory holding BENCH_*.json (default: "
                        "benchmarks)")
    p.add_argument("--baseline-git", default=None, metavar="REF",
                   help="take baselines from this git ref (e.g. HEAD); "
                        "default compares files to themselves (rule-table "
                        "self-test)")
    p.add_argument("--noisy-rel-tol", type=float, default=None,
                   help="override the tolerance of noisy (wall-clock) "
                        "rules, e.g. 2.0 on shared CI CPUs")
    p.add_argument("--report", default=None, metavar="PATH",
                   help="write the markdown report here")
    args = p.parse_args(argv)

    checks = run_gate(args.bench_dir, baseline_git=args.baseline_git,
                      noisy_rel_tol=args.noisy_rel_tol)
    report = markdown_report(checks)
    if args.report:
        with open(args.report, "w") as f:
            f.write(report)
    print(report)
    if not checks:
        print("no bench files found — nothing gated", file=sys.stderr)
        return 2
    return 1 if any(c.failed for c in checks) else 0


if __name__ == "__main__":
    raise SystemExit(main())
