"""Flight recorder + watchtower for the federated split engine: tracing,
metrics, recording + replay, profiling (ISSUE 6 / ROADMAP item 4), and the
detection layer over it — health monitors, content digests, run diffing,
bench regression gating (ISSUE 7).

  * :mod:`repro.obs.trace`    — two-clock nested spans + Chrome-trace export
  * :mod:`repro.obs.metrics`  — typed counter/gauge/histogram registry + JSONL
  * :mod:`repro.obs.recorder` — per-run persistence of feedback/knobs/metrics
    /alerts/digests
  * :mod:`repro.obs.replay`   — offline controller replay over recorded logs
  * :mod:`repro.obs.profile`  — jit + kernel timing feeding the roofline model
  * :mod:`repro.obs.health`   — per-round numeric-health monitors + policies
  * :mod:`repro.obs.digest`   — content digests of the committed global state
  * :mod:`repro.obs.diff`     — cross-run divergence localization
  * :mod:`repro.obs.regress`  — bench-baseline regression gate (CLI)
"""
from repro.obs.diff import DiffEntry, RunDiff, diff_runs
from repro.obs.digest import (RoundDigest, digest_from_dict, digest_to_dict,
                              state_digest, tree_digest, tree_sketch)
from repro.obs.health import (HEALTH_CHECKS, HealthAbort, HealthAlert,
                              HealthMonitor, alert_from_dict, alert_to_dict)
from repro.obs.metrics import (Counter, Gauge, Histogram, JsonlSink,
                               MetricsRegistry, load_jsonl, observe_round)
from repro.obs.profile import (KernelProfile, profile_agg_fuse,
                               profile_dp_clip, profile_engine_kernels,
                               profile_fedavg, profile_jit)
from repro.obs.recorder import (FlightRecorder, RunRecord, feedback_from_dict,
                                feedback_to_dict, knobs_from_dict,
                                knobs_to_dict, load_run)
from repro.obs.replay import (ReplayResult, replay_decisions, replay_run,
                              suite_from_manifest)
from repro.obs.trace import (Span, Tracer, validate_chrome_trace)

__all__ = [
    "DiffEntry", "RunDiff", "diff_runs",
    "RoundDigest", "digest_from_dict", "digest_to_dict", "state_digest",
    "tree_digest", "tree_sketch",
    "HEALTH_CHECKS", "HealthAbort", "HealthAlert", "HealthMonitor",
    "alert_from_dict", "alert_to_dict",
    "Counter", "Gauge", "Histogram", "JsonlSink", "MetricsRegistry",
    "load_jsonl", "observe_round",
    "KernelProfile", "profile_agg_fuse", "profile_dp_clip",
    "profile_engine_kernels", "profile_fedavg", "profile_jit",
    "FlightRecorder", "RunRecord", "feedback_from_dict", "feedback_to_dict",
    "knobs_from_dict", "knobs_to_dict", "load_run",
    "ReplayResult", "replay_decisions", "replay_run", "suite_from_manifest",
    "Span", "Tracer", "validate_chrome_trace",
]
