"""Flight recorder for the federated split engine: tracing, metrics,
recording + replay, and profiling (see ISSUE 6 / ROADMAP item 4).

  * :mod:`repro.obs.trace`    — two-clock nested spans + Chrome-trace export
  * :mod:`repro.obs.metrics`  — typed counter/gauge/histogram registry + JSONL
  * :mod:`repro.obs.recorder` — per-run persistence of feedback/knobs/metrics
  * :mod:`repro.obs.replay`   — offline controller replay over recorded logs
  * :mod:`repro.obs.profile`  — jit + kernel timing feeding the roofline model
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, JsonlSink,
                               MetricsRegistry, load_jsonl, observe_round)
from repro.obs.profile import (KernelProfile, profile_dp_clip,
                               profile_engine_kernels, profile_fedavg,
                               profile_jit)
from repro.obs.recorder import (FlightRecorder, RunRecord, feedback_from_dict,
                                feedback_to_dict, knobs_from_dict,
                                knobs_to_dict, load_run)
from repro.obs.replay import (ReplayResult, replay_decisions, replay_run,
                              suite_from_manifest)
from repro.obs.trace import (Span, Tracer, validate_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "JsonlSink", "MetricsRegistry",
    "load_jsonl", "observe_round",
    "KernelProfile", "profile_dp_clip", "profile_engine_kernels",
    "profile_fedavg", "profile_jit",
    "FlightRecorder", "RunRecord", "feedback_from_dict", "feedback_to_dict",
    "knobs_from_dict", "knobs_to_dict", "load_run",
    "ReplayResult", "replay_decisions", "replay_run", "suite_from_manifest",
    "Span", "Tracer", "validate_chrome_trace",
]
