"""Profiling hooks: jit compile-time and per-kernel timing, feeding the
roofline model.

Everything here is measurement-only and OFF by default
(``cfg.obs.profile_kernels=False``): profiling triggers extra jit
compilations of the hot aggregation/privacy kernels (fedavg, dp_clip) on
synthetic inputs, so the gate exists to keep ``control=frozen`` runs doing
zero extra work — numerics are untouched either way (the profiled
programs never feed training state).

Each profile records the three costs a kernel pays:

  * ``lower_s`` / ``compile_s`` — jit trace + XLA compile wall time
    (the constant SplitEasy warns dominates short on-device runs);
  * ``run_s``   — best-of-N executed wall time (block_until_ready);
  * roofline terms — flops / bytes from ``compiled.cost_analysis()``
    against the target HwSpec (``repro.roofline.analysis.kernel_terms``),
    i.e. where the kernel sits on the compute/memory roof.
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.roofline.analysis import kernel_terms
from repro.roofline.hw import TPU_V5E, HwSpec


@dataclass
class KernelProfile:
    name: str
    lower_s: float
    compile_s: float
    run_s: float                  # best-of-N executed time
    runs: int
    flops: float = 0.0
    bytes_accessed: float = 0.0
    compute_term_s: float = 0.0
    memory_term_s: float = 0.0
    arithmetic_intensity: float = 0.0

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)


def profile_jit(name: str, fn: Callable, *args, hw: HwSpec = TPU_V5E,
                runs: int = 3) -> KernelProfile:
    """Lower + compile + time one callable on the given args.

    ``fn`` is traced fresh through ``jax.jit`` so the lower/compile split
    is measured even when the callable is already cached elsewhere.
    """
    jfn = jax.jit(fn)
    t0 = time.perf_counter()
    lowered = jfn.lower(*args)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    best = float("inf")
    for _ in range(max(1, runs)):
        r0 = time.perf_counter()
        out = compiled(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - r0)
    terms = kernel_terms(compiled, hw)
    return KernelProfile(name=name, lower_s=t1 - t0, compile_s=t2 - t1,
                         run_s=best, runs=max(1, runs), **terms)


# ---------------------------------------------------------------------------
# the engine's hot kernels on synthetic inputs
# ---------------------------------------------------------------------------

def profile_fedavg(*, num_clients: int = 4, n: int = 8192,
                   interpret: bool = True, hw: HwSpec = TPU_V5E,
                   runs: int = 3) -> KernelProfile:
    """The fedavg aggregation kernel: (C, N) stacked client updates ->
    weighted mean.  ``interpret=True`` runs the Pallas kernel in interpret
    mode (the CPU-safe path CI uses)."""
    from repro.kernels.fedavg.ops import fedavg_flat
    key = jax.random.PRNGKey(0)
    stacked = jax.random.normal(key, (num_clients, n), jnp.float32)
    weights = jnp.ones((num_clients,), jnp.float32)

    def fn(s, w):
        return fedavg_flat(s, w, interpret=interpret)

    return profile_jit(f"fedavg_c{num_clients}_n{n}", fn, stacked, weights,
                       hw=hw, runs=runs)


def profile_dp_clip(*, batch: int = 8, n: int = 4096, clip: float = 1.0,
                    sigma: float = 1.0, use_kernel: bool = False,
                    interpret: bool = True, hw: HwSpec = TPU_V5E,
                    runs: int = 3) -> KernelProfile:
    """The dp_clip privatization: per-example (B, N) grads -> clipped,
    noised sum (the DP-SGD inner release)."""
    from repro.kernels.dp_clip.ops import dp_clip_noise_flat
    key = jax.random.PRNGKey(1)
    k1, k2 = jax.random.split(key)
    stacked = jax.random.normal(k1, (batch, n), jnp.float32)
    noise = jax.random.normal(k2, (n,), jnp.float32)
    c = jnp.asarray(clip, jnp.float32)
    s = jnp.asarray(sigma * clip, jnp.float32)

    def fn(g, nz):
        return dp_clip_noise_flat(g, c, s, nz, use_kernel=use_kernel,
                                  interpret=interpret)

    kind = "kernel" if use_kernel else "ref"
    return profile_jit(f"dp_clip_{kind}_b{batch}_n{n}", fn, stacked, noise,
                       hw=hw, runs=runs)


def profile_boundary_fuse(*, batch: int = 8, n: int = 4096,
                          codec: str = "int8", clip: float = 1.0,
                          sigma: float = 0.5, use_kernel: bool = False,
                          interpret: bool = True, hw: HwSpec = TPU_V5E,
                          runs: int = 3) -> KernelProfile:
    """The fused boundary-crossing stage (kernels/boundary_fuse): codec
    qdq + per-example clip + Gaussian noise over one flattened (B, N)
    boundary tensor — what every hop of a composed ``codec+dp`` split
    stage pays."""
    from repro.kernels.boundary_fuse.ops import fused_boundary_flat
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (batch, n), jnp.float32)
    noise = jax.random.normal(k2, (batch, n), jnp.float32)
    c = jnp.asarray(clip, jnp.float32)
    s = jnp.asarray(sigma * clip, jnp.float32)

    def fn(t, nz):
        return fused_boundary_flat(t, c, s, nz, codec=codec,
                                   use_kernel=use_kernel,
                                   interpret=interpret)

    kind = "kernel" if use_kernel else "ref"
    return profile_jit(f"boundary_fuse_{codec}_{kind}_b{batch}_n{n}",
                       fn, x, noise, hw=hw, runs=runs)


def profile_agg_fuse(*, num_clients: int = 4, n: int = 8192,
                     codec: str = "int8", use_kernel: bool = False,
                     interpret: bool = True, hw: HwSpec = TPU_V5E,
                     runs: int = 3) -> KernelProfile:
    """The fused dequant-reduce server aggregation (kernels/agg_fuse):
    (C, N) compressed client wires + per-client scales -> one fp32
    weighted mean without materializing decoded trees — what
    ``fed.server_reduce != 'decode'`` replaces decode-then-fedavg with."""
    from repro.kernels.agg_fuse.ops import dequant_reduce_flat
    key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    if codec == "int8":
        wires = jax.random.randint(k1, (num_clients, n), -127, 128,
                                   jnp.int32).astype(jnp.int8)
        scales = jax.random.uniform(k2, (num_clients,), jnp.float32,
                                    1e-3, 1e-1)
    else:
        wires = jax.random.normal(k1, (num_clients, n), jnp.float32)
        if codec == "fp16":
            wires = wires.astype(jnp.float16)
        scales = jnp.ones((num_clients,), jnp.float32)
    weights = jnp.ones((num_clients,), jnp.float32)

    def fn(w, s, wt):
        return dequant_reduce_flat(w, s, wt, use_kernel=use_kernel,
                                   interpret=interpret)

    kind = "kernel" if use_kernel else "ref"
    return profile_jit(f"agg_fuse_{codec}_{kind}_c{num_clients}_n{n}",
                       fn, wires, scales, weights, hw=hw, runs=runs)


def profile_engine_kernels(cfg=None, *, hw: HwSpec = TPU_V5E,
                           runs: int = 3) -> Dict[str, Dict[str, Any]]:
    """Profile the kernels one engine round leans on, sized from ``cfg``
    when given (aggregation width = number of clients; dp_clip on when the
    privacy subsystem is; boundary_fuse when the split stage composes).
    Returns ``{name: profile dict}`` — what the recorder writes to
    ``profile.json``."""
    num_clients = cfg.fsl.num_clients if cfg is not None else 4
    profiles = [profile_fedavg(num_clients=max(2, num_clients),
                               interpret=True, hw=hw, runs=runs)]
    dp_on = cfg is None or cfg.privacy.enabled
    if dp_on:
        # interpret mode keeps the Pallas path CPU-safe regardless of the
        # training config's kernel flags — this is a probe, not training
        profiles.append(profile_dp_clip(
            use_kernel=bool(cfg and cfg.privacy.use_kernel),
            interpret=True, hw=hw, runs=runs))
    stage = cfg.split.boundary_stage if cfg is not None else "int8+dp"
    if "+" in stage:
        codec = stage.split("+")[0]
        if codec in ("fp16", "int8"):
            profiles.append(profile_boundary_fuse(
                codec=codec,
                use_kernel=bool(cfg and cfg.split.use_kernel),
                interpret=True, hw=hw, runs=runs))
    # compressed-domain server reduce (kernels/agg_fuse): profiled when a
    # dense lossy uplink codec is configured — the fused dequant-reduce is
    # what fed.server_reduce != "decode" folds each uplink through
    up_codec = cfg.fed.codec if cfg is not None else "int8"
    if up_codec in ("fp16", "int8"):
        profiles.append(profile_agg_fuse(
            num_clients=max(2, num_clients), codec=up_codec,
            use_kernel=bool(cfg and cfg.fed.kernel_aggregation),
            interpret=True, hw=hw, runs=runs))
    return {p.name: p.to_dict() for p in profiles}
