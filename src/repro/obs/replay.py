"""Offline controller replay — the on-ramp to trace-driven simulation
(ROADMAP item 4).

The PR-5 controllers are pure functions ``(history, knobs) -> knobs``, so
a recorded run's knob decisions are a deterministic fold over its feedback
log:

    decision_r = suite(history[:r], decision_{r-1}),
    decision_{-1} = knobs_from_config(cfg)

which is EXACTLY the fold the live trainer runs before each round (the
adaptive branch of ``FSLGANTrainer.train_epoch``).  :func:`replay_run`
loads a recorded run directory, rebuilds the controller suite from its
manifest, re-runs the fold over the recorded feedback, and compares
against the recorded knob log — bit-exact equality is pinned in tests
(floats round-trip exactly through the JSONL; :class:`ControlKnobs` holds
no NaN fields, so frozen-dataclass equality is the right comparison).

This is what makes controller tuning an offline activity: edit a
controller constant, replay a week of recorded feedback, diff the decision
sequences — no engine, no jit, no GPU.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.config import RunConfig
from repro.control.controllers import ControllerSuite, make_controllers
from repro.control.feedback import (ControlKnobs, RoundFeedback,
                                    knobs_from_config)
from repro.obs.recorder import RunRecord, load_run


def replay_decisions(suite: ControllerSuite,
                     history: Sequence[RoundFeedback],
                     initial_knobs: ControlKnobs) -> List[ControlKnobs]:
    """The pure decision fold: what knobs were in force during each
    recorded round.  ``decisions[r]`` is the suite's output given the
    feedback of rounds ``0..r-1`` — the trainer applies it BEFORE round
    ``r`` runs."""
    decisions: List[ControlKnobs] = []
    knobs = initial_knobs
    for r in range(len(history)):
        knobs = suite(list(history[:r]), knobs)
        decisions.append(knobs)
    return decisions


def suite_from_manifest(manifest: dict) -> ControllerSuite:
    """Rebuild the exact live controller suite from a run manifest."""
    cfg = RunConfig.from_dict(manifest["config"])
    return make_controllers(
        cfg, leaf_sizes=manifest["leaf_sizes"],
        steps_per_round_hint=manifest.get("steps_per_round_hint", 1))


@dataclass
class ReplayResult:
    record: RunRecord
    decisions: List[ControlKnobs] = field(default_factory=list)
    mismatches: List[int] = field(default_factory=list)   # round indices

    @property
    def matches(self) -> bool:
        """True iff every replayed decision equals the recorded one."""
        return not self.mismatches and \
            len(self.decisions) == len(self.record.knobs)

    def diff(self) -> List[str]:
        out = []
        for r in self.mismatches:
            out.append(f"round {r}: replayed {self.decisions[r]} != "
                       f"recorded {self.record.knobs[r]}")
        return out


def replay_run(run_dir: str, *,
               suite: Optional[ControllerSuite] = None) -> ReplayResult:
    """Load a recorded run and replay its feedback through the (rebuilt or
    provided) controller suite; compare against the recorded knob log.

    A frozen-mode recording replays trivially (empty suite, knobs constant
    at the config seed); an adaptive recording must reproduce every codec
    swap, sigma rebind, split regroup and deadline retune bit-exactly —
    any mismatch means a controller stopped being a pure function of the
    feedback history, which is exactly the regression this guards."""
    rec = load_run(run_dir)
    if not rec.manifest:
        raise FileNotFoundError(f"{run_dir}: no manifest.json — "
                                "was the run recorded with the feedback "
                                "sink enabled?")
    cfg = RunConfig.from_dict(rec.manifest["config"])
    if suite is None:
        # mirror the trainer's adaptive gate: a frozen run never consults
        # the suite, so replaying one through controllers that were never
        # live would manufacture spurious mismatches
        if cfg.control.mode == "adaptive" and cfg.control.controllers:
            suite = suite_from_manifest(rec.manifest)
        else:
            suite = ControllerSuite([])
    decisions = replay_decisions(suite, rec.feedback, knobs_from_config(cfg))
    result = ReplayResult(record=rec, decisions=decisions)
    for r, (got, want) in enumerate(zip(decisions, rec.knobs)):
        if got != want:
            result.mismatches.append(r)
    return result
