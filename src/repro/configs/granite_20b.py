"""granite-20b — dense (code), 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152. Llama-style architecture with multi-query attention.
[arXiv:2405.04324]
"""
from repro.config import ModelConfig, OptimConfig, ParallelConfig, RunConfig


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="granite-20b", family="dense",
            num_layers=52, d_model=6144, num_heads=48, num_kv_heads=1,
            head_dim=128, d_ff=24576, vocab_size=49152, max_seq_len=8192,
            source="[arXiv:2405.04324]",
        ),
        parallel=ParallelConfig(param_dtype="bfloat16", microbatches=8),
        optim=OptimConfig(lr=2e-4, weight_decay=0.1, schedule="cosine",
                          warmup_steps=200, total_steps=10_000),
    ).validate()
