"""qwen3-14b — dense, 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.

qk_norm on query/key heads, SwiGLU MLP, RoPE. [hf:Qwen/Qwen3-8B]
"""
from repro.config import ModelConfig, OptimConfig, ParallelConfig, RunConfig


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="qwen3-14b", family="dense",
            num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
            head_dim=128, d_ff=17408, vocab_size=151936, max_seq_len=32768,
            qk_norm=True, rope_theta=1_000_000.0,
            source="[hf:Qwen/Qwen3-8B]",
        ),
        parallel=ParallelConfig(param_dtype="bfloat16", microbatches=8),
        optim=OptimConfig(lr=3e-4, weight_decay=0.1, schedule="cosine",
                          warmup_steps=200, total_steps=10_000),
    ).validate()
