"""Architecture registry: ``--arch <id>`` resolution.

Each assigned architecture lives in its own module exposing ``config()``.
``long_500k`` applicability per DESIGN.md §4: native for state-based archs,
sliding-window variant for full-attention decoders, skipped for whisper.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ATTN_SLIDING, INPUT_SHAPES, RunConfig

# arch id -> module name
_ARCHS: Dict[str, str] = {
    "qwen3-14b": "repro.configs.qwen3_14b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1b6",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "whisper-base": "repro.configs.whisper_base",
    "granite-20b": "repro.configs.granite_20b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "llama3-405b": "repro.configs.llama3_405b",
    # the paper's own model
    "dcgan-mnist": "repro.configs.dcgan_mnist",
}

ASSIGNED_ARCHS: List[str] = [a for a in _ARCHS if a != "dcgan-mnist"]
SHAPES: List[str] = list(INPUT_SHAPES)

# long_500k handling per arch (DESIGN.md §4)
LONG_NATIVE = {"rwkv6-1.6b", "recurrentgemma-9b"}
LONG_SKIP = {"whisper-base"}          # decoder max positions = 448


def list_archs() -> List[str]:
    return list(_ARCHS)


def get_config(arch: str, shape: str | None = None) -> RunConfig:
    """Resolve ``--arch <id>`` (optionally bound to an input shape)."""
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCHS)}")
    cfg: RunConfig = importlib.import_module(_ARCHS[arch]).config()
    if shape is not None:
        if shape not in INPUT_SHAPES:
            raise KeyError(f"unknown shape {shape!r}; known: {SHAPES}")
        cfg = cfg.override({
            "shape.name": INPUT_SHAPES[shape].name,
            "shape.seq_len": INPUT_SHAPES[shape].seq_len,
            "shape.global_batch": INPUT_SHAPES[shape].global_batch,
            "shape.mode": INPUT_SHAPES[shape].mode,
        })
        if shape == "long_500k" and arch not in LONG_NATIVE:
            if arch in LONG_SKIP:
                raise SkippedShape(
                    f"{arch}: long_500k skipped (decoder max positions 448)")
            # dense/moe/vlm: beyond-paper sliding-window variant (DESIGN.md §4)
            cfg = cfg.override({"model.attention": ATTN_SLIDING,
                                "model.sliding_window": 4096})
        cfg = cfg.validate()
    return cfg


class SkippedShape(Exception):
    """Raised when an (arch, shape) pair is skipped by design (DESIGN.md §4)."""


def iter_pairs(include_skipped: bool = False):
    """Yield (arch, shape, cfg_or_None) for the 10x4 assignment matrix."""
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            try:
                yield arch, shape, get_config(arch, shape)
            except SkippedShape:
                if include_skipped:
                    yield arch, shape, None
