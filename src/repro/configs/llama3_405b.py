"""llama3-405b — dense, 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256. The scale stressor for the production mesh. [arXiv:2407.21783]

Dry-run memory accounting uses bf16 params + bf16 Adam moments (ZeRO-style
fully sharded); see EXPERIMENTS.md §Dry-run for the per-device bytes.
"""
from repro.config import ModelConfig, OptimConfig, ParallelConfig, RunConfig


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="llama3-405b", family="dense",
            num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
            head_dim=128, d_ff=53248, vocab_size=128256, max_seq_len=8192,
            rope_theta=500_000.0,
            source="[arXiv:2407.21783]",
        ),
        # microbatches=16 keeps per-microbatch batch (256/16) == data axis
        # extent so activations stay batch-sharded (EXPERIMENTS §Perf it1)
        parallel=ParallelConfig(param_dtype="bfloat16", microbatches=16,
                                accum_dtype="bfloat16"),
        optim=OptimConfig(lr=8e-5, weight_decay=0.1, schedule="cosine",
                          warmup_steps=2000, total_steps=50_000,
                          state_dtype="bfloat16"),
    ).validate()
