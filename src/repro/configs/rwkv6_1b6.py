"""rwkv6-1.6b — SSM ("Finch"), 24L d_model=2048 attention-free d_ff=7168
vocab=65536. Data-dependent decay WKV recurrence, token-shift ddlerp,
channel-mix MLP. [arXiv:2404.05892]

Attention-free: decode state is O(heads * head_dim^2) per layer, so
`long_500k` runs natively.
"""
from repro.config import ModelConfig, OptimConfig, ParallelConfig, RWKVConfig, RunConfig


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="rwkv6-1.6b", family="ssm",
            num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
            head_dim=64, d_ff=7168, vocab_size=65536, max_seq_len=4096,
            attention="none",
            rwkv=RWKVConfig(head_dim=64, decay_lora=64, token_shift_lora=32,
                            gate_lora=64),
            source="[arXiv:2404.05892]",
        ),
        parallel=ParallelConfig(microbatches=1),
        optim=OptimConfig(lr=6e-4, weight_decay=0.0, schedule="cosine",
                          warmup_steps=100, total_steps=10_000),
    ).validate()
