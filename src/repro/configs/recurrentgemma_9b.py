"""recurrentgemma-9b — hybrid, 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000. RG-LRU recurrent blocks + local attention in a 1:2 pattern
(two recurrent blocks per local-attention block), window 2048. [arXiv:2402.19427]

`long_500k` runs natively: the recurrent state is O(1) and the attention
cache is bounded by the 2048-token window.
"""
from repro.config import ModelConfig, OptimConfig, ParallelConfig, RGLRUConfig, RunConfig


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="recurrentgemma-9b", family="hybrid",
            num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
            head_dim=256, d_ff=12288, vocab_size=256000, max_seq_len=8192,
            attention="sliding", sliding_window=2048,
            rglru=RGLRUConfig(lru_width=4096, conv_width=4, window=2048,
                              pattern=("rglru", "rglru", "attn")),
            source="[arXiv:2402.19427]",
        ),
        # mb=8 brings train_4k temp under the 16 GiB HBM budget
        # (21.7 -> 12.8 GiB incl. args; EXPERIMENTS §Perf)
        parallel=ParallelConfig(param_dtype="bfloat16", microbatches=8),
        optim=OptimConfig(lr=4e-4, weight_decay=0.1, schedule="cosine",
                          warmup_steps=200, total_steps=10_000),
    ).validate()
