"""whisper-base — audio enc-dec, 6L(dec) d_model=512 8H d_ff=2048 vocab=51865.
6 encoder layers over 1500 mel frames (30 s). [arXiv:2212.04356]

The mel-spectrogram + 2-conv frontend is a STUB (assignment carve-out):
`input_specs()` supplies precomputed (batch, 1500, 512) frame embeddings.
Decoder max positions = 448, so `long_500k` is skipped (see DESIGN.md §4);
`decode_32k`/`prefill_32k` exercise the decoder against the stubbed encoder
context at the assigned batch sizes with target length capped at 448.
"""
from repro.config import EncDecConfig, ModelConfig, OptimConfig, ParallelConfig, RunConfig


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="whisper-base", family="audio",
            num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
            head_dim=64, d_ff=2048, vocab_size=51865, max_seq_len=448,
            act="gelu", rope_theta=0.0,   # whisper uses learned/sinusoidal pos, no rope
            encdec=EncDecConfig(encoder_layers=6, encoder_seq=1500,
                                max_target_positions=448),
            source="[arXiv:2212.04356]",
        ),
        parallel=ParallelConfig(microbatches=1),
        optim=OptimConfig(lr=1e-3, weight_decay=0.0, schedule="linear",
                          warmup_steps=100, total_steps=5_000),
    ).validate()
