"""chameleon-34b — VLM (early fusion), 48L d_model=8192 64H (GQA kv=8)
d_ff=22016 vocab=65536. Images enter as VQ-VAE token ids interleaved with
text in one sequence; the transformer is a plain decoder over the mixed
vocabulary. [arXiv:2405.09818]

The VQ image tokenizer is a STUB frontend (per the assignment carve-out):
`input_specs()` supplies already-tokenized mixed sequences; a modality mask
marks image spans for the example pipeline.
"""
from repro.config import ModelConfig, OptimConfig, ParallelConfig, RunConfig


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="chameleon-34b", family="vlm",
            num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
            head_dim=128, d_ff=22016, vocab_size=65536, max_seq_len=8192,
            qk_norm=True,   # chameleon uses qk-norm for training stability
            source="[arXiv:2405.09818]",
        ),
        parallel=ParallelConfig(param_dtype="bfloat16", microbatches=8),
        optim=OptimConfig(lr=1e-4, weight_decay=0.1, schedule="cosine",
                          warmup_steps=500, total_steps=20_000),
    ).validate()
