"""deepseek-v2-lite-16b — MoE, 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400. MLA with kv_lora_rank=512 (decoupled rope dim 64),
2 shared + 64 routed experts, top-6. [arXiv:2405.04434]

Assignment note: the line reads "2 shared+160 routed top-6"; 160 routed is the
full V2 — V2-*Lite* has 64 routed (paper Table 1) which also matches the
"MoE 64e" prefix, so 64 routed is used (see DESIGN.md §4).
"""
from repro.config import MLAConfig, ModelConfig, MoEConfig, OptimConfig, ParallelConfig, RunConfig


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="deepseek-v2-lite-16b", family="moe",
            num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
            head_dim=128, d_ff=1408, vocab_size=102400, max_seq_len=32768,
            moe=MoEConfig(num_experts=64, num_shared_experts=2, top_k=6,
                          d_ff_expert=1408, router_aux_coef=0.003),
            mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
                          v_head_dim=128),
            source="[arXiv:2405.04434]",
        ),
        parallel=ParallelConfig(param_dtype="bfloat16", microbatches=4),
        optim=OptimConfig(lr=4e-4, weight_decay=0.1, schedule="cosine",
                          warmup_steps=200, total_steps=10_000),
    ).validate()
