"""dcgan-mnist — the paper's own model: DCGAN (Radford et al. 2016) with
3 conv blocks on 28x28x1 MNIST, latent dim 100, BATCH_SIZE=256,
24 batches/client/epoch, 5 clients x 4 devices. [paper §5]
"""
from repro.config import (DCGANConfig, FSLConfig, ModelConfig, OptimConfig,
                          ParallelConfig, RunConfig, ShapeConfig)


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="dcgan-mnist", family="dcgan",
            num_layers=3, d_model=0, num_heads=0, num_kv_heads=0,
            d_ff=0, vocab_size=0,
            dcgan=DCGANConfig(image_size=28, channels=1, latent_dim=100,
                              base_filters=64, conv_blocks=3),
            source="[arXiv:1511.06434; paper §5]",
        ),
        parallel=ParallelConfig(fsdp=False, tensor_parallel=False,
                                sequence_parallel=False,
                                param_dtype="float32", compute_dtype="float32"),
        # DCGAN defaults per Radford et al.: Adam(2e-4, beta1=0.5)
        optim=OptimConfig(name="adam", lr=2e-4, beta1=0.5, beta2=0.999,
                          weight_decay=0.0, grad_clip=0.0),
        fsl=FSLConfig(num_clients=5, devices_per_client=4,
                      selection="sorted_multi", local_steps=1,
                      lan_latency_s=0.050, heterogeneity="paper"),
        shape=ShapeConfig(name="mnist", seq_len=0, global_batch=256, mode="train"),
    )
