"""olmoe-1b-7b — MoE, 16L d_model=2048 16H (kv=16) d_ff(expert)=1024
vocab=50304. 64 routed experts, top-8, no shared experts, standard attention
(no MLA), qk-norm per the OLMoE recipe. [arXiv:2409.02060]
"""
from repro.config import ModelConfig, MoEConfig, OptimConfig, ParallelConfig, RunConfig


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="olmoe-1b-7b", family="moe",
            num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
            head_dim=128, d_ff=1024, vocab_size=50304, max_seq_len=4096,
            qk_norm=True,
            moe=MoEConfig(num_experts=64, num_shared_experts=0, top_k=8,
                          d_ff_expert=1024, router_aux_coef=0.01),
            source="[arXiv:2409.02060]",
        ),
        # mb=4: per-microbatch batch 64 stays data-axis divisible; halves
        # MoE dispatch-buffer residency vs mb=2 (EXPERIMENTS §Perf hc1)
        parallel=ParallelConfig(microbatches=4),
        optim=OptimConfig(lr=4e-4, weight_decay=0.1, schedule="cosine",
                          warmup_steps=200, total_steps=10_000),
    ).validate()
