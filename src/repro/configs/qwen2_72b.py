"""qwen2-72b — dense, 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064. QKV bias (the Qwen signature), SwiGLU, RoPE. [arXiv:2407.10671]
"""
from repro.config import ModelConfig, OptimConfig, ParallelConfig, RunConfig


def config() -> RunConfig:
    return RunConfig(
        model=ModelConfig(
            name="qwen2-72b", family="dense",
            num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
            head_dim=128, d_ff=29568, vocab_size=152064, max_seq_len=32768,
            qkv_bias=True, rope_theta=1_000_000.0,
            source="[arXiv:2407.10671]",
        ),
        parallel=ParallelConfig(param_dtype="bfloat16", microbatches=16),
        optim=OptimConfig(lr=1.5e-4, weight_decay=0.1, schedule="cosine",
                          warmup_steps=500, total_steps=20_000),
    ).validate()
