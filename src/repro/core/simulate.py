"""Analytic time model for FSL-GAN epochs (reproduces Fig 2).

The paper measures, per splitting strategy, the per-epoch wall time of the
*slowest* client (the system bottleneck), with
  - per-device compute time = (portion compute units) x Time_Factor,
  - 50 ms per LAN hop between devices of one client,
  - 24 batches per client per epoch, communication counted per batch,
  - forward + backward both traverse the chain (2x hops), backward ~2x
    forward compute (standard 1:2 fwd:bwd FLOP ratio).

On a TPU pod the same model prices ICI hops instead of LAN (see
roofline/hw.py); the LAN constants here deliberately mirror the paper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.devices import Client
from repro.core.selection import make_plan, plan_all_clients
from repro.core.split import SplitPlan

BWD_FWD_RATIO = 2.0


@dataclass
class TimeReport:
    per_client: Dict[str, float]          # epoch seconds per client
    slowest_client: str
    slowest_time: float
    mean_time: float


def plan_epoch_time(plan: SplitPlan, client: Client,
                    batches_per_epoch: int = 24,
                    lan_latency_s: float = 0.050,
                    compute_unit_s: float = 0.010,
                    boundary_bytes: Optional[Sequence[int]] = None,
                    lan_bandwidth_bps: float = 100e6,
                    pipeline_microbatches: int = 1) -> float:
    """Seconds for one epoch of discriminator training under this plan.

    Sequential (``pipeline_microbatches = 1``): the SL chain is additive
    per batch — every device computes its portion (fwd then bwd),
    activations/gradients hop the LAN at each boundary, nothing
    overlaps.  Pipelined (``K > 1``): the per-batch time is the makespan
    of the explicit 1F1B :class:`core.pipeline.OverlapSchedule` — device
    segments overlap across micro-batches, hops carry ``1/K`` of the
    payload each, and the additive model is the schedule's own ``K = 1``
    degenerate case (exactly, pinned).

    LAN pricing has two modes:

      * **measured** — ``boundary_bytes`` lists the bytes of every hop event
        one batch ships (each boundary crossing, forward and backward; see
        ``core/split.SplitExecution.step_wire_bytes``).  Each hop costs
        ``lan_latency_s + 8 * bytes / lan_bandwidth_bps``.
      * **analytic fallback** — ``boundary_bytes=None`` keeps the paper's
        model: a fixed ``lan_latency_s`` (50 ms) per hop, 2 hops per
        boundary (forward + backward traversal), payload size ignored.
        This is what prices plans that train unsplit.
    """
    tf = {d.device_id: d.time_factor for d in client.devices}
    if pipeline_microbatches > 1 and plan.num_boundaries > 0:
        from repro.core.pipeline import schedule_for
        segs: List[Tuple[str, float]] = []
        for p in plan.portions:
            if segs and segs[-1][0] == p.device_id:
                segs[-1] = (p.device_id, segs[-1][1] + p.cost)
            else:
                segs.append((p.device_id, p.cost))
        sched = schedule_for(
            [c for _, c in segs], [d for d, _ in segs], tf,
            num_microbatches=pipeline_microbatches,
            compute_unit_s=compute_unit_s, bwd_fwd_ratio=BWD_FWD_RATIO,
            lan_latency_s=lan_latency_s, hop_bytes=boundary_bytes,
            lan_bandwidth_bps=lan_bandwidth_bps)
        return sched.makespan * batches_per_epoch
    compute = sum(p.cost * compute_unit_s * tf[p.device_id] * (1 + BWD_FWD_RATIO)
                  for p in plan.portions)
    if boundary_bytes is None:
        lan = plan.num_boundaries * 2 * lan_latency_s
    else:
        bw = max(float(lan_bandwidth_bps), 1.0)
        lan = sum(lan_latency_s + 8.0 * int(b) / bw for b in boundary_bytes)
    per_batch = compute + lan
    return per_batch * batches_per_epoch


def epoch_time_report(clients: List[Client],
                      layers: Sequence[Tuple[str, float]], strategy: str,
                      seed: int = 0, batches_per_epoch: int = 24,
                      lan_latency_s: float = 0.050,
                      compute_unit_s: float = 0.010) -> TimeReport:
    plans = plan_all_clients(clients, layers, strategy, seed)
    if not plans:
        raise ValueError("no feasible client")
    by_id = {c.client_id: c for c in clients}
    times = {cid: plan_epoch_time(p, by_id[cid], batches_per_epoch,
                                  lan_latency_s, compute_unit_s)
             for cid, p in plans.items()}
    slowest = max(times, key=times.get)
    return TimeReport(per_client=times, slowest_client=slowest,
                      slowest_time=times[slowest],
                      mean_time=float(np.mean(list(times.values()))))


def strategy_sweep(clients: List[Client],
                   layers: Sequence[Tuple[str, float]],
                   seeds: Sequence[int] = range(10),
                   **kw) -> Dict[str, Tuple[float, float]]:
    """Fig 2: mean +/- std of slowest-client epoch time per strategy."""
    from repro.core.selection import STRATEGIES
    out = {}
    for s in STRATEGIES:
        vals = [epoch_time_report(clients, layers, s, seed=sd, **kw)
                .slowest_time for sd in seeds]
        out[s] = (float(np.mean(vals)), float(np.std(vals)))
    return out
