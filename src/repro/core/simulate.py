"""Analytic time model for FSL-GAN epochs (reproduces Fig 2).

The paper measures, per splitting strategy, the per-epoch wall time of the
*slowest* client (the system bottleneck), with
  - per-device compute time = (portion compute units) x Time_Factor,
  - 50 ms per LAN hop between devices of one client,
  - 24 batches per client per epoch, communication counted per batch,
  - forward + backward both traverse the chain (2x hops), backward ~2x
    forward compute (standard 1:2 fwd:bwd FLOP ratio).

On a TPU pod the same model prices ICI hops instead of LAN (see
roofline/hw.py); the LAN constants here deliberately mirror the paper.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.devices import Client
from repro.core.selection import make_plan, plan_all_clients
from repro.core.split import SplitPlan

BWD_FWD_RATIO = 2.0


@dataclass
class TimeReport:
    per_client: Dict[str, float]          # epoch seconds per client
    slowest_client: str
    slowest_time: float
    mean_time: float


def plan_epoch_time(plan: SplitPlan, client: Client,
                    batches_per_epoch: int = 24,
                    lan_latency_s: float = 0.050,
                    compute_unit_s: float = 0.010,
                    boundary_bytes: Optional[Sequence[int]] = None,
                    lan_bandwidth_bps: float = 100e6) -> float:
    """Seconds for one epoch of discriminator training under this plan.

    The SL chain is sequential per batch: every device computes its portion
    (fwd then bwd), activations/gradients hop the LAN at each boundary.

    LAN pricing has two modes:

      * **measured** — ``boundary_bytes`` lists the bytes of every hop event
        one batch ships (each boundary crossing, forward and backward; see
        ``core/split.SplitExecution.step_wire_bytes``).  Each hop costs
        ``lan_latency_s + 8 * bytes / lan_bandwidth_bps``.
      * **analytic fallback** — ``boundary_bytes=None`` keeps the paper's
        model: a fixed ``lan_latency_s`` (50 ms) per hop, 2 hops per
        boundary (forward + backward traversal), payload size ignored.
        This is what prices plans that train unsplit.
    """
    tf = {d.device_id: d.time_factor for d in client.devices}
    compute = sum(p.cost * compute_unit_s * tf[p.device_id] * (1 + BWD_FWD_RATIO)
                  for p in plan.portions)
    if boundary_bytes is None:
        lan = plan.num_boundaries * 2 * lan_latency_s
    else:
        bw = max(float(lan_bandwidth_bps), 1.0)
        lan = sum(lan_latency_s + 8.0 * int(b) / bw for b in boundary_bytes)
    per_batch = compute + lan
    return per_batch * batches_per_epoch


def epoch_time_report(clients: List[Client],
                      layers: Sequence[Tuple[str, float]], strategy: str,
                      seed: int = 0, batches_per_epoch: int = 24,
                      lan_latency_s: float = 0.050,
                      compute_unit_s: float = 0.010) -> TimeReport:
    plans = plan_all_clients(clients, layers, strategy, seed)
    if not plans:
        raise ValueError("no feasible client")
    by_id = {c.client_id: c for c in clients}
    times = {cid: plan_epoch_time(p, by_id[cid], batches_per_epoch,
                                  lan_latency_s, compute_unit_s)
             for cid, p in plans.items()}
    slowest = max(times, key=times.get)
    return TimeReport(per_client=times, slowest_client=slowest,
                      slowest_time=times[slowest],
                      mean_time=float(np.mean(list(times.values()))))


def strategy_sweep(clients: List[Client],
                   layers: Sequence[Tuple[str, float]],
                   seeds: Sequence[int] = range(10),
                   **kw) -> Dict[str, Tuple[float, float]]:
    """Fig 2: mean +/- std of slowest-client epoch time per strategy."""
    from repro.core.selection import STRATEGIES
    out = {}
    for s in STRATEGIES:
        vals = [epoch_time_report(clients, layers, s, seed=sd, **kw)
                .slowest_time for sd in seeds]
        out[s] = (float(np.mean(vals)), float(np.std(vals)))
    return out
