"""1F1B overlap schedules for pipelined split execution.

`core.split.SplitExecution` runs the per-segment vjp chain strictly in
sequence: every device waits for the previous hop, so a three-device
split leaves two devices idle at any instant.  Splitting the batch into
``K`` micro-batches lets segment ``s`` of micro-batch ``m`` run
concurrently with segment ``s+1`` of micro-batch ``m-1`` — the classic
1F1B pipeline shape.  This module builds the *explicit* overlap
schedule for that execution so the virtual-clock model
(`core.simulate.plan_epoch_time`), the trace timeline
(`SplitExecution.round_timeline`) and the deadline controller all price
the same overlapped round instead of the strictly-additive per-hop sum.

Model
-----
* Each merged plan segment is one pipeline *stage* pinned to a device.
  A device is occupied only while computing; compute time for a
  micro-batch is the full-batch segment time divided by ``K``.
* A boundary hop is latency on the dependency edge between stages: it
  delays the consumer but does not occupy either device (full-duplex
  LAN links, one per boundary).  A micro-batch hop pays the full
  per-message latency but only ``1/K`` of the serialization bytes.
* Dependencies: ``F(m, s)`` needs ``F(m, s-1)`` plus the forward hop;
  ``B(m, S-1)`` needs ``F(m, S-1)``; ``B(m, s)`` needs ``B(m, s+1)``
  plus the backward hop.  Scheduling is event-driven greedy list
  scheduling with backward-first tie-breaking (1F1B drain order).

For ``K == 1`` the schedule degenerates to the sequential chain and the
makespan reproduces the additive per-batch time *exactly* (same
floating-point accumulation order) — pinned by tests so the pipelined
pricing is a strict superset of the legacy model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "PipelineTask",
    "OverlapSchedule",
    "overlap_schedule",
    "schedule_for",
    "effective_microbatches",
]


def effective_microbatches(batch_size: int, requested: int) -> int:
    """Largest ``K <= requested`` that divides ``batch_size`` evenly.

    Pipelined execution requires equal micro-batches (so per-tail mean
    losses average back to the full-batch loss); a request that does
    not divide the batch is clamped to the nearest divisor rather than
    rejected.  ``batch_size <= 1`` (e.g. DP-SGD per-example steps)
    always yields 1.
    """
    k = max(1, int(requested))
    b = int(batch_size)
    if b <= 1:
        return 1
    k = min(k, b)
    while b % k:
        k -= 1
    return k


@dataclass(frozen=True)
class PipelineTask:
    """One scheduled unit: a segment compute or a boundary hop."""

    kind: str          # "fwd" | "bwd" | "hop_fwd" | "hop_bwd"
    microbatch: int
    index: int         # segment index for compute, boundary index for hops
    device: str        # owning device (hop: the sending device)
    t0: float
    t1: float

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclass(frozen=True)
class OverlapSchedule:
    """Explicit 1F1B schedule over ``num_microbatches`` micro-batches.

    ``seg_fwd_s`` / ``seg_bwd_s`` are *full-batch* per-segment compute
    seconds; ``hop_fwd_s`` / ``hop_bwd_s`` are per-*micro-batch* hop
    seconds; ``hop_fwd_full_s`` / ``hop_bwd_full_s`` price the same
    hops for a single full-batch message (the ``K = 1`` baseline used
    by :attr:`sequential_s`).
    """

    num_microbatches: int
    devices: Tuple[str, ...]
    tasks: Tuple[PipelineTask, ...]
    seg_fwd_s: Tuple[float, ...]
    seg_bwd_s: Tuple[float, ...]
    hop_fwd_s: Tuple[float, ...]
    hop_bwd_s: Tuple[float, ...]
    hop_fwd_full_s: Tuple[float, ...]
    hop_bwd_full_s: Tuple[float, ...]

    @property
    def num_segments(self) -> int:
        return len(self.devices)

    @property
    def makespan(self) -> float:
        """Per-batch wall time of the overlapped execution."""
        return max((t.t1 for t in self.tasks), default=0.0)

    @property
    def sequential_s(self) -> float:
        """Per-batch time of the legacy strictly-additive execution
        (one full-batch message per hop, no overlap), accumulated in
        the same order as ``SplitExecution.round_timeline``."""
        t = 0.0
        s = self.num_segments
        for si in range(s):
            t += self.seg_fwd_s[si]
            if si < s - 1:
                t += self.hop_fwd_full_s[si]
        for si in range(s - 1, -1, -1):
            t += self.seg_bwd_s[si]
            if si > 0:
                t += self.hop_bwd_full_s[si - 1]
        return t

    @property
    def speedup(self) -> float:
        """Analytic sequential / pipelined per-batch ratio (>= 1 when
        pipelining helps; 1.0 for a degenerate single-task schedule)."""
        mk = self.makespan
        return self.sequential_s / mk if mk > 0.0 else 1.0

    def device_busy_s(self) -> Dict[str, float]:
        """Total scheduled *compute* seconds per device (hops excluded)."""
        busy: Dict[str, float] = {}
        for t in self.tasks:
            if t.kind in ("fwd", "bwd"):
                busy[t.device] = busy.get(t.device, 0.0) + t.duration
        return busy

    def segment_work_s(self) -> List[float]:
        """Total scheduled compute seconds per segment — conserved work:
        equals ``seg_fwd_s[i] + seg_bwd_s[i]`` up to micro-batch split
        rounding regardless of ``K``."""
        work = [0.0] * self.num_segments
        for t in self.tasks:
            if t.kind in ("fwd", "bwd"):
                work[t.index] += t.duration
        return work


def overlap_schedule(
    seg_fwd_s: Sequence[float],
    seg_bwd_s: Sequence[float],
    *,
    num_microbatches: int,
    hop_fwd_s: Sequence[float],
    hop_bwd_s: Sequence[float],
    hop_fwd_full_s: Optional[Sequence[float]] = None,
    hop_bwd_full_s: Optional[Sequence[float]] = None,
    devices: Optional[Sequence[str]] = None,
) -> OverlapSchedule:
    """Build the 1F1B schedule for per-segment full-batch compute times
    and per-micro-batch hop times.

    ``hop_*_full_s`` defaults to ``hop_*_s`` (appropriate when hops are
    pure latency with no serialization term).
    """
    s = len(seg_fwd_s)
    if len(seg_bwd_s) != s:
        raise ValueError("seg_fwd_s and seg_bwd_s length mismatch")
    if len(hop_fwd_s) != max(0, s - 1) or len(hop_bwd_s) != max(0, s - 1):
        raise ValueError("expected one hop time per internal boundary")
    k = max(1, int(num_microbatches))
    devs = tuple(devices) if devices is not None \
        else tuple(f"d{i}" for i in range(s))
    if len(devs) != s:
        raise ValueError("devices length mismatch")
    hop_fwd_full = tuple(hop_fwd_full_s) if hop_fwd_full_s is not None \
        else tuple(hop_fwd_s)
    hop_bwd_full = tuple(hop_bwd_full_s) if hop_bwd_full_s is not None \
        else tuple(hop_bwd_s)

    # Per-micro-batch compute durations.  For K == 1 use the segment
    # time verbatim (no divide) so the degenerate schedule is bit-equal
    # to the additive model.
    if k == 1:
        mb_fwd = list(seg_fwd_s)
        mb_bwd = list(seg_bwd_s)
    else:
        mb_fwd = [t / k for t in seg_fwd_s]
        mb_bwd = [t / k for t in seg_bwd_s]

    finish: Dict[Tuple[str, int, int], float] = {}
    dev_free = [0.0] * s
    tasks: List[PipelineTask] = []

    def ready(kind: str, m: int, si: int) -> Optional[float]:
        """Dependency-ready time, or None if a dependency is unscheduled.
        Hop latency rides on the edge (max, not +=, against dev_free)."""
        if kind == "fwd":
            if si == 0:
                return 0.0
            prev = finish.get(("fwd", m, si - 1))
            return None if prev is None else prev + hop_fwd_s[si - 1]
        if si == s - 1:
            prev = finish.get(("fwd", m, si))
            return None if prev is None else prev
        prev = finish.get(("bwd", m, si + 1))
        return None if prev is None else prev + hop_bwd_s[si]

    pending = [("fwd", m, si) for m in range(k) for si in range(s)]
    pending += [("bwd", m, si) for m in range(k) for si in range(s)]
    while pending:
        best = None
        best_key = None
        for item in pending:
            kind, m, si = item
            r = ready(kind, m, si)
            if r is None:
                continue
            est = max(r, dev_free[si])
            # Earliest start wins; ties drain backward work first
            # (1F1B), then lower micro-batch, then lower segment.
            key = (est, 0 if kind == "bwd" else 1, m, si)
            if best_key is None or key < best_key:
                best, best_key = item, key
        assert best is not None, "dependency cycle in pipeline schedule"
        kind, m, si = best
        est = best_key[0]
        dur = mb_fwd[si] if kind == "fwd" else mb_bwd[si]
        t1 = est + dur
        finish[(kind, m, si)] = t1
        dev_free[si] = t1
        tasks.append(PipelineTask(kind, m, si, devs[si], est, t1))
        pending.remove(best)

    # Hop tasks (for timelines): each rides the producing task's finish.
    for m in range(k):
        for b in range(s - 1):
            f = finish[("fwd", m, b)]
            tasks.append(PipelineTask("hop_fwd", m, b, devs[b],
                                      f, f + hop_fwd_s[b]))
            g = finish[("bwd", m, b + 1)]
            tasks.append(PipelineTask("hop_bwd", m, b, devs[b + 1],
                                      g, g + hop_bwd_s[b]))

    return OverlapSchedule(
        num_microbatches=k,
        devices=devs,
        tasks=tuple(tasks),
        seg_fwd_s=tuple(seg_fwd_s),
        seg_bwd_s=tuple(seg_bwd_s),
        hop_fwd_s=tuple(hop_fwd_s),
        hop_bwd_s=tuple(hop_bwd_s),
        hop_fwd_full_s=hop_fwd_full,
        hop_bwd_full_s=hop_bwd_full,
    )


def schedule_for(
    seg_costs: Sequence[float],
    seg_devices: Sequence[str],
    time_factors: Dict[str, float],
    *,
    num_microbatches: int,
    compute_unit_s: float = 0.010,
    bwd_fwd_ratio: float = 2.0,
    lan_latency_s: float = 0.050,
    hop_bytes: Optional[Sequence[int]] = None,
    lan_bandwidth_bps: float = 100e6,
) -> OverlapSchedule:
    """Price a merged split plan into an :class:`OverlapSchedule`.

    ``seg_costs`` / ``seg_devices`` come from the merged plan segments
    (`core.split.plan_segments`); ``hop_bytes`` is the flat
    ``[b0.fwd, b0.bwd, b1.fwd, ...]`` full-batch wire-bytes list (same
    layout as ``plan_epoch_time``'s ``boundary_bytes``), ``None``
    meaning latency-only hops.
    """
    s = len(seg_costs)
    if len(seg_devices) != s:
        raise ValueError("seg_costs and seg_devices length mismatch")
    k = max(1, int(num_microbatches))
    tf = {d: float(f) for d, f in time_factors.items()}
    seg_fwd = [float(c) * compute_unit_s * tf.get(d, 1.0)
               for c, d in zip(seg_costs, seg_devices)]
    seg_bwd = [t * bwd_fwd_ratio for t in seg_fwd]

    def hop(ev: int, frac: float) -> float:
        if hop_bytes is None:
            return lan_latency_s
        return lan_latency_s + 8.0 * int(hop_bytes[ev]) * frac \
            / lan_bandwidth_bps

    nb = max(0, s - 1)
    hop_fwd = [hop(2 * b, 1.0 / k) for b in range(nb)]
    hop_bwd = [hop(2 * b + 1, 1.0 / k) for b in range(nb)]
    hop_fwd_full = [hop(2 * b, 1.0) for b in range(nb)]
    hop_bwd_full = [hop(2 * b + 1, 1.0) for b in range(nb)]
    return overlap_schedule(
        seg_fwd, seg_bwd,
        num_microbatches=k,
        hop_fwd_s=hop_fwd, hop_bwd_s=hop_bwd,
        hop_fwd_full_s=hop_fwd_full, hop_bwd_full_s=hop_bwd_full,
        devices=seg_devices,
    )
