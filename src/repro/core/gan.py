"""FSL-GAN training (paper §3-§5).

Roles:
  * **Server** owns the generator G. It never sees real data — it only ships
    generated (fake) images to clients and receives averaged discriminator
    parameters, which is the paper's privacy argument.
  * **Clients** each own a discriminator replica D_c trained on their local
    real data + the server's fakes. After ``local_steps`` batches the D
    parameters are FedAvg'd (weighted by client example counts).
  * Within a client, D training is *split* across that client's devices
    per the SplitPlan (core/split.py). The split changes wall-time (priced
    by core/simulate.py), not math — split_forward == monolithic forward is
    a pinned test invariant, so the simulation trains the monolithic D.

Losses: non-saturating DCGAN BCE.
    L_D = BCE(D(x_real), 1) + BCE(D(G(z)), 0)
    L_G = BCE(D(G(z)), 1)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.core.devices import make_pool
from repro.core.fedavg import fedavg
from repro.core.selection import plan_all_clients
from repro.core.split import SplitPlan
from repro.models.dcgan import (disc_apply, disc_init, disc_layer_costs,
                                disc_layer_names, gen_apply, gen_init)
from repro.optim import make_optimizer


def bce_logits(logits: jnp.ndarray, target: float) -> jnp.ndarray:
    """Numerically-stable binary cross entropy with logits."""
    l = logits.astype(jnp.float32)
    t = jnp.full_like(l, target)
    return jnp.mean(jnp.maximum(l, 0) - l * t + jnp.log1p(jnp.exp(-jnp.abs(l))))


def d_loss_fn(d_params, real, fake, c) -> jnp.ndarray:
    return (bce_logits(disc_apply(d_params, real, c), 1.0)
            + bce_logits(disc_apply(d_params, fake, c), 0.0))


def g_loss_fn(g_params, d_params, z, c) -> jnp.ndarray:
    fake = gen_apply(g_params, z, c)
    return bce_logits(disc_apply(d_params, fake, c), 1.0)


@dataclass
class GANState:
    g_params: Any
    g_opt: Any
    d_params: Dict[str, Any]          # per-client discriminator replicas
    d_opt: Dict[str, Any]
    step: int = 0
    history: Dict[str, List[float]] = field(default_factory=dict)


class FSLGANTrainer:
    """Paper-faithful sequential simulation (clients share one accelerator,
    exactly like the paper's Colab runs)."""

    def __init__(self, cfg: RunConfig, client_data: Dict[str, np.ndarray],
                 seed: int = 0):
        self.cfg = cfg
        self.c = cfg.model.dcgan
        self.client_ids = list(client_data)
        self.client_data = client_data
        self.batch_size = cfg.shape.global_batch
        key = jax.random.PRNGKey(seed)
        kg, kd = jax.random.split(key)
        self.g_optimizer = make_optimizer(cfg.optim)
        self.d_optimizer = make_optimizer(cfg.optim)
        g_params = gen_init(kg, self.c)
        d0 = disc_init(kd, self.c)
        self.state = GANState(
            g_params=g_params,
            g_opt=self.g_optimizer.init(g_params),
            d_params={cid: jax.tree.map(jnp.copy, d0)
                      for cid in self.client_ids},
            d_opt={cid: self.d_optimizer.init(d0) for cid in self.client_ids},
        )
        # split planning (prices the wall-time; see simulate.py)
        pool = make_pool(cfg.fsl.heterogeneity, cfg.fsl.num_clients,
                         cfg.fsl.devices_per_client, cfg.fsl.seed)
        costs = disc_layer_costs(self.c)
        layers = [(n, costs[n]) for n in disc_layer_names(self.c)]
        self.plans: Dict[str, SplitPlan] = plan_all_clients(
            pool, layers, cfg.fsl.selection, cfg.fsl.seed)
        self._rng = np.random.default_rng(seed)
        self._build_steps()

    # ------------------------------------------------------------------
    def _build_steps(self):
        c, lr = self.c, self.cfg.optim.lr

        @jax.jit
        def d_step(d_params, d_opt, real, fake):
            loss, grads = jax.value_and_grad(d_loss_fn)(d_params, real, fake, c)
            d_params, d_opt = self.d_optimizer.update(grads, d_opt, d_params,
                                                      jnp.asarray(lr))
            return d_params, d_opt, loss

        @jax.jit
        def g_step(g_params, g_opt, d_params, z):
            loss, grads = jax.value_and_grad(g_loss_fn)(g_params, d_params, z, c)
            g_params, g_opt = self.g_optimizer.update(grads, g_opt, g_params,
                                                      jnp.asarray(lr))
            return g_params, g_opt, loss

        @jax.jit
        def gen_batch(g_params, z):
            return gen_apply(g_params, z, c)

        self._d_step, self._g_step, self._gen = d_step, g_step, gen_batch

    def _sample_real(self, cid: str, n: int) -> jnp.ndarray:
        data = self.client_data[cid]
        idx = self._rng.integers(0, len(data), n)
        return jnp.asarray(data[idx])

    def _z(self, n: int) -> jnp.ndarray:
        return jnp.asarray(self._rng.standard_normal(
            (n, self.c.latent_dim), dtype=np.float32))

    # ------------------------------------------------------------------
    def train_epoch(self, batches_per_client: int = 24) -> Dict[str, float]:
        """One FL round = paper epoch: local D training then FedAvg then G."""
        st = self.state
        d_losses = []
        active = [cid for cid in self.client_ids if cid in self.plans] \
            or self.client_ids
        for cid in active:
            dp, do = st.d_params[cid], st.d_opt[cid]
            for b in range(batches_per_client):
                real = self._sample_real(cid, self.batch_size)
                fake = self._gen(st.g_params, self._z(self.batch_size))
                # server ships fakes; client never shares `real`
                dp, do, dl = self._d_step(dp, do, real,
                                          jax.lax.stop_gradient(fake))
                d_losses.append(float(dl))
            st.d_params[cid], st.d_opt[cid] = dp, do

        # FedAvg over client discriminators (weighted by examples)
        weights = ([len(self.client_data[cid]) for cid in active]
                   if self.cfg.fsl.weighted_average else None)
        d_avg = fedavg([st.d_params[cid] for cid in active], weights)
        for cid in self.client_ids:
            st.d_params[cid] = jax.tree.map(jnp.copy, d_avg)

        # server G update against the averaged D (never touches real data)
        g_losses = []
        for _ in range(batches_per_client):
            st.g_params, st.g_opt, gl = self._g_step(
                st.g_params, st.g_opt, d_avg, self._z(self.batch_size))
            g_losses.append(float(gl))
        st.step += 1
        metrics = {"d_loss": float(np.mean(d_losses)),
                   "g_loss": float(np.mean(g_losses)),
                   "num_clients": float(len(active))}
        for k, v in metrics.items():
            st.history.setdefault(k, []).append(v)
        return metrics

    def generate(self, n: int, seed: int = 0) -> np.ndarray:
        z = jax.random.normal(jax.random.PRNGKey(seed),
                              (n, self.c.latent_dim))
        return np.asarray(self._gen(self.state.g_params, z))
