"""FSL-GAN training (paper §3-§5).

Roles:
  * **Server** owns the generator G. It never sees real data — it only ships
    generated (fake) images to clients and receives averaged discriminator
    parameters, which is the paper's privacy argument.
  * **Clients** each own a discriminator replica D_c trained on their local
    real data + the server's fakes. After their local round the D
    parameters are FedAvg'd (weighted by client example counts).
  * Within a client, D training is *split* across that client's devices
    per the SplitPlan (core/split.py).  With ``cfg.split.enabled`` the plan
    IS the local step: forward/backward execute device-segment by
    device-segment (SplitExecution), every boundary tensor passes the
    configured boundary stage (identity | transport codec | DP noise), and
    round time + LAN bytes are priced from the measured per-boundary
    payloads.  Under the identity stage this is bit-exact with the
    monolithic step (pinned invariant); disabled, the plan only prices
    wall-time analytically and the monolithic D trains as in the paper's
    Colab runs.

Losses: non-saturating DCGAN BCE.
    L_D = BCE(D(x_real), 1) + BCE(D(G(z)), 0)
    L_G = BCE(D(G(z)), 1)

The trainer is composition over three orthogonal axes, all selected by
config (the scheduling x backend x privacy matrix — see ROADMAP PR-3):

  * **scheduling** (``cfg.fed``): the federation engine runs sync barrier /
    FedAsync / FedBuff rounds with codecs, straggler deadlines and
    availability churn (fed/engine.py);
  * **backend** (``cfg.fed.backend`` or ``train_epoch(backend=...)``): the
    client-side local round is ONE program (fed/programs.LocalProgram)
    compiled either as a per-client loop of jitted steps ("loop" — the
    seed's dispatch pattern, bit-exact) or as a single jitted
    vmap-over-clients / scan-over-batches program ("vectorized");
  * **privacy** (``cfg.privacy``): plain step, DP-SGD per-example
    clip+noise inside the step (either backend), or the pre-codec uplink
    DP stage in the engine.

Per-client ``lr_scale`` / ``local_steps`` schedules
(``cfg.fed.client_lr_scales`` / ``client_local_steps``) thread through
both backends.  ``train_epoch`` runs one engine round per epoch; the
default (sync, codec none, loop backend, no privacy) reproduces the
original sequential loop bit-for-bit — ``train_epoch_sequential`` keeps
that seed loop as the pinned numeric reference.
"""
from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.control import (ControllerSuite, ControlKnobs, RoundFeedback,
                           knobs_from_config, make_controllers)
from repro.core.devices import make_pool
from repro.core.fedavg import fedavg
from repro.core.pipeline import effective_microbatches
from repro.core.selection import plan_all_clients
from repro.core.simulate import plan_epoch_time
from repro.core.split import (SplitExecution, SplitPlan, make_boundary_stage,
                              plan_segments)
from repro.fed.engine import ClientSpec, FederationEngine
from repro.fed.programs import ClientHyper, LocalProgram, RoundExecutor
from repro.fed.transport import apply_delta, delta_tree, fake_batch_bytes
from repro.models.dcgan import (disc_apply, disc_apply_layer, disc_init,
                                disc_layer_costs, disc_layer_names,
                                gen_apply, gen_init)
from repro.obs import FlightRecorder, profile_engine_kernels
from repro.obs.digest import RoundDigest, state_digest, tree_digest
from repro.obs.health import (SEV_FATAL, HealthAbort, HealthAlert,
                              HealthMonitor)
from repro.optim import make_optimizer
from repro.privacy.defenses import (RDPAccountant, make_dp_d_step,
                                    make_uplink_stage)


def bce_logits(logits: jnp.ndarray, target: float) -> jnp.ndarray:
    """Numerically-stable binary cross entropy with logits."""
    l = logits.astype(jnp.float32)
    t = jnp.full_like(l, target)
    return jnp.mean(jnp.maximum(l, 0) - l * t + jnp.log1p(jnp.exp(-jnp.abs(l))))


def d_loss_fn(d_params, real, fake, c) -> jnp.ndarray:
    return (bce_logits(disc_apply(d_params, real, c), 1.0)
            + bce_logits(disc_apply(d_params, fake, c), 0.0))


def g_loss_fn(g_params, d_params, z, c) -> jnp.ndarray:
    fake = gen_apply(g_params, z, c)
    return bce_logits(disc_apply(d_params, fake, c), 1.0)


@dataclass
class GANState:
    g_params: Any
    g_opt: Any
    d_params: Dict[str, Any]          # per-client discriminator replicas
    d_opt: Dict[str, Any]
    step: int = 0
    history: Dict[str, List[float]] = field(default_factory=dict)


class FSLGANTrainer:
    """Paper-faithful sequential simulation (clients share one accelerator,
    exactly like the paper's Colab runs)."""

    def __init__(self, cfg: RunConfig, client_data: Dict[str, np.ndarray],
                 seed: int = 0):
        self.cfg = cfg
        self.c = cfg.model.dcgan
        self.client_ids = list(client_data)
        self.client_data = client_data
        self.batch_size = cfg.shape.global_batch
        key = jax.random.PRNGKey(seed)
        kg, kd = jax.random.split(key)
        self.g_optimizer = make_optimizer(cfg.optim)
        self.d_optimizer = make_optimizer(cfg.optim)
        g_params = gen_init(kg, self.c)
        d0 = disc_init(kd, self.c)
        self.state = GANState(
            g_params=g_params,
            g_opt=self.g_optimizer.init(g_params),
            d_params={cid: jax.tree.map(jnp.copy, d0)
                      for cid in self.client_ids},
            d_opt={cid: self.d_optimizer.init(d0) for cid in self.client_ids},
        )
        # control plane (cfg.control): knobs seed from the static config;
        # 'frozen' (default) never changes them — bit-exact with the
        # uncontrolled build — while 'adaptive' consults the controller
        # suite between rounds.  RoundFeedback is emitted either way.
        self.knobs: ControlKnobs = knobs_from_config(cfg)
        self.feedback: List[RoundFeedback] = []
        self._suite: Optional[ControllerSuite] = None
        # split planning.  cfg.split.enabled compiles each plan into the
        # executed local step (core/split.SplitExecution); otherwise the
        # plan only prices the round (analytic hop model) and training
        # runs the monolithic D.
        self.pool = make_pool(cfg.fsl.heterogeneity, cfg.fsl.num_clients,
                              cfg.fsl.devices_per_client, cfg.fsl.seed)
        costs = disc_layer_costs(self.c)
        self._layers = [(n, costs[n]) for n in disc_layer_names(self.c)]
        self.plans: Dict[str, SplitPlan] = plan_all_clients(
            self.pool, self._layers, self.knobs.split_strategy,
            cfg.fsl.seed)
        self._rng = np.random.default_rng(seed)
        self._build_steps()
        # privacy subsystem (cfg.privacy): DP-SGD inside the local step
        # (either backend — the program compiles it), the pre-codec uplink
        # stage, and/or an RDP accountant.  Disabled => every path is
        # bit-exact with the non-private build (pinned test).
        priv = cfg.privacy
        self._dp_step = None
        self.accountant: Optional[RDPAccountant] = None
        # ONE uplink stage for the trainer's lifetime: engine rebuilds must
        # NOT reset its per-client round counters, or the same Gaussian
        # noise vector would be reused on fresh deltas (noise cancellation
        # voids the DP guarantee).
        self._uplink_stage = make_uplink_stage(priv)
        if priv.enabled:
            # The accountant's subsampling amplification assumes Poisson
            # sampling at rate q; our loader samples uniformly with
            # replacement, so cfg sample_rate <= batch/|data| is the honest
            # setting and 1.0 (no amplification claimed) the safe default.
            self.accountant = RDPAccountant(priv.noise_multiplier,
                                            priv.sample_rate)
            self._dp_key = jax.random.PRNGKey(priv.seed)
            if priv.mode == "dp_sgd":
                # sequential-reference DP step (engine backends compile
                # their own from the same definition in fed/programs)
                self._dp_step = make_dp_d_step(
                    self.d_optimizer,
                    functools.partial(d_loss_fn, c=self.c),
                    self.cfg.optim.lr, priv.clip_norm,
                    priv.noise_multiplier, use_kernel=priv.use_kernel,
                    interpret=priv.kernel_interpret)
            elif priv.mode != "uplink":
                raise ValueError(f"unknown privacy mode {priv.mode!r}")
        # federation runtime (built on first train_epoch — compute times
        # depend on batches_per_client)
        self.engine: Optional[FederationEngine] = None
        self._engine_batches: Optional[int] = None
        # backend="auto": the one-shot dispatch probe's pick + wall-times,
        # pinned for the trainer's lifetime after the first round
        self._auto_backend: Optional[str] = None
        # mean analytic sequential/pipelined per-batch ratio across split
        # clients (1.0 unsplit or K == 1); set by _ensure_engine, carried
        # into RoundFeedback for the deadline controller's rescaling
        self._pipeline_speedup: float = 1.0
        # flight recorder (cfg.obs): traces, metrics, feedback persistence.
        # Disabled (default) => None everywhere — the engine emits no spans
        # and every training path is untouched (pinned bit-exact).
        self.recorder: Optional[FlightRecorder] = None
        self._trace_timelines: Dict[str, Any] = {}
        self._manifest_written = False
        self._profiled = False
        if cfg.obs.enabled:
            self.recorder = FlightRecorder.from_config(cfg)
        # watchtower (cfg.obs.health): read-only per-round monitors.
        # Orthogonal to the recorder — monitors run without persistence
        # (alerts stay on self.health_alerts), and policy='record' is
        # bit-exact with monitors off because checks never write training
        # state.  Rollback keeps one snapshot of the last healthy state.
        self.monitor: Optional[HealthMonitor] = None
        self.health_alerts: List[HealthAlert] = []
        self._healthy_snapshot: Optional[Tuple[Any, Any, Any, Any]] = None
        if cfg.obs.health.enabled:
            self.monitor = HealthMonitor(cfg.obs.health)

    # ------------------------------------------------------------------
    def _build_steps(self):
        c, lr = self.c, self.cfg.optim.lr

        @jax.jit
        def d_step(d_params, d_opt, real, fake):
            loss, grads = jax.value_and_grad(d_loss_fn)(d_params, real, fake, c)
            d_params, d_opt = self.d_optimizer.update(grads, d_opt, d_params,
                                                      jnp.asarray(lr))
            return d_params, d_opt, loss

        @jax.jit
        def g_step(g_params, g_opt, d_params, z):
            loss, grads = jax.value_and_grad(g_loss_fn)(g_params, d_params, z, c)
            g_params, g_opt = self.g_optimizer.update(grads, g_opt, g_params,
                                                      jnp.asarray(lr))
            return g_params, g_opt, loss

        @jax.jit
        def gen_batch(g_params, z):
            return gen_apply(g_params, z, c)

        self._d_step, self._g_step, self._gen = d_step, g_step, gen_batch
        self._stage_key = jax.random.PRNGKey(self.cfg.split.seed)
        self._build_split_programs()

    def _boundary_stages(self, plan: SplitPlan
                         ) -> Optional[List[Any]]:
        """Per-boundary stage list for one plan under the current knobs, or
        None for the uniform config stage (the static path)."""
        stage_map = self.knobs.stage_by_boundary
        if stage_map is None:
            return None
        nb = len(plan_segments(plan)) - 1
        base = self.cfg.split.boundary_stage or "identity"
        return [make_boundary_stage(self.cfg.split,
                                    stage_map.get(b, base))
                for b in range(nb)]

    def _build_split_programs(self):
        """(Re)compile the split executions + the client program from the
        current plans and knobs.  Called at construction and again by the
        split controller after a replan / per-boundary stage reassignment
        (a *split-signature regroup*: new signatures, new step cache)."""
        c, lr = self.c, self.cfg.optim.lr
        # executed split (cfg.split): each feasible plan compiles into a
        # staged local step whose boundary tensors pass the configured
        # stage; measured per-step LAN bytes are cached for pricing
        self.split_execs: Dict[str, SplitExecution] = {}
        self._split_step_bytes: Dict[str, int] = {}
        self._split_hop_events: Dict[str, List[int]] = {}
        if self.cfg.split.enabled:
            stage = make_boundary_stage(self.cfg.split)
            apply_layer = functools.partial(disc_apply_layer, c=c)
            tails = (functools.partial(bce_logits, target=1.0),
                     functools.partial(bce_logits, target=0.0))
            x_shape = (self.batch_size, c.image_size, c.image_size,
                       c.channels)
            # wire bytes are a pure function of (split signature, x_shape)
            # — measure once per signature, not once per client
            bytes_by_sig: Dict[Any, Tuple[int, List[Dict[str, int]]]] = {}
            pipeline_k = self._pipeline_k()
            for cid, plan in self.plans.items():
                ex = SplitExecution(plan, apply_layer, tails, stage=stage,
                                    stages=self._boundary_stages(plan),
                                    pipeline_microbatches=pipeline_k,
                                    pipeline_scan=self.cfg.split.pipeline_scan)
                self.split_execs[cid] = ex
                if ex.signature not in bytes_by_sig:
                    bytes_by_sig[ex.signature] = ex.step_wire_bytes(
                        self.state.d_params[cid], x_shape)
                total, per_b = bytes_by_sig[ex.signature]
                self._split_step_bytes[cid] = total
                # per-batch LAN hop events: at each boundary one fwd and
                # one bwd crossing, each carrying both passes' tensors
                self._split_hop_events[cid] = [
                    ex.num_passes * b[d] for b in per_b
                    for d in ("fwd", "bwd")]
        # the client program: one local-round definition, compiled as both
        # the looped and the vectorized backend (fed/programs.py), with the
        # privacy stage (plain | dp_sgd) and split execution selected
        # orthogonally
        self.program = LocalProgram(
            self.d_optimizer, functools.partial(d_loss_fn, c=c), lr,
            privacy=self.cfg.privacy, split=self.split_execs or None)
        # a controller-retuned sigma survives split regroups: the program
        # is rebuilt from the static config, so rebind the live knob
        if self.program.is_dp \
                and self.knobs.sigma != self.cfg.privacy.noise_multiplier:
            self.program.rebind_sigma(self.knobs.sigma)

    def _d_update(self, dp, do, real, fake):
        """One reference D step for ``train_epoch_sequential``: DP-SGD when
        ``cfg.privacy`` says so (accounted per batch), the plain jitted
        step otherwise (bit-exact seed path)."""
        if self._dp_step is not None:
            self._dp_key, k = jax.random.split(self._dp_key)
            if self.accountant is not None:
                self.accountant.step()
            return self._dp_step(dp, do, real, fake, k)
        return self._d_step(dp, do, real, fake)

    def _sample_real(self, cid: str, n: int) -> jnp.ndarray:
        data = self.client_data[cid]
        idx = self._rng.integers(0, len(data), n)
        return jnp.asarray(data[idx])

    def _z(self, n: int) -> jnp.ndarray:
        return jnp.asarray(self._rng.standard_normal(
            (n, self.c.latent_dim), dtype=np.float32))

    # ------------------------------------------------------------------
    # federation-runtime glue
    # ------------------------------------------------------------------
    def _active_clients(self) -> List[str]:
        """Clients with a feasible split plan (paper: infeasible clients are
        dropped); all clients if planning found none feasible."""
        return [cid for cid in self.client_ids if cid in self.plans] \
            or self.client_ids

    def _client_steps(self, cid: str, default: int) -> int:
        return int(self.cfg.fed.client_local_steps.get(cid, default))

    def _lan_latency_s(self) -> float:
        """Per-hop LAN latency for the split chain: the
        ``cfg.split.lan_latency_s`` override when set, else the paper's
        ``cfg.fsl.lan_latency_s`` (50 ms) — configurable end-to-end, never
        the pricing functions' hard-coded default."""
        return self.cfg.split.lan_latency_s or self.cfg.fsl.lan_latency_s

    def _pipeline_k(self) -> int:
        """Micro-batches per batch for the pipelined split step: the
        configured K clamped to a divisor of the batch size (1 when split
        execution is off)."""
        if not self.cfg.split.enabled:
            return 1
        return effective_microbatches(self.batch_size,
                                      self.cfg.split.pipeline_microbatches)

    def _ensure_engine(self, batches_per_client: int) -> FederationEngine:
        """(Re)build the engine when the local-round length changes — client
        compute times are priced per round (per-client ``local_steps``
        schedules included).  Rebuilding resets the virtual clock and codec
        residuals, not any training state."""
        if self.engine is not None \
                and self._engine_batches == batches_per_client:
            return self.engine
        by_id = {cl.client_id: cl for cl in self.pool}
        specs = []
        pipeline_k = self._pipeline_k()
        speedups: List[float] = []
        for cid in self._active_clients():
            steps = self._client_steps(cid, batches_per_client)
            if cid in self.plans and cid in by_id:
                # split-executed clients are priced from the MEASURED
                # per-boundary bytes their step actually ships; unsplit
                # training falls back to the analytic hop constant.
                # Pipelined steps (K > 1) are priced by the 1F1B overlap
                # schedule's makespan, not the additive chain.
                price = functools.partial(
                    plan_epoch_time,
                    self.plans[cid], by_id[cid], batches_per_epoch=steps,
                    lan_latency_s=self._lan_latency_s(),
                    boundary_bytes=self._split_hop_events.get(cid),
                    lan_bandwidth_bps=self.cfg.split.lan_bandwidth_bps)
                ct = price(pipeline_microbatches=pipeline_k)
                if pipeline_k > 1 and cid in self.split_execs and ct > 0.0:
                    speedups.append(price(pipeline_microbatches=1) / ct)
            else:
                ct = 0.0
            specs.append(ClientSpec(
                cid, float(len(self.client_data[cid])), ct,
                lr_scale=float(self.cfg.fed.client_lr_scales.get(cid, 1.0)),
                local_steps=steps))
        self._pipeline_speedup = float(np.mean(speedups)) if speedups \
            else 1.0
        # static cohort map for two-tier aggregation: roster order sliced
        # into contiguous cohorts, shared by the engine's edge pre-reduce
        # AND the executor's (round, cohort, client) noise-key chain so
        # grouping and key derivation can never disagree
        self._cohort_of = None
        cohorts = int(getattr(self.cfg.fed, "hierarchy_cohorts", 0))
        if cohorts >= 2:
            from repro.fed.hierarchy import assign_cohorts
            grouped = assign_cohorts([s.client_id for s in specs], cohorts)
            cmap = {cid: c for c, ms in grouped.items() for cid in ms}
            self._cohort_of = lambda cid: cmap.get(cid, 0)
        self.engine = FederationEngine(
            self.cfg.fed, specs, weighted=self.cfg.fsl.weighted_average,
            uplink_stage=self._uplink_stage, cohort_of=self._cohort_of)
        if getattr(self.cfg.fed, "server_reduce", "decode") == "batched":
            # the batched compressed-domain reduce shards its per-leaf
            # wire stacks over the same client mesh the vectorized
            # backend trains on (None when fed.shard_clients is off)
            self.engine.set_mesh(self._client_mesh())
        self._engine_batches = batches_per_client
        if self.recorder is not None:
            self._attach_recorder(by_id)
        return self.engine

    def _attach_recorder(self, by_id) -> None:
        """Hook the flight recorder into a (re)built engine: tracer with a
        virtual-clock offset (the fresh engine's clock restarts at 0, the
        recording's timeline must stay monotone), the ledger's wire
        observer, and one split timeline per client for span subdivision."""
        rec = self.recorder
        if rec.wants("trace"):
            tr = rec.tracer
            tr.set_virtual_offset(tr.last_virtual_end())
            self.engine.set_tracer(tr, batch_cap=self.cfg.obs.trace_batches)
        if rec.wants("digests"):
            # stamp RoundReport.global_digest on the as-aggregated tree —
            # pre-health-action, so digests.jsonl can show what a rolled-
            # back round actually aggregated
            self.engine.set_digester(tree_digest)
        self.engine.ledger.observer = self._observe_wire
        self.engine.ledger.edge_observer = self._observe_edge
        self._trace_timelines = {}
        if self.cfg.split.enabled:
            for cid, ex in self.split_execs.items():
                cl = by_id.get(cid)
                if cl is None:
                    continue
                tf = {d.device_id: d.time_factor for d in cl.devices}
                # round_timeline emits overlapping 1F1B spans when the
                # executor is pipelined (K from ex.pipeline_microbatches)
                self._trace_timelines[cid] = ex.round_timeline(
                    tf, lan_latency_s=self._lan_latency_s(),
                    hop_bytes=self._split_hop_events.get(cid),
                    lan_bandwidth_bps=self.cfg.split.lan_bandwidth_bps)

    def _observe_wire(self, cid: str, up: int, down: int, lan: int) -> None:
        """TrafficLedger observer -> per-client cumulative wire counters
        (the per-round totals come from RoundFeedback via observe_round;
        distinct namespaces, no double counting)."""
        reg = self.recorder.registry
        if up:
            reg.counter(f"wire.client.{cid}.up_bytes").inc(up)
        if down:
            reg.counter(f"wire.client.{cid}.down_bytes").inc(down)
        if lan:
            reg.counter(f"wire.client.{cid}.lan_bytes").inc(lan)

    def _observe_edge(self, cid: str, nbytes: int) -> None:
        """TrafficLedger edge observer -> per-client client->edge wire
        counter (the two-tier pre-reduce hop)."""
        if nbytes:
            self.recorder.registry.counter(
                f"wire.client.{cid}.edge_bytes").inc(nbytes)

    def _sample_round_batches(self, cid: str, steps: int
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """``steps`` local batches for one client, sampled in the seed
        loop's host-RNG order (real_t, z_t alternating): local reals +
        server fakes.  The server ships fakes; the client never shares
        ``real``."""
        st = self.state
        rs, fs = [], []
        for _ in range(steps):
            rs.append(self._sample_real(cid, self.batch_size))
            fs.append(self._gen(st.g_params, self._z(self.batch_size)))
        return jnp.stack(rs), jnp.stack(fs)

    def _bind_round(self, batches_per_client: int, backend: str
                    ) -> RoundExecutor:
        """Bind the client program to this round: data sampling, opt-state
        lookup, per-client hyperparameter schedules, and (under DP-SGD) a
        fresh round noise key.  Schedules come from the engine's
        ``ClientSpec``s — the single resolved form of the
        ``cfg.fed.client_*`` maps (built in ``_ensure_engine``)."""
        round_key = None
        if self.program.is_dp:
            self._dp_key, round_key = jax.random.split(self._dp_key)
        elif self.program.needs_key:
            # stochastic boundary stage without DP-SGD: its own key chain
            self._stage_key, round_key = jax.random.split(self._stage_key)
        hyper = {cid: ClientHyper(lr_scale=spec.lr_scale,
                                  local_steps=spec.local_steps)
                 for cid, spec in self.engine.specs.items()}
        return RoundExecutor(
            self.program, backend=backend,
            sample=self._sample_round_batches,
            opt_lookup=lambda cid: self.state.d_opt[cid],
            default_steps=batches_per_client, hyper=hyper,
            round_key=round_key,
            mesh=self._client_mesh() if backend == "vectorized" else None,
            cohort_of=getattr(self, "_cohort_of", None))

    def _client_mesh(self):
        """The cached `clients` mesh (launch/mesh.make_client_mesh) when
        ``fed.shard_clients`` is on and the host exposes > 1 device —
        None otherwise, which keeps single-device placement (and the
        frozen-control bit-exactness pin) untouched."""
        if not getattr(self.cfg.fed, "shard_clients", False):
            return None
        if not getattr(self, "_mesh_resolved", False):
            from repro.launch.mesh import make_client_mesh, mesh_chips
            mesh = make_client_mesh()
            self._mesh = mesh if mesh_chips(mesh) > 1 else None
            self._mesh_resolved = True
        return self._mesh

    def _num_shards(self, backend: str) -> int:
        """`clients`-mesh devices the round's stacked dispatch spanned."""
        if backend != "vectorized":
            return 1
        mesh = self._client_mesh()
        if mesh is None:
            return 1
        from repro.launch.mesh import mesh_chips
        return int(mesh_chips(mesh))

    def _resolve_auto_backend(self, batches_per_client: int
                              ) -> Tuple[str, Dict[str, float]]:
        """``backend="auto"``: one-shot timed probe of both dispatch paths.

        Runs each backend's full round dispatch over the active roster on
        zero batches — one warm-up execution (compile) then one timed
        execution — and pins the faster backend for the trainer's
        lifetime.  The probe consumes no host RNG and commits no training
        state (``ClientResult`` is pure and discarded), and warming both
        backends populates the program's per-signature step caches, so
        the winning backend's real round pays no additional compile.
        Returns ``(backend, probe_us)``; ``probe_us`` is empty on every
        round after the probe ran.
        """
        if self._auto_backend is not None:
            return self._auto_backend, {}
        import time as _time
        cids = self._active_clients()
        c = self.c
        max_steps = max(self._client_steps(cid, batches_per_client)
                        for cid in cids)
        zeros = jnp.zeros((max_steps, self.batch_size, c.image_size,
                           c.image_size, c.channels), jnp.float32)
        key = jax.random.PRNGKey(0) if self.program.needs_key else None
        hyper = None
        if self.engine is not None:
            hyper = {cid: ClientHyper(lr_scale=spec.lr_scale,
                                      local_steps=spec.local_steps)
                     for cid, spec in self.engine.specs.items()}
        global_d = self.state.d_params[cids[0]]
        probe_us: Dict[str, float] = {}
        for be in ("loop", "vectorized"):
            def run_once():
                ex = RoundExecutor(
                    self.program, backend=be,
                    sample=lambda cid, steps: (zeros[:steps], zeros[:steps]),
                    opt_lookup=lambda cid: self.state.d_opt[cid],
                    default_steps=batches_per_client, hyper=hyper,
                    round_key=key)
                jax.block_until_ready(
                    [r.params for r in ex.run(list(cids), global_d)])
            run_once()                       # compile + warm
            t0 = _time.perf_counter()
            run_once()
            probe_us[be] = (_time.perf_counter() - t0) * 1e6
        self._auto_backend = "loop" \
            if probe_us["loop"] <= probe_us["vectorized"] else "vectorized"
        return self._auto_backend, probe_us

    # ------------------------------------------------------------------
    # control plane (cfg.control)
    # ------------------------------------------------------------------
    def _adaptive(self) -> bool:
        return (self.cfg.control.mode == "adaptive"
                and bool(self.cfg.control.controllers))

    def _controller_inputs(self, batches_per_client: int
                           ) -> Tuple[List[int], int]:
        """The non-config inputs ``make_controllers`` needs: uplink-tree
        leaf sizes (codec byte prediction) and the expected DP releases per
        round.  Shared between the live suite build and the recorder's
        manifest — replay must rebuild the exact same suite."""
        leaf_sizes = [int(l.size) for l in jax.tree.leaves(
            self.state.d_params[self.client_ids[0]])]
        if self.cfg.privacy.mode == "dp_sgd":
            hint = sum(self._client_steps(cid, batches_per_client)
                       for cid in self._active_clients())
        else:                              # uplink: one release per client
            hint = len(self._active_clients())
        return leaf_sizes, hint

    def _ensure_controllers(self, batches_per_client: int) -> ControllerSuite:
        """Build the controller suite on first use (the DP steps-per-round
        hint depends on the round length)."""
        if self._suite is None:
            leaf_sizes, hint = self._controller_inputs(batches_per_client)
            self._suite = make_controllers(
                self.cfg, leaf_sizes=leaf_sizes, steps_per_round_hint=hint)
        return self._suite

    def _apply_knobs(self, new: ControlKnobs) -> None:
        """Apply a knob diff to the layers that own each knob.  Codec and
        deadline land on the engine (after ``_ensure_engine``, in
        ``train_epoch``); sigma rebinds the uplink stage in place and the
        DP-SGD program via ``LocalProgram.rebind_sigma``; split knobs
        replan + regroup the split programs (new signatures reprice the
        engine's client compute times)."""
        old, self.knobs = self.knobs, new
        if new.split_strategy != old.split_strategy:
            self.plans = plan_all_clients(self.pool, self._layers,
                                          new.split_strategy,
                                          self.cfg.fsl.seed)
            self.engine = None             # client times need repricing
        if (new.split_strategy != old.split_strategy
                or new.stage_by_boundary != old.stage_by_boundary) \
                and self.cfg.split.enabled:
            self._build_split_programs()   # split-signature regroup
            self.engine = None
        if new.sigma != old.sigma:
            if self._uplink_stage is not None:
                self._uplink_stage.noise_multiplier = float(new.sigma)
            self.program.rebind_sigma(new.sigma)

    def _probe_boundary_dcor(self) -> Dict[str, Tuple[float, ...]]:
        """Measured input-vs-activation distance correlation per boundary
        per split client, on a fixed data prefix — deterministic and
        host-RNG-free, so probing never perturbs training.

        Probes the RAW (pre-stage) boundary activation: the controller
        needs each boundary's *intrinsic* leak to decide protection.
        Probing post-stage would measure the noise it just assigned,
        suppress the signal, strip the stage next round, and oscillate
        (protect / unprotect every other round, recompiling each flip).
        The deployed post-stage leakage is the attack suite's job
        (``privacy/attacks.make_shipped_prefix_fn``), not the control
        signal's."""
        from repro.privacy.metrics import distance_correlation
        out: Dict[str, Tuple[float, ...]] = {}
        n = int(self.cfg.control.probe_batch)
        for cid in self._active_clients():
            ex = self.split_execs.get(cid)
            if ex is None or ex.num_boundaries == 0:
                continue
            data = self.client_data[cid]
            x0 = jnp.asarray(data[:min(n, len(data))])
            params, x, dcors = self.state.d_params[cid], x0, []
            for dev, names in ex.segments[:-1]:
                for name in names:
                    x = ex.apply_layer(name, params, x)
                dcors.append(float(distance_correlation(x0, x)))
            out[cid] = tuple(dcors)
        return out

    # ------------------------------------------------------------------
    # watchtower (cfg.obs.health)
    # ------------------------------------------------------------------
    def _snapshot_state(self) -> Tuple[Any, Any, Any, Any]:
        """Copy of the committed training state (all D replicas + opts, G
        params + opt) — what ``policy='rollback'`` restores.  Host RNG and
        the engine's clock/codec residuals are deliberately NOT captured:
        rollback restarts from healthy *parameters* with fresh data, it
        does not rewind time."""
        st = self.state
        cp = functools.partial(jax.tree.map, jnp.copy)
        return (cp(st.d_params), cp(st.d_opt),
                cp(st.g_params), cp(st.g_opt))

    def _restore_snapshot(self) -> None:
        d_params, d_opt, g_params, g_opt = self._healthy_snapshot
        st = self.state
        # jax arrays are immutable, so handing the snapshot trees back is
        # safe; copy anyway so a later snapshot refresh never aliases
        cp = functools.partial(jax.tree.map, jnp.copy)
        st.d_params, st.d_opt = cp(d_params), cp(d_opt)
        st.g_params, st.g_opt = cp(g_params), cp(g_opt)

    def _apply_health_policy(self, alerts: List[HealthAlert]
                             ) -> Tuple[bool, bool, Optional[HealthAlert]]:
        """Turn this round's alerts into the configured action.  Returns
        ``(rolled_back, state_healthy, abort_alert)``; the caller records
        everything first and raises ``abort_alert`` last, so an aborting
        run still leaves a complete ``alerts.jsonl``.

        ``state_healthy`` is False only when a non-finite fatal fired and
        was NOT repaired — the caller must not refresh the rollback
        snapshot from poisoned state."""
        pol = self.cfg.obs.health.policy
        fatal = [a for a in alerts if a.severity == SEV_FATAL]
        poisoned = any(a.check in ("nonfinite_params", "nonfinite_loss")
                       for a in fatal)
        rolled, abort_alert = False, None
        if pol == "record":
            return rolled, not poisoned, abort_alert
        to_warn = list(alerts)
        if pol == "abort" and fatal:
            abort_alert = fatal[0]
            to_warn = [a for a in alerts if a is not abort_alert]
        elif pol == "rollback" and fatal:
            recoverable = [a for a in fatal if a.recoverable]
            if recoverable and self._healthy_snapshot is not None:
                self._restore_snapshot()
                rolled, poisoned = True, False
            # non-recoverable fatals (epsilon overspend) and a poisoned
            # round 0 with nothing to restore degrade to warnings below
        for a in to_warn:
            warnings.warn(
                f"[health] round {a.round_index} {a.check} "
                f"({a.severity}): {a.message}", RuntimeWarning)
        return rolled, not poisoned, abort_alert

    def _g_updates(self, d_avg, batches: int) -> List[float]:
        """Server G update against the averaged D (never touches real data)."""
        st = self.state
        g_losses = []
        for _ in range(batches):
            st.g_params, st.g_opt, gl = self._g_step(
                st.g_params, st.g_opt, d_avg, self._z(self.batch_size))
            g_losses.append(float(gl))
        return g_losses

    def _record(self, metrics: Dict[str, float]) -> Dict[str, float]:
        for k, v in metrics.items():
            self.state.history.setdefault(k, []).append(v)
        return metrics

    # ------------------------------------------------------------------
    def train_epoch(self, batches_per_client: int = 24,
                    backend: Optional[str] = None) -> Dict[str, float]:
        """One FL round on the federation engine.

        ``cfg.fed`` selects scheduling (sync / fedasync / fedbuff), uplink
        codec, straggler deadline and availability churn; ``backend``
        (default ``cfg.fed.backend``) selects how the client program is
        compiled — ``"loop"`` (per-client jitted steps; with the default
        sync/no-codec/no-privacy config this reproduces the seed's
        sequential loop bit-for-bit) or ``"vectorized"`` (every scheduled
        client's whole round as ONE jitted vmap/scan program).
        ``"auto"`` probes both dispatch paths once on the first round
        (``_resolve_auto_backend``) and pins the measured-faster one —
        the pick and probe times land in ``RoundFeedback``.  Privacy
        (``cfg.privacy``) composes with either backend: DP-SGD inside the
        compiled step, uplink DP as the engine's pre-codec stage.

        Optimizer state commits only for clients whose update landed
        (``RoundReport.opt_states``) — dropped stragglers leave no trace.

        The control plane (``cfg.control``) wraps the round: under
        ``mode='adaptive'`` the controller suite turns the accumulated
        ``RoundFeedback`` history into knob decisions BEFORE the round
        (codec swap, sigma rebind, split regroup, deadline retune); a new
        ``RoundFeedback`` is appended AFTER it either way (``self.feedback``
        — frozen mode measures without steering).

        The watchtower (``cfg.obs.health``) closes the round: monitors
        scan the aggregated state + feedback and the configured policy
        acts on alerts — ``record``/``warn`` observe, ``abort`` raises
        :class:`~repro.obs.health.HealthAbort`, ``rollback`` restores the
        last healthy state so one poisoned round degrades gracefully.
        When the recorder's ``digests`` sink is on, the round also commits
        a content digest of the post-action global state
        (``digests.jsonl``).
        """
        backend = backend or self.cfg.fed.backend
        st = self.state
        if self.monitor is not None \
                and self.cfg.obs.health.policy == "rollback" \
                and self._healthy_snapshot is None:
            # round-start state = the last known-healthy state a poisoned
            # round 0 can fall back to
            self._healthy_snapshot = self._snapshot_state()
        if self.recorder is not None and not self._manifest_written:
            leaf_sizes, hint = self._controller_inputs(batches_per_client)
            self.recorder.set_manifest(self.cfg, leaf_sizes=leaf_sizes,
                                       steps_per_round_hint=hint)
            self._manifest_written = True
            if self.cfg.obs.profile_kernels and not self._profiled:
                self.recorder.write_profile(
                    profile_engine_kernels(self.cfg))
                self._profiled = True
        if self._adaptive():
            self._apply_knobs(self._ensure_controllers(batches_per_client)(
                self.feedback, self.knobs))
        eng = self._ensure_engine(batches_per_client)
        probe_us: Dict[str, float] = {}
        if backend == "auto":
            backend, probe_us = self._resolve_auto_backend(
                batches_per_client)
        if self._adaptive():
            eng.set_codec(self.knobs.codec, self.knobs.topk_frac)
            eng.set_deadline(self.knobs.deadline_s)
        acct_steps_before = self.accountant.steps if self.accountant else 0
        batch_b = fake_batch_bytes(
            self.batch_size,
            (self.c.image_size, self.c.image_size, self.c.channels))
        # downlink payload priced per client: a longer local_steps
        # schedule downloads proportionally more fake batches
        down_by_client = {cid: spec.local_steps * batch_b
                          for cid, spec in eng.specs.items()}
        # measured LAN payload of one local round per split-executed client
        lan_by_client = {cid: spec.local_steps * self._split_step_bytes[cid]
                         for cid, spec in eng.specs.items()
                         if cid in self._split_step_bytes}
        # the global D: every replica equals the last broadcast average
        global_d = st.d_params[self._active_clients()[0]]
        rep = eng.run_round(global_d,
                            self._bind_round(batches_per_client, backend),
                            down_bytes=batches_per_client * batch_b,
                            down_bytes_by_client=down_by_client,
                            lan_bytes_by_client=lan_by_client,
                            timeline_by_client=self._trace_timelines or None)
        d_avg = rep.global_params
        for cid, opt in rep.opt_states.items():
            st.d_opt[cid] = opt
        for cid in self.client_ids:
            st.d_params[cid] = jax.tree.map(jnp.copy, d_avg)

        d_losses = [l for _, info in rep.client_infos
                    for l in info["losses"]]
        g_losses = self._g_updates(d_avg, batches_per_client)
        st.step += 1
        if self.accountant is not None:
            # adaptive runs account each round at the sigma the controller
            # actually bound; frozen runs use the constructor default
            sigma_arg = self.knobs.sigma if self._adaptive() else None
            if self.cfg.privacy.mode == "dp_sgd":
                # one Gaussian-mechanism release per EXECUTED DP batch,
                # whichever backend compiled it — this counts async cycles
                # and late-but-executed straggler work that never makes
                # rep.participated
                self.accountant.step(sum(info.get("steps", 0)
                                         for _, info in rep.client_infos),
                                     noise_multiplier=sigma_arg)
            elif self.cfg.privacy.mode == "uplink":
                # one release per executed uplink: every client_infos entry
                # ran _codec_roundtrip once
                self.accountant.step(len(rep.client_infos),
                                     noise_multiplier=sigma_arg)
        metrics = {
            "d_loss": float(np.mean(d_losses)) if d_losses else float("nan"),
            "g_loss": float(np.mean(g_losses)),
            "num_clients": float(len(rep.participated)),
            "round_time_s": rep.round_time_s,
            "clock_s": rep.clock_s,
            "up_mbytes": rep.traffic.total_up / 1e6,
            "down_mbytes": rep.traffic.total_down / 1e6,
            "stragglers": float(len(rep.stragglers)),
            "mean_staleness": rep.mean_staleness,
        }
        if rep.traffic.total_edge:
            metrics["edge_mbytes"] = rep.traffic.total_edge / 1e6
        loads: Dict[str, float] = {}
        if self.split_execs:
            # executed-split reporting: measured boundary bytes that
            # actually crossed the LAN this round, and the compute load
            # each device carried (plan cost units)
            loads = self.device_load_report()
            metrics["lan_mbytes"] = rep.traffic.total_lan / 1e6
            metrics["max_device_load"] = max(loads.values())
            metrics["mean_device_load"] = float(np.mean(list(
                loads.values())))
        if self.accountant is not None:
            metrics["dp_epsilon"] = self.accountant.epsilon(
                self.cfg.privacy.delta)[0]
        cerrs = list(rep.codec_error.values())
        if cerrs:
            metrics["codec_error"] = float(np.mean(cerrs))
        # the round's measurements as ONE typed record — what the
        # controllers consume next round (and what frozen runs still log)
        probe: Dict[str, Tuple[float, ...]] = {}
        if self._adaptive() and "split" in self.cfg.control.controllers \
                and self.split_execs:
            probe = self._probe_boundary_dcor()
        fb = RoundFeedback(
            round_index=st.step - 1,
            backend=backend,
            codec=eng.codec_name,
            sigma=self.knobs.sigma,
            deadline_s=eng.deadline_s,
            split_strategy=self.knobs.split_strategy,
            up_bytes=int(rep.traffic.total_up),
            down_bytes=int(rep.traffic.total_down),
            lan_bytes=int(rep.traffic.total_lan),
            codec_error=float(np.mean(cerrs)) if cerrs else float("nan"),
            uplink_bps=float(self.cfg.fed.uplink_bps),
            round_time_s=float(rep.round_time_s),
            clock_s=float(rep.clock_s),
            client_finish_s=dict(rep.finish_s),
            num_clients=len(rep.participated),
            stragglers=len(rep.stragglers),
            d_loss=metrics["d_loss"],
            g_loss=metrics["g_loss"],
            dp_epsilon=metrics.get("dp_epsilon", float("nan")),
            dp_steps=(self.accountant.steps - acct_steps_before
                      if self.accountant else 0),
            device_loads=loads,
            boundary_dcor=probe,
            pipeline_microbatches=self._pipeline_k(),
            pipeline_speedup=self._pipeline_speedup,
            backend_probe_us=probe_us,
            edge_bytes=int(rep.traffic.total_edge),
            cohorts=int(getattr(self.cfg.fed, "hierarchy_cohorts", 0)),
            shards=self._num_shards(backend))
        self.feedback.append(fb)

        # watchtower: check the round, act per policy, THEN digest the
        # committed state — so a rolled-back round's committed digest
        # equals the last healthy one while RoundReport.global_digest
        # (stamped pre-action by the engine's digester) keeps what the
        # poisoned aggregate actually was.
        alerts: List[HealthAlert] = []
        rolled_back, state_healthy, abort_alert = False, True, None
        if self.monitor is not None:
            alerts = self.monitor.check_round(fb, params=d_avg,
                                              update_base=global_d)
            self.health_alerts.extend(alerts)
            if alerts:
                rolled_back, state_healthy, abort_alert = \
                    self._apply_health_policy(alerts)
        digest: Optional[RoundDigest] = None
        if self.recorder is not None and self.recorder.wants("digests"):
            digest = state_digest(
                st.d_params[self._active_clients()[0]], st.d_opt,
                st.g_params, st.g_opt, round_index=fb.round_index,
                aggregated=rep.global_digest or "",
                rolled_back=rolled_back)
        if self.recorder is not None:
            # feedback + the knobs in force during this round (the
            # decision the offline replay must reproduce), then re-export
            # the trace so a killed run still leaves a loadable file
            self.recorder.on_round(fb, self.knobs)
            for a in alerts:
                self.recorder.on_alert(a)
            if digest is not None:
                self.recorder.on_digest(digest)
            self.recorder.flush()
        if self.monitor is not None \
                and self.cfg.obs.health.policy == "rollback" \
                and state_healthy:
            # refresh the rollback point: the state now committed is
            # healthy (either genuinely, or because we just restored it)
            self._healthy_snapshot = self._snapshot_state()
        if abort_alert is not None:
            raise HealthAbort(abort_alert)
        return self._record(metrics)

    # ------------------------------------------------------------------
    def train_epoch_sequential(self, batches_per_client: int = 24
                               ) -> Dict[str, float]:
        """The seed's sequential client loop, kept verbatim as the numeric
        reference: engine sync mode (loop backend) must match this
        bit-for-bit (pinned in tests/test_fed_runtime.py).  Uplink DP is
        applied to each client's round delta exactly as the engine's
        pre-codec stage would, so the reference also covers
        ``privacy.mode='uplink'`` with ``codec='none'``.

        This loop always trains the MONOLITHIC D, which equals the
        split-executed step only under the identity boundary stage (the
        bit-exact pin); a lossy/noisy stage trains a genuinely different
        model, so that combination is refused rather than silently
        diverging from every engine path."""
        if self.split_execs and any(s.name != "identity"
                                    for ex in self.split_execs.values()
                                    for s in ex.stages):
            raise ValueError(
                "train_epoch_sequential is the unsplit/identity-stage "
                f"reference; boundary_stage="
                f"{self.cfg.split.boundary_stage!r} trains a different "
                "(staged) model — use train_epoch")
        st = self.state
        d_losses = []
        active = self._active_clients()
        for cid in active:
            start = st.d_params[cid]
            dp, do = start, st.d_opt[cid]
            for b in range(batches_per_client):
                real = self._sample_real(cid, self.batch_size)
                fake = self._gen(st.g_params, self._z(self.batch_size))
                # server ships fakes; client never shares `real`
                dp, do, dl = self._d_update(dp, do, real,
                                            jax.lax.stop_gradient(fake))
                d_losses.append(float(dl))
            if self._uplink_stage is not None:
                # the engine's pre-codec uplink path with the identity
                # codec: clip+noise the fp32 round delta, then rebase —
                # the SAME delta_tree/apply_delta arithmetic, so the
                # engine's sync/no-codec uplink round pins against this
                # loop structurally
                dp = apply_delta(
                    start, self._uplink_stage(cid, delta_tree(dp, start)))
            st.d_params[cid], st.d_opt[cid] = dp, do

        if self.accountant is not None and self.cfg.privacy.mode == "uplink":
            self.accountant.step(len(active))

        # FedAvg over client discriminators (weighted by examples)
        weights = ([len(self.client_data[cid]) for cid in active]
                   if self.cfg.fsl.weighted_average else None)
        d_avg = fedavg([st.d_params[cid] for cid in active], weights)
        for cid in self.client_ids:
            st.d_params[cid] = jax.tree.map(jnp.copy, d_avg)

        g_losses = self._g_updates(d_avg, batches_per_client)
        st.step += 1
        metrics = {"d_loss": float(np.mean(d_losses)),
                   "g_loss": float(np.mean(g_losses)),
                   "num_clients": float(len(active))}
        if self.accountant is not None:
            metrics["dp_epsilon"] = self.accountant.epsilon(
                self.cfg.privacy.delta)[0]
        return self._record(metrics)

    def device_load_report(self) -> Dict[str, float]:
        """Compute units each device carries under the current plans
        (device ids are globally unique: ``c<i>_d<j>``)."""
        loads: Dict[str, float] = {}
        for cid in self._active_clients():
            if cid in self.plans:
                for dev, load in self.plans[cid].device_loads().items():
                    loads[dev] = loads.get(dev, 0.0) + load
        return loads or {"unsplit": 0.0}

    def generate(self, n: int, seed: int = 0) -> np.ndarray:
        z = jax.random.normal(jax.random.PRNGKey(seed),
                              (n, self.c.latent_dim))
        return np.asarray(self._gen(self.state.g_params, z))
