"""Device and client models (paper §3.2).

Each FL *client* owns a pool of SL *devices*. A device is characterised by
  Time_Factor     seconds to train one unit of model compute (lower = faster)
  Client_Capacity memory slots: how many model portions it can hold

``efficiency`` (paper §4, Sort_By_Time selection) combines both:
    efficiency = capacity / time_factor
i.e. trainable portions per unit time — a device with plenty of memory but a
slow processor (the paper's "old device without AVX/GPU") scores low.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List

import numpy as np


@dataclass(frozen=True)
class Device:
    device_id: str
    time_factor: float          # sec per compute unit (paper: Time_Factor)
    capacity: int               # portions it can store (paper: Client_Capacity)

    @property
    def efficiency(self) -> float:
        return self.capacity / max(self.time_factor, 1e-9)


@dataclass
class Client:
    client_id: str
    devices: List[Device]
    num_examples: int = 6144    # paper: 24 batches x 256 per epoch

    def total_capacity(self) -> int:
        return sum(d.capacity for d in self.devices)


# ---------------------------------------------------------------------------
# heterogeneity presets
# ---------------------------------------------------------------------------

def paper_pool(num_clients: int = 5, devices_per_client: int = 4,
               seed: int = 0) -> List[Client]:
    """The paper's simulated environment: 5 clients x 4 devices with mixed
    speeds/memories, *including* slow-but-roomy old devices (the case that
    makes ``random_multi`` the worst strategy in Fig 2).
    """
    rng = np.random.default_rng(seed)
    # archetypes: (time_factor, capacity)
    archetypes = [
        (0.4, 2),    # modern phone: fast, modest memory
        (1.0, 2),    # mid-range
        (2.5, 4),    # old desktop: slow (no AVX/GPU) but lots of memory
        (0.6, 1),    # fast wearable: tiny memory
    ]
    clients = []
    for c in range(num_clients):
        devs = []
        order = rng.permutation(len(archetypes))
        for i in range(devices_per_client):
            tf, cap = archetypes[order[i % len(archetypes)]]
            jitter = float(rng.uniform(0.8, 1.25))
            devs.append(Device(f"c{c}_d{i}", tf * jitter, cap))
        clients.append(Client(f"c{c}", devs))
    return clients


def uniform_pool(num_clients: int, devices_per_client: int,
                 time_factor: float = 1.0, capacity: int = 2) -> List[Client]:
    """Homogeneous pool (TPU-pod analogue: every chip identical)."""
    return [
        Client(f"c{c}", [Device(f"c{c}_d{i}", time_factor, capacity)
                         for i in range(devices_per_client)])
        for c in range(num_clients)
    ]


def make_pool(preset: str, num_clients: int, devices_per_client: int,
              seed: int = 0) -> List[Client]:
    if preset == "paper":
        return paper_pool(num_clients, devices_per_client, seed)
    if preset == "uniform":
        return uniform_pool(num_clients, devices_per_client)
    raise ValueError(f"unknown heterogeneity preset {preset!r}")
