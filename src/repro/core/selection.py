"""Device-selection strategies (paper §4).

Four strategies, the cross product of
  {random, sort_by_time(efficiency)} x {single portion, multiple portions}:

  random_single   pick a device at random, give it ONE portion (one layer
                  unit), pick again (with replacement of remaining-capacity
                  devices) until the model is covered.
  random_multi    pick a device at random, fill it with as many consecutive
                  portions as its capacity allows, continue.
  sorted_single   sort devices by efficiency (desc); round-robin one portion
                  at a time over that order.
  sorted_multi    sort devices by efficiency (desc); fill each device to
                  capacity before moving to the next.  (paper's winner)

Drop rules (paper §4): a device that cannot take any portion is removed from
the pool; a client whose devices cannot cover the whole model is removed
from the FL round (InfeasibleSplit).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.config.base import SELECTION_STRATEGIES as STRATEGIES
from repro.core.devices import Client, Device
from repro.core.split import InfeasibleSplit, Portion, SplitPlan


def _check_feasible(client: Client, n_units: int) -> None:
    if client.total_capacity() < n_units:
        raise InfeasibleSplit(
            f"client {client.client_id}: capacity {client.total_capacity()} "
            f"< {n_units} layer units — dropped from FL round (paper §4)")


def _plan_from_order(client: Client, layers: Sequence[Tuple[str, float]],
                     device_order: List[Device], multi: bool) -> SplitPlan:
    """Walk layers in model order, assigning to devices in `device_order`.

    multi=True fills a device to capacity before advancing; multi=False
    takes one unit per visit (the order list may repeat devices).
    """
    plan = SplitPlan(client_id=client.client_id)
    remaining = {d.device_id: d.capacity for d in client.devices}
    li = 0
    for dev in device_order:
        if li >= len(layers):
            break
        cap = remaining.get(dev.device_id, 0)
        if cap <= 0:
            continue            # paper: device with no room is skipped/removed
        take = min(cap, len(layers) - li) if multi else 1
        names = tuple(n for n, _ in layers[li:li + take])
        cost = float(sum(c for _, c in layers[li:li + take]))
        plan.portions.append(Portion(dev.device_id, names, cost))
        remaining[dev.device_id] = cap - take
        li += take
    if li < len(layers):
        raise InfeasibleSplit(
            f"client {client.client_id}: ran out of devices at layer {li}")
    return plan


def make_plan(client: Client, layers: Sequence[Tuple[str, float]],
              strategy: str, seed: int = 0) -> SplitPlan:
    """layers: ordered (name, cost) units. Returns a validated SplitPlan."""
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; one of {STRATEGIES}")
    _check_feasible(client, len(layers))
    rng = np.random.default_rng(seed)
    if strategy.startswith("random"):
        # random order with enough repeats that capacity can be consumed
        idx = list(range(len(client.devices)))
        order: List[Device] = []
        while len(order) < len(layers) * 2 + len(idx):
            rng.shuffle(idx)
            order.extend(client.devices[i] for i in idx)
    else:
        by_eff = sorted(client.devices, key=lambda d: -d.efficiency)
        if strategy == "sorted_single":
            # round-robin in efficiency order until capacity exhausted
            order = []
            for _ in range(max(d.capacity for d in by_eff)):
                order.extend(by_eff)
        else:
            order = by_eff
    multi = strategy.endswith("multi")
    plan = _plan_from_order(client, layers, order, multi)
    plan.validate([n for n, _ in layers])
    return plan


def plan_all_clients(clients: List[Client],
                     layers: Sequence[Tuple[str, float]], strategy: str,
                     seed: int = 0) -> Dict[str, SplitPlan]:
    """Plan every client; infeasible clients are dropped (paper §4)."""
    plans: Dict[str, SplitPlan] = {}
    for i, c in enumerate(clients):
        try:
            plans[c.client_id] = make_plan(c, layers, strategy, seed + i)
        except InfeasibleSplit:
            continue
    return plans
