"""FSL-GAN core: the paper's contribution (split + selection + fedavg + GAN)."""
from repro.core.devices import Client, Device, make_pool  # noqa: F401
from repro.core.fedavg import (fedavg, fedavg_collective,  # noqa: F401
                               fedavg_weighted_collective)
from repro.core.gan import FSLGANTrainer, bce_logits, d_loss_fn, g_loss_fn  # noqa: F401
from repro.core.selection import STRATEGIES, make_plan, plan_all_clients  # noqa: F401
from repro.core.simulate import epoch_time_report, strategy_sweep  # noqa: F401
from repro.core.split import (InfeasibleSplit, Portion, SplitPlan,  # noqa: F401
                              split_forward)
