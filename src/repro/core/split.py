"""Model splitting (paper §3.2/§4): partition an ordered layer stack into
contiguous *portions* and assign each portion to one of a client's devices.

The planner is model-agnostic: it consumes an ordered list of
(layer_name, cost) pairs — the DCGAN discriminator's conv blocks, or any
assigned transformer architecture's blocks (the paper's technique applied
beyond GANs; see DESIGN.md §4).

A :class:`SplitPlan` is the paper's central artifact: which device trains
which contiguous layer range. ``plan_time()`` (core/simulate.py) prices it;
``split_forward`` (this module) executes it portion-by-portion and is
numerically identical to the unsplit forward — the property the tests pin.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.devices import Client, Device


@dataclass(frozen=True)
class Portion:
    """A contiguous run of layers assigned to one device."""
    device_id: str
    layer_names: Tuple[str, ...]
    cost: float                 # sum of layer costs (compute units)


@dataclass
class SplitPlan:
    client_id: str
    portions: List[Portion] = field(default_factory=list)

    @property
    def num_boundaries(self) -> int:
        """Device-to-device hand-offs along the chain (LAN hops, fwd)."""
        n = 0
        for a, b in zip(self.portions, self.portions[1:]):
            if a.device_id != b.device_id:
                n += 1
        return n

    def layers_in_order(self) -> List[str]:
        return [n for p in self.portions for n in p.layer_names]

    def device_loads(self) -> Dict[str, float]:
        loads: Dict[str, float] = {}
        for p in self.portions:
            loads[p.device_id] = loads.get(p.device_id, 0.0) + p.cost
        return loads

    def validate(self, layer_names: Sequence[str]) -> None:
        got = self.layers_in_order()
        if got != list(layer_names):
            raise ValueError(
                f"split plan does not cover the model in order:\n"
                f"  expected {list(layer_names)}\n  got      {got}")


class InfeasibleSplit(Exception):
    """Client lacks capacity to host the model (paper: client is dropped)."""


# ---------------------------------------------------------------------------
# split execution — numerically identical to the unsplit forward
# ---------------------------------------------------------------------------

def split_forward(x, plan: SplitPlan,
                  apply_layer: Callable[[str, object], object],
                  boundary_hook: Optional[Callable[[int, str, str, object],
                                                   None]] = None):
    """Run a forward pass portion-by-portion, as the devices would.

    ``apply_layer(name, x) -> x`` applies one named layer. On real FSL
    hardware each portion runs on its own device with activations crossing
    the LAN at portion boundaries; here the boundary is a list hop, and the
    result is bit-identical to the monolithic forward (tested property).

    ``boundary_hook(boundary_idx, from_device, to_device, activation)`` is
    called at every device-to-device hand-off with the smashed activation
    that would cross the LAN — the observation point of the privacy
    subsystem's activation-inversion attack (privacy/attacks.py).
    """
    n_boundary = 0
    for pi, portion in enumerate(plan.portions):
        for name in portion.layer_names:
            x = apply_layer(name, x)
        if boundary_hook is not None and pi + 1 < len(plan.portions):
            nxt = plan.portions[pi + 1]
            if nxt.device_id != portion.device_id:
                boundary_hook(n_boundary, portion.device_id,
                              nxt.device_id, x)
                n_boundary += 1
    return x


def boundary_activations(x, plan: SplitPlan,
                         apply_layer: Callable[[str, object], object]
                         ) -> List[Tuple[int, str, str, object]]:
    """All (boundary_idx, from_device, to_device, activation) tuples a LAN
    observer sees during one split forward pass."""
    seen: List[Tuple[int, str, str, object]] = []
    split_forward(x, plan, apply_layer,
                  boundary_hook=lambda i, a, b, act: seen.append(
                      (i, a, b, act)))
    return seen
