"""Model splitting (paper §3.2/§4): the SplitPlan as the *executed* local
step, not just a pricing artifact.

The planner is model-agnostic: it consumes an ordered list of
(layer_name, cost) pairs — the DCGAN discriminator's conv blocks, or any
assigned transformer architecture's blocks (the paper's technique applied
beyond GANs; see DESIGN.md §4).  A :class:`SplitPlan` records which device
trains which contiguous layer range.

Two execution layers sit on top of the plan:

  * ``split_forward`` / ``boundary_activations`` — the inference-only walk:
    portion-by-portion forward, bit-identical to the unsplit forward, with a
    hook at every device hand-off (the privacy subsystem's original
    observation point).
  * :class:`SplitExecution` — the *training* step.  It compiles the plan
    into a staged ``value_and_grad``: the forward runs device-segment by
    device-segment (``jax.vjp`` per segment), the backward walks the same
    segments in reverse, and EVERY tensor that crosses a segment boundary —
    the smashed activation on the way forward, its gradient on the way back
    — passes through a :class:`BoundaryStage` first.  With the identity
    stage the composed gradient is bit-exact with the monolithic
    ``jax.value_and_grad`` (pinned in tests/test_split_selection.py); codec
    stages (``fed/transport``) and Gaussian clip+noise stages
    (``privacy/defenses``) model lossy/noisy LAN links, exactly what
    SplitFed-style deployments ship.  Stages are applied straight-through
    (not differentiated): they model the wire, not the math.

The same object prices what it executes: ``step_wire_bytes`` measures the
per-boundary LAN payload of one local step (``tree_bytes`` of the staged
tensors / the codec's wire bytes), which ``core/simulate.plan_epoch_time``
consumes in place of the paper's fixed 50 ms hop constant and
``fed/transport.TrafficLedger`` records per round.  ``fed/programs.
make_local_step(..., split_exec=...)`` builds the client-side training step
from this staged execution, so split training composes with every backend,
scheduler, codec and privacy mode — plan → execute → measure → attack,
instead of plan → price.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.devices import Client, Device


@dataclass(frozen=True)
class Portion:
    """A contiguous run of layers assigned to one device."""
    device_id: str
    layer_names: Tuple[str, ...]
    cost: float                 # sum of layer costs (compute units)


@dataclass
class SplitPlan:
    client_id: str
    portions: List[Portion] = field(default_factory=list)

    @property
    def num_boundaries(self) -> int:
        """Device-to-device hand-offs along the chain (LAN hops, fwd)."""
        n = 0
        for a, b in zip(self.portions, self.portions[1:]):
            if a.device_id != b.device_id:
                n += 1
        return n

    def layers_in_order(self) -> List[str]:
        return [n for p in self.portions for n in p.layer_names]

    def device_loads(self) -> Dict[str, float]:
        loads: Dict[str, float] = {}
        for p in self.portions:
            loads[p.device_id] = loads.get(p.device_id, 0.0) + p.cost
        return loads

    def validate(self, layer_names: Sequence[str]) -> None:
        got = self.layers_in_order()
        if got != list(layer_names):
            raise ValueError(
                f"split plan does not cover the model in order:\n"
                f"  expected {list(layer_names)}\n  got      {got}")


class InfeasibleSplit(Exception):
    """Client lacks capacity to host the model (paper: client is dropped)."""


# ---------------------------------------------------------------------------
# split execution — numerically identical to the unsplit forward
# ---------------------------------------------------------------------------

def split_forward(x, plan: SplitPlan,
                  apply_layer: Callable[[str, object], object],
                  boundary_hook: Optional[Callable[[int, str, str, object],
                                                   None]] = None):
    """Run a forward pass portion-by-portion, as the devices would.

    ``apply_layer(name, x) -> x`` applies one named layer. On real FSL
    hardware each portion runs on its own device with activations crossing
    the LAN at portion boundaries; here the boundary is a list hop, and the
    result is bit-identical to the monolithic forward (tested property).

    ``boundary_hook(boundary_idx, from_device, to_device, activation)`` is
    called at every device-to-device hand-off with the smashed activation
    that would cross the LAN — the observation point of the privacy
    subsystem's activation-inversion attack (privacy/attacks.py).
    """
    n_boundary = 0
    for pi, portion in enumerate(plan.portions):
        for name in portion.layer_names:
            x = apply_layer(name, x)
        if boundary_hook is not None and pi + 1 < len(plan.portions):
            nxt = plan.portions[pi + 1]
            if nxt.device_id != portion.device_id:
                boundary_hook(n_boundary, portion.device_id,
                              nxt.device_id, x)
                n_boundary += 1
    return x


def boundary_activations(x, plan: SplitPlan,
                         apply_layer: Callable[[str, object], object]
                         ) -> List[Tuple[int, str, str, object]]:
    """All (boundary_idx, from_device, to_device, activation) tuples a LAN
    observer sees during one split forward pass."""
    seen: List[Tuple[int, str, str, object]] = []
    split_forward(x, plan, apply_layer,
                  boundary_hook=lambda i, a, b, act: seen.append(
                      (i, a, b, act)))
    return seen


# ---------------------------------------------------------------------------
# staged training execution: segments, boundary stages, SplitExecution
# ---------------------------------------------------------------------------

def plan_segments(plan: SplitPlan) -> List[Tuple[str, Tuple[str, ...]]]:
    """Merge consecutive same-device portions into *device segments*.

    A segment is the unit of staged execution: activations only cross the
    LAN between segments, so ``len(segments) - 1 == plan.num_boundaries``.
    """
    segs: List[Tuple[str, Tuple[str, ...]]] = []
    for p in plan.portions:
        if segs and segs[-1][0] == p.device_id:
            segs[-1] = (p.device_id, segs[-1][1] + p.layer_names)
        else:
            segs.append((p.device_id, p.layer_names))
    return segs


@dataclass(frozen=True)
class Boundary:
    """One LAN hand-off in the executed chain."""
    index: int
    from_device: str
    to_device: str
    depth: int                  # layers applied before the hand-off


def partition_params(plan: SplitPlan, params) -> List[Dict[str, Any]]:
    """Partition a {layer_name: subtree} param tree by portion: what each
    device actually holds.  Layers absent from ``params`` (shared heads
    etc.) are skipped."""
    return [{n: params[n] for n in p.layer_names if n in params}
            for p in plan.portions]


def tensor_wire_bytes(shape: Sequence[int],
                      dtype=jnp.float32) -> int:
    """Native payload bytes of one boundary tensor (identity wire)."""
    n = 1
    for s in shape:
        n *= int(s)
    return n * jnp.dtype(dtype).itemsize


class BoundaryStage:
    """What happens to a tensor as it crosses a segment boundary.

    ``apply(x, key)`` transforms the tensor (identity here); ``wire_bytes``
    prices what the transformed tensor costs on the LAN.  Stages are
    straight-through: the backward pass applies the stage to the crossing
    *gradient* but never differentiates through the stage itself — noise
    and compression model the wire, not the computation.
    """
    name = "identity"
    stochastic = False          # True => ``apply`` consumes the key

    @property
    def signature(self) -> Tuple:
        """Compilation identity: stages with equal signatures compile to
        the same staged program.  Subclasses with parameters MUST include
        them, or differently-parameterized stages would silently share one
        compiled step (``fed/programs.LocalProgram`` dedups on this)."""
        return (self.name,)

    def apply(self, x: jnp.ndarray, key=None) -> jnp.ndarray:
        del key
        return x

    def wire_bytes(self, shape: Sequence[int], dtype=jnp.float32) -> int:
        return tensor_wire_bytes(shape, dtype)


class CodecBoundaryStage(BoundaryStage):
    """Run each boundary tensor through a transport codec round-trip
    (``fed/transport``): the downstream device computes on what a
    compressed LAN link would actually deliver.

    Only stateless codecs compose with jit-compiled training steps —
    ``make_boundary_stage`` constructs top-k *without* error feedback.
    """
    stochastic = False

    def __init__(self, codec):
        if getattr(codec, "error_feedback", False):
            raise ValueError(
                "stateful codecs (top-k error feedback) cannot run inside "
                "a jitted training step; build with error_feedback=False")
        self.codec = codec
        self.name = codec.name

    @property
    def signature(self) -> Tuple:
        return (self.name, float(getattr(self.codec, "frac", 0.0)))

    def apply(self, x: jnp.ndarray, key=None) -> jnp.ndarray:
        del key
        dec, _ = self.codec.roundtrip(x)
        return dec

    def wire_bytes(self, shape: Sequence[int], dtype=jnp.float32) -> int:
        _, nbytes = self.codec.roundtrip(jnp.zeros(tuple(shape), dtype))
        return int(nbytes)


class GaussianBoundaryStage(BoundaryStage):
    """Per-example clip + Gaussian noise on every crossing tensor — the
    split-learning analogue of DP-SGD's privatized release, applied to the
    smashed activation (fwd) and its gradient (bwd) at the LAN surface the
    activation-inversion attack observes (privacy/attacks.py)."""
    name = "dp"
    stochastic = True

    def __init__(self, clip: float, sigma: float):
        self.clip = float(clip)
        self.sigma = float(sigma)

    @property
    def signature(self) -> Tuple:
        return (self.name, self.clip, self.sigma)

    def apply(self, x: jnp.ndarray, key=None) -> jnp.ndarray:
        flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
        norms = jnp.linalg.norm(flat, axis=1)
        scale = jnp.minimum(1.0, self.clip / jnp.maximum(norms, 1e-12))
        y = flat * scale[:, None]
        if self.sigma > 0.0 and key is not None:
            y = y + self.sigma * self.clip * jax.random.normal(
                key, y.shape, jnp.float32)
        return y.reshape(x.shape).astype(x.dtype)


class ComposedBoundaryStage(BoundaryStage):
    """Sequential composition of boundary stages (applied in listed
    order, e.g. ``int8+dp`` = codec round-trip, then clip+noise).

    Wire pricing uses the FIRST codec stage in the chain (the codec's
    encoding is the payload that crosses the LAN; the clip+noise is the
    sender-side privatization of what that encoding will deliver).  The
    step key is passed to every sub-stage unchanged — only one
    stochastic stage may appear per composition, which keeps the fused
    implementation bit-compatible."""

    def __init__(self, stages: Sequence[BoundaryStage]):
        self.stages_seq = list(stages)
        if sum(1 for s in self.stages_seq if s.stochastic) > 1:
            raise ValueError("at most one stochastic stage per composition")
        self.name = "+".join(s.name for s in self.stages_seq)
        self.stochastic = any(s.stochastic for s in self.stages_seq)

    @property
    def signature(self) -> Tuple:
        return ("compose",) + tuple(s.signature for s in self.stages_seq)

    def apply(self, x: jnp.ndarray, key=None) -> jnp.ndarray:
        for s in self.stages_seq:
            x = s.apply(x, key)
        return x

    def wire_bytes(self, shape: Sequence[int], dtype=jnp.float32) -> int:
        for s in self.stages_seq:
            if isinstance(s, CodecBoundaryStage):
                return s.wire_bytes(shape, dtype)
        return tensor_wire_bytes(shape, dtype)


class FusedBoundaryStage(BoundaryStage):
    """``codec + dp`` composition in ONE traversal: quantize/dequantize,
    per-example clip and Gaussian noise fused into a single pass
    (``kernels/boundary_fuse``) instead of the three separate traversals
    ``CodecBoundaryStage`` → ``GaussianBoundaryStage`` makes over every
    shipped tensor.  Numerics are pinned against the unfused composition
    (tests/test_pipeline.py); fusable codecs are the elementwise ones
    (``fp16``, ``int8`` and the degenerate ``none``) — global top-k
    selection is not streamable tile-by-tile and stays composed."""

    FUSABLE = ("none", "fp16", "int8")
    stochastic = True

    def __init__(self, codec_name: str, clip: float, sigma: float, *,
                 use_kernel: bool = False, interpret: bool = False):
        if codec_name not in self.FUSABLE:
            raise ValueError(f"codec {codec_name!r} is not fusable "
                             f"(expected one of {self.FUSABLE})")
        self.codec_name = codec_name
        self.clip = float(clip)
        self.sigma = float(sigma)
        self.use_kernel = bool(use_kernel)
        self.interpret = bool(interpret)
        self.name = "dp" if codec_name == "none" else f"{codec_name}+dp"

    @property
    def signature(self) -> Tuple:
        return ("fused", self.codec_name, self.clip, self.sigma,
                self.use_kernel, self.interpret)

    def apply(self, x: jnp.ndarray, key=None) -> jnp.ndarray:
        from repro.kernels.boundary_fuse.ops import fused_boundary_flat
        flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
        noise_scale = 0.0
        noise = jnp.zeros_like(flat)
        if self.sigma > 0.0 and key is not None:
            # Same draw (key, flat shape) as GaussianBoundaryStage, so
            # fused == composed holds bit-for-bit per noise sample.
            noise_scale = self.sigma * self.clip
            noise = jax.random.normal(key, flat.shape, jnp.float32)
        y = fused_boundary_flat(flat, self.clip, noise_scale, noise,
                                codec=self.codec_name,
                                use_kernel=self.use_kernel,
                                interpret=self.interpret)
        return y.reshape(x.shape).astype(x.dtype)

    def wire_bytes(self, shape: Sequence[int], dtype=jnp.float32) -> int:
        if self.codec_name == "none":
            return tensor_wire_bytes(shape, dtype)
        from repro.fed.transport import make_codec
        _, nbytes = make_codec(self.codec_name).roundtrip(
            jnp.zeros(tuple(shape), dtype))
        return int(nbytes)


def make_boundary_stage(split_cfg, name: Optional[str] = None
                        ) -> BoundaryStage:
    """Factory keyed by ``config.SplitConfig.boundary_stage``; ``name``
    overrides it (the split controller builds per-boundary stages from the
    same clip/sigma/frac parameters, varying only the stage kind).

    Composed names (``"fp16+dp"``, ``"int8+dp"``, ``"topk+dp"``) chain
    stages in order; when the chain is a fusable codec followed by
    ``dp`` and ``split_cfg.fuse_boundary`` is not disabled, the fused
    single-traversal implementation is selected automatically.
    """
    if name is None:
        name = getattr(split_cfg, "boundary_stage", "identity")
    if "+" in name:
        parts = [p for p in name.split("+") if p]
        if (len(parts) == 2 and parts[1] == "dp"
                and parts[0] in FusedBoundaryStage.FUSABLE
                and getattr(split_cfg, "fuse_boundary", True)):
            return FusedBoundaryStage(
                parts[0], split_cfg.stage_clip, split_cfg.stage_sigma,
                use_kernel=getattr(split_cfg, "use_kernel", False),
                interpret=getattr(split_cfg, "kernel_interpret", False))
        return ComposedBoundaryStage(
            [make_boundary_stage(split_cfg, p) for p in parts])
    if name in ("", "identity", "none"):
        return BoundaryStage()
    if name == "dp":
        return GaussianBoundaryStage(split_cfg.stage_clip,
                                     split_cfg.stage_sigma)
    from repro.fed.transport import make_codec
    return CodecBoundaryStage(make_codec(
        name, topk_frac=getattr(split_cfg, "topk_frac", 0.01),
        error_feedback=False))


class SplitExecution:
    """A :class:`SplitPlan` compiled into the executed local training step.

    ``apply_layer(name, params, x) -> x`` applies one named layer;
    ``tails`` is one scalar loss tail per forward pass (the GAN D loss is
    two passes: BCE(real, 1) and BCE(fake, 0)).  Both passes traverse the
    SAME boundaries per step — each hand-off ships one tensor per pass per
    direction.

    ``value_and_grad`` is jit/vmap-compatible and, under the identity
    stage, bit-exact with ``jax.value_and_grad`` of the monolithic loss:
    each device segment contributes its parameters' gradients through its
    own ``jax.vjp``, and the cotangent chain crosses boundaries exactly
    where the activations did (pinned property).
    """

    def __init__(self, plan: SplitPlan, apply_layer, tails: Sequence, *,
                 stage: Optional[BoundaryStage] = None,
                 stages: Optional[Sequence[BoundaryStage]] = None,
                 pipeline_microbatches: int = 1,
                 pipeline_scan: bool = False):
        """``stage`` applies one stage uniformly at every boundary;
        ``stages`` assigns a stage PER boundary (index-aligned with
        ``self.boundaries``) — the split controller's lever for noising
        only the boundaries the attack actually reads.  Passing both uses
        ``stages`` and keeps ``stage`` as the documented uniform default.

        ``pipeline_microbatches`` > 1 makes ``value_and_grad`` run the
        1F1B-pipelined step (``run_pipelined``): each batch splits into
        that many micro-batches so device segments overlap, with the
        per-batch wall time priced by ``overlap_schedule`` instead of
        the additive chain.  ``1`` (default) is the sequential step,
        bit-exact with the pre-pipeline executor.

        ``pipeline_scan`` compiles the K-micro-batch loop as ONE
        ``lax.scan`` over the chunk axis instead of K unrolled copies of
        the staged chain — trace size (and compile time) O(1) in K,
        tolerance-pinned against the unrolled loop.  Only the
        non-collecting path scans; ``collect=True`` (boundary-tensor
        capture) keeps the Python loop, whose per-chunk records it needs.
        """
        self.plan = plan
        self.apply_layer = apply_layer
        self.tails = tuple(tails)
        self.stage = stage or BoundaryStage()
        self.pipeline_microbatches = max(1, int(pipeline_microbatches))
        self.pipeline_scan = bool(pipeline_scan)
        self.segments = plan_segments(plan)
        self.boundaries: List[Boundary] = []
        depth = 0
        for i, (dev, names) in enumerate(self.segments[:-1]):
            depth += len(names)
            self.boundaries.append(Boundary(
                i, dev, self.segments[i + 1][0], depth))
        if stages is None:
            self.stages: List[BoundaryStage] = \
                [self.stage] * len(self.boundaries)
        else:
            self.stages = list(stages)
            if len(self.stages) != len(self.boundaries):
                raise ValueError(
                    f"{len(self.stages)} stages for "
                    f"{len(self.boundaries)} boundaries")
        self._shape_cache: Dict[Tuple, List[Tuple[int, ...]]] = {}

    # ------------------------------------------------------------------
    @property
    def num_boundaries(self) -> int:
        return len(self.boundaries)

    @property
    def num_passes(self) -> int:
        return len(self.tails)

    @property
    def stochastic(self) -> bool:
        """True when ANY boundary's stage consumes the noise key."""
        return any(s.stochastic for s in self.stages)

    @property
    def signature(self) -> Tuple:
        """Compilation key: two plans with the same boundary depths and
        the same (fully parameterized) per-boundary stages compile to the
        same staged program — device *identity* only affects pricing,
        never math.  Pipelined executions (``pipeline_microbatches > 1``)
        carry K in the signature: a pipelined step compiles to different
        XLA than the sequential one and must never share its cache slot
        (``fed/programs.LocalProgram`` dedups on this)."""
        base = (tuple(b.depth for b in self.boundaries),
                tuple(s.signature for s in self.stages))
        if self.pipeline_microbatches > 1:
            tag = "pipeline-scan" if self.pipeline_scan else "pipeline"
            return base + ((tag, self.pipeline_microbatches),)
        return base

    # ------------------------------------------------------------------
    def _segment_fn(self, names: Tuple[str, ...]):
        def seg(params, xs):
            out = []
            for x in xs:
                for n in names:
                    x = self.apply_layer(n, params, x)
                out.append(x)
            return tuple(out)
        return seg

    def _key(self, key, b: int, p: int, direction: int):
        """Per-(boundary, pass, direction) stage key, collision-free within
        one step (direction: 0 fwd, 1 bwd)."""
        if key is None:
            return None
        return jax.random.fold_in(
            key, 1 + (b * self.num_passes + p) * 2 + direction)

    # ------------------------------------------------------------------
    def run(self, params, batches: Sequence[jnp.ndarray], key=None,
            collect: bool = False):
        """One staged forward+backward over per-pass ``batches``.

        Returns ``(loss, grads, records)``; ``records`` (when ``collect``)
        holds the staged tensors that actually crossed each boundary:
        ``records["fwd"][b][p]`` / ``records["bwd"][b][p]`` for boundary
        ``b``, pass ``p`` — the exact artifacts a LAN observer captures.
        """
        if len(batches) != self.num_passes:
            raise ValueError(f"{len(batches)} batches for "
                             f"{self.num_passes} loss tails")
        if key is None and self.stochastic:
            # a stochastic stage must NEVER run keyless-and-noiseless: the
            # observed/collected tensors would understate the stage and
            # overstate leakage.  Default key == run_looped's default.
            key = jax.random.PRNGKey(0)
        records = {"fwd": [None] * self.num_boundaries,
                   "bwd": [None] * self.num_boundaries}
        xs = tuple(batches)
        vjps = []
        for si, (dev, names) in enumerate(self.segments):
            xs, vjp = jax.vjp(self._segment_fn(names), params, xs)
            vjps.append(vjp)
            if si < len(self.segments) - 1:
                xs = tuple(self.stages[si].apply(x, self._key(key, si, p, 0))
                           for p, x in enumerate(xs))
                if collect:
                    records["fwd"][si] = xs

        def total_loss(zs):
            return sum(tail(z) for tail, z in zip(self.tails, zs))

        loss, tail_vjp = jax.vjp(total_loss, xs)
        (g_act,) = tail_vjp(jnp.ones_like(loss))
        grads = None
        for si in range(len(self.segments) - 1, -1, -1):
            gp, g_act = vjps[si](g_act)
            grads = gp if grads is None \
                else jax.tree.map(jnp.add, grads, gp)
            if si > 0:
                g_act = tuple(
                    self.stages[si - 1].apply(g, self._key(key, si - 1, p, 1))
                    for p, g in enumerate(g_act))
                if collect:
                    records["bwd"][si - 1] = g_act
        return loss, grads, records

    def run_pipelined(self, params, batches: Sequence[jnp.ndarray],
                      key=None, collect: bool = False,
                      num_microbatches: Optional[int] = None):
        """The 1F1B-pipelined local step: split each pass's batch into K
        equal micro-batches and run the staged chain per micro-batch, so
        on real hardware segment ``s`` of micro-batch ``m`` overlaps
        segment ``s+1`` of micro-batch ``m-1`` (the schedule
        ``overlap_schedule`` prices).  Math: with equal chunks and
        mean-reducing loss tails, loss and grads are the micro-batch
        means — tolerance-pinned against the mean of per-chunk monolithic
        gradients (the exact equivalence; batch-norm layers see
        per-micro-batch statistics, the usual grad-accumulation shift
        from the full-batch gradient).

        ``K = 1`` (or a batch K does not divide — clamped to the nearest
        divisor, see ``core.pipeline.effective_microbatches``) falls
        through to ``run`` unchanged: bit-exact with the sequential
        step, pinned.  Stochastic stage keys fold the micro-batch index
        (``fold_in(key, m)``) so micro-batches draw independent noise;
        at ``K = 1`` the key is used as-is, preserving the pin.
        """
        from repro.core.pipeline import effective_microbatches
        if len(batches) != self.num_passes:
            raise ValueError(f"{len(batches)} batches for "
                             f"{self.num_passes} loss tails")
        req = self.pipeline_microbatches if num_microbatches is None \
            else int(num_microbatches)
        bsz = min(int(b.shape[0]) for b in batches)
        k = effective_microbatches(bsz, req)
        if k == 1:
            return self.run(params, batches, key, collect)
        if key is None and self.stochastic:
            key = jax.random.PRNGKey(0)
        mb = bsz // k
        if self.pipeline_scan and not collect:
            return self._run_pipelined_scan(params, batches, key, k, mb)
        loss = None
        grads = None
        recs = []
        for m in range(k):
            chunk = tuple(b[m * mb:(m + 1) * mb] for b in batches)
            mkey = None if key is None else jax.random.fold_in(key, m)
            l, g, r = self.run(params, chunk, mkey, collect)
            loss = l if loss is None else loss + l
            grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
            recs.append(r)
        inv = 1.0 / k
        loss = loss * inv
        grads = jax.tree.map(lambda g: g * inv, grads)
        records = {"fwd": [None] * self.num_boundaries,
                   "bwd": [None] * self.num_boundaries}
        if collect:
            for d in ("fwd", "bwd"):
                for b in range(self.num_boundaries):
                    records[d][b] = tuple(
                        jnp.concatenate([r[d][b][p] for r in recs], axis=0)
                        for p in range(self.num_passes))
        return loss, grads, records

    def _run_pipelined_scan(self, params, batches, key, k: int, mb: int):
        """The K-micro-batch accumulation as ONE ``lax.scan``: chunk 0
        initializes the carry (same accumulation order as the unrolled
        loop — l0, l0+l1, ...), the scan body folds in each chunk's
        micro-batch index for its stage key exactly like the loop does.
        The staged chain is traced twice total (init + body) regardless
        of K, vs K times unrolled."""
        stacked = tuple(b[:k * mb].reshape((k, mb) + tuple(b.shape[1:]))
                        for b in batches)
        l0, g0, _ = self.run(
            params, tuple(s[0] for s in stacked),
            None if key is None else jax.random.fold_in(key, 0),
            collect=False)

        def body(carry, xs):
            m, chunk = xs
            mkey = None if key is None else jax.random.fold_in(key, m)
            l, g, _ = self.run(params, chunk, mkey, collect=False)
            cl, cg = carry
            return (cl + l, jax.tree.map(jnp.add, cg, g)), None

        (loss, grads), _ = jax.lax.scan(
            body, (l0, g0),
            (jnp.arange(1, k), tuple(s[1:] for s in stacked)))
        inv = 1.0 / k
        return (loss * inv, jax.tree.map(lambda g: g * inv, grads),
                {"fwd": [None] * self.num_boundaries,
                 "bwd": [None] * self.num_boundaries})

    def value_and_grad(self, params, real, fake, key=None):
        """The D-loss contract of ``fed/programs.make_local_step``:
        ``(params, real, fake, key) -> (loss, grads)`` through the staged
        execution — pipelined when ``pipeline_microbatches > 1``."""
        if self.pipeline_microbatches > 1:
            loss, grads, _ = self.run_pipelined(params, (real, fake), key)
        else:
            loss, grads, _ = self.run(params, (real, fake), key)
        return loss, grads

    # ------------------------------------------------------------------
    def forward_boundaries(self, params, x, key=None,
                           upto: Optional[int] = None) -> List[jnp.ndarray]:
        """The staged activations ONE forward pass ships, per boundary —
        the tensors the activation-inversion attack should target
        (post-codec, post-noise), not a separate clean forward.  ``upto``
        stops after that boundary index (an attacker at boundary b never
        needs the deeper segments' compute)."""
        if key is None and self.stochastic:
            key = jax.random.PRNGKey(0)
        out = []
        for si, (dev, names) in enumerate(self.segments[:-1]):
            for n in names:
                x = self.apply_layer(n, params, x)
            x = self.stages[si].apply(x, self._key(key, si, 0, 0))
            out.append(x)
            if upto is not None and si >= upto:
                break
        return out

    def shipped_boundaries(self, params, real, fake, key=None
                           ) -> Dict[str, List[Tuple[jnp.ndarray, ...]]]:
        """Every boundary tensor one local step ships (fwd activations and
        bwd activation-grads, both passes), as staged — per-micro-batch
        tensors concatenated back to the full-batch view when the step
        is pipelined (what the LAN observer sees is unchanged in union,
        just split across K messages)."""
        _, _, records = self.run_pipelined(params, (real, fake), key,
                                           collect=True)
        return records

    # ------------------------------------------------------------------
    def boundary_shapes(self, params, x_shape: Sequence[int],
                        dtype=jnp.float32) -> List[Tuple[int, ...]]:
        """Activation shape at each boundary for one pass of ``x_shape``
        batches (no FLOPs — ``jax.eval_shape``)."""
        ck = (tuple(x_shape), jnp.dtype(dtype).name)
        if ck not in self._shape_cache:
            def prefixes(p, x):
                out = []
                for dev, names in self.segments[:-1]:
                    for n in names:
                        x = self.apply_layer(n, p, x)
                    out.append(x)
                return out
            shapes = jax.eval_shape(
                prefixes, params,
                jax.ShapeDtypeStruct(tuple(x_shape), dtype))
            self._shape_cache[ck] = [tuple(s.shape) for s in shapes]
        return self._shape_cache[ck]

    def segment_costs(self) -> List[float]:
        """Compute units per device segment (portions merged exactly as
        ``plan_segments`` merges them)."""
        costs: List[float] = []
        prev: Optional[str] = None
        for p in self.plan.portions:
            if prev == p.device_id:
                costs[-1] += p.cost
            else:
                costs.append(p.cost)
                prev = p.device_id
        return costs

    def overlap_schedule(self, time_factors: Dict[str, float], *,
                         lan_latency_s: float = 0.050,
                         compute_unit_s: float = 0.010,
                         bwd_fwd_ratio: float = 2.0,
                         hop_bytes: Optional[Sequence[int]] = None,
                         lan_bandwidth_bps: float = 100e6,
                         pipeline_microbatches: Optional[int] = None):
        """The explicit 1F1B :class:`core.pipeline.OverlapSchedule` for
        one batch of this plan (K defaults to the executor's configured
        ``pipeline_microbatches``)."""
        from repro.core.pipeline import schedule_for
        k = self.pipeline_microbatches if pipeline_microbatches is None \
            else int(pipeline_microbatches)
        return schedule_for(
            self.segment_costs(), [dev for dev, _ in self.segments],
            time_factors, num_microbatches=k,
            compute_unit_s=compute_unit_s, bwd_fwd_ratio=bwd_fwd_ratio,
            lan_latency_s=lan_latency_s, hop_bytes=hop_bytes,
            lan_bandwidth_bps=lan_bandwidth_bps)

    def round_timeline(self, time_factors: Dict[str, float], *,
                       lan_latency_s: float = 0.050,
                       compute_unit_s: float = 0.010,
                       bwd_fwd_ratio: float = 2.0,
                       hop_bytes: Optional[Sequence[int]] = None,
                       lan_bandwidth_bps: float = 100e6,
                       pipeline_microbatches: Optional[int] = None
                       ) -> Tuple[List[Dict[str, Any]], float]:
        """The ordered phases of ONE local batch under this plan, as the
        flight recorder traces them: forward segment computes and boundary
        hops chain down the device list, then the backward pass walks the
        same chain in reverse (segment computes scaled ``bwd_fwd_ratio``).

        ``time_factors`` maps device id -> Time_Factor; ``hop_bytes``
        (optional) lists the bytes of each hop event in the flattened
        ``[b0.fwd, b0.bwd, b1.fwd, ...]`` order the trainer's
        ``_split_hop_events`` uses — given, each hop costs
        ``lan_latency_s + 8*bytes/bw``; absent, the analytic
        ``lan_latency_s`` per hop.

        Returns ``(phases, batch_time_s)``: phases are dicts with
        ``name``/``cat``/``track``/``t0``/``t1``/``args`` (times relative
        to batch start).  Sequential (``K = 1``) phases chain end to end
        and their durations sum EXACTLY to ``core/simulate.
        plan_epoch_time``'s per-batch time under the same arguments — the
        trace is the price, subdivided, never a second model of it
        (pinned in tests).  Pipelined (``K > 1``, defaulting to the
        executor's ``pipeline_microbatches``) phases come from the 1F1B
        overlap schedule — per-micro-batch spans that genuinely overlap
        across devices, with ``batch_time_s`` the schedule makespan,
        still equal to ``plan_epoch_time``'s per-batch time at the same
        K (same pin).
        """
        k = self.pipeline_microbatches if pipeline_microbatches is None \
            else int(pipeline_microbatches)
        if k > 1 and self.num_boundaries > 0:
            sched = self.overlap_schedule(
                time_factors, lan_latency_s=lan_latency_s,
                compute_unit_s=compute_unit_s, bwd_fwd_ratio=bwd_fwd_ratio,
                hop_bytes=hop_bytes, lan_bandwidth_bps=lan_bandwidth_bps,
                pipeline_microbatches=k)
            phases: List[Dict[str, Any]] = []
            for task in sched.tasks:
                if task.kind in ("fwd", "bwd"):
                    dev = task.device
                    phases.append({
                        "name": f"{task.kind} {dev} mb{task.microbatch}",
                        "cat": "segment", "track": dev,
                        "t0": task.t0, "t1": task.t1,
                        "args": {"microbatch": task.microbatch,
                                 "segment": task.index}})
                else:
                    b = self.boundaries[task.index]
                    direction = "fwd" if task.kind == "hop_fwd" else "bwd"
                    frm, to = (b.from_device, b.to_device) \
                        if direction == "fwd" \
                        else (b.to_device, b.from_device)
                    phases.append({
                        "name": f"b{b.index} {direction} {frm}->{to} "
                                f"mb{task.microbatch}",
                        "cat": "boundary", "track": frm,
                        "t0": task.t0, "t1": task.t1,
                        "args": {"boundary": b.index,
                                 "direction": direction,
                                 "microbatch": task.microbatch,
                                 "stage": self.stages[b.index].name}})
            return phases, sched.makespan
        seg_costs = self.segment_costs()
        bw = max(float(lan_bandwidth_bps), 1.0)

        def hop_time(b: int, direction: int) -> float:
            if hop_bytes is None:
                return lan_latency_s
            return lan_latency_s + 8.0 * int(hop_bytes[2 * b + direction]) / bw

        def seg_time(si: int, ratio: float) -> float:
            dev = self.segments[si][0]
            return seg_costs[si] * compute_unit_s * time_factors[dev] * ratio

        phases: List[Dict[str, Any]] = []
        t = 0.0

        def emit(name: str, cat: str, track: str, dur: float, **args):
            nonlocal t
            phases.append({"name": name, "cat": cat, "track": track,
                           "t0": t, "t1": t + dur, "args": args})
            t += dur

        for si, (dev, names) in enumerate(self.segments):
            emit(f"fwd {dev}", "segment", dev, seg_time(si, 1.0),
                 layers=len(names))
            if si < len(self.segments) - 1:
                b = self.boundaries[si]
                emit(f"b{b.index} fwd {b.from_device}->{b.to_device}",
                     "boundary", b.from_device, hop_time(si, 0),
                     boundary=b.index, direction="fwd",
                     stage=self.stages[si].name)
        for si in range(len(self.segments) - 1, -1, -1):
            dev = self.segments[si][0]
            emit(f"bwd {dev}", "segment", dev, seg_time(si, bwd_fwd_ratio))
            if si > 0:
                b = self.boundaries[si - 1]
                emit(f"b{b.index} bwd {b.to_device}->{b.from_device}",
                     "boundary", b.to_device, hop_time(si - 1, 1),
                     boundary=b.index, direction="bwd",
                     stage=self.stages[si - 1].name)
        return phases, t

    def step_wire_bytes(self, params, x_shape: Sequence[int],
                        dtype=jnp.float32) -> Tuple[int, List[Dict[str, int]]]:
        """Measured LAN bytes of ONE local step under this plan + stage.

        Returns ``(total, per_boundary)`` where ``per_boundary[b]`` has
        ``fwd``/``bwd`` bytes for one pass; the total counts both
        directions across all passes (the cotangent has the activation's
        shape, so fwd == bwd under every stage here).
        """
        per = []
        total = 0
        shapes = self.boundary_shapes(params, x_shape, dtype)
        for si, shp in enumerate(shapes):
            wb = self.stages[si].wire_bytes(shp, dtype)
            per.append({"fwd": wb, "bwd": wb})
            total += 2 * wb * self.num_passes
        return total, per
