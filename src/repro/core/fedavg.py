"""FedAvg aggregation (McMahan et al. 2017), as used by the paper for the
discriminator parameters.

Two forms:
  * fedavg(trees, weights)        host-side, cross-silo: explicit list of
                                  client parameter trees (the paper's setting
                                  — sequential simulation on one accelerator).
  * fedavg_collective(tree, axis) in-mesh: parameters live sharded on the
                                  pod; averaging is one `lax.pmean` over the
                                  data axis inside shard_map/pjit (the
                                  TPU-native adaptation, DESIGN.md §2).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp


def _check_same_structure(trees: Sequence) -> None:
    s0 = jax.tree.structure(trees[0])
    for i, t in enumerate(trees[1:], 1):
        if jax.tree.structure(t) != s0:
            raise ValueError(f"client tree {i} structure differs from client 0")


def fedavg(trees: Sequence, weights: Optional[Sequence[float]] = None):
    """Weighted average of parameter pytrees (fp32 accumulate)."""
    if not trees:
        raise ValueError("fedavg of zero clients")
    _check_same_structure(trees)
    if weights is None:
        weights = [1.0] * len(trees)
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)

    def avg(*leaves):
        acc = sum(l.astype(jnp.float32) * w[i] for i, l in enumerate(leaves))
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(avg, *trees)


def fedavg_collective(tree, axis_name: str):
    """Average a replicated-per-client tree over a mesh axis (use inside
    shard_map). Equal-weight clients; weighted form scales before pmean."""
    return jax.tree.map(
        lambda x: jax.lax.pmean(x.astype(jnp.float32), axis_name
                                ).astype(x.dtype), tree)


def fedavg_weighted_collective(tree, weight, axis_name: str):
    """Weighted in-mesh FedAvg: weight is this shard's client weight."""
    wsum = jax.lax.psum(jnp.asarray(weight, jnp.float32), axis_name)

    def avg(x):
        contrib = x.astype(jnp.float32) * weight
        return (jax.lax.psum(contrib, axis_name) / wsum).astype(x.dtype)

    return jax.tree.map(avg, tree)
