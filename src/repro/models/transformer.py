"""Language-model assembly: embeddings -> period-scanned block stack -> head.

Supports every assigned family: decoder-only (dense/moe/ssm/hybrid/vlm) and
encoder-decoder (whisper). Layers are stacked per *period* (see blocks.py)
and executed with ``jax.lax.scan`` + remat so the HLO stays O(one period)
regardless of depth — essential both for 126-layer dry-run compiles on one
CPU core and for real compile times on a pod.

Public API
----------
  lm_init(key, m, dtype)                  real params (smoke scale)
  lm_param_shapes(m, dtype)               ShapeDtypeStruct tree (dry-run scale)
  lm_specs(m)                             logical-axis tree (matches params)
  lm_apply(params, batch, m, ...)         -> (logits, aux_loss)
  lm_loss(params, batch, m, ...)          -> (loss, metrics)
  init_decode_state(m, batch, cache_len)  stacked decode state
  decode_state_specs(m)                   logical-axis tree for the state
  lm_prefill(params, batch, m, ...)       -> (logits_last, state, index)
  lm_decode_step(params, token, state, index, m, ...) -> (logits, state)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AUDIO, ModelConfig
from repro.models import layers as L
from repro.models.blocks import (block_apply, block_decode, block_init,
                                 block_specs, block_state_init,
                                 block_state_specs, layer_kinds, period_of,
                                 split_periods)
from repro.sharding.specs import Lg, constrain


# ---------------------------------------------------------------------------
# init / specs / shapes
# ---------------------------------------------------------------------------

def _stack_init(key, m: ModelConfig, dtype):
    period = period_of(m)
    n_full, rem = split_periods(m)
    pkeys = jax.random.split(key, max(n_full, 1))

    def one_period(k):
        ks = jax.random.split(k, len(period))
        return {f"b{i}": block_init(ks[i], kind, m, dtype)
                for i, kind in enumerate(period)}

    per = [one_period(pkeys[i]) for i in range(n_full)]
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *per) if per else {}
    tail = {f"t{i}": block_init(jax.random.fold_in(key, 1000 + i), kind, m,
                                dtype)
            for i, kind in enumerate(rem)}
    return stack, tail


def _stack_specs(m: ModelConfig):
    period = period_of(m)
    n_full, rem = split_periods(m)
    one = {f"b{i}": block_specs(kind, m) for i, kind in enumerate(period)}
    # prepend the stacked "layers" axis to every Lg leaf
    stack = jax.tree.map(lambda lg: Lg("layers", *lg), one,
                         is_leaf=lambda x: isinstance(x, Lg)) if n_full else {}
    tail = {f"t{i}": block_specs(kind, m) for i, kind in enumerate(rem)}
    return stack, tail


def lm_init(key, m: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    p["embed"] = L.embedding_init(ks[0], m.vocab_size, m.d_model, dtype)
    p["stack"], p["tail"] = _stack_init(ks[1], m, dtype)
    p["final_norm"] = (L.layernorm_init(m.d_model, dtype) if m.family == AUDIO
                       else L.rmsnorm_init(m.d_model, dtype))
    if not m.tie_embeddings:
        p["head"] = {"w": (jax.random.normal(ks[2],
                                             (m.d_model, m.vocab_size),
                                             jnp.float32)
                           * m.d_model ** -0.5).astype(dtype)}
    if m.encdec.enabled:
        enc_m = _encoder_model_cfg(m)
        e_stack, e_tail = _stack_init(ks[3], enc_m, dtype)
        p["encoder"] = {"stack": e_stack, "tail": e_tail,
                        "norm": L.layernorm_init(m.d_model, dtype)}
    return p


def lm_param_shapes(m: ModelConfig, dtype=jnp.float32):
    """Parameter tree as ShapeDtypeStructs — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda k: lm_init(k, m, dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def lm_specs(m: ModelConfig) -> Dict[str, Any]:
    p: Dict[str, Any] = {}
    p["embed"] = L.embedding_specs()
    p["stack"], p["tail"] = _stack_specs(m)
    p["final_norm"] = (L.layernorm_specs() if m.family == AUDIO
                       else L.rmsnorm_specs())
    if not m.tie_embeddings:
        p["head"] = {"w": Lg("embed", "vocab")}
    if m.encdec.enabled:
        enc_m = _encoder_model_cfg(m)
        e_stack, e_tail = _stack_specs(enc_m)
        p["encoder"] = {"stack": e_stack, "tail": e_tail,
                        "norm": L.layernorm_specs()}
    return p


def _encoder_model_cfg(m: ModelConfig) -> ModelConfig:
    """Encoder stack config: same dims, 'enc' blocks, encoder depth."""
    import dataclasses
    enc = dataclasses.replace(m, num_layers=m.encdec.encoder_layers,
                              family="dense")
    enc._force_kind = "enc"  # type: ignore[attr-defined]  (see blocks.layer_kinds)
    return enc


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _run_stack(stack, tail, x, m: ModelConfig, positions, cd, enc_out,
               remat: str, use_kernel: bool, cache_len: int = 0,
               cache_dtype=jnp.bfloat16, scan_layers: bool = True):
    """Run the period-scanned stack. If cache_len > 0, also collect the
    decode cache produced by prefill (returned in init_decode_state layout).
    """
    period = period_of(m)
    n_full, rem = split_periods(m)

    def period_fn(x, pparams):
        aux = jnp.zeros((), jnp.float32)
        caches = {}
        for i, kind in enumerate(period):
            # layer-boundary residual sharding (sequence-parallel when the
            # runtime policy enables it; identity otherwise)
            x = constrain(x, ("batch", "seq", None))
            x, a, c = block_apply(kind, pparams[f"b{i}"], x, m, positions, cd,
                                  enc_out, use_kernel, cache_len, cache_dtype)
            aux = aux + a
            if cache_len:
                caches[f"b{i}"] = c
        return x, (aux, caches) if cache_len else (aux, None)

    f = period_fn
    if remat == "full":
        f = jax.checkpoint(period_fn, prevent_cse=False)
    elif remat == "dots":
        f = jax.checkpoint(
            period_fn, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    aux_total = jnp.zeros((), jnp.float32)
    stack_cache = {}
    if n_full and scan_layers:
        x, (auxs, stack_cache) = jax.lax.scan(lambda c, xs: f(c, xs), x, stack)
        aux_total = aux_total + jnp.sum(auxs)
    elif n_full:
        # unrolled (probe/accounting mode): python loop over period slices
        per_caches = []
        for i in range(n_full):
            sl = jax.tree.map(lambda a: a[i], stack)
            x, (a, cch) = f(x, sl)
            aux_total = aux_total + a
            per_caches.append(cch)
        if cache_len and per_caches:
            stack_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *per_caches)
    tail_cache = {}
    for i, kind in enumerate(rem):
        x, a, c = block_apply(kind, tail[f"t{i}"], x, m, positions, cd,
                              enc_out, use_kernel, cache_len, cache_dtype)
        aux_total = aux_total + a
        if cache_len:
            tail_cache[f"t{i}"] = c
    if cache_len:
        return x, aux_total, {"stack": stack_cache or {}, "tail": tail_cache}
    return x, aux_total, None


def encode(params, enc_embeds, m: ModelConfig, cd=None, remat: str = "full",
           scan_layers: bool = True):
    """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
    se, d = enc_embeds.shape[1], m.d_model
    x = enc_embeds + L.sinusoidal_positions(se, d).astype(enc_embeds.dtype)
    enc_m = _encoder_model_cfg(m)
    enc = params["encoder"]
    x, _, _ = _run_stack(enc["stack"], enc["tail"], x, enc_m,
                         jnp.arange(se), cd, None, remat, False,
                         scan_layers=scan_layers)
    return L.layernorm_apply(enc["norm"], x)


def lm_apply(params, batch: Dict[str, jnp.ndarray], m: ModelConfig,
             cd=None, remat: str = "full", use_kernel: bool = False,
             positions=None, scan_layers: bool = True
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """batch: {"tokens": (B,S) int32, ["enc_embeds": (B,Se,d)]}."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embedding_apply(params["embed"], tokens, cd)
    if m.family == "hybrid":                 # gemma-style embed scaling
        x = x * jnp.asarray(m.d_model ** 0.5, x.dtype)
    if m.encdec.enabled:                     # whisper: sinusoidal positions
        x = x + L.sinusoidal_positions(s, m.d_model).astype(x.dtype)
    if positions is None:
        positions = jnp.arange(s)
    enc_out = None
    if m.encdec.enabled:
        enc_out = encode(params, batch["enc_embeds"], m, cd, remat,
                         scan_layers)
    x, aux, _ = _run_stack(params["stack"], params["tail"], x, m, positions,
                           cd, enc_out, remat, use_kernel,
                           scan_layers=scan_layers)
    x = (L.layernorm_apply(params["final_norm"], x) if m.family == AUDIO
         else L.rmsnorm_apply(params["final_norm"], x, m.norm_eps))
    if m.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x)
    else:
        # bf16 operands + f32 accumulation: keeps the (d, V) gather and the
        # dW/dx cotangents in bf16 (half the collective bytes vs f32
        # upcasting; EXPERIMENTS §Perf hc2)
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                            preferred_element_type=jnp.float32)
    return logits, aux


def lm_loss(params, batch: Dict[str, jnp.ndarray], m: ModelConfig,
            cd=None, remat: str = "full", use_kernel: bool = False,
            scan_layers: bool = True
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token xent. batch["labels"]: (B,S) with -1 = ignore."""
    logits, aux = lm_apply(params, batch, m, cd, remat, use_kernel,
                           scan_layers=scan_layers)
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.maximum(labels, 0)
    # xent without gathering along the (model-sharded) vocab axis:
    # nll = logsumexp(logits) - logits[label], picked via a one-hot
    # contraction that GSPMD partitions cleanly (no vocab all-gather).
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(lab, logits.shape[-1], dtype=logits.dtype)
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - picked
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(nll * valid) / denom
    total = loss + aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": jnp.sum(valid).astype(jnp.float32)}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(m: ModelConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16):
    period = period_of(m)
    n_full, rem = split_periods(m)

    def one(kind):
        return block_state_init(kind, m, batch, cache_len, dtype)

    stack = {}
    if n_full:
        one_p = {f"b{i}": one(kind) for i, kind in enumerate(period)}
        stack = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_full, *x.shape)), one_p)
    tail = {f"t{i}": one(kind) for i, kind in enumerate(rem)}
    return {"stack": stack, "tail": tail}


def decode_state_shapes(m: ModelConfig, batch: int, cache_len: int,
                        dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_decode_state(m, batch, cache_len, dtype))


def decode_state_specs(m: ModelConfig):
    period = period_of(m)
    n_full, rem = split_periods(m)
    stack = {}
    if n_full:
        one_p = {f"b{i}": block_state_specs(kind, m)
                 for i, kind in enumerate(period)}
        stack = jax.tree.map(lambda lg: Lg("layers", *lg), one_p,
                             is_leaf=lambda x: isinstance(x, Lg))
    tail = {f"t{i}": block_state_specs(kind, m) for i, kind in enumerate(rem)}
    return {"stack": stack, "tail": tail}


def lm_decode_step(params, token: jnp.ndarray, state, index, m: ModelConfig,
                   cd=None, scan_layers: bool = True
                   ) -> Tuple[jnp.ndarray, Any]:
    """token: (B,) int32; index: scalar int32 current position."""
    period = period_of(m)
    n_full, rem = split_periods(m)
    x = L.embedding_apply(params["embed"], token[:, None], cd)
    if m.family == "hybrid":
        x = x * jnp.asarray(m.d_model ** 0.5, x.dtype)
    if m.encdec.enabled:
        pos_emb = jax.lax.dynamic_slice_in_dim(
            L.sinusoidal_positions(m.encdec.max_target_positions, m.d_model),
            jnp.minimum(index, m.encdec.max_target_positions - 1), 1, axis=0)
        x = x + pos_emb.astype(x.dtype)[None]

    new_state: Dict[str, Any] = {"stack": {}, "tail": {}}
    if n_full:
        def body(x, xs):
            pparams, pstate = xs
            ns = {}
            for i, kind in enumerate(period):
                x, s = block_decode(kind, pparams[f"b{i}"], x,
                                    pstate[f"b{i}"], index, m, cd)
                ns[f"b{i}"] = s
            return x, ns
        if scan_layers:
            x, ns = jax.lax.scan(body, x, (params["stack"], state["stack"]))
        else:
            per = []
            for i in range(n_full):
                sl = jax.tree.map(lambda a: a[i],
                                  (params["stack"], state["stack"]))
                x, nsi = body(x, sl)
                per.append(nsi)
            ns = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        new_state["stack"] = ns
    for i, kind in enumerate(rem):
        x, s = block_decode(kind, params["tail"][f"t{i}"], x,
                            state["tail"][f"t{i}"], index, m, cd)
        new_state["tail"][f"t{i}"] = s

    x = (L.layernorm_apply(params["final_norm"], x) if m.family == AUDIO
         else L.rmsnorm_apply(params["final_norm"], x, m.norm_eps))
    if m.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"],
                            preferred_element_type=jnp.float32)
    return logits[:, 0], new_state


def lm_prefill(params, batch: Dict[str, jnp.ndarray], m: ModelConfig,
               cache_len: int, cd=None, cache_dtype=jnp.bfloat16,
               remat: str = "none", scan_layers: bool = True
               ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Process the full prompt, returning (last-token logits, decode state,
    next index). The cache is populated *inside* the forward scan (each
    block contributes its K/V / recurrent state), so prefill is one pass.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embedding_apply(params["embed"], tokens, cd)
    if m.family == "hybrid":
        x = x * jnp.asarray(m.d_model ** 0.5, x.dtype)
    if m.encdec.enabled:
        x = x + L.sinusoidal_positions(s, m.d_model).astype(x.dtype)
    enc_out = None
    if m.encdec.enabled:
        enc_out = encode(params, batch["enc_embeds"], m, cd, remat,
                         scan_layers)
    positions = jnp.arange(s)
    x, _, state = _run_stack(params["stack"], params["tail"], x, m, positions,
                             cd, enc_out, remat, False,
                             cache_len=cache_len, cache_dtype=cache_dtype,
                             scan_layers=scan_layers)
    x = (L.layernorm_apply(params["final_norm"], x) if m.family == AUDIO
         else L.rmsnorm_apply(params["final_norm"], x, m.norm_eps))
    x_last = x[:, -1:]
    if m.tie_embeddings:
        logits = L.unembed_apply(params["embed"], x_last)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x_last, params["head"]["w"],
                            preferred_element_type=jnp.float32)
    return logits[:, 0], state, jnp.asarray(s, jnp.int32)
