"""Mixture-of-Experts layer (DeepSeek-V2-Lite, OLMoE).

Dropless-ish dispatch via *sort-by-expert*: token->expert assignments are
argsorted so each expert sees a contiguous (E, C, d) slab, computed with one
batched matmul per projection — the TPU-native formulation (all-to-all falls
out of the expert-sharded einsum under GSPMD, rather than being emulated with
point-to-point sends as a GPU port would).

Capacity C = ceil(T * top_k / E * capacity_factor); overflow tokens are
dropped from expert compute (their combine weight contribution is zero) —
standard GShard/Switch semantics. ``capacity_factor=0`` selects a generous
default of 2.0 so drops are rare at smoke scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_apply, dense_init, dense_specs, mlp_apply, mlp_init, mlp_specs
from repro.sharding.specs import Lg, constrain


def moe_init(key, d: int, cfg, dtype=jnp.float32):
    """cfg: MoEConfig."""
    ks = jax.random.split(key, 4)
    e, ff = cfg.num_experts, cfg.d_ff_expert
    p = {
        "router": dense_init(ks[0], d, e, dtype, scale=0.02),
        "experts": {
            "gate": (jax.random.normal(ks[1], (e, d, ff), jnp.float32)
                     * d ** -0.5).astype(dtype),
            "up": (jax.random.normal(ks[2], (e, d, ff), jnp.float32)
                   * d ** -0.5).astype(dtype),
            "down": (jax.random.normal(ks[3], (e, ff, d), jnp.float32)
                     * ff ** -0.5).astype(dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(jax.random.fold_in(key, 7), d,
                               ff * cfg.num_shared_experts, "silu", dtype)
    return p


def moe_specs(cfg):
    p = {
        "router": dense_specs("embed", None),
        "experts": {
            "gate": Lg("experts", "embed", "mlp"),
            "up": Lg("experts", "embed", "mlp"),
            "down": Lg("experts", "mlp", "embed"),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_specs("silu")
    return p


def router_probs(p, x, cfg, compute_dtype=None):
    """Softmax router over experts; returns (probs, logits) in fp32."""
    logits = dense_apply(p["router"], x, compute_dtype).astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1), logits


def load_balance_loss(probs: jnp.ndarray, top_idx: jnp.ndarray, e: int
                      ) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e f_e * P_e over the token batch."""
    # probs: (T, E); top_idx: (T, k)
    t = probs.shape[0]
    counts = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    f = counts / (top_idx.size + 1e-9)                 # fraction routed
    pbar = jnp.mean(probs, axis=0)                     # mean router prob
    return e * jnp.sum(f * pbar)


def _dispatch_groups(t: int, k: int, target: int = 32) -> int:
    """Largest divisor of t that is <= target and leaves >= 4k tokens/group."""
    g = 1
    for cand in range(1, target + 1):
        if t % cand == 0 and t // cand >= 4 * k:
            g = cand
    return g


def _local_moe(xt, p, cfg, cd):
    """Dispatch + expert compute for ONE token group. xt: (Tg, d)."""
    tg, d = xt.shape
    e, k = cfg.num_experts, cfg.top_k
    cf = cfg.capacity_factor or 2.0
    cap = int(max(k, ((tg * k * cf) / e) // 1 + 1))

    probs, _ = router_probs(p, xt, cfg, cd)
    top_p, top_i = jax.lax.top_k(probs, k)             # (Tg, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    aux = load_balance_loss(probs, top_i, e) * cfg.router_aux_coef

    # sort token-slots by expert id (local to the group)
    flat_e = top_i.reshape(-1)                         # (Tg*k,)
    flat_w = top_p.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(tg), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    first_of_e = jnp.full((e,), tg * k, jnp.int32).at[se].min(
        jnp.arange(tg * k, dtype=jnp.int32))
    pos_in_e = jnp.arange(tg * k) - first_of_e[se]
    keep = pos_in_e < cap                              # overflow drop
    slot = se * cap + jnp.where(keep, pos_in_e, 0)

    buf = jnp.zeros((e * cap, d), xt.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[stok], 0))
    xe = buf.reshape(e, cap, d)

    we = p["experts"]
    g = jnp.einsum("ecd,edf->ecf", xe.astype(cd), we["gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", xe.astype(cd), we["up"].astype(cd))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, we["down"].astype(cd))
    ye = ye.reshape(e * cap, d)

    out = jnp.zeros((tg, d), jnp.float32)
    out = out.at[stok].add(ye[slot].astype(jnp.float32)
                           * (sw * keep)[:, None])
    return out.astype(xt.dtype), aux


def moe_apply(p, x, cfg, compute_dtype=None):
    """x: (B, S, d) -> (y, aux_loss).

    Hierarchical (GShard-style) dispatch: tokens are split into G groups
    (G <= 32, a divisor of T) and each group routes/sorts/scatters *locally*
    via vmap. The group dim shards over (pod, data) and the expert dim over
    model, so the only cross-shard movement is the group<->expert all-to-all
    around the expert einsum — a global argsort/scatter (the previous
    formulation) forced GSPMD to replicate the T*k-row dispatch buffers
    (EXPERIMENTS §Perf hc1: 70 GiB -> measured below).
    """
    b, s, d = x.shape
    t = b * s
    cd = compute_dtype or x.dtype
    groups = _dispatch_groups(t, cfg.top_k)
    xt = x.reshape(groups, t // groups, d)
    xt = constrain(xt, ("batch", None, None))
    y, aux = jax.vmap(lambda xg: _local_moe(xg, p, cfg, cd))(xt)
    y = constrain(y, ("batch", None, None))
    aux = jnp.mean(aux)
    y = y.reshape(b, s, d)

    if cfg.num_shared_experts:
        y = y + mlp_apply(p["shared"], x, "silu", compute_dtype)
    return y, aux
