"""DCGAN (Radford et al. 2016) — the paper's model: 3-conv-block
discriminator + transposed-conv generator for 28x28x1 MNIST.

The discriminator is the part the paper federates and splits; it is
deliberately expressed as an ordered list of *named layers* so the FSL split
planner (core/split.py) can cost and partition it exactly the way the paper
partitions "portions" across a client's devices.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.config import DCGANConfig
from repro.sharding.specs import Lg

DN = ("NHWC", "HWIO", "NHWC")    # conv dimension numbers


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan = kh * kw * cin
    return {"w": (jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
                  * (2.0 / fan) ** 0.5 * 0.7).astype(dtype),
            "b": jnp.zeros((cout,), dtype)}


def _bn_init(c, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def _bn_apply(p, x, eps=1e-5):
    # batch norm over (N,H,W); GAN training uses per-batch statistics
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * p["scale"] + p["bias"]


# ---------------------------------------------------------------------------
# Discriminator — an ordered stack of named layers (splittable)
# ---------------------------------------------------------------------------

def disc_layer_names(c: DCGANConfig) -> List[str]:
    names = []
    for i in range(c.conv_blocks):
        names.append(f"conv{i}")
    names.append("classifier")
    return names


def disc_layer_costs(c: DCGANConfig, image_size: int = 0) -> Dict[str, float]:
    """Relative FLOP cost per layer (drives the split planner)."""
    s = image_size or c.image_size
    f = c.base_filters
    costs = {}
    cin, sz = c.channels, s
    for i in range(c.conv_blocks):
        cout = f * (2 ** i)
        costs[f"conv{i}"] = 25.0 * cin * cout * (sz / 2) ** 2
        cin, sz = cout, sz / 2
    costs["classifier"] = cin * sz * sz * 1.0
    return costs


def disc_init(key, c: DCGANConfig, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, c.conv_blocks + 1)
    p: Dict[str, Any] = {}
    cin = c.channels
    for i in range(c.conv_blocks):
        cout = c.base_filters * (2 ** i)
        p[f"conv{i}"] = _conv_init(ks[i], 5, 5, cin, cout, dtype)
        if i > 0:
            p[f"conv{i}"]["bn"] = _bn_init(cout, dtype)
        cin = cout
    final_sz = c.image_size // (2 ** c.conv_blocks)
    # pad 28 -> strided convs give ceil: 28->14->7->4
    final_sz = -(-c.image_size // (2 ** c.conv_blocks))
    p["classifier"] = {
        "w": (jax.random.normal(ks[-1], (final_sz * final_sz * cin, 1),
                                jnp.float32)
              * (final_sz * final_sz * cin) ** -0.5).astype(dtype),
        "b": jnp.zeros((1,), dtype)}
    return p


def disc_specs(c: DCGANConfig) -> Dict[str, Any]:
    p: Dict[str, Any] = {}
    for i in range(c.conv_blocks):
        p[f"conv{i}"] = {"w": Lg(None, None, None, "mlp"), "b": Lg("mlp")}
        if i > 0:
            p[f"conv{i}"]["bn"] = {"scale": Lg("mlp"), "bias": Lg("mlp")}
    p["classifier"] = {"w": Lg("mlp", None), "b": Lg(None)}
    return p


def disc_apply_layer(name: str, p, x, c: DCGANConfig) -> jnp.ndarray:
    """Apply one named discriminator layer (the unit of an FSL portion)."""
    if name.startswith("conv"):
        lp = p[name]
        y = jax.lax.conv_general_dilated(
            x, lp["w"].astype(x.dtype), window_strides=(2, 2),
            padding="SAME", dimension_numbers=DN)
        y = y + lp["b"].astype(y.dtype)
        if "bn" in lp:
            y = _bn_apply(lp["bn"], y)
        return jax.nn.leaky_relu(y, 0.2)
    if name == "classifier":
        lp = p["classifier"]
        flat = x.reshape(x.shape[0], -1)
        return flat @ lp["w"].astype(flat.dtype) + lp["b"].astype(flat.dtype)
    raise ValueError(name)


def disc_apply(p, images: jnp.ndarray, c: DCGANConfig) -> jnp.ndarray:
    """images: (B, H, W, C) in [-1, 1] -> logits (B, 1)."""
    x = images
    for name in disc_layer_names(c):
        x = disc_apply_layer(name, p, x, c)
    return x


# ---------------------------------------------------------------------------
# Generator — trained by the central server (never sees real data)
# ---------------------------------------------------------------------------

def _deconv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    """Kernel stored (H, W, Cin, Cout) for conv_transpose(transpose_kernel=False)."""
    fan = kh * kw * cin
    return {"w": (jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
                  * (2.0 / fan) ** 0.5 * 0.7).astype(dtype),
            "b": jnp.zeros((cout,), dtype)}


def gen_init(key, c: DCGANConfig, dtype=jnp.float32) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    f = c.base_filters
    s0 = c.image_size // 4            # 7 for 28x28
    return {
        "proj": {"w": (jax.random.normal(ks[0], (c.latent_dim, s0 * s0 * f * 4),
                                         jnp.float32)
                       * c.latent_dim ** -0.5).astype(dtype),
                 "b": jnp.zeros((s0 * s0 * f * 4,), dtype),
                 "bn": _bn_init(f * 4, dtype)},
        "deconv0": {**_deconv_init(ks[1], 5, 5, f * 4, f * 2, dtype),
                    "bn": _bn_init(f * 2, dtype)},
        "deconv1": {**_deconv_init(ks[2], 5, 5, f * 2, f, dtype),
                    "bn": _bn_init(f, dtype)},
        "out": _conv_init(ks[3], 5, 5, f, c.channels, dtype),
    }


def gen_specs(c: DCGANConfig) -> Dict[str, Any]:
    bn = {"scale": Lg(None), "bias": Lg(None)}
    return {
        "proj": {"w": Lg(None, "mlp"), "b": Lg("mlp"), "bn": bn},
        "deconv0": {"w": Lg(None, None, "mlp", None), "b": Lg(None), "bn": bn},
        "deconv1": {"w": Lg(None, None, "mlp", None), "b": Lg(None), "bn": bn},
        "out": {"w": Lg(None, None, None, None), "b": Lg(None)},
    }


def _deconv(x, lp, stride=2):
    y = jax.lax.conv_transpose(
        x, lp["w"].astype(x.dtype), strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + lp["b"].astype(y.dtype)


def gen_apply(p, z: jnp.ndarray, c: DCGANConfig) -> jnp.ndarray:
    """z: (B, latent) -> images (B, H, W, C) in (-1, 1)."""
    f = c.base_filters
    s0 = c.image_size // 4
    b = z.shape[0]
    x = z @ p["proj"]["w"].astype(z.dtype) + p["proj"]["b"].astype(z.dtype)
    x = x.reshape(b, s0, s0, f * 4)
    x = jax.nn.relu(_bn_apply(p["proj"]["bn"], x))
    x = jax.nn.relu(_bn_apply(p["deconv0"]["bn"], _deconv(x, p["deconv0"])))
    x = jax.nn.relu(_bn_apply(p["deconv1"]["bn"], _deconv(x, p["deconv1"])))
    x = jax.lax.conv_general_dilated(x, p["out"]["w"].astype(x.dtype),
                                     (1, 1), "SAME", dimension_numbers=DN)
    x = x + p["out"]["b"].astype(x.dtype)
    return jnp.tanh(x)
