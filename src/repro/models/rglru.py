"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: x -> [linear -> GeLU] gate branch, [linear -> causal conv1d(4) ->
RG-LRU] recurrent branch, merge by product, project back to d_model.

RG-LRU (per channel, fp32):
    r_t = sigmoid(a_x x_t + a_b)          recurrence gate
    i_t = sigmoid(i_x x_t + i_b)          input gate
    a_t = a_base ** (c * r_t)             with a_base = sigmoid(lambda), c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Gates are per-channel (diagonal) — the parameter-count-faithful reading of
the paper's block-diagonal gates (DESIGN.md §4 notes this simplification).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_apply, dense_init, dense_specs
from repro.sharding.specs import Lg

_C = 8.0  # Griffin's fixed temperature on the recurrence gate


def rglru_block_init(key, d: int, cfg, dtype=jnp.float32):
    """cfg: RGLRUConfig. Returns the full recurrent block params."""
    lw = cfg.lru_width or d
    ks = jax.random.split(key, 5)
    # lambda init so a_base^c spans ~(0.9, 0.999) as in the paper
    lam = jax.random.uniform(ks[0], (lw,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(lam ** (1.0 / _C) / (1 - lam ** (1.0 / _C)))
    return {
        "w_gate": dense_init(ks[1], d, lw, dtype),       # GeLU branch
        "w_rec": dense_init(ks[2], d, lw, dtype),        # recurrent branch
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, lw), jnp.float32)
                   * cfg.conv_width ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((lw,), dtype),
        "lam": lam.astype(dtype),
        "a_x": jnp.zeros((lw,), dtype), "a_b": jnp.zeros((lw,), dtype),
        "i_x": jnp.zeros((lw,), dtype), "i_b": jnp.zeros((lw,), dtype),
        "w_out": dense_init(ks[4], lw, d, dtype),
    }


def rglru_block_specs(cfg):
    return {
        "w_gate": dense_specs("embed", "mlp"),
        "w_rec": dense_specs("embed", "mlp"),
        "conv_w": Lg(None, "mlp"), "conv_b": Lg("mlp"),
        "lam": Lg("mlp"),
        "a_x": Lg("mlp"), "a_b": Lg("mlp"),
        "i_x": Lg("mlp"), "i_b": Lg("mlp"),
        "w_out": dense_specs("mlp", "embed"),
    }


def causal_conv1d(x, w, b, state=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. x: (B,T,C); w: (W,C); state: (B,W-1,C)."""
    bsz, t, c = x.shape
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((bsz, width - 1, c), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = jnp.zeros((bsz, t, c), jnp.float32)
    for i in range(width):
        out = out + xp[:, i:i + t, :].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    return out.astype(x.dtype), xp[:, -(width - 1):, :]


def rglru_scan(x, r_gate, i_gate, a_base, h0=None):
    """The LRU recurrence. x, r_gate, i_gate: (B,T,C) fp32; a_base: (C,)."""
    b, t, c = x.shape
    log_a = _C * r_gate * jax.nn.log_sigmoid(a_base)[None, None, :]  # (B,T,C) <= 0
    a = jnp.exp(log_a)
    gated = i_gate * x
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    if h0 is None:
        h0 = jnp.zeros((b, c), jnp.float32)

    def step(h, xs):
        at, ut = xs
        h = at * h + ut
        return h, h

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(beta * gated, 1, 0))
    hT, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1), hT


def rglru_block_apply(p, x, cfg, conv_state=None, h0=None, compute_dtype=None):
    """x: (B,T,d) -> (y, (conv_state, h_state))."""
    gate = jax.nn.gelu(dense_apply(p["w_gate"], x, compute_dtype)
                       .astype(jnp.float32))
    rec = dense_apply(p["w_rec"], x, compute_dtype)
    rec, conv_state = causal_conv1d(rec, p["conv_w"], p["conv_b"], conv_state)
    rec32 = rec.astype(jnp.float32)
    r = jax.nn.sigmoid(rec32 * p["a_x"].astype(jnp.float32)
                       + p["a_b"].astype(jnp.float32))
    i = jax.nn.sigmoid(rec32 * p["i_x"].astype(jnp.float32)
                       + p["i_b"].astype(jnp.float32))
    h, hT = rglru_scan(rec32, r, i, p["lam"].astype(jnp.float32), h0)
    y = (h * gate).astype(x.dtype)
    return dense_apply(p["w_out"], y, compute_dtype), (conv_state, hT)
