"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV are compressed to a rank-``kv_lora_rank`` latent c_kv plus a shared
decoupled-RoPE key k_rope. Two execution forms:

  * **expanded** (train/prefill): latents are up-projected to full per-head
    K/V and standard attention runs — best for long-sequence matmul shapes.
  * **absorbed** (decode): W_uk is absorbed into the query and W_uv into the
    output so attention runs *in latent space* against the cached
    (S, kv_lora + rope_dim) latents — the cache is ~an order of magnitude
    smaller than GQA's and no per-step latent expansion is needed. This is
    the production decode path (DeepSeek-V2 §2.1.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import (NEG_INF, apply_rope, attention, dense_apply,
                                 dense_init, dense_specs, rmsnorm_apply,
                                 rmsnorm_init, rmsnorm_specs)
from repro.sharding.specs import Lg


def mla_init(key, d: int, num_heads: int, head_dim: int, cfg, dtype=jnp.float32):
    """cfg: MLAConfig. head_dim is the nope (non-rope) per-head dim."""
    ks = jax.random.split(key, 6)
    rk, rh = cfg.kv_lora_rank, cfg.rope_head_dim
    vh = cfg.v_head_dim or head_dim
    qd = num_heads * (head_dim + rh)
    return {
        "wq": dense_init(ks[0], d, qd, dtype),                 # full-rank q (V2-Lite)
        "w_dkv": dense_init(ks[1], d, rk + rh, dtype),         # downproj + rope k
        "kv_norm": rmsnorm_init(rk, dtype),
        "w_uk": (jax.random.normal(ks[2], (num_heads, rk, head_dim), jnp.float32)
                 * rk ** -0.5).astype(dtype),
        "w_uv": (jax.random.normal(ks[3], (num_heads, rk, vh), jnp.float32)
                 * rk ** -0.5).astype(dtype),
        "wo": dense_init(ks[4], num_heads * vh, d, dtype,
                         scale=(num_heads * vh) ** -0.5),
    }


def mla_specs(cfg):
    return {
        "wq": dense_specs("embed", "mlp"),
        "w_dkv": dense_specs("embed", None),
        "kv_norm": rmsnorm_specs(),
        "w_uk": Lg("heads", None, None),
        "w_uv": Lg("heads", None, None),
        "wo": dense_specs("mlp", "embed"),
    }


def _split_q(q, num_heads, head_dim, rh):
    b, s, _ = q.shape
    q = q.reshape(b, s, num_heads, head_dim + rh)
    return q[..., :head_dim], q[..., head_dim:]


def mla_latents(p, x, positions, cfg, rope_theta, compute_dtype=None):
    """Compress x -> (c_kv normalized, k_rope with rope applied)."""
    rk, rh = cfg.kv_lora_rank, cfg.rope_head_dim
    dkv = dense_apply(p["w_dkv"], x, compute_dtype)
    c_kv, k_rope = dkv[..., :rk], dkv[..., rk:]
    c_kv = rmsnorm_apply(p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_apply(p, x, num_heads, head_dim, cfg, positions=None,
              rope_theta=10000.0, compute_dtype=None):
    """Expanded-form self-attention for train/prefill. x: (B, S, d)."""
    b, s, _ = x.shape
    rh = cfg.rope_head_dim
    vh = cfg.v_head_dim or head_dim
    if positions is None:
        positions = jnp.arange(s)
    q = dense_apply(p["wq"], x, compute_dtype)
    q_nope, q_rope = _split_q(q, num_heads, head_dim, rh)
    q_rope = apply_rope(q_rope, positions, rope_theta)
    c_kv, k_rope = mla_latents(p, x, positions, cfg, rope_theta, compute_dtype)

    cd = compute_dtype or x.dtype
    k_nope = jnp.einsum("bsr,hrd->bshd", c_kv.astype(cd), p["w_uk"].astype(cd))
    v = jnp.einsum("bsr,hrv->bshv", c_kv.astype(cd), p["w_uv"].astype(cd))

    # Expanded MLA == standard MHA with per-head K=[k_nope, k_rope(shared)],
    # Q=[q_nope, q_rope]; reuse the (chunked, memory-safe) attention core.
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_nope.shape[:3], rh)).astype(cd)], axis=-1)
    out = attention(qf, kf, v, positions, positions)
    out = out.reshape(b, s, num_heads * vh)
    return dense_apply(p["wo"], out, compute_dtype), (c_kv, k_rope)


def mla_decode(p, x, cache_ckv, cache_krope, index, num_heads, head_dim, cfg,
               rope_theta=10000.0, compute_dtype=None):
    """Absorbed-form single-token decode.

    cache_ckv: (B, S, rk); cache_krope: (B, S, rh). Attention runs in latent
    space: q_lat = q_nope @ W_uk, scores = q_lat . c_kv + q_rope . k_rope,
    out = (probs @ c_kv) @ W_uv.
    """
    b = x.shape[0]
    rk, rh = cfg.kv_lora_rank, cfg.rope_head_dim
    vh = cfg.v_head_dim or head_dim
    pos = jnp.full((1,), index, jnp.int32)
    q = dense_apply(p["wq"], x, compute_dtype)
    q_nope, q_rope = _split_q(q, num_heads, head_dim, rh)     # (B,1,H,*)
    q_rope = apply_rope(q_rope, pos, rope_theta)
    c_kv, k_rope = mla_latents(p, x, pos, cfg, rope_theta, compute_dtype)

    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv.astype(cache_ckv.dtype), index, axis=1)
    cache_krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, k_rope.astype(cache_krope.dtype), index, axis=1)

    cd = compute_dtype or x.dtype
    # absorb W_uk into q: (B,1,H,dh) x (H,rk,dh) -> (B,H,rk)
    q_lat = jnp.einsum("bqhd,hrd->bhr", q_nope.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    scale = (head_dim + rh) ** -0.5
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat,
                         cache_ckv.astype(jnp.float32))
              + jnp.einsum("bqhd,bsd->bhs", q_rope.astype(jnp.float32),
                           cache_krope.astype(jnp.float32))) * scale
    s_cache = cache_ckv.shape[1]
    valid = jnp.arange(s_cache) <= index
    logits = jnp.where(valid[None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    # out latent: (B,H,rk); absorb W_uv on the way out
    o_lat = jnp.einsum("bhs,bsr->bhr", probs, cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bhr,hrv->bhv", o_lat, p["w_uv"].astype(jnp.float32))
    out = out.reshape(b, 1, num_heads * vh).astype(cd)
    return dense_apply(p["wo"], out, compute_dtype), (cache_ckv, cache_krope)
