"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent decay, ddlerp token shift, and squared-ReLU channel mix.

Per head (head_dim n):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state: n x n, fp32)
    o_t = r_t^T (diag(u) k_t v_t^T + S_{t-1})
with w_t = exp(-exp(decay_t)) computed per channel from the token via a
LoRA ("data-dependent decay" — the Finch contribution over RWKV-5).

The jnp implementation here is the *oracle*; the Pallas kernel in
``repro.kernels.wkv6`` chunks the same recurrence for TPU.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_apply, dense_init, dense_specs
from repro.sharding.specs import Lg

MIX_NAMES = ("r", "k", "v", "w", "g")   # receptance, key, value, decay, gate


def _lora(key, d: int, rank: int, out: int, dtype):
    k1, k2 = jax.random.split(key)
    return {"a": (jax.random.normal(k1, (d, rank), jnp.float32) * d ** -0.5
                  ).astype(dtype),
            "b": jnp.zeros((rank, out), dtype)}


def _lora_specs():
    return {"a": Lg("embed", None), "b": Lg(None, None)}


def _lora_apply(p, x, act=jnp.tanh):
    h = act(x.astype(jnp.float32) @ p["a"].astype(jnp.float32))
    return h @ p["b"].astype(jnp.float32)


def timemix_init(key, d: int, cfg, dtype=jnp.float32):
    """cfg: RWKVConfig."""
    ks = jax.random.split(key, 12)
    p: Dict = {
        "mu_x": jnp.zeros((d,), dtype),            # base lerp for the shared ddlerp
        "mu": jnp.zeros((len(MIX_NAMES), d), dtype),
        "ts_lora": {n: _lora(ks[i], d, cfg.token_shift_lora, d, dtype)
                    for i, n in enumerate(MIX_NAMES)},
        "wr": dense_init(ks[5], d, d, dtype),
        "wk": dense_init(ks[6], d, d, dtype),
        "wv": dense_init(ks[7], d, d, dtype),
        "wg": dense_init(ks[8], d, d, dtype),
        "wo": dense_init(ks[9], d, d, dtype),
        "decay_base": jnp.zeros((d,), dtype),      # per-channel base decay
        "decay_lora": _lora(ks[10], d, cfg.decay_lora, d, dtype),
        "bonus_u": jnp.zeros((d,), dtype),         # per-channel "first token" bonus
    }
    return p


def timemix_specs(cfg):
    return {
        "mu_x": Lg(None),
        "mu": Lg(None, None),
        "ts_lora": {n: _lora_specs() for n in MIX_NAMES},
        "wr": dense_specs("embed", "mlp"),
        "wk": dense_specs("embed", "mlp"),
        "wv": dense_specs("embed", "mlp"),
        "wg": dense_specs("embed", "mlp"),
        "wo": dense_specs("mlp", "embed"),
        "decay_base": Lg(None),
        "decay_lora": _lora_specs(),
        "bonus_u": Lg(None),
    }


def ddlerp(p, x, x_prev):
    """Data-dependent lerp (Finch token shift) -> dict of mixed inputs."""
    xx = (x_prev - x).astype(jnp.float32)
    base = x.astype(jnp.float32) + xx * jax.nn.sigmoid(
        p["mu_x"].astype(jnp.float32))
    out = {}
    for i, n in enumerate(MIX_NAMES):
        mix = p["mu"][i].astype(jnp.float32) + _lora_apply(p["ts_lora"][n], base)
        out[n] = x.astype(jnp.float32) + xx * jax.nn.sigmoid(mix)
    return out


def wkv6_scan(r, k, v, w, u, head_dim: int,
              state0: jnp.ndarray | None = None,
              chunk: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The WKV-6 recurrence over time (pure-jnp oracle).

    r,k,v,w: (B, T, H, n); u: (H, n). Returns (out (B,T,H,n), final state
    (B,H,n,n)). State rows indexed by k-channel, cols by v-channel.

    The time scan is *chunk-rematerialised*: a plain lax.scan saves the
    (B,H,n,n) state for every timestep for the backward pass (103 GiB/chip
    at train_4k scale — EXPERIMENTS §Perf it5); scanning over
    jax.checkpoint'ed chunks saves only chunk-boundary states and recomputes
    inside, the standard RWKV training trade (T/chunk x smaller residency
    for ~2x chunk recompute).
    """
    b, t, h, n = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs                       # (B,H,n) each
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,n,n)
        out = jnp.einsum("bhn,bhnm->bhm", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    if t <= chunk or t % chunk != 0:
        S, outs = jax.lax.scan(step, state0, xs)
        return jnp.moveaxis(outs, 0, 1), S        # (B,T,H,n), (B,H,n,n)

    n_chunks = t // chunk
    xs_c = tuple(a.reshape(n_chunks, chunk, *a.shape[1:]) for a in xs)

    @jax.checkpoint
    def chunk_body(S, xs_chunk):
        S, outs = jax.lax.scan(step, S, xs_chunk)
        return S, outs

    S, outs = jax.lax.scan(chunk_body, state0, xs_c)
    outs = outs.reshape(t, b, h, n)
    return jnp.moveaxis(outs, 0, 1), S


def timemix_apply(p, x, cfg, x_prev_last=None, state0=None,
                  compute_dtype=None, use_kernel: bool = False):
    """x: (B, T, d). x_prev_last: (B, d) carry for decode/chunking.

    Returns (y, (last_x, state)) so decode can stream token by token.
    """
    b, t, d = x.shape
    n = cfg.head_dim
    h = d // n
    if x_prev_last is None:
        x_prev_last = jnp.zeros((b, d), x.dtype)
    x_prev = jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)
    m = ddlerp(p, x, x_prev)

    r = dense_apply(p["wr"], m["r"].astype(x.dtype), compute_dtype)
    k = dense_apply(p["wk"], m["k"].astype(x.dtype), compute_dtype)
    v = dense_apply(p["wv"], m["v"].astype(x.dtype), compute_dtype)
    g = dense_apply(p["wg"], m["g"].astype(x.dtype), compute_dtype)
    # data-dependent decay (fp32 for stability)
    dec = (p["decay_base"].astype(jnp.float32)
           + _lora_apply(p["decay_lora"], m["w"]))
    w = jnp.exp(-jnp.exp(dec))                    # (B,T,d) in (0,1)

    rs = r.reshape(b, t, h, n).astype(jnp.float32)
    ks = k.reshape(b, t, h, n).astype(jnp.float32)
    vs = v.reshape(b, t, h, n).astype(jnp.float32)
    ws = w.reshape(b, t, h, n)
    u = p["bonus_u"].astype(jnp.float32).reshape(h, n)

    if use_kernel:
        from repro.kernels.wkv6.ops import wkv6 as wkv6_kernel
        out, state = wkv6_kernel(rs, ks, vs, ws, u, state0=state0)
    else:
        out, state = wkv6_scan(rs, ks, vs, ws, u, n, state0)
    out = out.reshape(b, t, d)
    # group-norm per head (RWKV normalizes heads); plain rms here per head
    out = out.reshape(b, t, h, n)
    out = out * jax.lax.rsqrt(jnp.mean(out * out, -1, keepdims=True) + 1e-5)
    out = out.reshape(b, t, d).astype(x.dtype)
    y = dense_apply(p["wo"], (out * jax.nn.silu(g.astype(out.dtype))),
                    compute_dtype)
    return y, (x[:, -1, :], state)


def channelmix_init(key, d: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {"mu_k": jnp.zeros((d,), dtype),
            "mu_r": jnp.zeros((d,), dtype),
            "wk": dense_init(ks[0], d, d_ff, dtype),
            "wv": dense_init(ks[1], d_ff, d, dtype),
            "wr": dense_init(ks[2], d, d, dtype)}


def channelmix_specs():
    return {"mu_k": Lg(None), "mu_r": Lg(None),
            "wk": dense_specs("embed", "mlp"),
            "wv": dense_specs("mlp", "embed"),
            "wr": dense_specs("embed", "embed")}


def channelmix_apply(p, x, x_prev_last=None, compute_dtype=None):
    b, t, d = x.shape
    if x_prev_last is None:
        x_prev_last = jnp.zeros((b, d), x.dtype)
    x_prev = jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)
    xx = (x_prev - x).astype(jnp.float32)
    xk = (x.astype(jnp.float32)
          + xx * jax.nn.sigmoid(p["mu_k"].astype(jnp.float32))).astype(x.dtype)
    xr = (x.astype(jnp.float32)
          + xx * jax.nn.sigmoid(p["mu_r"].astype(jnp.float32))).astype(x.dtype)
    kk = jnp.square(jax.nn.relu(dense_apply(p["wk"], xk, compute_dtype)))
    rr = jax.nn.sigmoid(dense_apply(p["wr"], xr, compute_dtype)
                        .astype(jnp.float32)).astype(x.dtype)
    return rr * dense_apply(p["wv"], kk, compute_dtype), x[:, -1, :]
