"""Per-family transformer blocks: init/specs/apply/decode dispatch.

A *block kind* is one residual block:

  attn    GQA attention + dense MLP        (dense / vlm / hybrid-attn)
  moe     GQA attention + MoE MLP          (olmoe)
  mla     MLA attention + MoE MLP          (deepseek-v2)
  rwkv    RWKV-6 time-mix + channel-mix    (ssm)
  rglru   RG-LRU recurrent block + MLP     (hybrid-recurrent)
  enc     bidirectional attention + MLP    (whisper encoder)
  dec     causal self-attn + cross-attn + MLP (whisper decoder)

Layer stacks are organised in *periods* (the smallest repeating kind tuple,
e.g. ("rglru","rglru","attn") for RecurrentGemma) so heterogeneous stacks
still scan: params for one period are stacked across periods and
``jax.lax.scan`` runs the period function with remat.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import AUDIO, DCGAN, HYBRID, MOE, SSM, ModelConfig
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE_M
from repro.models import rglru as RG
from repro.models import rwkv6 as RW
from repro.models.layers import AttnDims
from repro.sharding.specs import Lg


# ---------------------------------------------------------------------------
# kinds & periods
# ---------------------------------------------------------------------------

def layer_kinds(m: ModelConfig) -> List[str]:
    force = getattr(m, "_force_kind", None)
    if force:                               # encoder stacks force 'enc'
        return [force] * m.num_layers
    if m.family == SSM:
        return ["rwkv"] * m.num_layers
    if m.family == HYBRID and m.rglru.enabled:
        pat = []
        while len(pat) < m.num_layers:
            pat.extend(m.rglru.pattern)
        return pat[: m.num_layers]
    if m.family == AUDIO:
        return ["dec"] * m.num_layers          # encoder handled separately
    if m.moe.enabled:
        return ["mla" if m.mla.enabled else "moe"] * m.num_layers
    return ["attn"] * m.num_layers


def period_of(m: ModelConfig) -> Tuple[str, ...]:
    if m.family == HYBRID and m.rglru.enabled:
        return tuple(m.rglru.pattern)
    kinds = layer_kinds(m)
    return (kinds[0],) if kinds else ()


def split_periods(m: ModelConfig) -> Tuple[int, List[str]]:
    """-> (num_full_periods, remainder_kinds)."""
    period = period_of(m)
    kinds = layer_kinds(m)
    n_full = len(kinds) // len(period)
    return n_full, kinds[n_full * len(period):]


# ---------------------------------------------------------------------------
# per-block init / specs
# ---------------------------------------------------------------------------

def _norm_init(m: ModelConfig, dtype):
    return (L.layernorm_init(m.d_model, dtype) if m.family == AUDIO
            else L.rmsnorm_init(m.d_model, dtype))


def _norm_specs(m: ModelConfig):
    return (L.layernorm_specs() if m.family == AUDIO else L.rmsnorm_specs())


def norm_apply(m: ModelConfig, p, x):
    return (L.layernorm_apply(p, x) if m.family == AUDIO
            else L.rmsnorm_apply(p, x, m.norm_eps))


def attn_dims(m: ModelConfig) -> AttnDims:
    return AttnDims(
        d_model=m.d_model, num_heads=m.num_heads,
        num_kv_heads=m.num_kv_heads, head_dim=m.head_dim,
        qk_norm=m.qk_norm, qkv_bias=m.qkv_bias or m.family == AUDIO,
        rope_theta=m.rope_theta,
        window=m.sliding_window if m.attention == "sliding" else 0)


def block_init(key, kind: str, m: ModelConfig, dtype) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    dims = attn_dims(m)
    if kind in ("attn", "moe", "enc"):
        p = {"ln1": _norm_init(m, dtype), "attn": L.gqa_init(k1, dims, dtype),
             "ln2": _norm_init(m, dtype)}
        p["mlp"] = (MOE_M.moe_init(k2, m.d_model, m.moe, dtype)
                    if kind == "moe" else
                    L.mlp_init(k2, m.d_model, m.d_ff, m.act, dtype))
        return p
    if kind == "mla":
        return {"ln1": _norm_init(m, dtype),
                "attn": MLA.mla_init(k1, m.d_model, m.num_heads, m.head_dim,
                                     m.mla, dtype),
                "ln2": _norm_init(m, dtype),
                "mlp": MOE_M.moe_init(k2, m.d_model, m.moe, dtype)}
    if kind == "rwkv":
        return {"ln1": _norm_init(m, dtype),
                "time": RW.timemix_init(k1, m.d_model, m.rwkv, dtype),
                "ln2": _norm_init(m, dtype),
                "chan": RW.channelmix_init(k2, m.d_model, m.d_ff, dtype)}
    if kind == "rglru":
        return {"ln1": _norm_init(m, dtype),
                "rec": RG.rglru_block_init(k1, m.d_model, m.rglru, dtype),
                "ln2": _norm_init(m, dtype),
                "mlp": L.mlp_init(k2, m.d_model, m.d_ff, m.act, dtype)}
    if kind == "dec":
        return {"ln1": _norm_init(m, dtype), "attn": L.gqa_init(k1, dims, dtype),
                "lnx": _norm_init(m, dtype), "xattn": L.gqa_init(k3, dims, dtype),
                "ln2": _norm_init(m, dtype),
                "mlp": L.mlp_init(k2, m.d_model, m.d_ff, m.act, dtype)}
    raise ValueError(kind)


def block_specs(kind: str, m: ModelConfig) -> Dict[str, Any]:
    dims = attn_dims(m)
    if kind in ("attn", "moe", "enc"):
        p = {"ln1": _norm_specs(m), "attn": L.gqa_specs(dims),
             "ln2": _norm_specs(m)}
        p["mlp"] = (MOE_M.moe_specs(m.moe) if kind == "moe"
                    else L.mlp_specs(m.act))
        return p
    if kind == "mla":
        return {"ln1": _norm_specs(m), "attn": MLA.mla_specs(m.mla),
                "ln2": _norm_specs(m), "mlp": MOE_M.moe_specs(m.moe)}
    if kind == "rwkv":
        return {"ln1": _norm_specs(m), "time": RW.timemix_specs(m.rwkv),
                "ln2": _norm_specs(m), "chan": RW.channelmix_specs()}
    if kind == "rglru":
        return {"ln1": _norm_specs(m), "rec": RG.rglru_block_specs(m.rglru),
                "ln2": _norm_specs(m), "mlp": L.mlp_specs(m.act)}
    if kind == "dec":
        return {"ln1": _norm_specs(m), "attn": L.gqa_specs(dims),
                "lnx": _norm_specs(m), "xattn": L.gqa_specs(dims),
                "ln2": _norm_specs(m), "mlp": L.mlp_specs(m.act)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def place_kv(k: jnp.ndarray, cache_len: int, window: int, dtype
             ) -> jnp.ndarray:
    """Lay a (B, S, H, hd) prefill K (or V) into a decode cache buffer.

    Full attention: pad/truncate to cache_len (positions 0..S-1).
    Sliding window: ring buffer of size min(cache_len, window); position p
    lands in slot p % ring so `gqa_decode` ring arithmetic lines up.
    """
    b, s, h, hd = k.shape
    if window:
        ring = min(cache_len, window)
        take = min(s, ring)
        tail = k[:, s - take:, :, :]
        slots = (jnp.arange(s - take, s)) % ring
        buf = jnp.zeros((b, ring, h, hd), dtype)
        return buf.at[:, slots].set(tail.astype(dtype))
    if s >= cache_len:
        return k[:, :cache_len].astype(dtype)
    return jnp.pad(k.astype(dtype), ((0, 0), (0, cache_len - s),
                                     (0, 0), (0, 0)))


def block_apply(kind: str, p, x, m: ModelConfig, positions, cd,
                enc_out: Optional[jnp.ndarray] = None,
                use_kernel: bool = False, cache_len: int = 0,
                cache_dtype=jnp.bfloat16
                ) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict]]:
    """One residual block over a full sequence.

    Returns (x, aux_loss, cache) — cache is a decode-state dict (matching
    ``block_state_init`` structure) when ``cache_len > 0`` (prefill), else
    None.
    """
    aux = jnp.zeros((), jnp.float32)
    cache: Optional[Dict] = None
    dims = attn_dims(m)
    if kind in ("attn", "moe", "enc"):
        h = norm_apply(m, p["ln1"], x)
        if kind == "enc":
            # bidirectional: every position attends to every position
            s = h.shape[1]
            pos = jnp.zeros((s,), jnp.int32)  # q_pos >= k_pos always true
            q, k, v = L.gqa_project_qkv(p["attn"], h, dims, positions,
                                        cd, rope=m.rope_theta > 0)
            o = L.attention(q, k, v, pos, pos, window=0)
            o = o.reshape(*h.shape[:2], dims.num_heads * dims.head_dim)
            a = L.dense_apply(p["attn"]["wo"], o, cd)
        else:
            a, (k, v) = L.gqa_apply(p["attn"], h, dims, positions, cd,
                                    use_kernel=use_kernel)
            if cache_len:
                cache = {"k": place_kv(k, cache_len, dims.window, cache_dtype),
                         "v": place_kv(v, cache_len, dims.window, cache_dtype)}
        x = x + a
        h = norm_apply(m, p["ln2"], x)
        if kind == "moe":
            y, aux = MOE_M.moe_apply(p["mlp"], h, m.moe, cd)
        else:
            y = L.mlp_apply(p["mlp"], h, m.act, cd)
        return x + y, aux, cache
    if kind == "mla":
        h = norm_apply(m, p["ln1"], x)
        a, (c_kv, k_rope) = MLA.mla_apply(p["attn"], h, m.num_heads,
                                          m.head_dim, m.mla, positions,
                                          m.rope_theta, cd)
        if cache_len:
            cache = {"ckv": place_kv(c_kv[:, :, None, :], cache_len, 0,
                                     cache_dtype)[:, :, 0],
                     "krope": place_kv(k_rope[:, :, None, :], cache_len, 0,
                                       cache_dtype)[:, :, 0]}
        x = x + a
        h = norm_apply(m, p["ln2"], x)
        y, aux = MOE_M.moe_apply(p["mlp"], h, m.moe, cd)
        return x + y, aux, cache
    if kind == "rwkv":
        h = norm_apply(m, p["ln1"], x)
        a, (xt, S) = RW.timemix_apply(p["time"], h, m.rwkv, compute_dtype=cd,
                                      use_kernel=use_kernel)
        x = x + a
        h2 = norm_apply(m, p["ln2"], x)
        y, xc = RW.channelmix_apply(p["chan"], h2, compute_dtype=cd)
        if cache_len:
            cache = {"x_time": xt.astype(cache_dtype),
                     "x_chan": xc.astype(cache_dtype), "S": S}
        return x + y, aux, cache
    if kind == "rglru":
        h = norm_apply(m, p["ln1"], x)
        a, (conv, hT) = RG.rglru_block_apply(p["rec"], h, m.rglru,
                                             compute_dtype=cd)
        if cache_len:
            cache = {"conv": conv.astype(cache_dtype), "h": hT}
        x = x + a
        h = norm_apply(m, p["ln2"], x)
        return x + L.mlp_apply(p["mlp"], h, m.act, cd), aux, cache
    if kind == "dec":
        h = norm_apply(m, p["ln1"], x)
        a, (k, v) = L.gqa_apply(p["attn"], h, dims, positions, cd)
        x = x + a
        h = norm_apply(m, p["lnx"], x)
        xa, (ck, cv) = _cross_attend(p["xattn"], h, enc_out, dims, cd)
        x = x + xa
        if cache_len:
            c = min(cache_len, m.encdec.max_target_positions)
            cache = {"k": place_kv(k, c, 0, cache_dtype),
                     "v": place_kv(v, c, 0, cache_dtype),
                     "ck": ck.astype(cache_dtype),
                     "cv": cv.astype(cache_dtype)}
        h = norm_apply(m, p["ln2"], x)
        return x + L.mlp_apply(p["mlp"], h, m.act, cd), aux, cache
    raise ValueError(kind)


def _cross_attend(p, h, enc_out, dims: AttnDims, cd):
    """Cross attention: queries from h, K/V from encoder output (no rope).

    Returns (out, (k, v)) so prefill can cache the cross K/V.
    """
    b, s, _ = h.shape
    se = enc_out.shape[1]
    q = L.dense_apply(p["wq"], h, cd).reshape(b, s, dims.num_heads,
                                              dims.head_dim)
    k = L.dense_apply(p["wk"], enc_out, cd).reshape(b, se, dims.num_kv_heads,
                                                    dims.head_dim)
    v = L.dense_apply(p["wv"], enc_out, cd).reshape(b, se, dims.num_kv_heads,
                                                    dims.head_dim)
    o = L.attention(q, k, v, jnp.zeros((s,), jnp.int32),
                    jnp.zeros((se,), jnp.int32))
    o = o.reshape(b, s, dims.num_heads * dims.head_dim)
    return L.dense_apply(p["wo"], o, cd), (k, v)


# ---------------------------------------------------------------------------
# decode state
# ---------------------------------------------------------------------------

def block_state_init(kind: str, m: ModelConfig, batch: int, cache_len: int,
                     dtype) -> Dict[str, Any]:
    """Zero decode-state for one block. cache_len already window-clipped."""
    d = m.d_model
    if kind in ("attn", "moe"):
        c = min(cache_len, m.sliding_window) if m.attention == "sliding" \
            else cache_len
        return {"k": jnp.zeros((batch, c, m.num_kv_heads, m.head_dim), dtype),
                "v": jnp.zeros((batch, c, m.num_kv_heads, m.head_dim), dtype)}
    if kind == "mla":
        return {"ckv": jnp.zeros((batch, cache_len, m.mla.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, cache_len, m.mla.rope_head_dim),
                                   dtype)}
    if kind == "rwkv":
        h = d // m.rwkv.head_dim
        return {"x_time": jnp.zeros((batch, d), dtype),
                "x_chan": jnp.zeros((batch, d), dtype),
                "S": jnp.zeros((batch, h, m.rwkv.head_dim, m.rwkv.head_dim),
                               jnp.float32)}
    if kind == "rglru":
        lw = m.rglru.lru_width or d
        return {"conv": jnp.zeros((batch, m.rglru.conv_width - 1, lw), dtype),
                "h": jnp.zeros((batch, lw), jnp.float32)}
    if kind == "dec":
        c = min(cache_len, m.encdec.max_target_positions)
        se = m.encdec.encoder_seq
        return {"k": jnp.zeros((batch, c, m.num_kv_heads, m.head_dim), dtype),
                "v": jnp.zeros((batch, c, m.num_kv_heads, m.head_dim), dtype),
                "ck": jnp.zeros((batch, se, m.num_kv_heads, m.head_dim), dtype),
                "cv": jnp.zeros((batch, se, m.num_kv_heads, m.head_dim), dtype)}
    raise ValueError(kind)


def block_state_specs(kind: str, m: ModelConfig) -> Dict[str, Any]:
    """Logical axes for decode state (leading dim = batch).

    The cache sequence dim is sharded over the model axis ("seq") — at
    long_500k (batch=1) this is the ONLY way the cache fits, and at
    decode_32k it avoids score-matrix replication; GSPMD handles the
    softmax over the sharded length. "kv" heads come after "seq" and only
    claim an axis when one is left and divisible.
    """
    if kind in ("attn", "moe"):
        return {"k": Lg("batch", "seq", "kv", None),
                "v": Lg("batch", "seq", "kv", None)}
    if kind == "mla":
        return {"ckv": Lg("batch", "seq", None),
                "krope": Lg("batch", "seq", None)}
    if kind == "rwkv":
        return {"x_time": Lg("batch", None), "x_chan": Lg("batch", None),
                "S": Lg("batch", "heads", None, None)}
    if kind == "rglru":
        return {"conv": Lg("batch", None, "mlp"), "h": Lg("batch", "mlp")}
    if kind == "dec":
        return {"k": Lg("batch", None, "kv", None),
                "v": Lg("batch", None, "kv", None),
                "ck": Lg("batch", None, "kv", None),
                "cv": Lg("batch", None, "kv", None)}
    raise ValueError(kind)


def block_decode(kind: str, p, x, state, index, m: ModelConfig, cd
                 ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Single-token decode through one block. x: (B,1,d)."""
    dims = attn_dims(m)
    if kind in ("attn", "moe"):
        h = norm_apply(m, p["ln1"], x)
        a, (ck, cv) = L.gqa_decode(p["attn"], h, state["k"], state["v"],
                                   index, dims, cd)
        x = x + a
        h = norm_apply(m, p["ln2"], x)
        if kind == "moe":
            y, _ = MOE_M.moe_apply(p["mlp"], h, m.moe, cd)
        else:
            y = L.mlp_apply(p["mlp"], h, m.act, cd)
        return x + y, {"k": ck, "v": cv}
    if kind == "mla":
        h = norm_apply(m, p["ln1"], x)
        a, (ckv, krope) = MLA.mla_decode(p["attn"], h, state["ckv"],
                                         state["krope"], index, m.num_heads,
                                         m.head_dim, m.mla, m.rope_theta, cd)
        x = x + a
        h = norm_apply(m, p["ln2"], x)
        y, _ = MOE_M.moe_apply(p["mlp"], h, m.moe, cd)
        return x + y, {"ckv": ckv, "krope": krope}
    if kind == "rwkv":
        h = norm_apply(m, p["ln1"], x)
        a, (xt, S) = RW.timemix_apply(p["time"], h, m.rwkv,
                                      x_prev_last=state["x_time"],
                                      state0=state["S"], compute_dtype=cd)
        x = x + a
        h = norm_apply(m, p["ln2"], x)
        y, xc = RW.channelmix_apply(p["chan"], h, x_prev_last=state["x_chan"],
                                    compute_dtype=cd)
        return x + y, {"x_time": xt.astype(state["x_time"].dtype),
                       "x_chan": xc.astype(state["x_chan"].dtype), "S": S}
    if kind == "rglru":
        h = norm_apply(m, p["ln1"], x)
        a, (conv, hT) = RG.rglru_block_apply(p["rec"], h, m.rglru,
                                             conv_state=state["conv"],
                                             h0=state["h"], compute_dtype=cd)
        x = x + a
        h = norm_apply(m, p["ln2"], x)
        return x + L.mlp_apply(p["mlp"], h, m.act, cd), \
            {"conv": conv.astype(state["conv"].dtype), "h": hT}
    if kind == "dec":
        h = norm_apply(m, p["ln1"], x)
        a, (ck, cv) = L.gqa_decode(p["attn"], h, state["k"], state["v"],
                                   index, dims, cd)
        x = x + a
        h = norm_apply(m, p["lnx"], x)
        x = x + _cross_decode(p["xattn"], h, state["ck"], state["cv"], dims, cd)
        h = norm_apply(m, p["ln2"], x)
        y = L.mlp_apply(p["mlp"], h, m.act, cd)
        return x + y, {"k": ck, "v": cv, "ck": state["ck"], "cv": state["cv"]}
    raise ValueError(kind)


def _cross_decode(p, h, ck, cv, dims: AttnDims, cd):
    b = h.shape[0]
    q = L.dense_apply(p["wq"], h, cd).reshape(b, 1, dims.num_heads,
                                              dims.head_dim)
    se = ck.shape[1]
    o = L.attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                    jnp.zeros((1,), jnp.int32), jnp.zeros((se,), jnp.int32))
    o = o.reshape(b, 1, dims.num_heads * dims.head_dim)
    return L.dense_apply(p["wo"], o, cd)
