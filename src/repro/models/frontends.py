"""Stub modality frontends (the one sanctioned carve-out, see DESIGN.md).

[audio]  whisper's mel-spectrogram + 2xConv1d feature extractor is replaced
         by precomputed frame embeddings of shape (B, encoder_seq, d_model).
[vlm]    chameleon's VQ-VAE image tokenizer is replaced by synthetic VQ token
         ids interleaved with text ids in one sequence (early fusion means
         the transformer itself is modality-agnostic).

These functions produce both the ShapeDtypeStructs used by the dry-run
(`input_specs`) and deterministic synthetic tensors for smoke tests/examples.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig


def audio_frame_embeddings(key, batch: int, m: ModelConfig,
                           dtype=jnp.float32) -> jnp.ndarray:
    """Stub for mel+conv frontend output: (B, S_enc, d)."""
    return 0.1 * jax.random.normal(
        key, (batch, m.encdec.encoder_seq, m.d_model), dtype)


def vlm_interleave(key, batch: int, seq_len: int, m: ModelConfig,
                   image_span: int = 256, text_vocab_frac: float = 0.75
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Early-fusion token stream: text ids + one VQ image span per sequence.

    Returns (tokens (B,S) int32, modality_mask (B,S) bool — True on image
    tokens). VQ codes live in the top (1 - text_vocab_frac) of the vocab,
    mirroring chameleon's shared-codebook layout.
    """
    v = m.vocab_size
    text_hi = int(v * text_vocab_frac)
    k1, k2, k3 = jax.random.split(key, 3)
    text = jax.random.randint(k1, (batch, seq_len), 0, text_hi)
    vq = jax.random.randint(k2, (batch, seq_len), text_hi, v)
    span = min(image_span, seq_len // 2)
    start = jax.random.randint(k3, (batch, 1), 0, max(seq_len - span, 1))
    pos = jnp.arange(seq_len)[None, :]
    mask = (pos >= start) & (pos < start + span)
    return jnp.where(mask, vq, text).astype(jnp.int32), mask
