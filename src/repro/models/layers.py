"""Core layers: norms, linear, embedding, RoPE, SwiGLU MLP, GQA attention.

All modules are pure-functional: ``*_init(key, ...) -> params`` (nested dict
of jnp arrays), ``*_specs(...) -> matching tree of Lg logical-axis leaves``,
``*_apply(params, x, ...) -> y``. No flax/equinox — parameter trees are the
public interface, which keeps FedAvg/split/ckpt trivially composable.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.specs import Lg, constrain

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None,
               bias: bool = False):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": _normal(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_specs(l_in, l_out, bias: bool = False):
    p = {"w": Lg(l_in, l_out)}
    if bias:
        p["b"] = Lg(l_out)
    return p


def dense_apply(p, x, compute_dtype=None):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_specs():
    return {"scale": Lg(None)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_specs():
    return {"scale": Lg(None), "bias": Lg(None)}


def layernorm_apply(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# embedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": _normal(key, (vocab, d), 0.02, dtype)}


def embedding_specs():
    return {"table": Lg("vocab", "embed")}


def embedding_apply(p, ids, compute_dtype=None):
    t = p["table"]
    if compute_dtype is not None:
        t = t.astype(compute_dtype)
    return jnp.take(t, ids, axis=0)


def unembed_apply(p, x):
    """Tied unembedding: bf16 operands, f32 accumulation (stable xent
    without f32 weight gathers)."""
    return jnp.einsum("...d,vd->...v", x, p["table"],
                      preferred_element_type=jnp.float32)


def sinusoidal_positions(length: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal embeddings (fp32)."""
    pos = jnp.arange(length)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * dim / (d // 2 - 1 + 1e-9))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, act: str = "silu", dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if act == "silu":   # SwiGLU: gate + up + down
        return {"gate": dense_init(ks[0], d, d_ff, dtype),
                "up": dense_init(ks[1], d, d_ff, dtype),
                "down": dense_init(ks[2], d_ff, d, dtype)}
    return {"up": dense_init(ks[1], d, d_ff, dtype, bias=True),
            "down": dense_init(ks[2], d_ff, d, dtype, bias=True)}


def mlp_specs(act: str = "silu"):
    if act == "silu":
        return {"gate": dense_specs("embed", "mlp"),
                "up": dense_specs("embed", "mlp"),
                "down": dense_specs("mlp", "embed")}
    return {"up": dense_specs("embed", "mlp", bias=True),
            "down": dense_specs("mlp", "embed", bias=True)}


def mlp_apply(p, x, act: str = "silu", compute_dtype=None):
    # explicit TP anchors: hidden activations shard over "mlp" (model axis),
    # matching the column/row-parallel weight layout — keeps GSPMD from
    # falling back to full weight replication (see EXPERIMENTS.md §Perf).
    if act == "silu":
        g = dense_apply(p["gate"], x, compute_dtype)
        u = dense_apply(p["up"], x, compute_dtype)
        h = constrain(jax.nn.silu(g) * u, ("batch", None, "mlp"))
        return dense_apply(p["down"], h, compute_dtype)
    h = jax.nn.gelu(dense_apply(p["up"], x, compute_dtype))
    h = constrain(h, ("batch", None, "mlp"))
    return dense_apply(p["down"], h, compute_dtype)


# ---------------------------------------------------------------------------
# Attention core
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, Hkv, hd) -> (B, S, Hkv*groups, hd) by repetition (GQA)."""
    if groups == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, groups, d)
                            ).reshape(b, s, h * groups, d)


def attention_scores_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                          window: int = 0) -> jnp.ndarray:
    """(Lq, Lk) bool mask: causal, optionally banded to a sliding window."""
    m = q_pos[:, None] >= k_pos[None, :]
    if window and window > 0:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def attention_full(q, k, v, q_pos, k_pos, window: int = 0,
                   kv_valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Plain softmax attention. q: (B,Lq,H,hd); k,v: (B,Lk,Hkv,hd)."""
    groups = q.shape[2] // k.shape[2]
    k = _repeat_kv(k, groups)
    v = _repeat_kv(v, groups)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = attention_scores_mask(q_pos, k_pos, window)            # (Lq, Lk)
    if kv_valid is not None:
        mask = mask & kv_valid[:, None, None, :]                  # (B,1,1,Lk)
    else:
        mask = mask[None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def attention_chunked(q, k, v, q_pos, k_pos, window: int = 0,
                      kv_valid: Optional[jnp.ndarray] = None,
                      kv_chunk: int = 1024) -> jnp.ndarray:
    """Online-softmax attention, scanning over KV chunks.

    Memory-safe reference for long sequences (the jnp analogue of the
    Pallas flash kernel — O(Lq * kv_chunk) live scores instead of O(Lq*Lk)).
    """
    b, lq, h, hd = q.shape
    lk = k.shape[1]
    if lk % kv_chunk != 0:
        pad = kv_chunk - lk % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=2**30)
        if kv_valid is None:
            kv_valid = jnp.arange(lk + pad)[None, :] < lk
            kv_valid = jnp.broadcast_to(kv_valid, (b, lk + pad))
        else:
            kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)))
        lk += pad
    groups = q.shape[2] // k.shape[2]
    n_chunks = lk // kv_chunk
    kc = k.reshape(b, n_chunks, kv_chunk, k.shape[2], k.shape[3])
    vc = v.reshape(b, n_chunks, kv_chunk, v.shape[2], v.shape[3])
    pc = k_pos.reshape(n_chunks, kv_chunk)
    valc = (kv_valid.reshape(b, n_chunks, kv_chunk)
            if kv_valid is not None else None)
    scale = hd ** -0.5

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        if valc is None:
            kcj, vcj, pj = xs
            validj = None
        else:
            kcj, vcj, pj, validj = xs
        kcj = _repeat_kv(kcj, groups)
        vcj = _repeat_kv(vcj, groups)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kcj,
                            preferred_element_type=jnp.float32) * scale
        mask = attention_scores_mask(q_pos, pj, window)
        if validj is not None:
            mask = mask & validj[:, None, None, :]
        else:
            mask = mask[None, None]
        logits = jnp.where(mask, logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vcj.dtype), vcj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    vd = v.shape[-1]
    m0 = jnp.full((b, h, lq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, lq), jnp.float32)
    a0 = jnp.zeros((b, h, lq, vd), jnp.float32)
    xs = (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc)
    if valc is not None:
        xs = xs + (jnp.moveaxis(valc, 1, 0),)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)   # (B, Lq, H, hd)


def attention(q, k, v, q_pos, k_pos, window: int = 0,
              kv_valid: Optional[jnp.ndarray] = None,
              kv_chunk: int = 1024, force_full: bool = False) -> jnp.ndarray:
    """Dispatch: full einsum for short KV, chunked online-softmax beyond."""
    if force_full or k.shape[1] <= kv_chunk:
        return attention_full(q, k, v, q_pos, k_pos, window, kv_valid)
    return attention_chunked(q, k, v, q_pos, k_pos, window, kv_valid, kv_chunk)


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + qk-norm + cache handling)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: int = 0          # 0 => full causal


def gqa_init(key, dims: AttnDims, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    d, q_dim = dims.d_model, dims.num_heads * dims.head_dim
    kv_dim = dims.num_kv_heads * dims.head_dim
    p = {"wq": dense_init(ks[0], d, q_dim, dtype, bias=dims.qkv_bias),
         "wk": dense_init(ks[1], d, kv_dim, dtype, bias=dims.qkv_bias),
         "wv": dense_init(ks[2], d, kv_dim, dtype, bias=dims.qkv_bias),
         "wo": dense_init(ks[3], q_dim, d, dtype,
                          scale=(q_dim ** -0.5))}
    if dims.qk_norm:
        p["q_norm"] = rmsnorm_init(dims.head_dim, dtype)
        p["k_norm"] = rmsnorm_init(dims.head_dim, dtype)
    return p


def gqa_specs(dims: AttnDims):
    p = {"wq": dense_specs("embed", "mlp", bias=dims.qkv_bias),
         "wk": dense_specs("embed", "kv", bias=dims.qkv_bias),
         "wv": dense_specs("embed", "kv", bias=dims.qkv_bias),
         "wo": dense_specs("mlp", "embed")}
    if dims.qk_norm:
        p["q_norm"] = rmsnorm_specs()
        p["k_norm"] = rmsnorm_specs()
    return p


def gqa_project_qkv(p, x, dims: AttnDims, positions, compute_dtype=None,
                    rope: bool = True):
    b, s, _ = x.shape
    q = dense_apply(p["wq"], x, compute_dtype).reshape(
        b, s, dims.num_heads, dims.head_dim)
    k = dense_apply(p["wk"], x, compute_dtype).reshape(
        b, s, dims.num_kv_heads, dims.head_dim)
    v = dense_apply(p["wv"], x, compute_dtype).reshape(
        b, s, dims.num_kv_heads, dims.head_dim)
    if dims.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    if rope:
        q = apply_rope(q, positions, dims.rope_theta)
        k = apply_rope(k, positions, dims.rope_theta)
    # TP anchors: heads shard over the model axis (kv heads too when they
    # divide it; logical_spec drops the axis otherwise)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv", None))
    v = constrain(v, ("batch", None, "kv", None))
    return q, k, v


def gqa_apply(p, x, dims: AttnDims, positions=None, compute_dtype=None,
              kv_chunk: int = 1024, use_kernel: bool = False):
    """Training/prefill self-attention over a (B, S, d) sequence.

    use_kernel=True dispatches the Pallas flash-attention kernel (Mosaic on
    TPU, interpreter elsewhere); otherwise the jnp chunked reference runs.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    pos_b = jnp.broadcast_to(positions, (s,)) if positions.ndim == 1 else positions
    q, k, v = gqa_project_qkv(p, x, dims, pos_b, compute_dtype)
    if use_kernel:
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=True, window=dims.window)
    else:
        out = attention(q, k, v, pos_b, pos_b, window=dims.window,
                        kv_chunk=kv_chunk)
    out = out.reshape(b, s, dims.num_heads * dims.head_dim)
    return dense_apply(p["wo"], out, compute_dtype), (k, v)


def gqa_decode(p, x, cache_k, cache_v, index, dims: AttnDims,
               compute_dtype=None, kv_chunk: int = 1024):
    """Single-token decode against a KV cache.

    x: (B, 1, d); cache_k/v: (B, S_cache, Hkv, hd); index: current position.
    Sliding-window archs use a ring buffer of size `window`.
    """
    b = x.shape[0]
    s_cache = cache_k.shape[1]
    pos = jnp.full((1,), index, jnp.int32)
    q, k, v = gqa_project_qkv(p, x, dims, pos, compute_dtype)
    slot = index % s_cache if dims.window else index
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), slot, axis=1)
    if dims.window:
        # ring buffer: absolute position of slot j given write head at `slot`
        j = jnp.arange(s_cache)
        k_pos = index - ((slot - j) % s_cache)
        valid = (k_pos >= 0) & (k_pos >= index - dims.window + 1)
    else:
        j = jnp.arange(s_cache)
        k_pos = j
        valid = j <= index
    valid_b = jnp.broadcast_to(valid[None, :], (b, s_cache))
    out = attention(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                    pos, k_pos, window=0, kv_valid=valid_b, kv_chunk=kv_chunk)
    out = out.reshape(b, 1, dims.num_heads * dims.head_dim)
    return dense_apply(p["wo"], out, compute_dtype), (cache_k, cache_v)
