from repro.runtime.train import make_fsl_train_step, make_train_step  # noqa: F401
from repro.runtime.serve import make_decode_step, make_prefill_step  # noqa: F401
