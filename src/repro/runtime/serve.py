"""Serving-step builders: prefill and single-token decode.

``decode`` is the step the decode_32k / long_500k dry-run shapes lower:
ONE new token against a populated cache of ``shape.seq_len`` positions.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import RunConfig
from repro.models.transformer import lm_decode_step, lm_prefill


def _dtype(name: str):
    return jnp.dtype(name)


def cache_length(cfg: RunConfig) -> int:
    """Decode-cache length for the configured shape (window-aware archs clip
    inside block_state_init; whisper clips to max_target_positions)."""
    return cfg.shape.seq_len


def make_prefill_step(cfg: RunConfig) -> Callable:
    m = cfg.model
    cd = _dtype(cfg.parallel.compute_dtype)
    cache_dt = _dtype(cfg.parallel.cache_dtype)
    clen = cache_length(cfg)

    def prefill(params, batch):
        return lm_prefill(params, batch, m, clen, cd, cache_dt,
                          remat=cfg.parallel.remat,
                          scan_layers=cfg.parallel.scan_layers)

    return prefill


def make_decode_step(cfg: RunConfig) -> Callable:
    m = cfg.model
    cd = _dtype(cfg.parallel.compute_dtype)

    def decode(params, token, state, index):
        return lm_decode_step(params, token, state, index, m, cd,
                              scan_layers=cfg.parallel.scan_layers)

    return decode
