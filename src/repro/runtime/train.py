"""Training-step builders.

Two step shapes:

  * ``make_train_step``      standard data-parallel training (gradient sync
                             every step — the paper's ``local_steps=1`` case;
                             pjit derives the gradient all-reduce from the
                             global-mean loss).
  * ``make_fsl_train_step``  FSL mode: one discriminator/model replica per
                             FL client (leading client axis, sharded over
                             ``data``), `local_steps` local updates between
                             FedAvg rounds — parameter averaging is a single
                             collective on the client axis. This is the
                             paper's FedAvg cadence as a first-class mesh
                             feature; cadence>1 divides parameter-sync
                             collective bytes by the cadence (EXPERIMENTS
                             §Perf quantifies this).

Both accumulate over ``parallel.microbatches`` with a `lax.scan` (bounding
live activations) and remat inside the layer scan.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import RunConfig
from repro.models.transformer import lm_loss
from repro.optim import make_optimizer
from repro.optim.schedule import make_schedule


def _dtype(name: str):
    return jnp.dtype(name)


def make_train_step(cfg: RunConfig) -> Callable:
    """-> step(params, opt_state, batch, step_idx) -> (params, opt, metrics)."""
    m = cfg.model
    par = cfg.parallel
    opt = make_optimizer(cfg.optim)
    sched = make_schedule(cfg.optim.schedule, cfg.optim.lr,
                          cfg.optim.warmup_steps, cfg.optim.total_steps)
    cd = _dtype(par.compute_dtype)
    acc_dt = _dtype(par.accum_dtype)
    nmb = max(1, par.microbatches)

    def loss_fn(params, mb):
        return lm_loss(params, mb, m, cd, par.remat, par.use_flash_kernel,
                       scan_layers=par.scan_layers)

    def train_step(params, opt_state, batch, step_idx):
        bsz = batch["tokens"].shape[0]
        assert bsz % nmb == 0, (bsz, nmb)

        def split_mb(x):
            return x.reshape(nmb, bsz // nmb, *x.shape[1:])

        mbs = jax.tree.map(split_mb, batch)

        def mb_body(carry, mb):
            gacc, lsum, auxsum = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            gacc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                gacc, grads)
            return (gacc, lsum + metrics["loss"],
                    auxsum + metrics["aux_loss"]), None

        gz = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
        carry0 = (gz, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
        if par.unroll_microbatches or nmb == 1:
            carry = carry0
            for i in range(nmb):
                carry, _ = mb_body(carry, jax.tree.map(lambda x: x[i], mbs))
            gacc, lsum, auxsum = carry
        else:
            (gacc, lsum, auxsum), _ = jax.lax.scan(mb_body, carry0, mbs)
        grads = jax.tree.map(lambda g: g / nmb, gacc)
        lr = sched(step_idx)
        params, opt_state = opt.update(grads, opt_state, params, lr)
        metrics = {"loss": lsum / nmb, "aux_loss": auxsum / nmb, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_fsl_train_step(cfg: RunConfig, num_clients: int) -> Callable:
    """FSL-mode step over stacked per-client replicas.

    params/opt leaves carry a leading (num_clients,) axis; batch leaves a
    leading client axis. Every ``cfg.fsl.local_steps`` steps the replicas
    are FedAvg'd (uniform mean — weighted form in core.fedavg).
    """
    base_step = make_train_step(cfg)
    local_steps = max(1, cfg.fsl.local_steps)

    def fsl_step(cparams, copt, cbatch, step_idx):
        cparams, copt, metrics = jax.vmap(
            lambda p, o, b: base_step(p, o, b, step_idx))(cparams, copt,
                                                          cbatch)
        do_avg = (step_idx + 1) % local_steps == 0

        def avg_all(t):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(
                    jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True),
                    x.shape).astype(x.dtype), t)

        # lax.cond (not where): the FedAvg collective only *executes* on
        # cadence steps, so cadence k really divides sync traffic by k
        # (EXPERIMENTS §Perf hc3). k=1 takes the static path (no cond).
        if local_steps == 1:
            cparams = avg_all(cparams)
        else:
            cparams = jax.lax.cond(do_avg, avg_all, lambda t: t, cparams)
        metrics = jax.tree.map(lambda x: jnp.mean(x), metrics)
        return cparams, copt, metrics

    return fsl_step
