"""Public dp_clip op: pytree <-> flat glue around the Pallas kernel.

``dp_clip_noise_tree`` is what the DP-SGD step (privacy/defenses.py) calls:
per-example gradient pytree in, privatized *summed* gradient tree out
(the caller divides by the batch size).  The whole tree is flattened into
ONE (B, N) stack so the clip norm is the global L2 over all parameters —
clipping leaf-by-leaf would be a different (weaker) mechanism.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.dp_clip.kernel import dp_clip_noise_kernel
from repro.kernels.dp_clip.ref import dp_clip_noise_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def dp_clip_noise_flat(stacked: jnp.ndarray, clip, noise_scale,
                       noise: jnp.ndarray, *, use_kernel: bool = True,
                       interpret: bool = False) -> jnp.ndarray:
    """stacked: (B, N) -> (N,) f32 privatized gradient sum."""
    if use_kernel:
        return dp_clip_noise_kernel(stacked, clip, noise_scale, noise,
                                    interpret=interpret)
    return dp_clip_noise_ref(stacked, clip, noise_scale, noise)


def flatten_per_example(tree) -> Tuple[jnp.ndarray, Any]:
    """Per-example grad tree (every leaf (B, ...)) -> ((B, N) stack, spec)."""
    leaves, treedef = jax.tree.flatten(tree)
    b = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(b, -1).astype(jnp.float32) for l in leaves], axis=1)
    spec = (treedef, [l.shape[1:] for l in leaves],
            [l.dtype for l in leaves])
    return flat, spec


def unflatten_summed(vec: jnp.ndarray, spec) -> Any:
    """(N,) privatized sum -> gradient tree with the original leaf shapes."""
    treedef, shapes, dtypes = spec
    out, off = [], 0
    for shape, dtype in zip(shapes, dtypes):
        size = 1
        for s in shape:
            size *= s
        out.append(vec[off:off + size].reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def dp_clip_noise_tree(per_example_grads, clip, noise_scale, key, *,
                       use_kernel: bool = True, interpret: bool = False):
    """Privatize a per-example gradient pytree.

    per_example_grads: tree of (B, ...) leaves.  Returns the tree of
    ``sum_b clip_b(g_b) + noise_scale * N(0, I)`` — divide by B for the
    DP-SGD mean gradient.  ``noise_scale`` is sigma * clip for the standard
    Gaussian mechanism.  One normal draw per parameter, from ``key``.
    """
    flat, spec = flatten_per_example(per_example_grads)
    noise = jax.random.normal(key, (flat.shape[1],), jnp.float32)
    vec = dp_clip_noise_flat(flat, jnp.asarray(clip, jnp.float32),
                             jnp.asarray(noise_scale, jnp.float32), noise,
                             use_kernel=use_kernel, interpret=interpret)
    return unflatten_summed(vec, spec)
