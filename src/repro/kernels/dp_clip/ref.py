"""Oracle for the dp_clip kernel: pure-JAX DP-SGD clip-sum-noise."""
import jax.numpy as jnp

NORM_EPS = 1e-12      # shared with kernel.py


def dp_clip_noise_ref(stacked: jnp.ndarray, clip, noise_scale,
                      noise: jnp.ndarray) -> jnp.ndarray:
    """stacked: (B, N); noise: (N,) -> (N,) f32.

    out = sum_b min(1, clip/||g_b||) g_b  +  noise_scale * noise
    """
    x = stacked.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(x * x, axis=1, keepdims=True))
    scale = jnp.minimum(1.0, clip / jnp.maximum(norms, NORM_EPS))
    return (jnp.sum(x * scale, axis=0)
            + jnp.asarray(noise_scale, jnp.float32)
            * noise.astype(jnp.float32))
