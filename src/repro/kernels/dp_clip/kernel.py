"""DP-SGD clip-and-noise as a Pallas kernel.

The device-side DP-SGD step (privacy/defenses.py) reduces stacked
per-example gradients g of shape (B, N) to

    out[n] = sum_b min(1, C / ||g_b||_2) * g[b, n]  +  noise_scale * z[n]

i.e. per-example L2 norm, clip to C, weighted sum, Gaussian-noise add.
Done naively that is four passes over the (B, N) stack (square, reduce,
scale, sum).  The kernel fuses it into a two-phase sequential grid:

  * phase 0 streams (B, bn) tiles through VMEM accumulating per-example
    partial squared norms into a (B, 1) VMEM scratch that persists across
    the grid (same pattern as the wkv6 state scratch);
  * phase 1 re-streams each tile, applies the per-example clip scale from
    the scratch, reduces over B on the VPU and adds the noise tile.

HBM traffic is therefore 2 reads + 1 write per element — the floor for any
clip-then-reduce (the norm must be complete before the first scaled element
is emitted).  Noise is a precomputed input tile (not in-kernel PRNG) so the
kernel is a deterministic function of its inputs and pins exactly against
the pure-JAX reference (ref.py) in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NORM_EPS = 1e-12      # shared with ref.py: guard for all-zero examples


def _dp_clip_kernel(x_ref, scal_ref, noise_ref, o_ref, norm_scr):
    phase = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(phase == 0)
    def _accumulate_norms():
        @pl.when(j == 0)
        def _init():
            norm_scr[...] = jnp.zeros_like(norm_scr)
        x = x_ref[...].astype(jnp.float32)               # (B, bn)
        norm_scr[...] += jnp.sum(x * x, axis=1, keepdims=True)
        o_ref[...] = jnp.zeros_like(o_ref)               # placeholder flush

    @pl.when(phase == 1)
    def _clip_sum_noise():
        x = x_ref[...].astype(jnp.float32)               # (B, bn)
        clip = scal_ref[0, 0]
        noise_scale = scal_ref[0, 1]
        norms = jnp.sqrt(norm_scr[...])                  # (B, 1)
        scale = jnp.minimum(1.0, clip / jnp.maximum(norms, NORM_EPS))
        acc = jnp.sum(x * scale, axis=0, keepdims=True)  # (1, bn)
        o_ref[...] = (acc + noise_scale * noise_ref[...]).astype(o_ref.dtype)


def dp_clip_noise_kernel(stacked: jnp.ndarray, clip: jnp.ndarray,
                         noise_scale: jnp.ndarray, noise: jnp.ndarray, *,
                         block_n: int = 2048,
                         interpret: bool = False) -> jnp.ndarray:
    """stacked: (B, N) per-example grads; noise: (N,).  -> (N,) f32.

    Arbitrary N: zero-padded to a block_n multiple (padded lanes add 0 to
    every norm and emit noise_scale * 0) and sliced back, like the fedavg
    kernel.  ``clip``/``noise_scale`` ride in one (1, 2) scalar tile.
    """
    b, n = stacked.shape
    block_n = min(block_n, max(n, 1))
    pad = (-n) % block_n
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
        noise = jnp.pad(noise, (0, pad))
    n_padded = n + pad
    scal = jnp.stack([jnp.asarray(clip, jnp.float32).reshape(()),
                      jnp.asarray(noise_scale, jnp.float32).reshape(())]
                     ).reshape(1, 2)
    out = pl.pallas_call(
        _dp_clip_kernel,
        grid=(2, n_padded // block_n),
        in_specs=[pl.BlockSpec((b, block_n), lambda i, j: (0, j)),
                  pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, block_n), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n_padded), jnp.float32),
        scratch_shapes=[pltpu.VMEM((b, 1), jnp.float32)],
        interpret=interpret,
    )(stacked.astype(jnp.float32), scal,
      noise.astype(jnp.float32).reshape(1, n_padded))[0]
    return out[:n] if pad else out
