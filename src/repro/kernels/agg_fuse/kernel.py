"""Fused dequantize-and-accumulate kernels for compressed-domain FedAvg.

The server-side reduce used to be decode-then-average: every client's
wire payload was dequantized to a full fp32 tree, all of them staged,
then ``kernels/fedavg`` streamed the (C, N) stack.  That pays one full
fp32 materialization + one extra HBM round-trip per client, and server
memory grows linearly in the cohort.  These kernels fold the codec
decode INTO the weighted reduction so the server only ever holds wire
payloads and ONE fp32 accumulator:

  * ``dequant_reduce_kernel`` — batch form.  Grid ``(nb, C)`` with the
    n-block OUTER and the client sweep INNER (the last grid dim iterates
    fastest on TPU), so a persistent (1, bn) VMEM scratch accumulates
    ``w_c * s_c * x_c`` across all clients of one block before emitting.
    Each client reads at its WIRE dtype (int8 / fp16 / fp32) — for int8
    that's 4x less HBM traffic than reducing a dequantized stack.
  * ``dequant_acc_kernel`` — streaming form: one landed uplink folded
    into the running fp32 accumulator (``acc + w * s * x``), the O(1)
    server-memory path the engine uses as uplinks arrive.
  * ``scatter_acc_kernel`` — sparse top-k form: (values, flat indices)
    added into the dense accumulator per n-block via a broadcast-compare
    one-hot sum, which also sums COLLIDING indices correctly — the wire
    is never densified into a per-client tree.

All follow the repo kernel idiom (``kernels/fedavg``,
``kernels/boundary_fuse``): zero-pad N to a block multiple and slice
back, ``pl.when`` phase gating with placeholder flushes on non-final
client steps, per-client scalars in tiny (·, 2) tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_dequant_reduce_kernel(num_clients: int):
    def kernel(x_ref, coef_ref, o_ref, acc_scr):
        i = pl.program_id(1)               # client index — INNER grid dim

        @pl.when(i == 0)
        def _init():
            acc_scr[...] = jnp.zeros_like(acc_scr)

        w = coef_ref[0, 0]                 # normalized fedavg weight
        s = coef_ref[0, 1]                 # codec dequant scale
        acc_scr[...] += w * s * x_ref[...].astype(jnp.float32)

        @pl.when(i == num_clients - 1)
        def _emit():
            o_ref[...] = acc_scr[...]

        @pl.when(i < num_clients - 1)
        def _flush():
            o_ref[...] = jnp.zeros_like(o_ref)   # placeholder flush

    return kernel


def dequant_reduce_kernel(wires: jnp.ndarray, coefs: jnp.ndarray, *,
                          block_n: int = 4096,
                          interpret: bool = False) -> jnp.ndarray:
    """wires: (C, N) at wire dtype; coefs: (C, 2) fp32 ``[weight, scale]``
    per client (weights already normalized).  -> (N,) fp32 weighted sum
    of the dequantized rows, computed without materializing them."""
    c, n = wires.shape
    block_n = min(block_n, max(n, 1))
    pad = (-n) % block_n
    if pad:
        wires = jnp.pad(wires, ((0, 0), (0, pad)))
    n_padded = n + pad
    out = pl.pallas_call(
        _make_dequant_reduce_kernel(c),
        grid=(n_padded // block_n, c),
        in_specs=[pl.BlockSpec((1, block_n), lambda j, i: (i, j)),
                  pl.BlockSpec((1, 2), lambda j, i: (i, 0))],
        out_specs=pl.BlockSpec((1, block_n), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n_padded), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_n), jnp.float32)],
        interpret=interpret,
    )(wires, coefs.astype(jnp.float32))[0]
    return out[:n] if pad else out


def _dequant_acc_kernel(acc_ref, x_ref, scal_ref, o_ref):
    o_ref[...] = acc_ref[...] + scal_ref[0, 0] * scal_ref[0, 1] \
        * x_ref[...].astype(jnp.float32)


def dequant_acc_kernel(acc: jnp.ndarray, wire: jnp.ndarray, scal: jnp.ndarray,
                       *, block_n: int = 4096,
                       interpret: bool = False) -> jnp.ndarray:
    """acc: (N,) fp32 running sum; wire: (N,) at wire dtype; scal: (1, 2)
    fp32 ``[weight, scale]``.  -> (N,) fp32 ``acc + w * s * wire``."""
    n = acc.shape[0]
    block_n = min(block_n, max(n, 1))
    pad = (-n) % block_n
    a2, x2 = acc.reshape(1, n), wire.reshape(1, n)
    if pad:
        a2 = jnp.pad(a2, ((0, 0), (0, pad)))
        x2 = jnp.pad(x2, ((0, 0), (0, pad)))
    n_padded = n + pad
    out = pl.pallas_call(
        _dequant_acc_kernel,
        grid=(n_padded // block_n,),
        in_specs=[pl.BlockSpec((1, block_n), lambda j: (0, j)),
                  pl.BlockSpec((1, block_n), lambda j: (0, j)),
                  pl.BlockSpec((1, 2), lambda j: (0, 0))],
        out_specs=pl.BlockSpec((1, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n_padded), jnp.float32),
        interpret=interpret,
    )(a2, x2, scal.astype(jnp.float32))[0]
    return out[:n] if pad else out


def _make_scatter_acc_kernel(k: int, block_n: int):
    def kernel(acc_ref, vals_ref, idx_ref, scal_ref, o_ref):
        j = pl.program_id(0)
        local = idx_ref[...] - j * block_n            # (K, 1)
        inr = jnp.logical_and(local >= 0, local < block_n)
        cols = jax.lax.broadcasted_iota(jnp.int32, (k, block_n), 1)
        sel = jnp.logical_and(local == cols, inr)     # (K, bn) one-hot rows
        # colliding indices each contribute a row, so the column sum adds
        # them — matching .at[idx].add() scatter semantics
        contrib = jnp.sum(jnp.where(sel, vals_ref[...], 0.0),
                          axis=0, keepdims=True)      # (1, bn)
        o_ref[...] = acc_ref[...] + scal_ref[0, 0] * contrib

    return kernel


def scatter_acc_kernel(acc: jnp.ndarray, vals: jnp.ndarray,
                       idx: jnp.ndarray, scal: jnp.ndarray, *,
                       block_n: int = 1024,
                       interpret: bool = False) -> jnp.ndarray:
    """acc: (N,) fp32; vals/idx: (K,) top-k values + flat indices; scal:
    (1, 2) fp32 ``[weight, unused]``.  -> (N,) fp32 with ``w * vals``
    scatter-added at ``idx`` (collisions sum), no densified wire."""
    n = acc.shape[0]
    k = vals.shape[0]
    block_n = min(block_n, max(n, 1))
    pad = (-n) % block_n
    a2 = acc.reshape(1, n)
    if pad:
        a2 = jnp.pad(a2, ((0, 0), (0, pad)))
    n_padded = n + pad
    out = pl.pallas_call(
        _make_scatter_acc_kernel(k, block_n),
        grid=(n_padded // block_n,),
        in_specs=[pl.BlockSpec((1, block_n), lambda j: (0, j)),
                  pl.BlockSpec((k, 1), lambda j: (0, 0)),
                  pl.BlockSpec((k, 1), lambda j: (0, 0)),
                  pl.BlockSpec((1, 2), lambda j: (0, 0))],
        out_specs=pl.BlockSpec((1, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n_padded), jnp.float32),
        interpret=interpret,
    )(a2, vals.astype(jnp.float32).reshape(k, 1),
      idx.astype(jnp.int32).reshape(k, 1), scal.astype(jnp.float32))[0]
    return out[:n] if pad else out
