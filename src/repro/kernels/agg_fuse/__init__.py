from repro.kernels.agg_fuse.ops import (dequant_acc_flat,  # noqa: F401
                                        dequant_reduce_flat,
                                        scatter_acc_flat)
from repro.kernels.agg_fuse.ref import (dequant_acc_ref,  # noqa: F401
                                        dequant_reduce_ref, scatter_acc_ref)
