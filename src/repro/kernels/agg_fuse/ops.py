"""Public agg_fuse ops: jitted kernel/ref dispatch on flat buffers.

Mirrors ``kernels/boundary_fuse/ops.py``: each op takes flat arrays plus
``use_kernel``/``interpret`` statics and routes to the Pallas kernel or
the jnp reference — callers (``fed/aggregate.StreamingAggregator``, the
engine's batched reduce) never touch grids or block specs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.agg_fuse.kernel import (dequant_acc_kernel,
                                           dequant_reduce_kernel,
                                           scatter_acc_kernel)
from repro.kernels.agg_fuse.ref import (dequant_acc_ref, dequant_reduce_ref,
                                        scatter_acc_ref)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def dequant_reduce_flat(wires: jnp.ndarray, scales: jnp.ndarray,
                        weights: jnp.ndarray, *, use_kernel: bool = False,
                        interpret: bool = False) -> jnp.ndarray:
    """Batch reduce: (C, N) wire rows + per-client (C,) scales and fedavg
    weights -> (N,) fp32 weighted MEAN of the dequantized rows (weights
    normalized here, like ``fedavg_flat``)."""
    w = (weights / jnp.sum(weights)).astype(jnp.float32)
    coefs = jnp.stack([w, scales.astype(jnp.float32)], axis=1)
    if use_kernel:
        return dequant_reduce_kernel(wires, coefs, interpret=interpret)
    return dequant_reduce_ref(wires, coefs)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"),
                   donate_argnames=("acc",))
def dequant_acc_flat(acc: jnp.ndarray, wire: jnp.ndarray, scale, weight, *,
                     use_kernel: bool = False,
                     interpret: bool = False) -> jnp.ndarray:
    """Streaming fold: (N,) fp32 accumulator + one (N,) wire at its wire
    dtype -> ``acc + weight * scale * dequant(wire)``.  UNnormalized —
    the aggregator divides by the weight sum at finalize."""
    if use_kernel:
        scal = jnp.stack([jnp.asarray(weight, jnp.float32).reshape(()),
                          jnp.asarray(scale, jnp.float32).reshape(())]
                         ).reshape(1, 2)
        return dequant_acc_kernel(acc, wire, scal, interpret=interpret)
    return dequant_acc_ref(acc, wire, weight, scale)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"),
                   donate_argnames=("acc",))
def scatter_acc_flat(acc: jnp.ndarray, vals: jnp.ndarray, idx: jnp.ndarray,
                     weight, *, use_kernel: bool = False,
                     interpret: bool = False) -> jnp.ndarray:
    """Sparse streaming fold: weighted top-k (vals, idx) scatter-added
    into the (N,) fp32 accumulator without densifying the wire."""
    if use_kernel:
        scal = jnp.stack([jnp.asarray(weight, jnp.float32).reshape(()),
                          jnp.zeros((), jnp.float32)]).reshape(1, 2)
        return scatter_acc_kernel(acc, vals, idx, scal, interpret=interpret)
    return scatter_acc_ref(acc, vals, idx, weight)
