"""jnp reference semantics the agg_fuse kernels pin against."""
from __future__ import annotations

import jax.numpy as jnp


def dequant_reduce_ref(wires: jnp.ndarray, coefs: jnp.ndarray) -> jnp.ndarray:
    """(C, N) wire-dtype rows, (C, 2) [weight, scale] -> (N,) fp32
    weighted sum of the dequantized rows."""
    coef = (coefs[:, 0] * coefs[:, 1]).astype(jnp.float32)
    return jnp.sum(wires.astype(jnp.float32) * coef[:, None], axis=0)


def dequant_acc_ref(acc: jnp.ndarray, wire: jnp.ndarray, weight,
                    scale) -> jnp.ndarray:
    """One streamed fold: ``acc + w * s * dequant(wire)``."""
    return acc + jnp.float32(weight) * jnp.float32(scale) \
        * wire.astype(jnp.float32)


def scatter_acc_ref(acc: jnp.ndarray, vals: jnp.ndarray, idx: jnp.ndarray,
                    weight) -> jnp.ndarray:
    """Sparse fold: weighted top-k values scatter-added (collisions sum)."""
    return acc.at[idx].add(jnp.float32(weight) * vals.astype(jnp.float32))
