"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0,
                  scale: Optional[float] = None,
                  q_offset: int = 0) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D). fp32 math throughout."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    groups = h // hkv
    k = jnp.repeat(k, groups, axis=1)
    v = jnp.repeat(v, groups, axis=1)
    scale = d ** -0.5 if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        q_pos = q_offset + jnp.arange(sq)[:, None]
        k_pos = jnp.arange(sk)[None, :]
        mask = q_pos >= k_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
