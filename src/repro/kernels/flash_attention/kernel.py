"""Flash attention as a Pallas TPU kernel.

Blockwise online-softmax (Dao et al.) adapted to TPU:
  * grid = (batch, q_heads, q_blocks, kv_blocks) — the kv dimension is the
    innermost *sequential* grid axis; VMEM scratch (running max, denom,
    accumulator) persists across kv iterations, which is the TPU-idiomatic
    replacement for a CUDA thread-block's shared-memory loop.
  * BlockSpec tiles: q (1,1,bq,d), k/v (1,1,bk,d) — bq/bk default 128/256 so
    the working set (q + k + v + acc ≈ bq*d + 2*bk*d + bq*d floats) stays
    well under the ~16 MB/core VMEM budget while the bq x bk score matmul
    feeds the 128x128 MXU with aligned shapes.
  * GQA folds into the k/v index_map (kv head = q head // group) — no
    repeated K/V materialisation in HBM.
  * causal + sliding-window masks are computed from global indices; fully
    masked kv blocks are skipped with ``pl.when`` (no MXU work issued).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, seq_q: int,
                  seq_k: int, causal: bool, window: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global positions of this tile
    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # is any (q, k) pair in this tile unmasked?  (static-shape predicate)
    run = True
    if causal:
        first_q = q_offset + qi * block_q
        last_q = first_q + block_q - 1
        first_k = ki * block_k
        run = jnp.asarray(first_k <= last_q)
        if window > 0:
            last_k = first_k + block_k - 1
            run = run & jnp.asarray(first_q - window + 1 <= last_k)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)        # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)        # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (k_pos < seq_k) & (q_pos < seq_q)
        if causal:
            mask &= q_pos >= k_pos
            if window > 0:
                mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                         # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int = 0,
                           scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 256,
                           seq_q_valid: Optional[int] = None,
                           seq_k_valid: Optional[int] = None,
                           q_offset: int = 0,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D), H % Hkv == 0.

    Sq/Sk must already be padded to block multiples (ops.py does this);
    seq_*_valid give the unpadded lengths for masking. ``q_offset`` is the
    global position of q row 0 (used for the decode/chunked case).
    """
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    groups = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    scale = d ** -0.5 if scale is None else scale
    grid = (b, h, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=(seq_q_valid if seq_q_valid is not None else sq) + q_offset,
        seq_k=seq_k_valid if seq_k_valid is not None else sk,
        causal=causal, window=window, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qi, ki, g=groups: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, qi, ki, g=groups: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
