"""Public flash-attention op: layout/padding glue around the Pallas kernel.

Accepts model-layout tensors (B, S, H, D) (as produced by the attention
blocks), pads sequence lengths to block multiples, transposes to the kernel
layout (B, H, S, D), and dispatches. ``interpret=True`` runs the kernel body
in Python on CPU (used by every test in this container); on a real TPU the
same call lowers to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 256,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D) -> (B, Sq, H, D).

    interpret=None auto-selects: Mosaic on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _flash_attention(q, k, v, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def _flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                     causal: bool = True, window: int = 0,
                     block_q: int = 128, block_k: int = 256,
                     interpret: bool = False) -> jnp.ndarray:
    b, sq, h, d = q.shape
    sk = k.shape[1]
    qt = _pad_to(jnp.swapaxes(q, 1, 2), 2, block_q)
    kt = _pad_to(jnp.swapaxes(k, 1, 2), 2, block_k)
    vt = _pad_to(jnp.swapaxes(v, 1, 2), 2, block_k)
    out = flash_attention_kernel(
        qt, kt, vt, causal=causal, window=window,
        block_q=min(block_q, qt.shape[2]), block_k=min(block_k, kt.shape[2]),
        seq_q_valid=sq, seq_k_valid=sk, interpret=interpret)
    return jnp.swapaxes(out[:, :, :sq], 1, 2)
