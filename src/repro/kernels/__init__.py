"""Pallas TPU kernels for the substrate's compute hot-spots.

The paper itself has no kernel-level contribution (DESIGN.md §6); these
kernels serve the assigned-architecture substrate:

  flash_attention   blockwise online-softmax attention (causal, GQA, window)
  wkv6              RWKV-6 data-dependent-decay recurrence, chunked
  fedavg            streaming weighted parameter average (paper's aggregation)

Each kernel package: ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper with padding/layout glue),
``ref.py`` (pure-jnp oracle used by the allclose test sweeps).
"""
