"""Public WKV-6 op: padding + dispatch glue around the Pallas kernel.

Padding steps use w=1, k=0: the state update becomes S <- 1*S + 0, an exact
no-op, so the padded tail never perturbs the carried state.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_kernel


def wkv6(r, k, v, w, u, state0: Optional[jnp.ndarray] = None, *,
         block_t: int = 64, interpret: Optional[bool] = None
         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r/k/v/w: (B, T, H, N); u: (H, N) -> (out (B,T,H,N), sT (B,H,N,N)).

    interpret=None auto-selects: Mosaic on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _wkv6(r, k, v, w, u, state0, block_t=block_t, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _wkv6(r, k, v, w, u, state0: Optional[jnp.ndarray] = None, *,
          block_t: int = 64, interpret: bool = False
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, t, h, n = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)
    bt = min(block_t, max(t, 8))
    pad = (-t) % bt
    if pad:
        zeros = jnp.zeros((b, pad, h, n), r.dtype)
        ones = jnp.ones((b, pad, h, n), w.dtype)
        r = jnp.concatenate([r, zeros], axis=1)
        k = jnp.concatenate([k, zeros], axis=1)
        v = jnp.concatenate([v, zeros], axis=1)
        w = jnp.concatenate([w, ones], axis=1)
    out, sT = wkv6_kernel(r, k, v, w, u, state0, block_t=bt,
                          interpret=interpret)
    return out[:, :t], sT
