"""Pure-jnp oracle for the WKV-6 kernel (same math as models/rwkv6.py)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, state0: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r/k/v/w: (B, T, H, N); u: (H, N); state0: (B, H, N, N) or None."""
    b, t, h, n = r.shape
    if state0 is None:
        state0 = jnp.zeros((b, h, n, n), jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhn,bhnm->bhm", rt,
                         S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (r, k, v, w))
    S, outs = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), S
