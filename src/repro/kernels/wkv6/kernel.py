"""RWKV-6 WKV recurrence as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §6): the original CUDA kernel assigns one thread
per channel and keeps state in registers/shared memory. Here each grid cell
(batch b, head h) keeps its (N x N) state in VMEM **scratch that persists
across the sequential time-chunk grid axis**, so the state never round-trips
to HBM during the scan; r/k/v/w stream through VMEM one (bt x N) chunk at a
time. The per-step update is VPU work on (N, N) tiles (N = head_dim, 64 for
rwkv6-1.6b — one fp32 (8,128)-lane tile pair).

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                 state_scr, *, block_t: int):
    tc = pl.program_id(2)
    ntc = pl.num_programs(2)

    @pl.when(tc == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    rr = r_ref[0, :, 0, :].astype(jnp.float32)      # (bt, N)
    kk = k_ref[0, :, 0, :].astype(jnp.float32)
    vv = v_ref[0, :, 0, :].astype(jnp.float32)
    ww = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)                # (N,)
    S = state_scr[...]

    def step(i, carry):
        S, out = carry
        rt = jax.lax.dynamic_slice_in_dim(rr, i, 1, 0)    # (1, N)
        kt = jax.lax.dynamic_slice_in_dim(kk, i, 1, 0)
        vt = jax.lax.dynamic_slice_in_dim(vv, i, 1, 0)
        wt = jax.lax.dynamic_slice_in_dim(ww, i, 1, 0)
        kv = kt.T * vt                                     # (N, N) outer
        ot = rt @ (S + u[:, None] * kv)                    # (1, N)
        S = wt.T * S + kv
        out = jax.lax.dynamic_update_slice_in_dim(out, ot, i, 0)
        return S, out

    S, out = jax.lax.fori_loop(0, block_t, step,
                               (S, jnp.zeros((block_t, rr.shape[1]),
                                             jnp.float32)))
    state_scr[...] = S
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)

    @pl.when(tc == ntc - 1)
    def _finish():
        sT_ref[0, 0] = state_scr[...].astype(sT_ref.dtype)


def wkv6_kernel(r, k, v, w, u, state0, *, block_t: int = 64,
                interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r/k/v/w: (B, T, H, N) fp32; u: (H, N); state0: (B, H, N, N).

    T must be a multiple of block_t (ops.py pads with w=1, k=0 steps which
    are exact no-ops on the state). Returns (out (B,T,H,N), sT (B,H,N,N)).
    """
    b, t, h, n = r.shape
    assert t % block_t == 0, (t, block_t)
    grid = (b, h, t // block_t)
    io_spec = pl.BlockSpec((1, block_t, 1, n),
                           lambda b_, h_, tc: (b_, tc, h_, 0))
    kernel = functools.partial(_wkv6_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[io_spec, io_spec, io_spec, io_spec,
                  pl.BlockSpec((1, n), lambda b_, h_, tc: (h_, 0)),
                  pl.BlockSpec((1, 1, n, n), lambda b_, h_, tc: (b_, h_, 0, 0))],
        out_specs=[io_spec,
                   pl.BlockSpec((1, 1, n, n),
                                lambda b_, h_, tc: (b_, h_, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, t, h, n), r.dtype),
                   jax.ShapeDtypeStruct((b, h, n, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state0)
