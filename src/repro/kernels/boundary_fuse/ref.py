"""Pure-JAX reference for the fused boundary stage.

One traversal computing exactly what the unfused
``CodecBoundaryStage`` -> ``GaussianBoundaryStage`` chain computes over a
flattened ``(B, N)`` boundary tensor:

    q      = qdq(x)                      # codec quantize/dequantize
    norms  = ||q_b||_2                   # per example
    out    = q * min(1, C/norms) + noise_scale * noise

The qdq and clip formulas are copied operation-for-operation from
``fed/transport.FP16Codec`` / ``Int8Codec`` and
``core/split.GaussianBoundaryStage`` so the fused stage is bit-equal to
the composed stages in fp32 (pinned in tests/test_pipeline.py); the
Pallas kernel (kernel.py) pins against THIS function.  Noise is a
precomputed input (same ``jax.random.normal`` draw the unfused stage
makes), never in-kernel PRNG.
"""
from __future__ import annotations

import jax.numpy as jnp

NORM_EPS = 1e-12      # shared with kernels/dp_clip: all-zero-example guard

CODECS = ("none", "fp16", "int8")


def codec_qdq(x: jnp.ndarray, codec: str) -> jnp.ndarray:
    """Elementwise quantize/dequantize, matching fed/transport codecs
    bit-for-bit on fp32 input (int8 amax is over the whole tensor — one
    boundary tensor is one codec leaf)."""
    if codec in ("none", "identity", ""):
        return x
    if codec == "fp16":
        return x.astype(jnp.float16).astype(x.dtype)
    if codec == "int8":
        amax = jnp.max(jnp.abs(x))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        return jnp.clip(jnp.round(x / scale), -127, 127) * scale
    raise ValueError(f"unknown fusable codec {codec!r} "
                     f"(expected one of {CODECS})")


def fused_boundary_ref(x: jnp.ndarray, clip, noise_scale,
                       noise: jnp.ndarray, *, codec: str = "none"
                       ) -> jnp.ndarray:
    """x: (B, N) f32; noise: (B, N) f32.  -> (B, N) f32."""
    x = x.astype(jnp.float32)
    q = codec_qdq(x, codec)
    norms = jnp.linalg.norm(q, axis=1)
    scale = jnp.minimum(1.0, clip / jnp.maximum(norms, NORM_EPS))
    return q * scale[:, None] + noise_scale * noise
