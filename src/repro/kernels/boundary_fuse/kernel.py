"""Fused boundary-crossing kernel: codec qdq + DP clip + Gaussian noise.

Every tensor that crosses a split boundary under a composed
``codec+dp`` stage pays three separate traversals today (quantize
round-trip, per-example clip, noise add — see ``core/split.
CodecBoundaryStage`` / ``GaussianBoundaryStage``).  This kernel fuses
them into one streaming pass over the flattened ``(B, N)`` tensor,
patterned on the two-phase ``kernels/dp_clip`` grid:

  * ``int8`` only — phase 0 streams tiles accumulating the global
    ``amax`` into a persistent (1, 1) VMEM scratch (the quantization
    scale needs the whole tensor, like the clip norm does);
  * phase P-2 streams tiles through the qdq and accumulates per-example
    partial squared norms into a (B, 1) VMEM scratch;
  * phase P-1 re-streams each tile, re-applies the qdq (recompute is
    cheaper than a round-trip to HBM), scales by the per-example clip
    factor and adds the precomputed noise tile.

So ``fp16``/``none`` compositions run a 2-phase grid (2 reads + 1 write
per element, the dp_clip floor) and ``int8`` a 3-phase grid (3 reads +
1 write).  Noise is an input tile, not in-kernel PRNG, so the kernel is
a deterministic function of its inputs and pins against ref.py in
interpret mode.  Top-k is NOT fusable: its selection threshold is a
global order statistic, not a streaming reduction — composed stages
keep handling it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.boundary_fuse.ref import CODECS, NORM_EPS


def _make_fuse_kernel(codec: str, num_phases: int):
    def kernel(x_ref, scal_ref, noise_ref, o_ref, norm_scr, amax_scr):
        phase = pl.program_id(0)
        j = pl.program_id(1)

        @pl.when(jnp.logical_and(phase == 0, j == 0))
        def _init():
            norm_scr[...] = jnp.zeros_like(norm_scr)
            amax_scr[...] = jnp.zeros_like(amax_scr)

        x = x_ref[...].astype(jnp.float32)               # (B, bn)

        if codec == "int8":
            @pl.when(phase == 0)
            def _amax():
                amax_scr[0, 0] = jnp.maximum(amax_scr[0, 0],
                                             jnp.max(jnp.abs(x)))
                o_ref[...] = jnp.zeros_like(o_ref)       # placeholder flush

        def qdq():
            if codec == "fp16":
                return x.astype(jnp.float16).astype(jnp.float32)
            if codec == "int8":
                amax = amax_scr[0, 0]
                s = jnp.where(amax > 0, amax / 127.0, 1.0)
                return jnp.clip(jnp.round(x / s), -127.0, 127.0) * s
            return x

        @pl.when(phase == num_phases - 2)
        def _accumulate_norms():
            q = qdq()
            norm_scr[...] += jnp.sum(q * q, axis=1, keepdims=True)
            o_ref[...] = jnp.zeros_like(o_ref)           # placeholder flush

        @pl.when(phase == num_phases - 1)
        def _emit():
            q = qdq()
            clip = scal_ref[0, 0]
            noise_scale = scal_ref[0, 1]
            norms = jnp.sqrt(norm_scr[...])              # (B, 1)
            scale = jnp.minimum(1.0, clip / jnp.maximum(norms, NORM_EPS))
            o_ref[...] = (q * scale
                          + noise_scale * noise_ref[...]).astype(o_ref.dtype)

    return kernel


def boundary_fuse_kernel(x: jnp.ndarray, clip, noise_scale,
                         noise: jnp.ndarray, *, codec: str = "none",
                         block_n: int = 2048,
                         interpret: bool = False) -> jnp.ndarray:
    """x: (B, N) flattened boundary tensor; noise: (B, N).  -> (B, N) f32.

    Arbitrary N: zero-padded to a block_n multiple (padded lanes add 0
    to every norm and to the amax, and emit 0 * noise_scale) and sliced
    back.  ``clip``/``noise_scale`` ride in one (1, 2) scalar tile.
    """
    if codec not in CODECS:
        raise ValueError(f"unknown fusable codec {codec!r}")
    b, n = x.shape
    block_n = min(block_n, max(n, 1))
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        noise = jnp.pad(noise, ((0, 0), (0, pad)))
    n_padded = n + pad
    scal = jnp.stack([jnp.asarray(clip, jnp.float32).reshape(()),
                      jnp.asarray(noise_scale, jnp.float32).reshape(())]
                     ).reshape(1, 2)
    num_phases = 3 if codec == "int8" else 2
    out = pl.pallas_call(
        _make_fuse_kernel(codec, num_phases),
        grid=(num_phases, n_padded // block_n),
        in_specs=[pl.BlockSpec((b, block_n), lambda i, j: (0, j)),
                  pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
                  pl.BlockSpec((b, block_n), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((b, block_n), lambda i, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_padded), jnp.float32),
        scratch_shapes=[pltpu.VMEM((b, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), scal, noise.astype(jnp.float32))
    return out[:, :n] if pad else out
