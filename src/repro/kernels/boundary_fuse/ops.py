"""Public fused-boundary op: the single entry point
``core/split.FusedBoundaryStage`` calls per crossing.

``use_kernel`` selects the Pallas kernel (TPU; ``interpret=True`` on
CPU) vs the single-traversal pure-JAX reference — the reference is the
default off-TPU path, mirroring ``kernels/dp_clip.ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.boundary_fuse.kernel import boundary_fuse_kernel
from repro.kernels.boundary_fuse.ref import fused_boundary_ref


@functools.partial(jax.jit,
                   static_argnames=("codec", "use_kernel", "interpret"))
def fused_boundary_flat(x: jnp.ndarray, clip, noise_scale,
                        noise: jnp.ndarray, *, codec: str = "none",
                        use_kernel: bool = False,
                        interpret: bool = False) -> jnp.ndarray:
    """x: (B, N) flattened boundary tensor -> (B, N) f32 staged release
    (codec qdq, per-example clip to ``clip``, plus
    ``noise_scale * noise``)."""
    if use_kernel:
        return boundary_fuse_kernel(x, clip, noise_scale, noise,
                                    codec=codec, interpret=interpret)
    return fused_boundary_ref(x, clip, noise_scale, noise, codec=codec)
