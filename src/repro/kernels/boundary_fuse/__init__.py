from repro.kernels.boundary_fuse.ops import fused_boundary_flat  # noqa: F401
from repro.kernels.boundary_fuse.ref import fused_boundary_ref  # noqa: F401
