"""Oracle for the fedavg kernel."""
import jax.numpy as jnp


def fedavg_ref(stacked: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """stacked: (C, N); weights: (C,) summing to 1 -> (N,)."""
    return jnp.sum(stacked.astype(jnp.float32) * weights[:, None], axis=0
                   ).astype(stacked.dtype)
