"""Public fedavg op: pytree <-> flat glue around the Pallas kernel."""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.fedavg.kernel import fedavg_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def fedavg_flat(stacked: jnp.ndarray, weights: jnp.ndarray,
                interpret: bool = False) -> jnp.ndarray:
    """stacked: (C, N) -> (N,). The kernel pads N to its tile internally."""
    w = weights / jnp.sum(weights)
    return fedavg_kernel(stacked, w, interpret=interpret)


def fedavg_trees(trees: Sequence, weights: Optional[Sequence[float]] = None,
                 interpret: bool = False):
    """Kernel-backed FedAvg over a list of identical-structure pytrees."""
    if weights is None:
        weights = [1.0] * len(trees)
    w = jnp.asarray(weights, jnp.float32)
    flats, treedef = zip(*[jax.tree.flatten(t) for t in trees])
    treedef = jax.tree.structure(trees[0])
    out_leaves = []
    for leaves in zip(*flats):
        shape, dtype = leaves[0].shape, leaves[0].dtype
        stacked = jnp.stack([l.reshape(-1).astype(jnp.float32)
                             for l in leaves])
        avg = fedavg_flat(stacked, w, interpret=interpret)
        out_leaves.append(avg.reshape(shape).astype(dtype))
    return jax.tree.unflatten(treedef, out_leaves)
