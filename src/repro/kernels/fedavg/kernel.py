"""Streaming weighted parameter average as a Pallas kernel.

The paper's aggregation step (FedAvg over client discriminator params) is
trivially memory-bound: out[n] = sum_c w[c] * params[c, n]. The kernel
streams (C, bn) tiles through VMEM and does the reduction on the VPU —
one HBM read per element, the roofline floor for this op. It exists to give
the paper's own aggregation an explicit, measured kernel (DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fedavg_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (C, bn)
    w = w_ref[...].astype(jnp.float32)          # (C, 1)
    o_ref[...] = jnp.sum(x * w, axis=0, keepdims=True).astype(o_ref.dtype)


def fedavg_kernel(stacked: jnp.ndarray, weights: jnp.ndarray, *,
                  block_n: int = 4096, interpret: bool = False) -> jnp.ndarray:
    """stacked: (C, N) client-major flat params; weights: (C,), sums to 1.

    Arbitrary N: the array is zero-padded up to a block_n multiple (real
    flattened param counts are never tile-aligned) and the result sliced
    back; padded lanes average zeros, which is wasted VPU work bounded by
    one tile.
    """
    c, n = stacked.shape
    block_n = min(block_n, max(n, 1))
    pad = (-n) % block_n
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    n_padded = n + pad
    w2 = weights.reshape(c, 1)
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(n_padded // block_n,),
        in_specs=[pl.BlockSpec((c, block_n), lambda i: (0, i)),
                  pl.BlockSpec((c, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_padded), stacked.dtype),
        interpret=interpret,
    )(stacked, w2)[0]
    return out[:n] if pad else out
