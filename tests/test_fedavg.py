"""FedAvg aggregation properties (host + property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.fedavg import fedavg


def _tree(seed, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return {"w": scale * jax.random.normal(k, (4, 5)),
            "b": {"x": scale * jax.random.normal(jax.random.fold_in(k, 1),
                                                 (3,))}}


def test_fedavg_equal_weights_is_mean():
    trees = [_tree(i) for i in range(4)]
    avg = fedavg(trees)
    want = jax.tree.map(lambda *xs: sum(xs) / 4, *trees)
    for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(ws=st.lists(st.floats(min_value=0.01, max_value=100.0),
                   min_size=2, max_size=6))
def test_fedavg_weighted_properties(ws):
    trees = [_tree(i) for i in range(len(ws))]
    avg = fedavg(trees, ws)
    # convexity: avg within [min, max] elementwise
    stacked = np.stack([np.asarray(t["w"]) for t in trees])
    a = np.asarray(avg["w"])
    assert (a >= stacked.min(0) - 1e-5).all()
    assert (a <= stacked.max(0) + 1e-5).all()
    # scale invariance of weights
    avg2 = fedavg(trees, [w * 7.5 for w in ws])
    np.testing.assert_allclose(np.asarray(avg2["w"]), a, atol=1e-5)


def test_fedavg_idempotent_on_identical_clients():
    t = _tree(0)
    avg = fedavg([t, t, t], [1, 2, 3])
    for a, b in zip(jax.tree.leaves(avg), jax.tree.leaves(t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_fedavg_structure_mismatch_raises():
    with pytest.raises(ValueError):
        fedavg([{"a": jnp.ones(3)}, {"b": jnp.ones(3)}])


def test_fedavg_dominant_weight_limits():
    t0, t1 = _tree(0), _tree(1)
    avg = fedavg([t0, t1], [1e6, 1e-6])
    np.testing.assert_allclose(np.asarray(avg["w"]), np.asarray(t0["w"]),
                               atol=1e-4)
