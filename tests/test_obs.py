"""Flight recorder (obs/): tracing, metrics, record+replay, profiling.

Pinned invariants (ISSUE 6 acceptance):
  * spans nest and stay monotone on the engine's virtual clock, including
    across engine rebuilds (the tracer re-anchors with a virtual offset);
  * the exported trace is valid Chrome-trace JSON with a span for every
    boundary crossing of a split round;
  * a recorded run's feedback JSONL replayed offline through the PR-5
    controller fold reproduces the live knob sequence BIT-EXACTLY;
  * observability off is the default and a run with obs on is bit-exact
    with the same run with obs off (measurement never steers);
  * kernel profiling is gated off by default (a probe, not training).
"""
import json
import math
import os

import pytest

from repro.configs.registry import get_config
from repro.control import ControlKnobs, knobs_from_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist
from repro.obs import (FlightRecorder, JsonlSink, MetricsRegistry, Tracer,
                       feedback_from_dict, feedback_to_dict, knobs_from_dict,
                       knobs_to_dict, load_jsonl, load_run, replay_decisions,
                       replay_run, validate_chrome_trace)


def _cfg(**over):
    base = {"shape.global_batch": 8, "fsl.num_clients": 2,
            "model.dcgan.base_filters": 8}
    base.update(over)
    return get_config("dcgan-mnist").override(base)


@pytest.fixture(scope="module")
def parts():
    imgs, labels = synthetic_mnist(120, seed=0)
    return partition_dirichlet(imgs, labels, 2, alpha=0.5, seed=0)


@pytest.fixture(scope="module")
def recorded_run(tmp_path_factory, parts):
    """One adaptive split run recorded end-to-end; shared by the replay,
    trace-schema, and span tests below."""
    out = str(tmp_path_factory.mktemp("obs"))
    cfg = _cfg(**{
        "split.enabled": True,
        "control.mode": "adaptive",
        "control.controllers": ["codec", "deadline"],
        "obs.enabled": True, "obs.out_dir": out, "obs.run_id": "pin"})
    tr = FSLGANTrainer(cfg, parts, seed=0)
    for _ in range(3):
        tr.train_epoch(batches_per_client=2)
    tr.recorder.flush()
    return tr, os.path.join(out, "pin")


# ---------------------------------------------------------------------------
# tracer unit behavior
# ---------------------------------------------------------------------------

def test_tracer_spans_nest_and_record_parents():
    tr = Tracer("t")
    with tr.span("outer", cat="round"):
        with tr.span("inner", cat="client"):
            pass
    outer = next(s for s in tr.spans if s.name == "outer")
    inner = next(s for s in tr.spans if s.name == "inner")
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    # wall-clock containment: the inner span closed first
    assert outer.wall_start <= inner.wall_start
    assert inner.wall_end <= outer.wall_end


def test_tracer_virtual_offset_keeps_clock_monotone():
    """The engine's virtual clock resets to 0 on rebuild; the tracer's
    offset re-anchors so recorded spans never go backwards."""
    tr = Tracer("t")
    tr.record("round 0", cat="round", track="server", v_start=0.0, v_end=5.0)
    assert tr.last_virtual_end() == 5.0
    tr.set_virtual_offset(tr.last_virtual_end())
    tr.record("round 1", cat="round", track="server", v_start=0.0, v_end=5.0)
    rounds = sorted(tr.by_cat("round"), key=lambda s: s.v_start)
    assert [(s.v_start, s.v_end) for s in rounds] == [(0.0, 5.0), (5.0, 10.0)]


def test_chrome_trace_export_is_schema_valid(tmp_path):
    tr = Tracer("t")
    parent = tr.record("round 0", cat="round", track="server",
                       v_start=0.0, v_end=2.0,
                       args={"bad": float("nan"), "ok": 1})
    tr.record("up c0", cat="uplink", track="c0", v_start=1.0, v_end=2.0,
              parent=parent)
    obj = tr.to_chrome("virtual")
    assert validate_chrome_trace(obj) == 2
    # non-finite args are stringified so the export stays strict JSON
    x = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert all(isinstance(e["args"]["bad"], str) for e in x
               if "bad" in e.get("args", {}))
    path = tmp_path / "trace.json"
    tr.export_chrome(str(path))
    with open(path) as f:
        assert validate_chrome_trace(json.load(f)) == 2


def test_validate_chrome_trace_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [
            {"name": "x", "ph": "X", "pid": 1, "tid": 1,
             "ts": float("nan"), "dur": 1.0}]})


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_registry_types_and_conflicts():
    reg = MetricsRegistry()
    c = reg.counter("wire.up_bytes")
    c.inc(10)
    c.inc(5)
    assert c.value == 15
    with pytest.raises(ValueError):
        c.inc(-1)
    reg.gauge("fed.round_time_s").set(2.5)
    h = reg.histogram("fed.client_finish_s")
    for v in (1.0, 2.0, 4.0):
        h.observe(v)
    assert h.count == 3 and h.mean == pytest.approx(7.0 / 3.0)
    assert h.quantile(0.0) <= h.quantile(1.0)
    with pytest.raises(TypeError):
        reg.gauge("wire.up_bytes")      # registered as a counter
    snap = reg.snapshot()
    assert snap["wire.up_bytes"]["value"] == 15
    assert "fed.client_finish_s" in reg


def test_jsonl_sink_round_trips(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with JsonlSink(path) as sink:
        sink.write({"a": 1})
        sink.write({"b": [1.5, 2.5]})
    rows = load_jsonl(path)
    assert rows == [{"a": 1}, {"b": [1.5, 2.5]}]


# ---------------------------------------------------------------------------
# record + replay
# ---------------------------------------------------------------------------

def test_knobs_serialization_round_trips_bit_exactly():
    cfg = _cfg(**{"split.enabled": True})
    k = knobs_from_config(cfg)
    k2 = k.replace(codec="int8", deadline_s=12.345678901234567,
                   stage_by_boundary={0: "dp", 1: "int8"})
    back = knobs_from_dict(json.loads(json.dumps(knobs_to_dict(k2))))
    assert back == k2                   # frozen dataclass, bit-exact floats
    assert all(isinstance(b, int) for b in back.stage_by_boundary)


def test_feedback_serialization_round_trips(recorded_run):
    tr, run_dir = recorded_run
    for fb in tr.feedback:
        d = json.loads(json.dumps(feedback_to_dict(fb)))
        back = feedback_from_dict(d)
        # NaN != NaN breaks equality; compare the serialized text forms
        assert (json.dumps(feedback_to_dict(back), sort_keys=True)
                == json.dumps(feedback_to_dict(fb), sort_keys=True))
        assert back.round_index == fb.round_index
        assert back.client_finish_s == fb.client_finish_s


def test_recorded_run_writes_all_artifacts(recorded_run):
    _, run_dir = recorded_run
    for name in ("manifest.json", "feedback.jsonl", "knobs.jsonl",
                 "metrics.jsonl", "trace.json"):
        assert os.path.exists(os.path.join(run_dir, name)), name
    rec = load_run(run_dir)
    assert rec.num_rounds == 3
    assert len(rec.knobs) == 3
    assert rec.manifest["config"]["control"]["mode"] == "adaptive"


def test_replay_reproduces_live_knob_decisions_bit_exactly(recorded_run):
    """ISSUE 6 acceptance pin: the recorded RoundFeedback JSONL replayed
    offline through the PR-5 controllers reproduces the live knob
    sequence bit-exactly."""
    tr, run_dir = recorded_run
    res = replay_run(run_dir)
    assert res.matches, res.diff()
    assert len(res.decisions) == 3
    # the offline decisions ARE the recorded ControlKnobs, field for field
    for dec, rec in zip(res.decisions, load_run(run_dir).knobs):
        assert dec == rec


def test_replay_decisions_is_the_controller_fold(recorded_run):
    """decision_r = suite(history[:r], decision_{r-1}) with decision_{-1}
    = knobs_from_config — the exact fold the trainer applies live."""
    tr, run_dir = recorded_run
    from repro.obs.replay import suite_from_manifest
    rec = load_run(run_dir)
    suite = suite_from_manifest(rec.manifest)
    decisions = replay_decisions(suite, rec.feedback,
                                 knobs_from_config(tr.cfg))
    assert decisions == rec.knobs


def test_replay_requires_manifest(tmp_path):
    with pytest.raises(FileNotFoundError):
        replay_run(str(tmp_path / "nope"))


# ---------------------------------------------------------------------------
# engine spans
# ---------------------------------------------------------------------------

def test_split_round_traces_every_boundary_crossing(recorded_run):
    tr, _ = recorded_run
    spans = tr.recorder.tracer.spans
    cats = {s.cat for s in spans}
    assert {"round", "downlink", "client", "batch", "segment", "boundary",
            "uplink", "aggregate"} <= cats
    # every LAN boundary of every traced batch appears fwd AND bwd
    hops = [s for s in spans if s.cat == "boundary"]
    batches = [s for s in spans if s.cat == "batch"]
    crossings_per_batch = {}
    for cid, ex in tr.split_execs.items():
        crossings_per_batch[cid] = 2 * ex.num_boundaries
    expect = sum(crossings_per_batch[s.track] for s in batches)
    assert expect == 0 or len(hops) == expect
    for h in hops:
        assert {"boundary", "direction"} <= set(h.args)


def test_spans_nest_on_the_virtual_clock(recorded_run):
    tr, _ = recorded_run
    tracer = tr.recorder.tracer
    tol = 1e-6
    for s in tracer.spans:
        if s.parent_id is None or not s.has_virtual:
            continue
        p = tracer.by_id(s.parent_id)
        if p is None or not p.has_virtual:
            continue
        assert p.v_start - tol <= s.v_start, (p.name, s.name)
        assert s.v_end <= p.v_end + tol, (p.name, s.name)


def test_round_spans_monotone_across_epochs(recorded_run):
    tr, _ = recorded_run
    rounds = sorted(tr.recorder.tracer.by_cat("round"),
                    key=lambda s: s.v_start)
    assert len(rounds) == 3
    for a, b in zip(rounds, rounds[1:]):
        assert a.v_end <= b.v_start + 1e-9
    # the trace clock is the feedback clock
    assert rounds[-1].v_end == pytest.approx(tr.feedback[-1].clock_s)


def test_async_engine_emits_spans(tmp_path, parts):
    cfg = _cfg(**{"fed.mode": "fedasync", "obs.enabled": True,
                  "obs.out_dir": str(tmp_path), "obs.run_id": "a"})
    tr = FSLGANTrainer(cfg, parts, seed=0)
    tr.train_epoch(batches_per_client=2)
    cats = {s.cat for s in tr.recorder.tracer.spans}
    assert {"round", "downlink", "client", "uplink", "aggregate"} <= cats
    tr.recorder.flush()
    with open(os.path.join(str(tmp_path), "a", "trace.json")) as f:
        assert validate_chrome_trace(json.load(f)) > 0


# ---------------------------------------------------------------------------
# obs never steers
# ---------------------------------------------------------------------------

def test_obs_on_is_bit_exact_with_obs_off(tmp_path, parts):
    losses = {}
    for on in (False, True):
        over = {"split.enabled": True}
        if on:
            over.update({"obs.enabled": True, "obs.out_dir": str(tmp_path),
                         "obs.run_id": "x"})
        tr = FSLGANTrainer(_cfg(**over), parts, seed=0)
        hist = []
        for _ in range(2):
            m = tr.train_epoch(batches_per_client=2)
            hist.append((m["d_loss"], m["g_loss"], m["round_time_s"]))
        losses[on] = hist
    assert losses[False] == losses[True]


def test_profiling_gated_off_by_default(recorded_run):
    _, run_dir = recorded_run
    assert not os.path.exists(os.path.join(run_dir, "profile.json"))


def test_profiling_writes_roofline_terms_when_enabled(tmp_path, parts):
    cfg = _cfg(**{"obs.enabled": True, "obs.out_dir": str(tmp_path),
                  "obs.run_id": "p", "obs.profile_kernels": True})
    tr = FSLGANTrainer(cfg, parts, seed=0)
    tr.train_epoch(batches_per_client=1)
    with open(os.path.join(str(tmp_path), "p", "profile.json")) as f:
        prof = json.load(f)
    names = list(prof)
    assert any(n.startswith("fedavg") for n in names)
    for p in prof.values():
        assert p["compile_s"] > 0 and p["run_s"] > 0
        assert p["flops"] >= 0 and p["compute_term_s"] >= 0


# ---------------------------------------------------------------------------
# flush idempotence (ISSUE 7 satellite: the _obs.py double-flush path)
# ---------------------------------------------------------------------------

def test_flush_is_idempotent(recorded_run):
    """A second flush with no new spans must not re-export the trace —
    finish() flushing and its caller flushing again costs one export."""
    tr, run_dir = recorded_run
    rec = tr.recorder
    path = rec.flush()
    assert path == os.path.join(run_dir, "trace.json")
    mtime = os.path.getmtime(path)
    with open(path) as f:
        before = f.read()
    os.utime(path, (mtime - 10, mtime - 10))     # make any rewrite visible
    assert rec.flush() == path                   # cached path, no export
    assert os.path.getmtime(path) == pytest.approx(mtime - 10)
    with open(path) as f:
        assert f.read() == before
    # new spans re-arm the export
    rec.tracer.record("probe", cat="round", track="server",
                      v_start=0.0, v_end=0.0)
    assert rec.flush() == path
    assert os.path.getmtime(path) > mtime - 10


# ---------------------------------------------------------------------------
# digests: artifact-level bit-exactness pins (ISSUE 7 satellite)
# ---------------------------------------------------------------------------

def _state_digests(tr):
    """Recompute the committed-state digest sequence a recorder would have
    written for this trainer's CURRENT state (single round boundary)."""
    from repro.obs import state_digest
    st = tr.state
    cid0 = tr._active_clients()[0]
    return state_digest(st.d_params[cid0], st.d_opt, st.g_params,
                        st.g_opt, round_index=st.step - 1)


def test_recorded_run_writes_digests_and_alert_sink(recorded_run):
    _, run_dir = recorded_run
    rec = load_run(run_dir)
    assert len(rec.digests) == 3
    assert [d.round_index for d in rec.digests] == [0, 1, 2]
    for d in rec.digests:
        assert len(d.global_digest) == 32 and len(d.opt_digest) == 32
        assert not d.rolled_back
        assert d.global_sketch[0] > 0            # L2 of a real tree
    # the committed digest equals the engine's as-aggregated digest in a
    # healthy run (no health action ever touched the tree)
    for d in rec.digests:
        assert d.aggregated_digest == d.global_digest


def test_digests_obs_on_matches_obs_off_state(tmp_path, parts):
    """obs-on == obs-off, at the artifact level: the digests a recorded
    run persists equal digests recomputed from an identical run that
    never recorded anything."""
    cfg_on = _cfg(**{"obs.enabled": True, "obs.out_dir": str(tmp_path),
                     "obs.run_id": "don"})
    tr_on = FSLGANTrainer(cfg_on, parts, seed=0)
    tr_off = FSLGANTrainer(_cfg(), parts, seed=0)
    off_digests = []
    for _ in range(2):
        tr_on.train_epoch(batches_per_client=2)
        tr_off.train_epoch(batches_per_client=2)
        off_digests.append(_state_digests(tr_off))
    rec = load_run(os.path.join(str(tmp_path), "don"))
    assert [d.global_digest for d in rec.digests] \
        == [d.global_digest for d in off_digests]
    assert [d.opt_digest for d in rec.digests] \
        == [d.opt_digest for d in off_digests]
    assert [d.gan_digest for d in rec.digests] \
        == [d.gan_digest for d in off_digests]


def test_digests_loop_vs_vectorized_backend(tmp_path, parts):
    """Cross-backend digest stability: loop and vectorized dispatch are a
    TOLERANCE pin (different XLA programs, ~1e-5 fp32 drift — same bound
    as the in-memory pin in test_fed_runtime), so their digest *sketches*
    must agree tightly while diff.py classifies the digest mismatch as
    numeric divergence at equal knobs."""
    import numpy as np
    from repro.obs import diff_runs
    dirs = {}
    for backend in ("loop", "vectorized"):
        cfg = _cfg(**{"fed.backend": backend, "obs.enabled": True,
                      "obs.out_dir": str(tmp_path),
                      "obs.run_id": f"b_{backend}"})
        tr = FSLGANTrainer(cfg, parts, seed=0)
        for _ in range(2):
            tr.train_epoch(batches_per_client=2)
        dirs[backend] = os.path.join(str(tmp_path), f"b_{backend}")
    ra = load_run(dirs["loop"])
    rb = load_run(dirs["vectorized"])
    for da, db in zip(ra.digests, rb.digests):
        np.testing.assert_allclose(da.global_sketch[:3], db.global_sketch[:3],
                                   rtol=1e-4, atol=1e-5)
        assert da.global_sketch[3] == db.global_sketch[3]   # leaf counts
    d = diff_runs(dirs["loop"], dirs["vectorized"])
    fd = d.first_divergence
    assert fd is not None and fd.kind == "numeric"
    assert fd.field.startswith("digest.")
    # the knobs never diverged — no controller-kind entries at all
    assert not any(e.kind == "controller" for e in d.entries)


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_obs_section_validates_names_at_construction():
    from repro.config import ObsConfig
    with pytest.raises(ValueError):
        ObsConfig(trace_clock="sundial")
    with pytest.raises(ValueError):
        ObsConfig(sinks=("trace", "punchcard"))
    cfg = _cfg(**{"obs.enabled": True, "obs.sinks": ["trace"]})
    assert cfg.obs.sinks == ("trace",)
    assert cfg.to_dict()["obs"]["enabled"] is True
