"""Spec-tree/param-tree structural consistency for ALL assigned archs at
FULL size (eval_shape — no allocation), plus frontend stubs.

This is the cheap version of the dry-run's hardest failure mode: a param
tree and its logical-axis spec tree drifting apart.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED_ARCHS, get_config
from repro.models.frontends import audio_frame_embeddings, vlm_interleave
from repro.models.transformer import (decode_state_shapes,
                                      decode_state_specs, lm_param_shapes,
                                      lm_specs)
from repro.sharding.specs import Lg, is_lg


def _structure(tree, is_leaf=None):
    return jax.tree.structure(
        jax.tree.map(lambda x: 0, tree, is_leaf=is_leaf))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_match_param_shapes_full_size(arch):
    cfg = get_config(arch)
    shapes = lm_param_shapes(cfg.model)
    specs = lm_specs(cfg.model)
    assert _structure(shapes) == _structure(specs, is_leaf=is_lg), arch
    # every spec leaf has the same rank as its parameter
    flat_p = jax.tree.leaves(shapes)
    flat_s = jax.tree.leaves(specs, is_leaf=is_lg)
    for p, s in zip(flat_p, flat_s):
        assert len(s) == len(p.shape), (arch, p.shape, tuple(s))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_state_specs_match_shapes(arch):
    cfg = get_config(arch)
    shapes = decode_state_shapes(cfg.model, 4, 128)
    specs = decode_state_specs(cfg.model)
    assert _structure(shapes) == _structure(specs, is_leaf=is_lg), arch
    for p, s in zip(jax.tree.leaves(shapes),
                    jax.tree.leaves(specs, is_leaf=is_lg)):
        assert len(s) == len(p.shape), (arch, p.shape, tuple(s))


def test_vlm_interleave_properties():
    cfg = get_config("chameleon-34b")
    m = cfg.model
    toks, mask = vlm_interleave(jax.random.PRNGKey(0), 4, 512, m,
                                image_span=64)
    assert toks.shape == (4, 512) and mask.shape == (4, 512)
    assert int(toks.max()) < m.vocab_size and int(toks.min()) >= 0
    text_hi = int(m.vocab_size * 0.75)
    # image-span tokens come from the VQ range, text tokens below it
    assert bool((jnp.where(mask, toks, text_hi) >= text_hi).all())
    assert bool((jnp.where(mask, 0, toks) < text_hi).all())
    assert int(mask.sum(1)[0]) == 64


def test_audio_frontend_shape():
    cfg = get_config("whisper-base")
    e = audio_frame_embeddings(jax.random.PRNGKey(0), 3, cfg.model)
    assert e.shape == (3, cfg.model.encdec.encoder_seq, cfg.model.d_model)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_param_shapes_match_analytic_count(arch):
    """eval_shape param totals vs the analytic param_count() (±8%)."""
    cfg = get_config(arch)
    shapes = lm_param_shapes(cfg.model)
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    analytic = cfg.model.param_count()
    assert abs(total - analytic) / analytic < 0.08, \
        f"{arch}: eval_shape {total/1e9:.2f}B vs analytic {analytic/1e9:.2f}B"
