"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fedavg.ops import fedavg_flat, fedavg_trees
from repro.kernels.fedavg.ref import fedavg_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# flash attention: sweep shapes / dtypes / gqa / window / padding
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (b, sq, sk, h, hkv, d, causal, window)
    (2, 128, 128, 4, 4, 64, True, 0),
    (1, 256, 256, 4, 2, 64, True, 0),      # GQA 2:1
    (2, 200, 200, 4, 1, 128, True, 0),     # MQA + unaligned seq (padding)
    (1, 256, 256, 2, 2, 64, True, 64),     # sliding window
    (1, 384, 384, 8, 8, 32, True, 0),      # small head_dim
    (1, 1, 384, 4, 2, 64, False, 0),       # single-query decode pattern
    (3, 64, 64, 2, 2, 64, True, 0),        # seq < block
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    b, sq, sk, h, hkv, d, causal, win = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), jnp.float32)
    out = flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), causal=causal, window=win,
                          interpret=True)
    ref = jnp.swapaxes(attention_ref(q, k, v, causal=causal, window=win),
                       1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, atol):
    b, s, h, d = 1, 128, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, h, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, d)).astype(dtype)
    out = flash_attention(q, k, v, interpret=True)
    ref = jnp.swapaxes(attention_ref(jnp.swapaxes(q, 1, 2),
                                     jnp.swapaxes(k, 1, 2),
                                     jnp.swapaxes(v, 1, 2)), 1, 2)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_flash_attention_block_shape_invariance():
    b, s, h, d = 1, 256, 2, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    o1 = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    o2 = flash_attention(q, k, v, block_q=128, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


# ---------------------------------------------------------------------------
# wkv6: sweep shapes / chunk sizes / state carry
# ---------------------------------------------------------------------------

WKV_CASES = [
    # (b, t, h, n, block_t)
    (2, 64, 2, 32, 16),
    (1, 100, 4, 64, 64),    # unaligned t (padding no-op property)
    (2, 17, 1, 16, 8),
    (1, 128, 2, 8, 32),
]


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv6_matches_ref(case):
    b, t, h, n, bt = case
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (b, t, h, n), jnp.float32)
               for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, h, n)) * 0.5))
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    s0 = jax.random.normal(KEY, (b, h, n, n)) * 0.1
    out, sT = wkv6(r, k, v, w, u, s0, block_t=bt, interpret=True)
    ref_out, ref_sT = wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(ref_sT), atol=1e-4)


def test_wkv6_state_chaining_equals_single_pass():
    """Running two halves with carried state == one full pass."""
    b, t, h, n = 1, 64, 2, 16
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, h, n)) * 0.5))
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    full, sT = wkv6(r, k, v, w, u, block_t=16, interpret=True)
    h1, s1 = wkv6(r[:, :32], k[:, :32], v[:, :32], w[:, :32], u,
                  block_t=16, interpret=True)
    h2, s2 = wkv6(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:], u, state0=s1,
                  block_t=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sT), atol=1e-4)


# ---------------------------------------------------------------------------
# fedavg
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,n", [(2, 4096), (5, 10000), (3, 100)])
def test_fedavg_kernel_matches_ref(c, n):
    st = jax.random.normal(KEY, (c, n))
    w = jnp.arange(1.0, c + 1)
    out = fedavg_flat(st, w, interpret=True)
    ref = fedavg_ref(st, w / w.sum())
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_fedavg_trees_matches_host_fedavg():
    from repro.core.fedavg import fedavg as favg
    trees = [{"a": jax.random.normal(jax.random.PRNGKey(i), (7, 13)),
              "b": jnp.full((3,), float(i))} for i in range(3)]
    got = fedavg_trees(trees, [1, 1, 2], interpret=True)
    want = favg(trees, [1, 1, 2])
    for g, w_ in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_), atol=1e-5)


def test_fedavg_identity_single_client():
    t = {"x": jnp.arange(10.0)}
    out = fedavg_trees([t], interpret=True)
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(t["x"]),
                               atol=1e-7)
