"""End-to-end behaviour: the whole pipeline wired together at smoke scale,
plus a 1-device mesh integration of the dry-run path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduce_for_smoke
from repro.configs.registry import get_config
from repro.data import synthetic_lm_batch
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import lm_init
from repro.optim import make_optimizer
from repro.runtime import make_decode_step, make_prefill_step, make_train_step


def test_lm_trains_on_synthetic_structure():
    """The synthetic token stream is learnable: loss drops toward structure."""
    cfg = reduce_for_smoke(get_config("rwkv6-1.6b", "train_4k"), seq_len=32,
                           batch=8)
    cfg = cfg.override({"optim.schedule": "constant", "optim.lr": 3e-3,
                        "optim.warmup_steps": 0})
    m = cfg.model
    params = lm_init(jax.random.PRNGKey(0), m)
    opt = make_optimizer(cfg.optim)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg))
    first = last = None
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic_lm_batch(8, 32, m.vocab_size, seed=i % 4).items()}
        params, opt_state, metrics = step(params, opt_state, batch,
                                          jnp.asarray(i, jnp.int32))
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 1.0, (first, last)


def test_serve_path_end_to_end():
    """prefill -> greedy decode through the runtime builders."""
    cfg = reduce_for_smoke(get_config("qwen3-14b", "decode_32k"), seq_len=32,
                           batch=2)
    cfg = cfg.override({"shape.seq_len": 32, "shape.mode": "decode",
                        "parallel.cache_dtype": "float32"})
    m = cfg.model
    params = lm_init(jax.random.PRNGKey(0), m)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, m.vocab_size)
    logits, state, idx = prefill(params, {"tokens": toks})
    assert logits.shape == (2, m.vocab_size)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = []
    for t in range(4):
        logits, state = decode(params, nxt, state,
                               jnp.asarray(int(idx) + t, jnp.int32))
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(np.asarray(nxt))
    assert all(o.shape == (2,) for o in outs)
    assert bool(jnp.isfinite(logits).all())


def test_dryrun_path_on_host_mesh():
    """The exact dry-run lowering path, on the host's 1-device mesh with a
    reduced config — catches sharding-spec/tree mismatches cheaply."""
    from repro.launch.dryrun import lower_one
    cfg = reduce_for_smoke(get_config("olmoe-1b-7b", "train_4k"), seq_len=32,
                           batch=4)
    mesh = make_host_mesh()
    lowered, compiled, secs = lower_one(cfg, mesh)
    from repro.roofline.analysis import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    assert float(ca.get("flops", 0)) > 0
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0


def test_dryrun_decode_path_on_host_mesh():
    from repro.launch.dryrun import lower_one
    cfg = reduce_for_smoke(get_config("rwkv6-1.6b", "decode_32k"), seq_len=64,
                           batch=2)
    cfg = cfg.override({"shape.mode": "decode", "shape.seq_len": 64})
    mesh = make_host_mesh()
    lowered, compiled, _ = lower_one(cfg, mesh)
    assert compiled is not None
