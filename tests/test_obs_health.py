"""Watchtower pins (ISSUE 7): health monitors + policy actions, cross-run
divergence diffing, and the bench regression gate.

The acceptance pins live here:

  * an injected NaN round under ``policy="rollback"`` restores the last
    healthy digest and training CONTINUES, with the alert in
    ``alerts.jsonl``; under ``abort`` the trainer raises; under ``record``
    the trajectory stays bit-exact with monitors off;
  * ``repro.obs.diff`` localizes a seeded one-knob divergence to the
    exact round and field, and classifies a seeded numeric perturbation
    as digest-divergence-at-equal-knobs;
  * ``repro.obs.regress`` passes on the unmodified tree and fails when a
    baseline metric is synthetically degraded.
"""
import copy
import json
import math
import os

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.control.feedback import RoundFeedback
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist
from repro.obs import (HealthAbort, HealthAlert, HealthMonitor, diff_runs,
                       load_run)
from repro.obs.health import SEV_FATAL, SEV_WARN, worst

BENCH_DIR = os.path.join(os.path.dirname(__file__), os.pardir, "benchmarks")


def _cfg(**over):
    return get_config("dcgan-mnist").override({
        "shape.global_batch": 8,
        "fsl.num_clients": 2,
        "model.dcgan.base_filters": 8,
        **over})


def _health_over(out, run_id, policy):
    return {"obs.enabled": True, "obs.out_dir": out, "obs.run_id": run_id,
            "obs.health.enabled": True, "obs.health.policy": policy}


def _poison(tr):
    """NaN the generator: the next round's fakes, D training, and the
    aggregated global D all go non-finite."""
    tr.state.g_params = jax.tree.map(lambda x: x * np.float32("nan"),
                                     tr.state.g_params)


@pytest.fixture(scope="module")
def parts():
    imgs, labels = synthetic_mnist(120, seed=0)
    return partition_dirichlet(imgs, labels, 2, alpha=0.5, seed=0)


# ---------------------------------------------------------------------------
# monitor unit behavior (no training loop)
# ---------------------------------------------------------------------------

def _mon(**over):
    from repro.config import HealthConfig
    return HealthMonitor(HealthConfig(enabled=True, **over))


def _fb(r, **over):
    base = dict(round_index=r, backend="loop", codec="none", sigma=0.0,
                deadline_s=0.0, split_strategy="sorted_multi",
                up_bytes=1000, down_bytes=1000, lan_bytes=0,
                codec_error=float("nan"), uplink_bps=1e6,
                round_time_s=1.0, clock_s=float(r + 1),
                num_clients=2, stragglers=0, d_loss=0.5, g_loss=0.5)
    base.update(over)
    return RoundFeedback(**base)


def test_monitor_flags_nonfinite_params():
    mon = _mon()
    bad = {"w": np.array([1.0, np.nan, np.inf], np.float32)}
    alerts = mon.check_round(_fb(0), params=bad)
    a = worst(alerts)
    assert a is not None and a.check == "nonfinite_params"
    assert a.severity == SEV_FATAL and a.recoverable
    assert a.value == 2.0                        # one NaN + one Inf


def test_monitor_nan_loss_is_unmeasured_until_seen_finite():
    mon = _mon()
    # round 0: both losses NaN = never measured -> silent
    assert mon.check_round(_fb(0, d_loss=float("nan"),
                               g_loss=float("nan"))) == []
    # round 1: d_loss goes live
    assert mon.check_round(_fb(1, g_loss=float("nan"))) == []
    # round 2: a live signal going NaN IS an alert; g_loss stays silent
    alerts = mon.check_round(_fb(2, d_loss=float("nan"),
                                 g_loss=float("nan")))
    assert [a.check for a in alerts] == ["nonfinite_loss"]
    assert "d_loss" in alerts[0].message
    # Inf always flags, even on a fresh monitor
    fresh = _mon()
    alerts = fresh.check_round(_fb(0, d_loss=float("inf")))
    assert any(a.check == "nonfinite_loss" for a in alerts)


def test_monitor_loss_ratio_window():
    mon = _mon(loss_ratio_max=50.0)
    assert mon.check_round(_fb(0, d_loss=2.0, g_loss=1.0)) == []
    for d, g in ((100.0, 1.0), (1.0, 100.0)):    # both directions trip
        alerts = _mon(loss_ratio_max=50.0).check_round(
            _fb(0, d_loss=d, g_loss=g))
        assert [a.check for a in alerts] == ["loss_ratio"]
        assert alerts[0].severity == SEV_WARN
        assert alerts[0].value == pytest.approx(100.0)


def test_monitor_update_norm_spike_needs_history():
    mon = _mon(window=4, min_history=2, update_norm_factor=10.0)
    base = {"w": np.zeros(4, np.float32)}
    small = {"w": np.full(4, 0.01, np.float32)}
    big = {"w": np.full(4, 5.0, np.float32)}
    for r in range(3):                           # build the window quietly
        assert mon.check_round(_fb(r), params=small, update_base=base) == []
    alerts = mon.check_round(_fb(3), params=big, update_base=base)
    assert [a.check for a in alerts] == ["update_norm"]
    assert alerts[0].value > alerts[0].threshold


def test_monitor_codec_error_spike():
    mon = _mon(window=4, min_history=2, codec_error_factor=10.0)
    assert mon.check_round(_fb(0, codec_error=1.0)) == []
    assert mon.check_round(_fb(1, codec_error=1.0)) == []
    alerts = mon.check_round(_fb(2, codec_error=50.0))
    assert [a.check for a in alerts] == ["codec_error_spike"]


def test_monitor_epsilon_overspend_is_fatal_nonrecoverable():
    mon = _mon(epsilon_budget=1.0)
    assert mon.check_round(_fb(0, dp_epsilon=0.5)) == []
    alerts = mon.check_round(_fb(1, dp_epsilon=2.0))
    assert [a.check for a in alerts] == ["epsilon_overspend"]
    assert alerts[0].severity == SEV_FATAL and not alerts[0].recoverable
    # budget 0 (default) disables the check entirely
    assert _mon().check_round(_fb(0, dp_epsilon=2.0)) == []


def test_monitor_straggler_runaway_needs_full_hot_window():
    mon = _mon(window=3, min_history=2, straggler_rate_max=0.5)
    hot = dict(num_clients=2, stragglers=2)
    assert mon.check_round(_fb(0, **hot)) == []
    assert mon.check_round(_fb(1, **hot)) == []
    alerts = mon.check_round(_fb(2, **hot))
    assert [a.check for a in alerts] == ["straggler_runaway"]
    # one cool round resets the streak
    assert mon.check_round(_fb(3, num_clients=2, stragglers=0)) == []
    assert mon.check_round(_fb(4, **hot)) == []


def test_alert_roundtrips_through_dicts():
    from repro.obs import alert_from_dict, alert_to_dict
    a = HealthAlert(3, "nonfinite_params", SEV_FATAL, 7.0, 0.0, "boom",
                    recoverable=False)
    assert alert_from_dict(json.loads(json.dumps(alert_to_dict(a)))) == a


# ---------------------------------------------------------------------------
# injected-fault policy pins (the trainer acting on alerts)
# ---------------------------------------------------------------------------

def test_rollback_restores_last_healthy_digest(tmp_path, parts):
    """THE graceful-degradation pin: poison round 1, train on — the
    committed state snaps back to round 0's digest and round 2 recovers."""
    cfg = _cfg(**_health_over(str(tmp_path), "rb", "rollback"))
    tr = FSLGANTrainer(cfg, parts, seed=0)
    m0 = tr.train_epoch(batches_per_client=2)
    assert math.isfinite(m0["d_loss"])
    _poison(tr)
    m1 = tr.train_epoch(batches_per_client=2)     # detected + rolled back
    assert not math.isfinite(m1["d_loss"])        # the round itself was lost
    m2 = tr.train_epoch(batches_per_client=2)     # ...but training recovered
    assert math.isfinite(m2["d_loss"])

    rec = load_run(os.path.join(str(tmp_path), "rb"))
    d0, d1, d2 = rec.digests
    assert d1.rolled_back and not d0.rolled_back and not d2.rolled_back
    # committed state == last healthy state, while the engine-stamped
    # as-aggregated digest keeps what the poisoned round actually produced
    assert d1.global_digest == d0.global_digest
    assert d1.opt_digest == d0.opt_digest
    assert d1.gan_digest == d0.gan_digest
    assert d1.aggregated_digest not in ("", d1.global_digest)
    # round 2 moved on from the restored state
    assert d2.global_digest != d1.global_digest
    # the alert trail persisted to alerts.jsonl
    assert any(a.check == "nonfinite_params" and a.round_index == 1
               and a.severity == SEV_FATAL and a.recoverable
               for a in rec.alerts)


def test_abort_policy_raises_after_recording(tmp_path, parts):
    cfg = _cfg(**_health_over(str(tmp_path), "ab", "abort"))
    tr = FSLGANTrainer(cfg, parts, seed=0)
    tr.train_epoch(batches_per_client=2)
    _poison(tr)
    with pytest.raises(HealthAbort) as exc:
        tr.train_epoch(batches_per_client=2)
    assert exc.value.alert.severity == SEV_FATAL
    assert exc.value.alert.round_index == 1
    # the aborting round still left a complete artifact trail
    rec = load_run(os.path.join(str(tmp_path), "ab"))
    assert rec.num_rounds == 2
    assert len(rec.digests) == 2 and not rec.digests[1].rolled_back
    assert any(a.severity == SEV_FATAL and a.round_index == 1
               for a in rec.alerts)


def test_warn_policy_warns_and_trains_on(tmp_path, parts):
    cfg = _cfg(**_health_over(str(tmp_path), "wn", "warn"))
    tr = FSLGANTrainer(cfg, parts, seed=0)
    tr.train_epoch(batches_per_client=2)
    _poison(tr)
    with pytest.warns(RuntimeWarning, match="nonfinite"):
        tr.train_epoch(batches_per_client=2)
    rec = load_run(os.path.join(str(tmp_path), "wn"))
    # no rollback: the poisoned state really committed
    assert not rec.digests[1].rolled_back
    assert rec.digests[1].global_digest != rec.digests[0].global_digest


def test_record_policy_is_bit_exact_with_monitors_off(parts):
    """Monitors only read — the record policy's trajectory is identical
    to a run that never armed them."""
    tr_on = FSLGANTrainer(_cfg(**{"obs.health.enabled": True,
                                  "obs.health.policy": "record"}),
                          parts, seed=0)
    tr_off = FSLGANTrainer(_cfg(), parts, seed=0)
    for _ in range(2):
        m_on = tr_on.train_epoch(batches_per_client=2)
        m_off = tr_off.train_epoch(batches_per_client=2)
        assert (m_on["d_loss"], m_on["g_loss"], m_on["round_time_s"]) \
            == (m_off["d_loss"], m_off["g_loss"], m_off["round_time_s"])
    assert tr_on.health_alerts == []             # healthy run stays quiet


def test_record_policy_logs_without_acting(parts):
    tr = FSLGANTrainer(_cfg(**{"obs.health.enabled": True,
                               "obs.health.policy": "record"}),
                       parts, seed=0)
    tr.train_epoch(batches_per_client=2)
    _poison(tr)
    tr.train_epoch(batches_per_client=2)         # no raise, no rollback
    assert any(a.severity == SEV_FATAL for a in tr.health_alerts)


# ---------------------------------------------------------------------------
# diff: cross-run divergence localization pins
# ---------------------------------------------------------------------------

def _run(out, run_id, parts, n_rounds=2, perturb_after=None, **over):
    cfg = _cfg(**{"obs.enabled": True, "obs.out_dir": out,
                  "obs.run_id": run_id, **over})
    tr = FSLGANTrainer(cfg, parts, seed=0)
    for r in range(n_rounds):
        tr.train_epoch(batches_per_client=2)
        if perturb_after == r:
            tr.state.d_params = jax.tree.map(
                lambda x: x * np.float32(1.0 + 1e-3), tr.state.d_params)
    return os.path.join(out, run_id)


def test_diff_identical_runs(tmp_path, parts):
    da = _run(str(tmp_path), "a", parts)
    db = _run(str(tmp_path), "b", parts)
    d = diff_runs(da, db)
    assert d.identical and d.kind is None and d.first_divergence is None
    assert d.config_diffs == []                  # obs.* excluded by design
    assert d.replay_ok_a and d.replay_ok_b
    assert "identical" in d.report()


def test_diff_localizes_one_knob_divergence(tmp_path, parts):
    """Seeded single-knob difference -> exact round + field, controller
    kind, with the config diff named."""
    da = _run(str(tmp_path), "ka", parts)
    db = _run(str(tmp_path), "kb", parts, **{"fed.codec": "fp16"})
    d = diff_runs(da, db)
    fd = d.first_divergence
    assert fd is not None
    assert (fd.round_index, fd.field, fd.kind) == (0, "knobs.codec",
                                                   "controller")
    assert (fd.a, fd.b) == ("none", "fp16")
    assert ("fed.codec", "none", "fp16") in d.config_diffs
    # steering explains everything downstream: no entry is ever blamed
    # on numerics once the knobs split
    assert all(e.kind == "controller" for e in d.entries)
    # each side is still a pure function of its own history
    assert d.replay_ok_a and d.replay_ok_b


def test_diff_classifies_numeric_divergence_at_equal_knobs(tmp_path, parts):
    """Seeded in-memory perturbation between rounds -> digest divergence
    at EQUAL knobs, classified numeric at the exact round."""
    da = _run(str(tmp_path), "na", parts)
    db = _run(str(tmp_path), "nb", parts, perturb_after=0)
    d = diff_runs(da, db)
    fd = d.first_divergence
    assert fd is not None and fd.kind == "numeric"
    assert fd.round_index == 1                   # round 0 was identical
    assert fd.field.startswith("digest.")
    assert d.config_diffs == []
    assert not any(e.kind == "controller" for e in d.entries)
    # feedback fallout of the perturbed state is measurement, not cause
    assert {e.kind for e in d.entries} <= {"numeric", "measurement"}


def test_diff_cli_exit_codes(tmp_path, parts):
    from repro.obs.diff import main
    da = _run(str(tmp_path), "ca", parts, n_rounds=1)
    db = _run(str(tmp_path), "cb", parts, n_rounds=1,
              **{"fed.codec": "fp16"})
    assert main([da, da]) == 0
    assert main([da, db]) == 1


# ---------------------------------------------------------------------------
# regress: bench baseline gating pins
# ---------------------------------------------------------------------------

def _control_bench():
    with open(os.path.join(BENCH_DIR, "BENCH_control.json")) as f:
        return json.load(f)


def test_regress_rule_table_passes_on_unmodified_tree():
    from repro.obs.regress import RULES, run_gate
    checks = run_gate(BENCH_DIR)                 # self-compare
    assert checks and not any(c.failed for c in checks)
    assert {c.file for c in checks} == set(RULES)


def test_regress_fails_on_degraded_value_metric():
    from repro.obs.regress import RULES, evaluate, markdown_report
    base = _control_bench()
    fresh = copy.deepcopy(base)
    fresh["codec"]["adaptive"]["up_bytes"] *= 10     # 10x the wire bytes
    checks = evaluate(fresh, base, RULES["BENCH_control.json"],
                      file="BENCH_control.json")
    bad = [c for c in checks if c.failed]
    assert [c.path for c in bad] == ["codec/adaptive/up_bytes"]
    assert "REGRESSION" in markdown_report(checks)
    assert "**FAIL**" in markdown_report(checks)


def test_regress_fails_on_flipped_acceptance_gate():
    from repro.obs.regress import RULES, evaluate
    base = _control_bench()
    fresh = copy.deepcopy(base)
    fresh["codec"]["frontier_ok"] = False
    checks = evaluate(fresh, base, RULES["BENCH_control.json"],
                      file="BENCH_control.json")
    assert any(c.failed and c.path == "codec/frontier_ok" for c in checks)


def test_regress_config_gate_skips_values_keeps_booleans():
    """Different bench shape -> the numbers are incomparable (skip), but
    acceptance booleans must hold at any size (still fail)."""
    from repro.obs.regress import RULES, evaluate
    base = _control_bench()
    fresh = copy.deepcopy(base)
    fresh["config"] = {"different": "shape"}
    fresh["codec"]["adaptive"]["up_bytes"] *= 10     # would fail...
    fresh["codec"]["frontier_ok"] = False
    checks = evaluate(fresh, base, RULES["BENCH_control.json"],
                      file="BENCH_control.json")
    by_path = {c.path: c for c in checks}
    assert by_path["codec/adaptive/up_bytes"].status == "skip"
    assert by_path["codec/frontier_ok"].failed


def test_regress_missing_boolean_gate_is_a_regression():
    from repro.obs.regress import RULES, evaluate
    base = _control_bench()
    fresh = copy.deepcopy(base)
    del fresh["codec"]["frontier_ok"]            # deleting the gate fails it
    checks = evaluate(fresh, base, RULES["BENCH_control.json"],
                      file="BENCH_control.json")
    gate = next(c for c in checks if c.path == "codec/frontier_ok")
    assert gate.failed and "absent" in gate.note


def test_regress_noisy_tolerance_is_overridable():
    from repro.obs.regress import Rule, evaluate
    base = {"dispatch": {"loop_us": 100.0}}
    fresh = {"dispatch": {"loop_us": 250.0}}     # 2.5x slower
    rules = (Rule("dispatch/*_us", "lower", 1.0, noisy=True),)
    assert any(c.failed for c in evaluate(fresh, base, rules))
    assert not any(c.failed for c in evaluate(fresh, base, rules,
                                              noisy_rel_tol=3.0))


def test_regress_cli(tmp_path):
    from repro.obs.regress import main
    assert main(["--bench-dir", str(tmp_path)]) == 2     # nothing to gate
    report = str(tmp_path / "report.md")
    assert main(["--bench-dir", BENCH_DIR, "--report", report]) == 0
    with open(report) as f:
        text = f.read()
    assert text.startswith("# Bench regression report")
    assert "**PASS**" in text
