"""Population-scale rounds: client-axis sharding specs + mesh helpers,
two-tier hierarchical aggregation, the lazy Roster, the scanned pipeline
loop, and the deterministic (round, cohort, client) key chain.

Pinned invariants:
  * sharding/specs: the `clients` logical axis shards only when the client
    count divides the mesh (drop-to-replicate policy), and
    stacked_shardings mirrors the tree structure exactly;
  * launch/mesh: host/client mesh shapes, mesh_chips;
  * a hierarchical round's aggregate == the flat FedAvg engine's to fp32
    tolerance at equal knobs — with the WAN uplink cut by >= the cohort
    fan-in factor (the ISSUE 9 acceptance pin; multi-shard variant runs
    whenever >= 4 simulated devices exist);
  * Roster resampling is reproducible, cohort-consistent, and its
    subsampled epsilon beats full participation;
  * SplitExecution.pipeline_scan == the unrolled micro-batch loop at
    K in {2, 4}, with and without a stochastic boundary stage.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist
from repro.fed.hierarchy import (CohortReduction, HierarchicalAggregator,
                                 assign_cohorts)
from repro.fed.programs import RoundExecutor, fedavg_stacked, stack_trees
from repro.fed.roster import Roster
from repro.launch.mesh import make_client_mesh, make_host_mesh, mesh_chips
from repro.sharding.specs import (client_axis_rules, logical_spec,
                                  stacked_shardings, tree_shardings, Lg)

_MULTI = len(jax.devices()) >= 4


# ---------------------------------------------------------------------------
# sharding/specs: client-axis rules (device-free via AbstractMesh)
# ---------------------------------------------------------------------------

def _amesh(n=4):
    return AbstractMesh((("clients", n),))


def test_client_axis_shards_only_when_divisible():
    m = _amesh(4)
    rules = client_axis_rules(m)
    assert logical_spec(m, rules, (8, 3), ("clients", None)) == P("clients")
    # 6 % 4 != 0 -> the whole dim replicates instead of failing
    assert logical_spec(m, rules, (6, 3), ("clients", None)) == P()
    # dim exactly the mesh size shards; 1 (fewer clients than shards) can't
    assert logical_spec(m, rules, (4,), ("clients",)) == P("clients")
    assert logical_spec(m, rules, (1, 5), ("clients", None)) == P()


def test_client_axis_rules_fall_back_without_clients_axis():
    m = AbstractMesh((("data", 2),))
    rules = client_axis_rules(m)
    assert rules.mesh_axes_for("clients") is None
    assert logical_spec(m, rules, (8,), ("clients",)) == P()


def test_stacked_shardings_mirror_tree_structure():
    m = _amesh(4)
    tree = {"w": jnp.zeros((8, 3, 3)), "b": {"x": jnp.zeros((8,))}}
    sh = stacked_shardings(m, tree)
    assert jax.tree.structure(sh) == jax.tree.structure(tree)
    assert sh["w"].spec == P("clients")
    assert sh["b"]["x"].spec == P("clients")
    ragged = {"w": jnp.zeros((6, 3))}
    assert stacked_shardings(m, ragged)["w"].spec == P()


def test_tree_shardings_rejects_structure_mismatch():
    m = _amesh(2)
    rules = client_axis_rules(m)
    tree = {"a": jnp.zeros((2, 2))}
    bad_spec = {"a": Lg("clients", None), "extra": Lg(None)}
    with pytest.raises(ValueError, match="mismatch"):
        tree_shardings(m, rules, tree, bad_spec)


# ---------------------------------------------------------------------------
# launch/mesh
# ---------------------------------------------------------------------------

def test_host_mesh_covers_all_devices():
    m = make_host_mesh()
    assert m.axis_names == ("data", "model")
    assert mesh_chips(m) == len(jax.devices())


def test_client_mesh_shape_and_cap():
    m = make_client_mesh()
    assert m.axis_names == ("clients",)
    assert mesh_chips(m) == len(jax.devices())
    assert mesh_chips(make_client_mesh(max_devices=1)) == 1


# ---------------------------------------------------------------------------
# hierarchy: weighted-mean-of-weighted-means == flat FedAvg
# ---------------------------------------------------------------------------

def _tree(v, seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": v + 0.1 * jax.random.normal(k, (4, 3)),
            "b": jnp.full((2,), float(v))}


def test_assign_cohorts_contiguous_and_balanced():
    got = assign_cohorts([f"c{i}" for i in range(7)], 3)
    assert got == {0: ["c0", "c1", "c2"], 1: ["c3", "c4", "c5"],
                   2: ["c6"]}
    # explicit assigner wins over contiguous slicing
    got = assign_cohorts(["a", "b", "c"], 2, cohort_of=lambda c: c == "b")
    assert got == {0: ["a", "c"], 1: ["b"]}


@pytest.mark.parametrize("use_kernel", [False, True])
def test_two_tier_reduce_matches_flat_fedavg(use_kernel):
    ups = {f"c{i}": (_tree(float(i), seed=i), 1.0 + i) for i in range(6)}
    h = HierarchicalAggregator(2, use_kernel=use_kernel, interpret=True)
    reds = h.reduce_all(ups)
    assert [r.cohort for r in reds] == [0, 1]
    assert sum(len(r.members) for r in reds) == 6
    flat = fedavg_stacked(stack_trees([u[0] for u in ups.values()]),
                          [u[1] for u in ups.values()])
    two = fedavg_stacked(stack_trees([r.aggregate for r in reds]),
                         [r.weight for r in reds])
    for a, b in zip(jax.tree.leaves(flat), jax.tree.leaves(two)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# engine: hierarchical round pins the flat engine (acceptance)
# ---------------------------------------------------------------------------

def _cfg(**over):
    base = {"shape.global_batch": 8, "fsl.num_clients": 4,
            "model.dcgan.base_filters": 8}
    base.update(over)
    return get_config("dcgan-mnist").override(base)


@pytest.fixture(scope="module")
def parts():
    imgs, labels = synthetic_mnist(200, seed=0)
    return partition_dirichlet(imgs, labels, 4, alpha=0.5, seed=0)


def _dead_bias(path) -> bool:
    keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    return (len(keys) == 2 and keys[1] == "b"
            and str(keys[0]).startswith("conv") and keys[0] != "conv0")


def _assert_live_params_close(ta, tb, tol=5e-6):
    cid = next(iter(ta.state.d_params))
    fa, _ = jax.tree_util.tree_flatten_with_path(ta.state.d_params[cid])
    fb, _ = jax.tree_util.tree_flatten_with_path(tb.state.d_params[cid])
    for (pa, a), (_, b) in zip(fa, fb):
        if _dead_bias(pa):
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol)


def _run_flat_vs_hier(parts, **hier_over):
    tf_ = FSLGANTrainer(_cfg(), parts, seed=0)
    mf = tf_.train_epoch(batches_per_client=2, backend="vectorized")
    over = {"fed.hierarchy_cohorts": 2}
    over.update(hier_over)
    th = FSLGANTrainer(_cfg(**over), parts, seed=0)
    mh = th.train_epoch(batches_per_client=2, backend="vectorized")
    return tf_, mf, th, mh


def test_hierarchical_round_pins_flat_engine(parts):
    tf_, mf, th, mh = _run_flat_vs_hier(parts)
    assert mf["num_clients"] == mh["num_clients"]
    assert abs(mf["d_loss"] - mh["d_loss"]) < 1e-5
    _assert_live_params_close(tf_, th)
    # WAN uplink cut by >= the cohort fan-in factor (4 clients / 2
    # cohorts): only one pre-reduced tree per cohort crossed the WAN
    fan_in = 4 / 2
    assert tf_.engine.ledger.total_up >= fan_in * th.engine.ledger.total_up
    # the client->edge hop carries what the WAN no longer does
    assert th.engine.ledger.total_edge == tf_.engine.ledger.total_up
    fb = th.feedback[-1]
    assert fb.cohorts == 2 and fb.edge_bytes > 0
    assert all(k.startswith("cohort")
               for k, v in th.engine.ledger.up_bytes.items() if v)


@pytest.mark.skipif(not _MULTI, reason="needs >= 4 simulated devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
def test_sharded_hierarchical_round_pins_flat_engine(parts):
    tf_, mf, th, mh = _run_flat_vs_hier(parts,
                                        **{"fed.shard_clients": True})
    assert abs(mf["d_loss"] - mh["d_loss"]) < 1e-5
    _assert_live_params_close(tf_, th)
    assert th.feedback[-1].shards == len(jax.devices())


@pytest.mark.skipif(not _MULTI, reason="needs >= 4 simulated devices")
def test_sharded_round_places_stacked_inputs_on_clients_mesh():
    mesh = make_client_mesh()
    tree = {"w": jnp.zeros((8, 3)), "b": jnp.zeros((8,))}
    sh = stacked_shardings(mesh, tree)
    placed = jax.device_put(tree, sh)
    assert len(placed["w"].sharding.device_set) == len(jax.devices())
    # non-divisible stack replicates rather than failing (6 % 4)
    ragged = jax.device_put(jnp.zeros((6, 3)),
                            stacked_shardings(mesh, jnp.zeros((6, 3))))
    assert ragged.sharding.is_fully_replicated


def test_hierarchical_round_emits_cohort_spans(parts):
    from repro.obs.trace import Tracer
    th = FSLGANTrainer(_cfg(**{"fed.hierarchy_cohorts": 2}), parts, seed=0)
    th._ensure_engine(2)
    tr = Tracer("t")
    th.engine.set_tracer(tr)
    th.train_epoch(batches_per_client=2, backend="vectorized")
    cohort_spans = [s for s in tr.spans if s.cat == "cohort"]
    assert len(cohort_spans) == 2
    rnd = next(s for s in tr.spans if s.cat == "round")
    assert all(s.parent_id == rnd.span_id for s in cohort_spans)
    assert all(s.args["wan_bytes"] > 0 for s in cohort_spans)


# ---------------------------------------------------------------------------
# roster: deterministic sampling, amplification, analytic pricing
# ---------------------------------------------------------------------------

def test_roster_resampling_reproducible_and_cohort_consistent():
    r = Roster(10_000, participants=16, cohorts=4, seed=3)
    s1, s2 = r.sample_round(7), r.sample_round(7)
    assert s1 == s2
    assert len(set(s1.client_ids)) == 16
    assert s1.client_ids != r.sample_round(8).client_ids
    for cid, c in zip(s1.client_ids, s1.cohorts):
        lo, hi = r.cohort_range(c)
        assert lo <= cid < hi
        assert r.cohort_of(cid) == c


def test_roster_key_chain_varies_each_component():
    r = Roster(1000, participants=8, cohorts=2, seed=0)
    base = r.client_key(1, 0, 42)
    for other in (r.client_key(2, 0, 42), r.client_key(1, 1, 42),
                  r.client_key(1, 0, 43)):
        assert not np.array_equal(np.asarray(base), np.asarray(other))
    np.testing.assert_array_equal(np.asarray(base),
                                  np.asarray(r.client_key(1, 0, 42)))


def test_roster_large_population_samples_lazily():
    r = Roster(1_000_000, participants=64, cohorts=8, seed=1)
    s = r.sample_round(0)
    assert len(set(s.client_ids)) == 64
    assert s == r.sample_round(0)
    assert r.sample_rate == 64 / 1_000_000


def test_roster_subsampling_amplifies_epsilon():
    r = Roster(100_000, participants=100, cohorts=4, seed=0)
    amplified = r.amplified_epsilon(1.1, rounds=50)
    full = Roster(100, participants=100, seed=0).amplified_epsilon(
        1.1, rounds=50)
    assert amplified < full / 10
    acct = r.accountant(1.1)
    acct.step(50)
    assert abs(acct.epsilon(1e-5)[0] - amplified) < 1e-9


def test_roster_analytic_pricing_monotone():
    r = Roster(10_000, participants=32, cohorts=4, seed=0)
    # the sync barrier's order-statistic quantile grows with more
    # participants, and hierarchy trades WAN bytes for an edge hop
    bigger = Roster(10_000, participants=256, cohorts=4, seed=0)
    assert bigger.barrier_compute_s() > r.barrier_compute_s()
    nb = 1 << 20
    assert r.wan_bytes_per_round(nb) == 32 * nb
    assert r.wan_bytes_per_round(nb, hierarchical=True) == 4 * nb
    assert r.wan_bytes_per_round(nb) \
        >= (32 / 4) * r.wan_bytes_per_round(nb, hierarchical=True)
    specs = r.specs_for_round(3)
    assert len(specs) == 32
    assert all(s.compute_time_s > 0 for s in specs)
    assert all(r.cohort_of_cid(s.client_id) == c
               for s, c in zip(specs, r.sample_round(3).cohorts))


# ---------------------------------------------------------------------------
# executor key chain: (round, cohort, client, execution)
# ---------------------------------------------------------------------------

def _executor(cohort_of=None, key=0):
    return RoundExecutor(
        program=None, backend="loop", sample=lambda cid, s: (None, None),
        opt_lookup=lambda cid: None, default_steps=1,
        round_key=jax.random.PRNGKey(key), cohort_of=cohort_of)


def test_executor_keys_deterministic_and_cohort_aware():
    a = _executor(cohort_of=lambda cid: 1)
    b = _executor(cohort_of=lambda cid: 1)
    np.testing.assert_array_equal(np.asarray(a._key_for("c0")),
                                  np.asarray(b._key_for("c0")))
    # a different cohort (or none) derives a different stream
    c = _executor(cohort_of=lambda cid: 2)
    d = _executor(cohort_of=None)
    k = _executor(cohort_of=lambda cid: 1)._key_for("c0")
    for other in (c._key_for("c0"), d._key_for("c0")):
        assert not np.array_equal(np.asarray(k), np.asarray(other))
    # re-execution (async cycles) advances the exec index
    e = _executor(cohort_of=lambda cid: 1)
    assert not np.array_equal(np.asarray(e._key_for("c0")),
                              np.asarray(e._key_for("c0")))


# ---------------------------------------------------------------------------
# scanned pipeline loop (split.pipeline_scan)
# ---------------------------------------------------------------------------

def _split_fixture(pipeline_microbatches, pipeline_scan, stage=None):
    from repro.config import DCGANConfig
    from repro.core.devices import Client, Device
    from repro.core.gan import bce_logits
    from repro.core.selection import make_plan
    from repro.core.split import SplitExecution
    from repro.models.dcgan import (disc_apply_layer, disc_layer_costs,
                                    disc_layer_names)
    c = DCGANConfig(base_filters=4)
    costs = disc_layer_costs(c)
    layers = [(n, costs[n]) for n in disc_layer_names(c)]
    plan = make_plan(Client("c0", [Device("d0", 1.0, 2),
                                   Device("d1", 0.5, 2)]),
                     layers, "sorted_multi", 3)
    tails = (functools.partial(bce_logits, target=1.0),
             functools.partial(bce_logits, target=0.0))
    ex = SplitExecution(plan, functools.partial(disc_apply_layer, c=c),
                        tails, stage=stage,
                        pipeline_microbatches=pipeline_microbatches,
                        pipeline_scan=pipeline_scan)
    return ex, c


@pytest.mark.parametrize("k", [2, 4])
@pytest.mark.parametrize("dp", [False, True])
def test_pipeline_scan_pins_unrolled_loop(k, dp):
    from repro.core.split import GaussianBoundaryStage
    from repro.models.dcgan import disc_init
    stage = GaussianBoundaryStage(1.0, 0.1) if dp else None
    loop, c = _split_fixture(k, False, stage=stage)
    scan, _ = _split_fixture(k, True, stage=stage)
    params = disc_init(jax.random.PRNGKey(0), c)
    kk = jax.random.PRNGKey(7)
    real = jax.random.normal(jax.random.fold_in(kk, 1), (8, 28, 28, 1))
    fake = jax.random.normal(jax.random.fold_in(kk, 2), (8, 28, 28, 1))
    ll, lg, _ = loop.run_pipelined(params, (real, fake),
                                   key=jax.random.PRNGKey(5))
    sl, sg, _ = scan.run_pipelined(params, (real, fake),
                                   key=jax.random.PRNGKey(5))
    np.testing.assert_allclose(float(ll), float(sl), atol=1e-5)
    for a, b in zip(jax.tree.leaves(lg), jax.tree.leaves(sg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # distinct compile cache slots: scanned XLA != unrolled XLA
    assert loop.signature != scan.signature


def test_pipeline_scan_collect_falls_back_to_loop():
    scan, c = _split_fixture(4, True)
    from repro.models.dcgan import disc_init
    params = disc_init(jax.random.PRNGKey(0), c)
    k = jax.random.PRNGKey(3)
    real = jax.random.normal(jax.random.fold_in(k, 1), (8, 28, 28, 1))
    fake = jax.random.normal(jax.random.fold_in(k, 2), (8, 28, 28, 1))
    _, _, recs = scan.run_pipelined(params, (real, fake), collect=True)
    # collect needs per-chunk records — the loop path serves them intact
    assert all(r is not None for r in recs["fwd"])
    assert recs["fwd"][0][0].shape[0] == 8


def test_pipeline_scan_k1_is_bitexact_run():
    scan, c = _split_fixture(1, True)
    from repro.models.dcgan import disc_init
    params = disc_init(jax.random.PRNGKey(0), c)
    k = jax.random.PRNGKey(3)
    real = jax.random.normal(jax.random.fold_in(k, 1), (4, 28, 28, 1))
    fake = jax.random.normal(jax.random.fold_in(k, 2), (4, 28, 28, 1))
    l1, g1, _ = scan.run_pipelined(params, (real, fake))
    l2, g2, _ = scan.run(params, (real, fake))
    assert float(l1) == float(l2)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
