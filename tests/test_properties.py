"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref
from repro.models.layers import attention_full, rmsnorm_apply, rmsnorm_init


@settings(max_examples=10, deadline=None)
@given(sq=st.integers(min_value=1, max_value=96),
       hkv=st.sampled_from([1, 2, 4]),
       groups=st.sampled_from([1, 2]),
       d=st.sampled_from([16, 32, 64]),
       window=st.sampled_from([0, 7]),
       seed=st.integers(min_value=0, max_value=100))
def test_flash_attention_random_shapes(sq, hkv, groups, d, window, seed):
    """Kernel == oracle over randomized shape/GQA/window combos."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    h = hkv * groups
    q = jax.random.normal(ks[0], (1, h, sq, d))
    k = jax.random.normal(ks[1], (1, hkv, sq, d))
    v = jax.random.normal(ks[2], (1, hkv, sq, d))
    out = flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), window=window,
                          block_q=32, block_k=32, interpret=True)
    ref = jnp.swapaxes(attention_ref(q, k, v, window=window), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(t=st.integers(min_value=1, max_value=80),
       n=st.sampled_from([8, 16, 32]),
       split=st.floats(min_value=0.1, max_value=0.9),
       seed=st.integers(min_value=0, max_value=50))
def test_wkv6_chunk_split_invariance(t, n, split, seed):
    """Any split point with carried state == the single pass (kernel)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    b, h = 1, 2
    r, k, v = (jax.random.normal(ks[i], (b, t, h, n)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, h, n)) * 0.5))
    u = 0.1 * jax.random.normal(ks[4], (h, n))
    full, sT = wkv6(r, k, v, w, u, block_t=16, interpret=True)
    cut = max(1, min(t - 1, int(t * split))) if t > 1 else 1
    if cut >= t:
        return
    h1, s1 = wkv6(r[:, :cut], k[:, :cut], v[:, :cut], w[:, :cut], u,
                  block_t=16, interpret=True)
    h2, s2 = wkv6(r[:, cut:], k[:, cut:], v[:, cut:], w[:, cut:], u,
                  state0=s1, block_t=16, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(sT), atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(scale=st.floats(min_value=0.1, max_value=100.0),
       seed=st.integers(min_value=0, max_value=50))
def test_rmsnorm_scale_invariance(scale, seed):
    """RMSNorm(c*x) ~= RMSNorm(x) for c where c^2 * mean(x^2) >> eps.

    (The invariance intentionally breaks for c -> 0 where eps dominates —
    that regime is excluded; eps=1e-6 vs mean(x^2)~1 at c>=0.1.)
    """
    p = rmsnorm_init(32)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 3, 32))
    a = rmsnorm_apply(p, x)
    b = rmsnorm_apply(p, x * scale)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-3, rtol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=100))
def test_attention_permutation_equivariance_over_batch(seed):
    """Permuting the batch permutes outputs identically (no cross-batch
    leakage — the privacy-adjacent invariant for federated batches)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    b, s, h, d = 4, 16, 2, 16
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    pos = jnp.arange(s)
    out = attention_full(q, k, v, pos, pos)
    perm = jnp.asarray([2, 0, 3, 1])
    out_p = attention_full(q[perm], k[perm], v[perm], pos, pos)
    np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p),
                               atol=1e-6)
