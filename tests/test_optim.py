"""Optimizers, schedules, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimConfig
from repro.optim import clip_by_global_norm, global_norm, make_optimizer
from repro.optim.schedule import make_schedule


def _minimize(opt, steps=200, lr=0.1):
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["x"] - jnp.asarray([1.0, 1.0])) ** 2)

    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(lr))
    return float(loss(params))


def test_adamw_converges_quadratic():
    opt = make_optimizer(OptimConfig(name="adam", lr=0.1))
    assert _minimize(opt) < 1e-3


def test_sgd_converges_quadratic():
    opt = make_optimizer(OptimConfig(name="sgd", beta1=0.9))
    assert _minimize(opt, lr=0.05) < 1e-3


def test_weight_decay_shrinks_params():
    p = {"x": jnp.asarray([10.0])}
    zero_g = {"x": jnp.zeros(1)}
    o_wd = make_optimizer(OptimConfig(name="adamw", weight_decay=0.1))
    s = o_wd.init(p)
    p2, _ = o_wd.update(zero_g, s, p, jnp.asarray(0.1))
    assert float(p2["x"][0]) < 10.0


def test_grad_clip_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
    small = {"a": jnp.full((4,), 0.01)}
    c2, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.01, rtol=1e-5)


def test_bf16_state_dtype():
    opt = make_optimizer(OptimConfig(name="adamw", state_dtype="bfloat16"))
    p = {"x": jnp.ones((4,), jnp.bfloat16)}
    s = opt.init(p)
    assert s["m"]["x"].dtype == jnp.bfloat16


@pytest.mark.parametrize("name", ["constant", "linear", "cosine"])
def test_schedules(name):
    sch = make_schedule(name, 1.0, warmup_steps=10, total_steps=100)
    # warmup ramps
    assert float(sch(0)) < float(sch(9)) or name == "constant" and True
    assert float(sch(9)) == pytest.approx(1.0, rel=0.15)
    if name != "constant":
        assert float(sch(99)) < float(sch(20))
    # never negative
    for s in [0, 10, 50, 99, 150]:
        assert float(sch(s)) >= 0.0
