"""Pipelined split execution: the 1F1B overlap schedule (core/pipeline),
micro-batched SplitExecution, the fused boundary kernel
(kernels/boundary_fuse), and the trainer wiring (auto backend, configured
LAN latency)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.config import DCGANConfig, SplitConfig
from repro.core.devices import Client, Device
from repro.core.gan import bce_logits, d_loss_fn
from repro.core.pipeline import (OverlapSchedule, effective_microbatches,
                                 overlap_schedule, schedule_for)
from repro.core.selection import make_plan
from repro.core.simulate import plan_epoch_time
from repro.core.split import (ComposedBoundaryStage, FusedBoundaryStage,
                              GaussianBoundaryStage, SplitExecution,
                              make_boundary_stage)
from repro.kernels.boundary_fuse.kernel import boundary_fuse_kernel
from repro.kernels.boundary_fuse.ref import CODECS, fused_boundary_ref
from repro.models.dcgan import (disc_apply_layer, disc_layer_costs,
                                disc_layer_names)

_C = DCGANConfig(base_filters=4)
_TAILS = (functools.partial(bce_logits, target=1.0),
          functools.partial(bce_logits, target=0.0))


def _client(caps, tfs):
    return Client("c0", [Device(f"d{i}", tf, cap)
                         for i, (cap, tf) in enumerate(zip(caps, tfs))])


def _exec_fixture(caps, tfs, strategy="sorted_multi", seed=3, stage=None,
                  stages=None, pipeline_microbatches=1):
    costs = disc_layer_costs(_C)
    layers = [(n, costs[n]) for n in disc_layer_names(_C)]
    plan = make_plan(_client(caps, tfs), layers, strategy, seed)
    return SplitExecution(plan, functools.partial(disc_apply_layer, c=_C),
                          _TAILS, stage=stage, stages=stages,
                          pipeline_microbatches=pipeline_microbatches)


def _batches(n=4, seed=0):
    k = jax.random.PRNGKey(seed)
    real = jax.random.normal(jax.random.fold_in(k, 1), (n, 28, 28, 1))
    fake = jax.random.normal(jax.random.fold_in(k, 2), (n, 28, 28, 1))
    return real, fake


# ---------------------------------------------------------------------------
# overlap schedule (core/pipeline)
# ---------------------------------------------------------------------------

def test_effective_microbatches_divisor_clamp():
    assert effective_microbatches(16, 4) == 4
    assert effective_microbatches(16, 5) == 4     # nearest divisor below
    assert effective_microbatches(16, 100) == 16
    assert effective_microbatches(6, 4) == 3
    assert effective_microbatches(7, 4) == 1      # prime batch
    assert effective_microbatches(1, 8) == 1      # per-example DP steps
    assert effective_microbatches(0, 8) == 1


def test_k1_schedule_is_additive_model_exactly():
    """Degenerate K=1 pin: the schedule's makespan IS the strictly
    additive per-batch time, bit for bit (same accumulation order)."""
    sched = overlap_schedule([0.3, 0.1, 0.2], [0.6, 0.2, 0.4],
                             num_microbatches=1,
                             hop_fwd_s=[0.05, 0.05], hop_bwd_s=[0.05, 0.05])
    assert sched.makespan == sched.sequential_s
    assert sched.speedup == 1.0


def test_overlap_schedule_shortens_multi_device_chain():
    sched = overlap_schedule([0.3, 0.1, 0.2], [0.6, 0.2, 0.4],
                             num_microbatches=4,
                             hop_fwd_s=[0.01, 0.01], hop_bwd_s=[0.01, 0.01])
    assert sched.makespan < sched.sequential_s
    assert sched.speedup > 1.0
    # conserved work: each segment computes its full fwd+bwd time
    np.testing.assert_allclose(sched.segment_work_s(),
                               [0.9, 0.3, 0.6], rtol=1e-12)


def test_overlap_schedule_respects_dependencies():
    """No micro-batch runs segment s before its segment s-1 + hop, and a
    device never runs two tasks at once."""
    sched = overlap_schedule([0.3, 0.1], [0.6, 0.2], num_microbatches=4,
                             hop_fwd_s=[0.02], hop_bwd_s=[0.02])
    fin = {(t.kind, t.microbatch, t.index): t
           for t in sched.tasks if t.kind in ("fwd", "bwd")}
    for (kind, m, si), t in fin.items():
        if kind == "fwd" and si > 0:
            assert t.t0 >= fin[("fwd", m, si - 1)].t1 + 0.02 - 1e-12
        if kind == "bwd":
            if si == sched.num_segments - 1:
                assert t.t0 >= fin[("fwd", m, si)].t1 - 1e-12
            else:
                assert t.t0 >= fin[("bwd", m, si + 1)].t1 + 0.02 - 1e-12
    for dev in sched.devices:
        spans = sorted((t.t0, t.t1) for t in sched.tasks
                       if t.kind in ("fwd", "bwd") and t.device == dev)
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert b0 >= a1 - 1e-12


@settings(max_examples=40, deadline=None)
@given(
    segs=st.lists(st.tuples(st.floats(0.01, 2.0), st.floats(0.01, 2.0)),
                  min_size=1, max_size=5),
    k=st.integers(min_value=1, max_value=8),
    hop=st.floats(0.0, 0.5),
)
def test_overlap_schedule_properties(segs, k, hop):
    """Property: for ANY chain, the overlapped makespan never exceeds the
    additive time, per-segment work is conserved, and K=1 is exact."""
    fwd = [f for f, _ in segs]
    bwd = [b for _, b in segs]
    hops = [hop] * (len(segs) - 1)
    sched = overlap_schedule(fwd, bwd, num_microbatches=k,
                             hop_fwd_s=hops, hop_bwd_s=hops)
    assert sched.makespan <= sched.sequential_s + 1e-9
    np.testing.assert_allclose(
        sched.segment_work_s(), [f + b for f, b in segs], rtol=1e-9)
    if k == 1:
        assert sched.makespan == sched.sequential_s


def test_schedule_for_prices_hop_bytes_per_microbatch():
    """Micro-batch hops pay full latency but 1/K of the serialization."""
    tf = {"d0": 1.0, "d1": 1.0}
    sched = schedule_for([2.0, 2.0], ["d0", "d1"], tf, num_microbatches=4,
                         lan_latency_s=0.01, hop_bytes=[1_000_000] * 2,
                         lan_bandwidth_bps=100e6)
    per_mb = 0.01 + 8.0 * 1_000_000 * 0.25 / 100e6
    full = 0.01 + 8.0 * 1_000_000 / 100e6
    assert sched.hop_fwd_s == (pytest.approx(per_mb),)
    assert sched.hop_fwd_full_s == (pytest.approx(full),)


# ---------------------------------------------------------------------------
# pipelined SplitExecution
# ---------------------------------------------------------------------------

def test_pipelined_k1_bitexact_sequential():
    """K=1 pin: run_pipelined IS run — same floats, bit for bit."""
    ex = _exec_fixture([2, 2], [1.0, 2.0])
    params = jax.tree.map(
        lambda s: jax.random.normal(jax.random.PRNGKey(1), s.shape),
        jax.eval_shape(lambda: __import__("repro.models.dcgan",
                                          fromlist=["disc_init"])
                       .disc_init(jax.random.PRNGKey(0), _C)))
    real, fake = _batches()
    sl, sg, _ = ex.run(params, (real, fake))
    pl, pg, _ = ex.run_pipelined(params, (real, fake), num_microbatches=1)
    np.testing.assert_array_equal(np.asarray(pl), np.asarray(sl))
    for a, b in zip(jax.tree.leaves(pg), jax.tree.leaves(sg)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipelined_matches_monolithic_grad():
    """K>1 pin: the pipelined step equals the mean of per-chunk MONOLITHIC
    gradients (tight — the staged chain never changes the math), and stays
    close to the full-batch gradient (loose — the discriminator's batch
    norm uses per-micro-batch statistics, the standard grad-accumulation
    shift, so full-batch equality is approximate by construction)."""
    from repro.models.dcgan import disc_init
    params = disc_init(jax.random.PRNGKey(0), _C)
    real, fake = _batches(n=8)
    ml, mg = jax.value_and_grad(d_loss_fn)(params, real, fake, _C)
    for k in (2, 4):
        ex = _exec_fixture([2, 2], [1.0, 2.0], pipeline_microbatches=k)
        pl, pg = ex.value_and_grad(params, real, fake)
        # tight: mean over chunks of the monolithic chunk gradient
        mb = 8 // k
        cl = [jax.value_and_grad(d_loss_fn)(
            params, real[m * mb:(m + 1) * mb], fake[m * mb:(m + 1) * mb],
            _C) for m in range(k)]
        rl = sum(l for l, _ in cl) / k
        rg = jax.tree.map(lambda *gs: sum(gs) / k, *[g for _, g in cl])
        np.testing.assert_allclose(np.asarray(pl), np.asarray(rl),
                                   atol=1e-5, rtol=1e-5)
        for a, b in zip(jax.tree.leaves(pg), jax.tree.leaves(rg)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)
        # loose: tracks the full-batch objective through the BN shift
        np.testing.assert_allclose(np.asarray(pl), np.asarray(ml),
                                   rtol=0.05)


def test_pipeline_k_in_signature():
    a = _exec_fixture([2, 2], [1.0, 2.0])
    b = _exec_fixture([2, 2], [1.0, 2.0], pipeline_microbatches=4)
    c = _exec_fixture([2, 2], [1.0, 2.0], pipeline_microbatches=4)
    assert a.signature != b.signature
    assert b.signature == c.signature
    assert ("pipeline", 4) in b.signature


def test_shipped_boundaries_full_batch_view_when_pipelined():
    """What the LAN observer sees is unchanged in union: per-micro-batch
    shipped tensors concatenate back to the full batch."""
    from repro.models.dcgan import disc_init
    params = disc_init(jax.random.PRNGKey(0), _C)
    real, fake = _batches(n=8)
    ex = _exec_fixture([2, 2], [1.0, 2.0], pipeline_microbatches=4)
    recs = ex.shipped_boundaries(params, real, fake)
    for d in ("fwd", "bwd"):
        for b in range(ex.num_boundaries):
            for p in range(ex.num_passes):
                assert recs[d][b][p].shape[0] == 8


# ---------------------------------------------------------------------------
# fused boundary stage + kernel
# ---------------------------------------------------------------------------

def _scfg(**over):
    base = dict(enabled=True, stage_clip=1.0, stage_sigma=0.5)
    base.update(over)
    return SplitConfig(**base)


@pytest.mark.parametrize("name", ["int8+dp", "fp16+dp"])
def test_make_boundary_stage_selects_fused(name):
    fused = make_boundary_stage(_scfg(), name)
    assert isinstance(fused, FusedBoundaryStage)
    unfused = make_boundary_stage(_scfg(fuse_boundary=False), name)
    assert isinstance(unfused, ComposedBoundaryStage)
    # global top-k needs the whole tensor — never fused
    assert isinstance(make_boundary_stage(_scfg(), "topk+dp"),
                      ComposedBoundaryStage)


@pytest.mark.parametrize("name", ["int8+dp", "fp16+dp"])
def test_fused_stage_matches_composed(name):
    """The single-traversal fused stage computes what the two-stage
    composition computes, both GAN passes, within fma re-association
    tolerance (the fused path runs under jit)."""
    fused = make_boundary_stage(_scfg(), name)
    composed = make_boundary_stage(_scfg(fuse_boundary=False), name)
    key = jax.random.PRNGKey(7)
    for p in range(2):
        x = jax.random.normal(jax.random.fold_in(key, 10 + p),
                              (8, 7, 7, 4), jnp.float32) * 3.0
        kp = jax.random.fold_in(key, p)
        np.testing.assert_allclose(
            np.asarray(fused.apply(x, kp)),
            np.asarray(composed.apply(x, kp)), atol=3e-6, rtol=3e-6)


def test_fused_execution_matches_composed_execution():
    """Full staged run (fwd + bwd crossings) under the fused stage equals
    the unfused composition — loss and every gradient leaf."""
    from repro.models.dcgan import disc_init
    params = disc_init(jax.random.PRNGKey(0), _C)
    real, fake = _batches(n=4)
    key = jax.random.PRNGKey(11)
    ex_f = _exec_fixture([2, 2], [1.0, 2.0],
                         stage=make_boundary_stage(_scfg(), "int8+dp"))
    ex_c = _exec_fixture(
        [2, 2], [1.0, 2.0],
        stage=make_boundary_stage(_scfg(fuse_boundary=False), "int8+dp"))
    fl, fg, _ = ex_f.run(params, (real, fake), key)
    cl, cg, _ = ex_c.run(params, (real, fake), key)
    np.testing.assert_allclose(np.asarray(fl), np.asarray(cl),
                               atol=3e-6, rtol=3e-6)
    for a, b in zip(jax.tree.leaves(fg), jax.tree.leaves(cg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-6, rtol=3e-6)


@pytest.mark.parametrize("codec", list(CODECS))
def test_boundary_fuse_kernel_matches_ref(codec):
    """The Pallas kernel (interpret mode) against the jnp oracle, padded
    and unpadded widths."""
    key = jax.random.PRNGKey(3)
    for n in (64, 100):          # 100 exercises the zero-pad path
        x = jax.random.normal(jax.random.fold_in(key, n), (4, n),
                              jnp.float32) * 2.0
        noise = jax.random.normal(jax.random.fold_in(key, n + 1), (4, n),
                                  jnp.float32)
        clip = jnp.asarray(0.8, jnp.float32)
        scale = jnp.asarray(0.4, jnp.float32)
        out = boundary_fuse_kernel(x, clip, scale, noise, codec=codec,
                                   block_n=32, interpret=True)
        ref = fused_boundary_ref(x, clip, scale, noise, codec=codec)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_fused_stage_sigma_zero_is_deterministic():
    """sigma=0 never draws noise — keyed and keyless applies agree (the
    stage stays declared stochastic, matching GaussianBoundaryStage)."""
    stage = FusedBoundaryStage("int8", 1.0, 0.0)
    x = jnp.linspace(-2.0, 2.0, 32).reshape(4, 8)
    a = stage.apply(x, None)
    b = stage.apply(x, jax.random.PRNGKey(9))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# codec buffer entry points (fed/transport)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ["fp16", "int8", "topk"])
def test_codec_encode_decode_matches_roundtrip(codec):
    from repro.fed.transport import make_codec
    c = make_codec(codec, topk_frac=0.1, error_feedback=False)
    x = jax.random.normal(jax.random.PRNGKey(5), (17, 9), jnp.float32) * 4.0
    wire, meta = c.encode(x)
    dec = c.decode(wire, meta, x.dtype)
    ref, _ = c.roundtrip(x)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(ref))


# ---------------------------------------------------------------------------
# pricing: plan_epoch_time + round_timeline under K
# ---------------------------------------------------------------------------

def _plan_client():
    costs = disc_layer_costs(_C)
    layers = [(n, costs[n]) for n in disc_layer_names(_C)]
    client = _client([2, 2], [1.0, 2.0])
    return make_plan(client, layers, "sorted_multi", seed=3), client


def test_plan_epoch_time_pipelined_never_slower():
    plan, client = _plan_client()
    assert plan.num_boundaries >= 1
    t1 = plan_epoch_time(plan, client, batches_per_epoch=4)
    tk = plan_epoch_time(plan, client, batches_per_epoch=4,
                         pipeline_microbatches=4)
    assert tk <= t1
    # K=1 through the schedule path is the legacy additive number
    assert plan_epoch_time(plan, client, batches_per_epoch=4,
                           pipeline_microbatches=1) == t1


def test_round_timeline_pipelined_agrees_with_plan_epoch_time():
    """The trace is the price, subdivided: the pipelined timeline's batch
    time equals plan_epoch_time's per-batch makespan, and its spans
    genuinely overlap across devices."""
    plan, client = _plan_client()
    ex = _exec_fixture([2, 2], [1.0, 2.0], pipeline_microbatches=4)
    tf = {d.device_id: d.time_factor for d in client.devices}
    phases, batch_s = ex.round_timeline(tf, lan_latency_s=0.01)
    expect = plan_epoch_time(plan, client, batches_per_epoch=1,
                             lan_latency_s=0.01, pipeline_microbatches=4)
    assert batch_s == pytest.approx(expect, rel=1e-12)
    comp = [p for p in phases if p["cat"] == "segment"]
    assert any(a["track"] != b["track"]
               and a["t0"] < b["t1"] and b["t0"] < a["t1"]
               for a in comp for b in comp)
    # sequential timeline unchanged by the K=1 default
    seq_phases, seq_s = ex.round_timeline(tf, lan_latency_s=0.01,
                                          pipeline_microbatches=1)
    assert seq_s >= batch_s
    assert all(b["t0"] >= a["t1"] - 1e-12
               for a, b in zip(seq_phases, seq_phases[1:]))


# ---------------------------------------------------------------------------
# config + trainer wiring
# ---------------------------------------------------------------------------

def test_split_config_validates_pipeline_fields():
    with pytest.raises(ValueError):
        SplitConfig(pipeline_microbatches=0)
    with pytest.raises(ValueError):
        SplitConfig(lan_latency_s=-0.1)


def test_trainer_lan_latency_wiring():
    from repro.configs.registry import get_config
    from repro.core.gan import FSLGANTrainer
    from repro.data import partition_dirichlet, synthetic_mnist
    imgs, labels = synthetic_mnist(64, seed=0)
    parts = partition_dirichlet(imgs, labels, 2, alpha=0.5, seed=0)
    base = get_config("dcgan-mnist").override({
        "shape.global_batch": 8, "fsl.num_clients": 2,
        "model.dcgan.base_filters": 8})
    tr = FSLGANTrainer(base, parts, seed=0)
    assert tr._lan_latency_s() == base.fsl.lan_latency_s
    tr2 = FSLGANTrainer(base.override({"split.lan_latency_s": 0.012}),
                        parts, seed=0)
    assert tr2._lan_latency_s() == 0.012


def test_trainer_auto_backend_and_pipeline_feedback():
    """One round with backend='auto' + a pipelined split: the probe picks
    a concrete backend, records its timings once, and the feedback carries
    the pipeline fields the deadline controller rescales with."""
    from repro.configs.registry import get_config
    from repro.core.gan import FSLGANTrainer
    from repro.data import partition_dirichlet, synthetic_mnist
    imgs, labels = synthetic_mnist(64, seed=0)
    parts = partition_dirichlet(imgs, labels, 2, alpha=0.5, seed=0)
    cfg = get_config("dcgan-mnist").override({
        "shape.global_batch": 8, "fsl.num_clients": 2,
        "model.dcgan.base_filters": 8,
        "split.enabled": True, "split.pipeline_microbatches": 2})
    tr = FSLGANTrainer(cfg, parts, seed=0)
    m = tr.train_epoch(batches_per_client=1, backend="auto")
    fb = tr.feedback[-1]
    assert fb.backend in ("loop", "vectorized")
    assert set(fb.backend_probe_us) == {"loop", "vectorized"}
    assert all(v > 0 for v in fb.backend_probe_us.values())
    assert fb.pipeline_microbatches == 2
    assert fb.pipeline_speedup >= 1.0
    assert np.isfinite(m["d_loss"])
    # probe runs once; later rounds reuse the cached choice
    tr.train_epoch(batches_per_client=1, backend="auto")
    assert tr.feedback[-1].backend == fb.backend
    assert not tr.feedback[-1].backend_probe_us
