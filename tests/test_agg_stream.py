"""Compressed-domain streaming aggregation (kernels/agg_fuse +
fed/aggregate): the fused dequant-reduce server path vs the decode-then-
fedavg reference.

Pinned invariants:
  * the Pallas kernels (dense dequant-reduce, per-wire dequant-acc, sparse
    scatter-acc) match their jnp references, including the zero-pad path
    (N not a block multiple) and colliding top-k indices;
  * StreamingAggregator.fold/finalize == stack-decode-then-weighted-mean
    per codec x weighting, on ragged leaf shapes;
  * trainer-level: one engine round under ``fed.server_reduce`` in
    {stream, batched} pins the decode reference to fma-level tolerance
    across codec x {flat sync, hierarchical, async} with IDENTICAL wire
    bytes and codec_error accounting (the equivalence is per-round: float
    reassociation differences are fma-level in one round but chaotically
    amplified by GAN training dynamics over many rounds, so multi-epoch
    trajectories are NOT comparable);
  * O(1) server memory: ``RoundReport.peak_live_trees`` stays constant in
    the cohort size under stream/batched while the decode reduce stages
    one decoded tree per landed client.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist
from repro.fed.aggregate import (StreamingAggregator, batched_reduce,
                                 codec_rel_error, decode_enc)
from repro.fed.programs import fedavg_stacked, stack_trees
from repro.fed.transport import make_codec
from repro.kernels.agg_fuse import (dequant_acc_flat, dequant_acc_ref,
                                    dequant_reduce_flat, dequant_reduce_ref,
                                    scatter_acc_flat, scatter_acc_ref)


# ---------------------------------------------------------------------------
# kernels vs jnp references (pad path + collisions)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4096, 5000])    # block multiple + pad path
@pytest.mark.parametrize("wire_dtype", [jnp.int8, jnp.float16])
def test_dequant_reduce_kernel_matches_ref(n, wire_dtype):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    c = 5
    if wire_dtype == jnp.int8:
        wires = jax.random.randint(k1, (c, n), -127, 128,
                                   jnp.int32).astype(jnp.int8)
        scales = jax.random.uniform(k2, (c,), jnp.float32, 1e-3, 1e-1)
    else:
        wires = jax.random.normal(k1, (c, n), jnp.float32).astype(wire_dtype)
        scales = jnp.ones((c,), jnp.float32)
    weights = jax.random.uniform(k3, (c,), jnp.float32, 0.5, 2.0)
    ker = dequant_reduce_flat(wires, scales, weights,
                              use_kernel=True, interpret=True)
    ref = dequant_reduce_flat(wires, scales, weights, use_kernel=False)
    assert ker.shape == (n,) and ker.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [4096, 5000])
def test_dequant_acc_kernel_matches_ref(n):
    key = jax.random.PRNGKey(1)
    k1, k2 = jax.random.split(key)
    acc = jax.random.normal(k1, (n,), jnp.float32)
    wire = jax.random.randint(k2, (n,), -127, 128,
                              jnp.int32).astype(jnp.int8)
    out_k = dequant_acc_flat(jnp.copy(acc), wire, 0.031, 1.7,
                             use_kernel=True, interpret=True)
    out_r = dequant_acc_ref(acc, wire, 1.7, 0.031)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-6)


def test_scatter_acc_kernel_sums_colliding_indices():
    n = 5000                                     # forces the pad path too
    acc = jnp.zeros((n,), jnp.float32)
    idx = jnp.asarray([0, 1, 1, 4999, 4999, 4999, 123], jnp.int32)
    vals = jnp.asarray([1., 2., 3., 4., 5., 6., 7.], jnp.float32)
    out_k = scatter_acc_flat(jnp.copy(acc), vals, idx, 2.0,
                             use_kernel=True, interpret=True)
    out_r = scatter_acc_ref(acc, vals, idx, 2.0)
    # collisions must SUM (matching .at[idx].add), not overwrite
    assert float(out_r[1]) == pytest.approx(10.0)
    assert float(out_r[4999]) == pytest.approx(30.0)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)


def test_dequant_reduce_ref_is_weighted_mean():
    wires = jnp.asarray([[2.0, 4.0], [6.0, 8.0]], jnp.float32)
    ones = jnp.ones((2,), jnp.float32)
    out = dequant_reduce_flat(wires, ones, jnp.asarray([1.0, 3.0]))
    np.testing.assert_allclose(np.asarray(out), [5.0, 7.0], rtol=1e-6)
    coefs = jnp.asarray([[0.5, 1.0], [1.0, 1.0]], jnp.float32)  # (w, s)
    np.testing.assert_allclose(
        np.asarray(dequant_reduce_ref(wires, coefs)),
        np.asarray(wires[0]) * 0.5 + np.asarray(wires[1]), rtol=1e-6)


# ---------------------------------------------------------------------------
# StreamingAggregator == stack-decode-then-weighted-mean (unit level)
# ---------------------------------------------------------------------------

def _delta_tree(seed):
    k = jax.random.PRNGKey(seed)
    return {"w": 0.1 * jax.random.normal(k, (33, 7)),     # ragged, pad path
            "b": {"x": 0.1 * jax.random.normal(jax.random.fold_in(k, 1),
                                               (11,))}}


@pytest.mark.parametrize("codec_name", ["none", "fp16", "int8", "topk"])
@pytest.mark.parametrize("weighted", [False, True])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_streaming_fold_matches_decode_then_fedavg(codec_name, weighted,
                                                   use_kernel):
    deltas = [_delta_tree(s) for s in range(3)]
    weights = [1.0, 2.5, 0.5] if weighted else [1.0, 1.0, 1.0]
    encs = []
    for i, d in enumerate(deltas):
        codec = make_codec(codec_name, topk_frac=0.25, error_feedback=False)
        encs.append(codec.encode_tree(d)[0])
    agg = StreamingAggregator(codec_name, use_kernel=use_kernel,
                              interpret=True)
    agg.init(deltas[0])
    for enc, w in zip(encs, weights):
        agg.fold(enc, w)
    got = agg.finalize()
    # reference: decode every wire, stack, weighted fedavg
    decoded = [decode_enc(codec_name, enc, deltas[0]) for enc in encs]
    want = fedavg_stacked(stack_trees(decoded), weights)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("codec_name", ["none", "fp16", "int8", "topk"])
def test_batched_reduce_matches_streaming(codec_name):
    deltas = [_delta_tree(10 + s) for s in range(4)]
    weights = [2.0, 1.0, 3.0, 0.5]
    encs = []
    for d in deltas:
        codec = make_codec(codec_name, topk_frac=0.25, error_feedback=False)
        encs.append(codec.encode_tree(d)[0])
    agg = StreamingAggregator(codec_name, interpret=True)
    agg.init(deltas[0])
    for enc, w in zip(encs, weights):
        agg.fold(enc, w)
    got_s = agg.finalize()
    got_b = batched_reduce(codec_name, encs, weights, deltas[0],
                           use_kernel=False, interpret=True)
    for a, b in zip(jax.tree.leaves(got_s), jax.tree.leaves(got_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_fold_reports_codec_error_matching_densified():
    """The in-fold rel_error (computed without densifying top-k wires)
    equals the decode-and-compare definition ``_codec_roundtrip`` uses."""
    d = _delta_tree(3)
    for codec_name in ("fp16", "int8", "topk"):
        codec = make_codec(codec_name, topk_frac=0.25, error_feedback=False)
        enc, _ = codec.encode_tree(d)
        err = codec_rel_error(codec_name, enc, d)
        dec = decode_enc(codec_name, enc, d)
        df = jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(d)])
        cf = jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                              for l in jax.tree.leaves(dec)])
        want = float(jnp.linalg.norm(cf - df)
                     / jnp.maximum(jnp.linalg.norm(df), 1e-12))
        assert err == pytest.approx(want, rel=1e-4, abs=1e-6)


# ---------------------------------------------------------------------------
# trainer-level: stream/batched pin the decode reference per round
# ---------------------------------------------------------------------------

def _cfg(**over):
    base = {"shape.global_batch": 8, "fsl.num_clients": 3,
            "model.dcgan.base_filters": 8}
    base.update(over)
    return get_config("dcgan-mnist").override(base)


@pytest.fixture(scope="module")
def parts():
    imgs, labels = synthetic_mnist(180, seed=0)
    return partition_dirichlet(imgs, labels, 3, alpha=0.5, seed=0)


def _one_round(parts, **over):
    tr = FSLGANTrainer(_cfg(**over), parts, seed=0)
    m = tr.train_epoch(batches_per_client=2)
    return tr, m


def _max_param_diff(ta, tb):
    """Max |diff| over the aggregated discriminator — the tree the server
    reduce produces.  The generator is excluded: its post-round Adam
    updates normalize gradients by ~zero second moments early in training,
    amplifying fma-level aggregate differences to O(lr) immediately."""
    d = 0.0
    cid = ta.client_ids[0]
    for a, b in zip(jax.tree.leaves(ta.state.d_params[cid]),
                    jax.tree.leaves(tb.state.d_params[cid])):
        d = max(d, float(jnp.max(jnp.abs(a - b))))
    return d


TOPOLOGIES = {
    "flat": {},
    "hier": {"fed.hierarchy_cohorts": 2},        # ragged cohorts: 2 + 1
    "async": {"fed.mode": "fedasync", "fed.async_cycles": 2},
}


@pytest.mark.parametrize("codec", ["none", "fp16", "int8", "topk"])
@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
def test_stream_round_pins_decode_round(parts, codec, topo):
    over = dict(TOPOLOGIES[topo])
    over["fed.codec"] = codec
    ta, ma = _one_round(parts, **over)
    tb, mb = _one_round(parts, **dict(over, **{"fed.server_reduce":
                                               "stream"}))
    # per-round equivalence: one reduce's float reassociation is fma-level
    assert _max_param_diff(ta, tb) <= 2e-5
    # wire accounting must be EXACTLY unchanged — encode_tree prices the
    # same bytes the decode roundtrip does
    assert ma["up_mbytes"] == mb["up_mbytes"]
    assert ma.get("edge_mbytes") == mb.get("edge_mbytes")
    if codec != "none":
        assert mb["codec_error"] == pytest.approx(ma["codec_error"],
                                                  rel=1e-3, abs=1e-6)


@pytest.mark.parametrize("codec", ["none", "int8"])
def test_batched_round_pins_decode_round(parts, codec):
    ta, ma = _one_round(parts, **{"fed.codec": codec})
    tb, mb = _one_round(parts, **{"fed.codec": codec,
                                  "fed.server_reduce": "batched"})
    assert _max_param_diff(ta, tb) <= 2e-5
    assert ma["up_mbytes"] == mb["up_mbytes"]


def test_peak_live_trees_is_o1_under_stream(parts):
    """The decode reduce stages one decoded tree per landed client; the
    compressed-domain fold holds only the fp32 accumulator."""
    ta, _ = _one_round(parts, **{"fed.codec": "int8"})
    assert ta.engine.last_report.peak_live_trees == 3     # O(C)
    tb, _ = _one_round(parts, **{"fed.codec": "int8",
                                 "fed.server_reduce": "stream"})
    assert tb.engine.last_report.peak_live_trees == 1     # O(1)
    tc, _ = _one_round(parts, **{"fed.codec": "int8",
                                 "fed.server_reduce": "batched"})
    assert tc.engine.last_report.peak_live_trees == 1
    # hierarchical: decode stages landed trees + reductions; stream holds
    # one accumulator per cohort round-trip but never the member trees
    th, _ = _one_round(parts, **{"fed.codec": "int8",
                                 "fed.hierarchy_cohorts": 2,
                                 "fed.server_reduce": "stream"})
    assert th.engine.last_report.peak_live_trees <= 3
    thd, _ = _one_round(parts, **{"fed.codec": "int8",
                                  "fed.hierarchy_cohorts": 2})
    assert thd.engine.last_report.peak_live_trees >= 5    # 3 landed + 2 red


def test_server_reduce_validated():
    with pytest.raises(ValueError):
        _cfg(**{"fed.server_reduce": "bogus"})
