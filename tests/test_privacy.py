"""Privacy subsystem (privacy/ + kernels/dp_clip): attacks, metrics,
defenses, and the trainer/engine wiring.

Pinned invariants (ISSUE 2 acceptance):
  * dp_clip Pallas kernel == pure-JAX DP-SGD reference to fp32 tolerance;
  * gradient-inversion reconstruction PSNR drops measurably when DP noise
    is enabled, while sync/no-privacy training stays bit-exact with the
    seed loop.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import DCGANConfig, PrivacyConfig, RunConfig
from repro.configs.registry import get_config
from repro.core.devices import Client, Device
from repro.core.gan import FSLGANTrainer, d_loss_fn
from repro.core.selection import make_plan
from repro.core.split import boundary_activations, split_forward
from repro.data import partition_dirichlet, synthetic_mnist
from repro.kernels.dp_clip.kernel import dp_clip_noise_kernel
from repro.kernels.dp_clip.ops import (dp_clip_noise_tree,
                                       flatten_per_example,
                                       unflatten_summed)
from repro.kernels.dp_clip.ref import dp_clip_noise_ref
from repro.models.dcgan import (disc_apply, disc_apply_layer, disc_init,
                                disc_layer_costs, disc_layer_names)
from repro.privacy import (ActivationInversionAttack, RDPAccountant,
                           attack_advantage, attack_auc, best_match_psnr,
                           distance_correlation, dp_epsilon,
                           invert_gradients, make_prefix_fn,
                           make_uplink_stage, membership_inference,
                           plan_boundary_depths, psnr,
                           rdp_sampled_gaussian, sigma_for_epsilon, ssim)
from repro.privacy.defenses import DPUplinkStage, make_dp_d_step

from _hyp import given, settings, st

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# kernels/dp_clip: Pallas kernel pinned against the pure-JAX reference
# ---------------------------------------------------------------------------

DP_CASES = [
    # (batch, n_params, block_n)
    (4, 100, 32),          # padding: n % block != 0
    (8, 5000, 2048),       # multi-block
    (1, 7, 8),             # single example, tiny leaf
    (16, 2048, 512),       # aligned
]


@pytest.mark.parametrize("case", DP_CASES)
def test_dp_clip_kernel_matches_ref(case):
    b, n, bn = case
    x = jax.random.normal(KEY, (b, n)) * 3.0
    z = jax.random.normal(jax.random.fold_in(KEY, 1), (n,))
    out = dp_clip_noise_kernel(x, 1.0, 0.7, z, block_n=bn, interpret=True)
    ref = dp_clip_noise_ref(x, 1.0, 0.7, z)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_dp_clip_kernel_zero_grads_and_no_noise():
    """All-zero per-example grads with sigma=0 emit exact zeros (the
    NORM_EPS guard must not inject anything)."""
    out = dp_clip_noise_kernel(jnp.zeros((4, 33)), 1.0, 0.0,
                               jnp.zeros((33,)), interpret=True)
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_array_equal(np.asarray(out), np.zeros(33, np.float32))


def test_dp_clip_semantics_clipping_actually_bounds():
    """Each example contributes at most clip_norm of L2 mass."""
    x = jax.random.normal(KEY, (1, 64)) * 100.0      # huge gradient
    out = dp_clip_noise_ref(x, 0.5, 0.0, jnp.zeros((64,)))
    assert float(jnp.linalg.norm(out)) == pytest.approx(0.5, rel=1e-5)
    # small gradients pass through unclipped
    x2 = jax.random.normal(KEY, (1, 64)) * 1e-3
    out2 = dp_clip_noise_ref(x2, 0.5, 0.0, jnp.zeros((64,)))
    np.testing.assert_allclose(np.asarray(out2), np.asarray(x2[0]),
                               atol=1e-7)


def test_dp_clip_tree_kernel_matches_host_path():
    tree = {"w": jax.random.normal(KEY, (4, 3, 5)),
            "b": jax.random.normal(jax.random.fold_in(KEY, 2), (4, 7))}
    t_kernel = dp_clip_noise_tree(tree, 1.0, 0.5, KEY, use_kernel=True,
                                  interpret=True)
    t_host = dp_clip_noise_tree(tree, 1.0, 0.5, KEY, use_kernel=False)
    for a, b in zip(jax.tree.leaves(t_kernel), jax.tree.leaves(t_host)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # flatten/unflatten round-trips shapes
    flat, spec = flatten_per_example(tree)
    assert flat.shape == (4, 3 * 5 + 7)
    back = unflatten_summed(flat[0], spec)
    assert jax.tree.structure(back) == jax.tree.structure(tree)
    assert back["w"].shape == (3, 5) and back["b"].shape == (7,)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_psnr_ssim_basics():
    a = jnp.zeros((2, 28, 28, 1))
    assert psnr(a, a) == float("inf")
    assert psnr(a, a + 1.0) == pytest.approx(10 * np.log10(4.0))
    assert ssim(a, a) == pytest.approx(1.0, abs=1e-5)
    noisy = a + 0.5 * jax.random.normal(KEY, a.shape)
    assert ssim(a, noisy) < 0.5


def test_best_match_psnr_is_permutation_invariant():
    imgs, _ = synthetic_mnist(4, seed=0)
    x = jnp.asarray(imgs)
    perm = x[::-1]
    assert best_match_psnr(perm, x) == float("inf")


def test_distance_correlation_endpoints():
    x = jax.random.normal(KEY, (32, 10))
    assert distance_correlation(x, x) == pytest.approx(1.0, abs=1e-4)
    assert distance_correlation(x, 2.0 * x + 1.0) == pytest.approx(
        1.0, abs=1e-4)
    indep = jax.random.normal(jax.random.fold_in(KEY, 1), (32, 10))
    assert distance_correlation(x, indep) < distance_correlation(x, x)


def test_attack_auc_and_advantage():
    assert attack_auc([3, 4, 5], [0, 1, 2]) == 1.0
    assert attack_auc([1, 1], [1, 1]) == 0.5
    adv, thr = attack_advantage([3, 4, 5], [0, 1, 2])
    assert adv == 1.0 and 2 < thr <= 3
    adv0, _ = attack_advantage([1, 1], [1, 1])
    assert adv0 == 0.0


# ---------------------------------------------------------------------------
# RDP accountant
# ---------------------------------------------------------------------------

def test_rdp_gaussian_q1_closed_form():
    # plain Gaussian mechanism: RDP(alpha) = alpha / (2 sigma^2)
    assert rdp_sampled_gaussian(1.0, 2.0, 2) == pytest.approx(2 / 8.0)
    assert rdp_sampled_gaussian(1.0, 1.0, 8) == pytest.approx(4.0)


def test_rdp_subsampling_amplifies():
    # smaller sampling rate => less RDP per step, at every order
    for order in (2, 4, 16):
        full = rdp_sampled_gaussian(1.0, 1.0, order)
        sub = rdp_sampled_gaussian(0.1, 1.0, order)
        tiny = rdp_sampled_gaussian(0.01, 1.0, order)
        assert tiny < sub < full


def test_accountant_epsilon_monotonicity():
    acct = RDPAccountant(1.0, 0.05)
    acct.step(100)
    e100 = acct.epsilon(1e-5)[0]
    acct.step(900)
    e1000 = acct.epsilon(1e-5)[0]
    assert 0 < e100 < e1000
    # more noise => less epsilon at equal steps
    assert dp_epsilon(2.0, 0.05, 1000) < dp_epsilon(1.0, 0.05, 1000)
    # no noise => no guarantee
    assert dp_epsilon(0.0, 0.05, 10) == float("inf")
    # no steps => nothing spent
    assert RDPAccountant(1.0, 0.5).epsilon()[0] == 0.0


def test_fractional_rdp_orders_interpolate_and_stay_sound():
    """Fractional orders (ISSUE 3 satellite): the real-alpha series matches
    the integer closed form at (near-)integer alpha, and RDP is monotone
    nondecreasing across the dense order grid (Rényi divergence is
    monotone in its order — a violated cell would be an unsound epsilon)."""
    from repro.privacy.defenses import DEFAULT_ORDERS
    q, sigma = 0.05, 1.0
    for a in (2, 3, 8, 16, 32):
        exact = rdp_sampled_gaussian(q, sigma, a)
        near = rdp_sampled_gaussian(q, sigma, a + 1e-9)
        assert near == pytest.approx(exact, rel=1e-6)
        # strictly between the neighbouring integers
        half = rdp_sampled_gaussian(q, sigma, a + 0.5)
        assert exact <= half <= rdp_sampled_gaussian(q, sigma, a + 1)
    vals = [rdp_sampled_gaussian(q, sigma, a) for a in DEFAULT_ORDERS]
    assert all(b >= a * (1 - 1e-9) for a, b in zip(vals, vals[1:]))
    # q=1 closed form holds at fractional orders too
    assert rdp_sampled_gaussian(1.0, 2.0, 2.5) == pytest.approx(2.5 / 8.0)


def test_fractional_order_grid_never_worse_than_integer_grid():
    """ISSUE 3 satellite acceptance: the dense (integer + fractional) grid
    can only tighten the epsilon report — and at realistic settings it
    strictly does (the optimal order lands between integers)."""
    from repro.privacy.defenses import (DEFAULT_ORDERS, FRACTIONAL_ORDERS,
                                        INTEGER_ORDERS)
    assert set(INTEGER_ORDERS) <= set(DEFAULT_ORDERS)
    assert set(FRACTIONAL_ORDERS) <= set(DEFAULT_ORDERS)
    assert all(int(a) != a for a in FRACTIONAL_ORDERS)
    for sigma, q, steps in ((1.0, 0.05, 500), (0.8, 1.0, 50),
                            (2.0, 0.1, 2000)):
        ai = RDPAccountant(sigma, q, orders=INTEGER_ORDERS)
        ad = RDPAccountant(sigma, q)
        ai.step(steps)
        ad.step(steps)
        eps_int, _ = ai.epsilon(1e-5)
        eps_dense, order = ad.epsilon(1e-5)
        assert eps_dense <= eps_int * (1 + 1e-12)
    # the subsampled setting picks a fractional optimum and strictly wins
    ai = RDPAccountant(1.0, 0.05, orders=INTEGER_ORDERS)
    ad = RDPAccountant(1.0, 0.05)
    ai.step(500)
    ad.step(500)
    assert ad.epsilon(1e-5)[0] < ai.epsilon(1e-5)[0]
    assert int(ad.epsilon(1e-5)[1]) != ad.epsilon(1e-5)[1]


# ---------------------------------------------------------------------------
# accountant inversion (ISSUE 5 satellite): sigma_for_epsilon + per-step
# sigma composition — the sigma controller's substrate
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(sigma=st.floats(0.3, 8.0), q=st.sampled_from((1.0, 0.1, 0.05)),
       steps=st.integers(1, 2000))
def test_epsilon_monotone_in_sigma_on_fractional_grid(sigma, q, steps):
    """More noise never reports more epsilon anywhere on the dense grid —
    the monotonicity sigma_for_epsilon's bisection relies on."""
    e = dp_epsilon(sigma, q, steps)
    assert dp_epsilon(sigma * 1.25, q, steps) <= e * (1 + 1e-9)
    assert dp_epsilon(sigma * 2.0, q, steps) < e


@settings(max_examples=25, deadline=None)
@given(sigma=st.floats(0.4, 6.0), q=st.sampled_from((1.0, 0.1)),
       steps=st.integers(1, 500))
def test_sigma_for_epsilon_roundtrips_within_tolerance(sigma, q, steps):
    """Inverting the epsilon a run actually spent recovers (almost exactly)
    the sigma it ran with, and the returned sigma never overspends."""
    eps = dp_epsilon(sigma, q, steps)
    if not np.isfinite(eps) or eps <= 0:
        return
    sig2 = sigma_for_epsilon(eps, steps, 1e-5, q)
    assert dp_epsilon(sig2, q, steps) <= eps * (1 + 1e-6)   # never exceeds
    assert abs(sig2 - sigma) / sigma < 5e-3                 # round-trip


def test_sigma_for_epsilon_edges():
    # generous budget clamps at the floor; impossible budget at the cap
    assert sigma_for_epsilon(1e6, 10, lo=0.5) == 0.5
    assert sigma_for_epsilon(1e-9, 10**6, hi=50.0) == 50.0
    with pytest.raises(ValueError):
        sigma_for_epsilon(0.0, 10)


def test_accountant_composes_heterogeneous_sigmas():
    """Per-round sigma changes compose additively in RDP: a mixed-sigma
    run spends strictly between the all-low and all-high runs, and
    projected_epsilon is exactly the epsilon the spend would produce."""
    lo_acct = RDPAccountant(0.8, 1.0)
    hi_acct = RDPAccountant(2.0, 1.0)
    mix = RDPAccountant(0.8, 1.0)
    lo_acct.step(20)
    hi_acct.step(20)
    mix.step(10, noise_multiplier=0.8)
    proj = mix.projected_epsilon(10, 1e-5, noise_multiplier=2.0)
    mix.step(10, noise_multiplier=2.0)
    assert hi_acct.epsilon()[0] < mix.epsilon()[0] < lo_acct.epsilon()[0]
    assert mix.epsilon()[0] == pytest.approx(proj, rel=1e-12)
    assert mix.steps == 20
    # zero-projection degenerate case
    assert RDPAccountant(1.0, 1.0).projected_epsilon(0) == 0.0


def test_accountant_zero_steps_never_poison_totals():
    """Regression: ``step(0)`` at sigma <= 0 (per-step RDP = inf) must be
    a no-op, not a 0*inf = NaN write into the running totals — a round
    where every client straggles records zero releases."""
    acct = RDPAccountant(0.0, 1.0)
    acct.step(0)
    assert acct.epsilon()[0] == 0.0                  # nothing spent
    assert acct.projected_epsilon(0) == 0.0
    acct.step(5)                                     # real sigma<=0 spend
    assert acct.epsilon()[0] == float("inf")         # inf, never NaN
    mixed = RDPAccountant(1.0, 1.0)
    mixed.step(3)
    mixed.step(0, noise_multiplier=0.0)              # no-op, not poison
    assert np.isfinite(mixed.epsilon()[0])


# ---------------------------------------------------------------------------
# defenses: DP-SGD step + uplink stage
# ---------------------------------------------------------------------------

def _tiny_loss(params, real, fake):
    # linear "discriminator" so the DP step's math is inspectable
    pred_r = jnp.mean(real * params["w"])
    pred_f = jnp.mean(fake * params["w"])
    return (pred_r - 1.0) ** 2 + pred_f ** 2


def test_dp_step_clip_only_bounds_update():
    from repro.optim.optimizers import sgd
    opt = sgd(momentum=0.0)
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    clip = 0.01
    step = make_dp_d_step(opt, _tiny_loss, lr=1.0, clip_norm=clip,
                          noise_multiplier=0.0)
    real = 10.0 * jax.random.normal(KEY, (8, 4, 4))
    fake = 10.0 * jax.random.normal(jax.random.fold_in(KEY, 1), (8, 4, 4))
    new_params, _, loss = step(params, state, real, fake, KEY)
    # mean of 8 per-example grads each clipped to 0.01 => update <= 0.01
    upd = float(jnp.linalg.norm(new_params["w"] - params["w"]))
    assert upd <= clip + 1e-6
    assert np.isfinite(float(loss))


def test_uplink_stage_clips_and_is_deterministic():
    delta = {"w": 100.0 * jax.random.normal(KEY, (16, 8))}
    stage = DPUplinkStage(clip_norm=1.0, noise_multiplier=0.0, seed=0)
    out = stage("c0", delta)
    assert float(jnp.linalg.norm(out["w"])) == pytest.approx(1.0, rel=1e-4)
    # same (seed, client, round) => same noise; later round => different
    s1 = DPUplinkStage(1.0, 0.5, seed=0)
    s2 = DPUplinkStage(1.0, 0.5, seed=0)
    a1, a2 = s1("c0", delta), s2("c0", delta)
    np.testing.assert_array_equal(np.asarray(a1["w"]), np.asarray(a2["w"]))
    b1 = s1("c0", delta)       # round 1 for s1
    assert not np.array_equal(np.asarray(a1["w"]), np.asarray(b1["w"]))
    # factory: disabled / non-uplink configs produce no stage
    assert make_uplink_stage(PrivacyConfig()) is None
    assert make_uplink_stage(PrivacyConfig(enabled=True,
                                           mode="dp_sgd")) is None
    assert isinstance(make_uplink_stage(
        PrivacyConfig(enabled=True, mode="uplink")), DPUplinkStage)


def test_privacy_config_roundtrips():
    cfg = RunConfig().override({"privacy.enabled": True,
                                "privacy.mode": "uplink",
                                "privacy.noise_multiplier": 1.5})
    assert cfg.privacy.enabled and cfg.privacy.mode == "uplink"
    back = RunConfig.from_dict(cfg.to_dict())
    assert back.privacy.noise_multiplier == 1.5


# ---------------------------------------------------------------------------
# split boundary hook
# ---------------------------------------------------------------------------

def test_split_forward_hook_sees_each_boundary_and_keeps_output():
    c = DCGANConfig(base_filters=8)
    params = disc_init(jax.random.PRNGKey(0), c)
    costs = disc_layer_costs(c)
    layers = [(n, costs[n]) for n in disc_layer_names(c)]
    client = Client("c0", [Device("d0", 1.0, 2), Device("d1", 2.0, 2)])
    plan = make_plan(client, layers, "sorted_multi", seed=0)
    x = jax.random.normal(KEY, (2, 28, 28, 1))
    apply_layer = lambda n, a: disc_apply_layer(n, params, a, c)  # noqa: E731
    seen = boundary_activations(x, plan, apply_layer)
    assert len(seen) == plan.num_boundaries
    depths = plan_boundary_depths(plan)
    assert len(depths) == plan.num_boundaries
    for (idx, dev_a, dev_b, act), depth in zip(seen, depths):
        assert dev_a != dev_b
        ref = make_prefix_fn(params, c, depth)(x)
        np.testing.assert_array_equal(np.asarray(act), np.asarray(ref))
    # the hook must not perturb the forward result
    out = split_forward(x, plan, apply_layer,
                        boundary_hook=lambda *a: None)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(disc_apply(params, x, c)))


# ---------------------------------------------------------------------------
# attacks (smoke scale)
# ---------------------------------------------------------------------------

def test_activation_inversion_leaks_less_with_depth():
    c = DCGANConfig(base_filters=8)
    params = disc_init(jax.random.PRNGKey(0), c)
    aux, _ = synthetic_mnist(128, seed=5)
    victim, _ = synthetic_mnist(16, seed=9)
    results = {}
    for depth in (1, 3):
        atk = ActivationInversionAttack(make_prefix_fn(params, c, depth),
                                        (28, 28, 1), seed=0)
        hist = atk.train(aux, steps=120, batch=32)
        assert hist[-1] < hist[0]              # the decoder actually learns
        rec = atk.reconstruct(victim)
        assert rec.shape == victim.shape
        results[depth] = {
            "psnr": psnr(rec, victim),
            "dcor": distance_correlation(
                jnp.asarray(victim), atk.prefix(jnp.asarray(victim)))}
    # deeper cut leaks less, on both the decoder and the dependence metric
    assert results[3]["psnr"] < results[1]["psnr"]
    assert results[3]["dcor"] < results[1]["dcor"]
    # shallow-cut reconstruction is genuinely good
    assert results[1]["psnr"] > 18.0


def test_membership_inference_near_chance_on_fresh_discriminator():
    c = DCGANConfig(base_filters=8)
    params = disc_init(jax.random.PRNGKey(0), c)
    member, _ = synthetic_mnist(64, seed=0)
    nonmember, _ = synthetic_mnist(64, seed=1)
    out = membership_inference(params, c, member, nonmember)
    assert 0.25 < out["auc"] < 0.75           # untrained: no signal
    assert 0.0 <= out["advantage"] <= 1.0


# ---------------------------------------------------------------------------
# pinned end-to-end: DP measurably blunts gradient inversion, while the
# no-privacy path stays bit-exact with the seed loop
# ---------------------------------------------------------------------------

def _cfg(**over):
    base = {"shape.global_batch": 8, "fsl.num_clients": 2,
            "model.dcgan.base_filters": 8}
    base.update(over)
    return get_config("dcgan-mnist").override(base)


@pytest.fixture(scope="module")
def parts():
    imgs, labels = synthetic_mnist(120, seed=0)
    return partition_dirichlet(imgs, labels, 2, alpha=0.5, seed=0)


def test_gradient_inversion_psnr_drops_under_dp_noise():
    """Acceptance pin: reconstruction PSNR from the uplinked D gradient
    falls by > 3 dB when DP-SGD clip+noise (sigma=2) privatizes it."""
    c = DCGANConfig(base_filters=8)
    params = disc_init(jax.random.PRNGKey(0), c)
    imgs, _ = synthetic_mnist(4, seed=1)
    real = jnp.asarray(imgs[:1])
    fake = 0.3 * jax.random.normal(jax.random.fold_in(KEY, 3),
                                   (1, 28, 28, 1))
    loss_fn = functools.partial(d_loss_fn, c=c)

    g_clean = jax.grad(loss_fn)(params, real, fake)
    rec_clean, hist_clean = invert_gradients(
        loss_fn, params, g_clean, fake, (1, 28, 28, 1), steps=200,
        key=jax.random.PRNGKey(7))
    psnr_clean = best_match_psnr(rec_clean, real)

    per_ex = jax.vmap(lambda r, f: jax.grad(loss_fn)(params, r[None],
                                                     f[None]),
                      in_axes=(0, 0))(real, fake)
    g_dp = dp_clip_noise_tree(per_ex, 1.0, 2.0, jax.random.PRNGKey(11),
                              use_kernel=True, interpret=True)
    rec_dp, _ = invert_gradients(
        loss_fn, params, g_dp, fake, (1, 28, 28, 1), steps=200,
        key=jax.random.PRNGKey(7))
    psnr_dp = best_match_psnr(rec_dp, real)

    assert hist_clean[-1] < 0.1              # attack converged on clean grads
    assert psnr_clean > 10.0                 # and genuinely reconstructs
    assert psnr_clean - psnr_dp > 3.0        # DP measurably blunts it


def test_no_privacy_training_stays_bit_exact_with_seed_loop(parts):
    """Acceptance pin: the privacy wiring, disabled, changes nothing —
    engine sync round == seed sequential loop bit-for-bit."""
    ta = FSLGANTrainer(_cfg(), parts, seed=0)
    tb = FSLGANTrainer(_cfg(), parts, seed=0)
    ma = ta.train_epoch(batches_per_client=2)
    mb = tb.train_epoch_sequential(batches_per_client=2)
    assert ma["d_loss"] == mb["d_loss"] and ma["g_loss"] == mb["g_loss"]
    assert "dp_epsilon" not in ma and ta.accountant is None
    for cid in ta.state.d_params:
        for a, b in zip(jax.tree.leaves(ta.state.d_params[cid]),
                        jax.tree.leaves(tb.state.d_params[cid])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dp_sgd_training_runs_and_accounts(parts):
    # honest q: batch / smallest client shard (loader samples w/ replacement)
    q = min(1.0, 8 / min(len(v) for v in parts.values()))
    t = FSLGANTrainer(_cfg(**{"privacy.enabled": True,
                              "privacy.noise_multiplier": 0.8,
                              "privacy.sample_rate": q}), parts, seed=0)
    m = t.train_epoch(batches_per_client=2)
    assert np.isfinite(m["d_loss"]) and np.isfinite(m["g_loss"])
    assert t.accountant.steps == 2 * 2      # 2 clients x 2 batches
    assert 0 < m["dp_epsilon"] < float("inf")
    # epsilon grows as training continues
    m2 = t.train_epoch(batches_per_client=2)
    assert m2["dp_epsilon"] > m["dp_epsilon"]
    # the vectorized backend applies the SAME DP stage inside the scanned
    # step (the old NotImplementedError wall is gone) and keeps accounting
    m3 = t.train_epoch(batches_per_client=1, backend="vectorized")
    assert np.isfinite(m3["d_loss"])
    assert t.accountant.steps == 2 * 2 + 2 * 2 + 2 * 1
    assert m3["dp_epsilon"] > m2["dp_epsilon"]


def test_uplink_mode_covers_every_path(parts):
    """The uplink DP stage now runs in every path (the old
    NotImplementedError walls are gone): the engine applies it pre-codec
    under either backend, and the sequential reference loop applies the
    identical delta arithmetic — pinned bit-for-bit against engine
    sync/no-codec."""
    over = {"privacy.enabled": True, "privacy.mode": "uplink",
            "privacy.noise_multiplier": 0.5}
    t_seq = FSLGANTrainer(_cfg(**over), parts, seed=0)
    t_eng = FSLGANTrainer(_cfg(**over), parts, seed=0)
    m_seq = t_seq.train_epoch_sequential(batches_per_client=2)
    m_eng = t_eng.train_epoch(batches_per_client=2)
    assert m_seq["d_loss"] == m_eng["d_loss"]
    assert m_seq["dp_epsilon"] == m_eng["dp_epsilon"] > 0
    for cid in t_seq.state.d_params:
        for a, b in zip(jax.tree.leaves(t_seq.state.d_params[cid]),
                        jax.tree.leaves(t_eng.state.d_params[cid])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # vectorized backend: same stage, applied to the jitted round's delta
    t_vec = FSLGANTrainer(_cfg(**over), parts, seed=0)
    m_vec = t_vec.train_epoch(batches_per_client=2, backend="vectorized")
    assert np.isfinite(m_vec["d_loss"]) and m_vec["dp_epsilon"] > 0


def test_uplink_stage_survives_engine_rebuild(parts):
    """Changing batches_per_client rebuilds the engine; the DP stage (and
    its per-client noise round counters) must persist, or identical noise
    would be reused across rounds (noise-cancellation attack)."""
    t = FSLGANTrainer(_cfg(**{"privacy.enabled": True,
                              "privacy.mode": "uplink",
                              "privacy.noise_multiplier": 0.5}),
                      parts, seed=0)
    t.train_epoch(batches_per_client=1)
    stage = t.engine.uplink_stage
    rounds_before = dict(stage._round)
    t.train_epoch(batches_per_client=2)      # different length => rebuild
    assert t.engine.uplink_stage is stage
    for cid, n in rounds_before.items():
        assert stage._round[cid] > n


def test_dp_sgd_with_kernel_runs(parts):
    t = FSLGANTrainer(_cfg(**{"privacy.enabled": True,
                              "privacy.noise_multiplier": 0.5,
                              "privacy.use_kernel": True,
                              "privacy.kernel_interpret": True}),
                      parts, seed=0)
    m = t.train_epoch(batches_per_client=1)
    assert np.isfinite(m["d_loss"])


def test_uplink_dp_composes_with_codec(parts):
    t = FSLGANTrainer(_cfg(**{"privacy.enabled": True,
                              "privacy.mode": "uplink",
                              "privacy.noise_multiplier": 0.3,
                              "fed.codec": "int8"}), parts, seed=0)
    t_raw = FSLGANTrainer(_cfg(**{"fed.codec": "int8"}), parts, seed=0)
    m = t.train_epoch(batches_per_client=1)
    m_raw = t_raw.train_epoch(batches_per_client=1)
    assert np.isfinite(m["d_loss"])
    # the stage rides inside the codec path: wire bytes unchanged
    assert m["up_mbytes"] == m_raw["up_mbytes"]
    # per-round accounting: one release per participating client
    assert t.accountant.steps == 2
    # ...and the privatized aggregate differs from the raw one
    diff = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(
                   jax.tree.leaves(t.state.d_params["c0"]),
                   jax.tree.leaves(t_raw.state.d_params["c0"])))
    assert diff > 0.0
