"""Data pipeline + checkpoint round-trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import (BatchIterator, partition_dirichlet, partition_iid,
                        synthetic_lm_batch, synthetic_mnist, synthetic_tokens)


def test_synthetic_mnist_deterministic_and_ranged():
    a, la = synthetic_mnist(64, seed=3)
    b, lb = synthetic_mnist(64, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (64, 28, 28, 1)
    assert a.min() >= -1.0 and a.max() <= 1.0
    assert set(np.unique(la)).issubset(set(range(10)))


def test_synthetic_mnist_classes_distinct():
    imgs, labels = synthetic_mnist(2000, seed=0)
    means = np.stack([imgs[labels == l].mean(0) for l in range(10)])
    # class prototypes differ pairwise
    d = np.linalg.norm((means[:, None] - means[None]).reshape(100, -1), axis=1)
    assert (d[np.eye(10, dtype=bool).reshape(-1) == 0] > 1.0).all()


def test_synthetic_tokens_vocab_bound():
    t = synthetic_tokens(8, 128, vocab=97, seed=1)
    assert t.min() >= 0 and t.max() < 97


def test_lm_batch_shift():
    b = synthetic_lm_batch(2, 16, 100, seed=0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=50, max_value=400),
       k=st.integers(min_value=2, max_value=8),
       alpha=st.floats(min_value=0.05, max_value=10.0))
def test_dirichlet_partition_properties(n, k, alpha):
    data = np.arange(n)
    labels = np.arange(n) % 10
    parts = partition_dirichlet(data, labels, k, alpha=alpha, seed=0)
    assert len(parts) == k
    allv = np.concatenate(list(parts.values()))
    # every client non-empty; no element duplicated beyond the guarantee pad
    assert all(len(v) > 0 for v in parts.values())
    assert len(np.unique(allv)) >= min(n, len(allv) - k)


def test_iid_partition_is_disjoint_cover():
    data = np.arange(100)
    parts = partition_iid(data, 4, seed=0)
    allv = np.sort(np.concatenate(list(parts.values())))
    np.testing.assert_array_equal(allv, data)


def test_batch_iterator_drop_last():
    it = BatchIterator(np.arange(10), batch_size=3, seed=0)
    batches = list(it.epoch())
    assert len(batches) == 3
    assert all(len(b) == 3 for b in batches)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.ones((3, 4), jnp.bfloat16),
            "b": {"c": jnp.arange(5), "d": jnp.asarray(2.5)}}
    p = os.path.join(tmp_path, "x.npz")
    save_pytree(p, tree, {"note": "hi"})
    got, extra = load_pytree(p, like=tree)
    assert extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.steps() == [3, 4]
    _, extra = mgr.restore(like=tree)
    assert extra["step"] == 4
