"""Control plane (control/): feedback emission, the four controllers, and
the knob-application path through the trainer/engine.

Pinned invariants (ISSUE 5 acceptance):
  * control.mode='frozen' (the default) emits RoundFeedback but never
    steers — training stays bit-exact with the static build (the
    sync/loop/no-codec pin in test_fed_runtime already runs under frozen;
    here the feedback record itself is checked against the measurements);
  * the sigma controller never spends past the (epsilon, delta) budget
    over a full run, pinned against the accountant;
  * the codec controller walks the bytes-vs-error frontier cheapest-first,
    so every probe is cheaper than the codec it commits to;
  * the split controller replans + reassigns per-boundary stages only on
    measured drift, and the regrouped run keeps training.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.control import (CodecController, ControlKnobs, DeadlineController,
                           RoundFeedback, SigmaController, SplitController,
                           knobs_from_config, make_controllers)
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist
from repro.fed.transport import predict_codec_bytes
from repro.privacy.defenses import RDPAccountant


def _cfg(**over):
    base = {"shape.global_batch": 8, "fsl.num_clients": 2,
            "model.dcgan.base_filters": 8}
    base.update(over)
    return get_config("dcgan-mnist").override(base)


@pytest.fixture(scope="module")
def parts():
    imgs, labels = synthetic_mnist(120, seed=0)
    return partition_dirichlet(imgs, labels, 2, alpha=0.5, seed=0)


def _fb(i, *, codec="none", codec_error=float("nan"), sigma=0.0,
        dp_steps=0, dp_epsilon=float("nan"), finish=None, loads=None,
        dcor=None, strategy="sorted_multi", up=1000):
    """Synthetic RoundFeedback for pure controller tests."""
    return RoundFeedback(
        round_index=i, backend="loop", codec=codec, sigma=sigma,
        deadline_s=0.0, split_strategy=strategy, up_bytes=up, down_bytes=0,
        lan_bytes=0, codec_error=codec_error, uplink_bps=10e6,
        round_time_s=1.0, clock_s=float(i), client_finish_s=finish or {},
        num_clients=2, stragglers=0, dp_epsilon=dp_epsilon,
        dp_steps=dp_steps, device_loads=loads or {}, boundary_dcor=dcor or {})


# ---------------------------------------------------------------------------
# frozen mode: measurement without steering
# ---------------------------------------------------------------------------

def test_frozen_default_emits_feedback_and_never_steers(parts):
    t = FSLGANTrainer(_cfg(), parts, seed=0)
    assert t.cfg.control.mode == "frozen"
    m = t.train_epoch(batches_per_client=2)
    assert len(t.feedback) == 1
    fb = t.feedback[-1]
    # the record reflects the measurements the metrics already report
    assert fb.up_bytes == int(m["up_mbytes"] * 1e6)
    assert fb.down_bytes == int(m["down_mbytes"] * 1e6)
    assert fb.round_time_s == m["round_time_s"]
    assert fb.codec == "none" and fb.sigma == 0.0 and fb.deadline_s == 0.0
    assert fb.num_clients == 2 and math.isnan(fb.dp_epsilon)
    # measured per-client finish times cover every participant
    assert set(fb.client_finish_s) == {"c0", "c1"}
    assert all(v > 0 for v in fb.client_finish_s.values())
    # frozen: knobs still the config values after the round
    assert t.knobs == knobs_from_config(t.cfg)
    assert t.engine.codec_name == "none"


def test_adaptive_mode_requires_valid_controller_names():
    with pytest.raises(ValueError, match="controllers"):
        _cfg(**{"control.mode": "adaptive",
                "control.controllers": ["codec", "warp"]})


# ---------------------------------------------------------------------------
# codec controller (pure)
# ---------------------------------------------------------------------------

def test_codec_controller_probes_cheapest_first_then_commits():
    leaf_sizes = [1000, 24]
    ctl = CodecController(("none", "fp16", "int8", "topk"), 0.05,
                          leaf_sizes, topk_frac=0.05)
    ranked = ctl.ranked
    assert ranked == sorted(ranked, key=ctl.bytes_of.get)
    assert ranked[0] == "topk"                 # cheapest for this tree
    knobs = ControlKnobs(codec="none")
    # round 0: no history -> probe the cheapest candidate
    k0 = ctl([], knobs)
    assert k0.codec == "topk"
    # topk measured over budget -> walk to the next-cheapest unprobed
    hist = [_fb(0, codec="topk", codec_error=0.9)]
    k1 = ctl(hist, k0)
    assert k1.codec == "int8"
    # int8 measured within budget -> commit (and stay committed)
    hist.append(_fb(1, codec="int8", codec_error=0.003))
    k2 = ctl(hist, k1)
    assert k2.codec == "int8"
    # every codec probed on the way is cheaper than the commit — the
    # structural reason adaptive bytes <= best static bytes
    assert ctl.bytes_of["topk"] < ctl.bytes_of["int8"]
    # drift: the committed codec's error rises over budget -> move on
    hist.append(_fb(2, codec="int8", codec_error=0.2))
    assert ctl(hist, k2).codec == "fp16"


def test_codec_controller_all_over_budget_stays_inside_candidates():
    """A restricted candidate list is a hard constraint: when every
    candidate measures over budget, the fallback is the least-lossy
    CANDIDATE, never a codec the config excluded (e.g. lossless 'none'
    on a bandwidth-capped run)."""
    ctl = CodecController(("topk", "int8"), 1e-6, [1000], topk_frac=0.05)
    hist = [_fb(0, codec="topk", codec_error=0.9),
            _fb(1, codec="int8", codec_error=0.1)]
    assert ctl(hist, ControlKnobs(codec="int8")).codec == "int8"
    assert "none" not in ctl.bytes_of


def test_codec_controller_rounds_with_no_uplink_measure_nothing():
    ctl = CodecController(("int8", "none"), 0.05, [100])
    # a deadline-starved round measures nothing: codec stays unprobed and
    # is probed again rather than treated as error-free
    hist = [_fb(0, codec="int8", codec_error=float("nan"))]
    assert ctl(hist, ControlKnobs(codec="int8")).codec == "int8"


def test_predict_codec_bytes_matches_codec_accounting():
    from repro.fed.transport import make_codec
    tree = {"w": jnp.ones((50, 20), jnp.float32),
            "b": jnp.ones((24,), jnp.float32)}
    sizes = [50 * 20, 24]
    for name in ("none", "fp16", "int8", "topk"):
        codec = make_codec(name, topk_frac=0.05, error_feedback=False)
        _, measured = codec.roundtrip(tree)
        assert predict_codec_bytes(name, sizes, topk_frac=0.05) == measured


# ---------------------------------------------------------------------------
# sigma controller (pure + pinned against the accountant)
# ---------------------------------------------------------------------------

def test_sigma_controller_solves_budget_and_self_corrects():
    ctl = SigmaController(4.0, 6, 1e-5, 1.0, steps_per_round_hint=2)
    knobs = ControlKnobs(sigma=1.0)
    k0 = ctl([], knobs)
    assert k0.sigma > 1.0          # config sigma would overspend
    # replaying the controller's own decisions never exceeds the budget
    acct = RDPAccountant(k0.sigma, 1.0)
    hist, k = [], k0
    for r in range(6):
        k = ctl(hist, k)
        acct.step(2, noise_multiplier=k.sigma)
        hist.append(_fb(r, sigma=k.sigma, dp_steps=2,
                        dp_epsilon=acct.epsilon(1e-5)[0]))
    assert acct.epsilon(1e-5)[0] <= 4.0 * (1 + 1e-9)
    # and it spends most of the budget rather than sandbagging
    assert acct.epsilon(1e-5)[0] > 0.8 * 4.0


def test_sigma_controller_hysteresis_never_relaxes_budget():
    ctl = SigmaController(1.0, 4, 1e-5, 1.0, steps_per_round_hint=1,
                          rel_change=0.5)
    # an under-noised current sigma MUST be raised even within rel_change
    k = ctl([], ControlKnobs(sigma=0.1))
    assert k.sigma > 0.1


def test_sigma_controller_unreachable_budget_clamps_to_sigma_max():
    """The guarantee's documented boundary: a budget below even the
    sigma_max spend clamps to sigma_max (maximum protection) rather than
    diverging or silently disabling noise."""
    ctl = SigmaController(1e-6, 10, 1e-5, 1.0, steps_per_round_hint=100,
                          sigma_max=50.0)
    assert ctl([], ControlKnobs(sigma=1.0)).sigma == 50.0
    # and fluctuating round lengths project at the historical maximum
    ctl2 = SigmaController(2.0, 4, 1e-5, 1.0, steps_per_round_hint=1)
    hist = [_fb(0, sigma=2.0, dp_steps=10), _fb(1, sigma=2.0, dp_steps=2)]
    k_small = ctl2(hist, ControlKnobs(sigma=2.0))
    hist_flat = [_fb(0, sigma=2.0, dp_steps=10),
                 _fb(1, sigma=2.0, dp_steps=10)]
    k_flat = ctl2(hist_flat, ControlKnobs(sigma=2.0))
    # same projected steps/round (the max), identically-sized tail budget
    # differences only from realized spend — both conservative
    assert k_small.sigma >= k_flat.sigma * 0.99


def test_sigma_controller_trainer_run_pinned_against_accountant(parts):
    """ISSUE 5 acceptance pin: a full adaptive run (uplink DP) spends at
    most the configured (epsilon, delta) budget, per the accountant."""
    budget, horizon = 3.0, 4
    over = {"privacy.enabled": True, "privacy.mode": "uplink",
            "privacy.noise_multiplier": 0.7,
            "control.mode": "adaptive", "control.controllers": ["sigma"],
            "control.epsilon_budget": budget,
            "control.horizon_rounds": horizon}
    t = FSLGANTrainer(_cfg(**over), parts, seed=0)
    for _ in range(horizon):
        m = t.train_epoch(batches_per_client=1)
    assert m["dp_epsilon"] <= budget * (1 + 1e-9)
    assert m["dp_epsilon"] == t.accountant.epsilon(t.cfg.privacy.delta)[0]
    # the controller retuned sigma away from the static config value
    assert t.feedback[-1].sigma != 0.7
    # and the rebound sigma reached the live uplink stage
    assert t._uplink_stage.noise_multiplier == t.knobs.sigma


# ---------------------------------------------------------------------------
# deadline controller (pure + engine application)
# ---------------------------------------------------------------------------

def test_deadline_controller_takes_quantile_of_measured_finishes():
    ctl = DeadlineController(quantile=0.75, slack=1.2, warmup=1)
    hist = [_fb(0, finish={"c0": 10.0, "c1": 20.0, "c2": 30.0,
                           "c3": 1000.0})]
    k = ctl(hist, ControlKnobs())
    assert k.deadline_s == pytest.approx(30.0 * 1.2)
    # warmup: no decision before enough feedback
    assert DeadlineController(warmup=2)(hist, ControlKnobs()).deadline_s \
        == 0.0


def test_deadline_controller_reaches_engine(parts):
    over = {"fed.client_local_steps": {"c1": 4},
            "control.mode": "adaptive",
            "control.controllers": ["deadline"],
            "control.deadline_quantile": 0.5,
            "control.deadline_slack": 1.05}
    t = FSLGANTrainer(_cfg(**over), parts, seed=0)
    t.train_epoch(batches_per_client=1)      # measure
    m = t.train_epoch(batches_per_client=1)  # decide + apply
    assert t.engine.deadline_s > 0
    assert t.engine.deadline_s == t.knobs.deadline_s
    # the median-based deadline cuts the 4x-longer c1 round
    assert m["stragglers"] >= 1.0


# ---------------------------------------------------------------------------
# split controller (pure + regroup integration)
# ---------------------------------------------------------------------------

def test_split_controller_pure_decisions():
    ctl = SplitController(imbalance_threshold=1.5, dcor_threshold=0.5,
                          replan_strategy="sorted_multi", leaky_stage="dp")
    knobs = ControlKnobs(split_strategy="random_single")
    # balanced loads, low dcor: nothing changes — an all-base stage map
    # normalizes to None so no spurious regroup/recompile is triggered
    hist = [_fb(0, loads={"d0": 1.0, "d1": 1.0},
                dcor={"c0": (0.2, 0.1)}, strategy="random_single")]
    k = ctl(hist, knobs)
    assert k is knobs
    assert k.split_strategy == "random_single"
    assert k.stage_by_boundary is None
    # imbalance + a leaky shallow boundary: replan + noise ONLY index 0
    hist = [_fb(0, loads={"d0": 10.0, "d1": 1.0},
                dcor={"c0": (0.9, 0.2), "c1": (0.7,)},
                strategy="random_single")]
    k = ctl(hist, knobs)
    assert k.split_strategy == "sorted_multi"
    assert k.stage_by_boundary == {0: "dp", 1: "identity"}


def test_split_controller_regroups_trainer_and_keeps_training(parts):
    over = {"split.enabled": True, "fsl.selection": "random_single",
            "split.stage_sigma": 0.3, "split.stage_clip": 5.0,
            "control.mode": "adaptive", "control.controllers": ["split"],
            "control.imbalance_threshold": 1.2,
            "control.dcor_threshold": 0.3, "control.probe_batch": 8}
    t = FSLGANTrainer(_cfg(**over), parts, seed=0)
    m0 = t.train_epoch(batches_per_client=1)
    sigs0 = {cid: ex.signature for cid, ex in t.split_execs.items()}
    assert t.feedback[-1].boundary_dcor          # the probe ran
    m1 = t.train_epoch(batches_per_client=1)
    # drift detected: replanned strategy + per-boundary stage reassignment
    assert t.knobs.split_strategy == "sorted_multi"
    assert t.knobs.stage_by_boundary is not None
    assert any(t.split_execs[cid].signature != sigs0.get(cid)
               for cid in t.split_execs)
    assert np.isfinite(m1["d_loss"]) and m1["num_clients"] == 2.0
    # only measured-leaky boundaries carry the dp stage; stage lists are
    # per boundary, not uniform
    for ex in t.split_execs.values():
        assert len(ex.stages) == ex.num_boundaries
    # NO oscillation: the probe measures the RAW (pre-stage) leak, so the
    # assigned noise does not suppress its own control signal.  Round 2
    # may still shrink the map's index set once (round 1's decision was
    # probed on the PRE-replan plans); from then on the protection is
    # stable — it never strips, and the engine stops being reset.
    m2 = t.train_epoch(batches_per_client=1)
    stage_map2 = dict(t.knobs.stage_by_boundary)
    assert set(stage_map2.values()) == {"dp"}    # still protected
    eng2 = t.engine
    m3 = t.train_epoch(batches_per_client=1)
    assert dict(t.knobs.stage_by_boundary or {}) == stage_map2
    assert t.engine is eng2
    assert np.isfinite(m2["d_loss"]) and np.isfinite(m3["d_loss"])


def test_per_boundary_stages_price_and_sign_independently(parts):
    """core/split: a stages list prices each boundary with ITS stage and
    the signature distinguishes per-boundary assignments from uniform."""
    t = FSLGANTrainer(_cfg(**{"split.enabled": True}), parts, seed=0)
    cid = max(t.split_execs, key=lambda c: t.split_execs[c].num_boundaries)
    ex = t.split_execs[cid]
    nb = ex.num_boundaries
    assert nb >= 2
    from repro.core.split import SplitExecution, make_boundary_stage
    mixed = [make_boundary_stage(t.cfg.split, "int8" if b == 0 else
                                 "identity") for b in range(nb)]
    ex2 = SplitExecution(ex.plan, ex.apply_layer, ex.tails, stages=mixed)
    assert ex2.signature != ex.signature
    x_shape = (t.batch_size, 28, 28, 1)
    tot_id, per_id = ex.step_wire_bytes(t.state.d_params[cid], x_shape)
    tot_mix, per_mix = ex2.step_wire_bytes(t.state.d_params[cid], x_shape)
    assert per_mix[0]["fwd"] < per_id[0]["fwd"]      # int8 shrank index 0
    assert per_mix[1:] == per_id[1:]                 # others untouched
    assert tot_mix < tot_id
    # all-identity stages list == uniform identity, bit-exact gradients
    ex3 = SplitExecution(ex.plan, ex.apply_layer, ex.tails,
                         stages=[make_boundary_stage(t.cfg.split,
                                                     "identity")] * nb)
    real = jnp.asarray(parts[cid][: t.batch_size])
    l1, g1 = ex.value_and_grad(t.state.d_params[cid], real, real)
    l3, g3 = ex3.value_and_grad(t.state.d_params[cid], real, real)
    assert float(l1) == float(l3)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# adaptive codec, end to end through the engine
# ---------------------------------------------------------------------------

def test_adaptive_codec_commits_within_budget_and_beats_lossless(parts):
    rounds = 3
    over = {"control.mode": "adaptive", "control.controllers": ["codec"],
            "control.error_budget": 0.05, "fed.topk_frac": 0.01}
    t = FSLGANTrainer(_cfg(**over), parts, seed=0)
    for _ in range(rounds):
        t.train_epoch(batches_per_client=1)
    trace = [fb.codec for fb in t.feedback]
    assert trace[0] == "topk"                # probe the cheapest first
    assert trace[-1] == "int8"               # cheapest within budget
    assert t.engine.codec_name == "int8"
    assert t.feedback[-1].codec_error <= 0.05
    # adaptive uplink total < the lossless static run's total
    t_none = FSLGANTrainer(_cfg(), parts, seed=0)
    for _ in range(rounds):
        t_none.train_epoch(batches_per_client=1)
    assert t.engine.ledger.total_up < t_none.engine.ledger.total_up


def test_suite_order_and_factory_names():
    cfg = _cfg(**{"control.mode": "adaptive",
                  "control.controllers": ["deadline", "codec", "sigma"],
                  "control.epsilon_budget": 1.0,
                  "control.horizon_rounds": 2})
    suite = make_controllers(cfg, leaf_sizes=[10])
    assert suite.names == ("codec", "sigma", "deadline")
