"""Federation runtime (fed/): codecs, events, engine, client programs.

Pinned invariants:
  * engine sync mode (loop backend) == seed sequential loop, bit-for-bit
    at fixed seed;
  * the program's vectorized backend == sequential per-client D-steps to
    fp32 tolerance (live params; BN-cancelled conv biases are analytically
    dead and excluded), with privacy off AND with DP-SGD on (looped-DP ==
    vectorized-DP — the ISSUE 3 acceptance pin);
  * every backend x privacy x codec cell trains (matrix smoke test);
  * dropped stragglers commit no optimizer state (ISSUE 3 regression);
  * codec round-trip error bounds; wire-byte accounting sanity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist
from repro.fed.events import (ARRIVE, FINISH, BernoulliAvailability,
                              EventQueue)
from repro.fed.policies import ClientUpdate, FedAsync, FedBuff, SyncFedAvg
from repro.fed.programs import (fedavg_stacked, sequential_d_rounds,
                                stack_trees, unstack_tree)
from repro.fed.transport import (FP16Codec, IdentityCodec, Int8Codec,
                                 LinkModel, TopKCodec, TrafficLedger,
                                 fake_batch_bytes, make_codec, tree_bytes)


def _tree(seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    return {"w": scale * jax.random.normal(k, (16, 8)),
            "b": {"x": scale * jax.random.normal(jax.random.fold_in(k, 1),
                                                 (32,))}}


# ---------------------------------------------------------------------------
# transport: codecs + byte accounting
# ---------------------------------------------------------------------------

def test_tree_bytes_counts_native_dtypes():
    t = {"a": jnp.zeros((4, 4), jnp.float32), "b": jnp.zeros(10, jnp.int8)}
    assert tree_bytes(t) == 4 * 4 * 4 + 10
    assert fake_batch_bytes(16, (28, 28, 1)) == 16 * 28 * 28 * 4


def test_identity_codec_exact():
    t = _tree()
    dec, nbytes = IdentityCodec().roundtrip(t)
    for a, b in zip(jax.tree.leaves(dec), jax.tree.leaves(t)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert nbytes == tree_bytes(t)


def test_fp16_codec_error_bound_and_bytes():
    t = _tree(scale=2.0)
    dec, nbytes = FP16Codec().roundtrip(t)
    for a, b in zip(jax.tree.leaves(dec), jax.tree.leaves(t)):
        a, b = np.asarray(a), np.asarray(b)
        # fp16 has 10 mantissa bits: relative error <= 2^-11 per element
        assert np.max(np.abs(a - b)) <= np.max(np.abs(b)) * 2 ** -10
    assert nbytes == tree_bytes(t) // 2


def test_int8_codec_error_bound_and_bytes():
    t = _tree(scale=3.0)
    dec, nbytes = Int8Codec().roundtrip(t)
    for a, b in zip(jax.tree.leaves(dec), jax.tree.leaves(t)):
        a, b = np.asarray(a), np.asarray(b)
        # quantization step is amax/127; round-to-nearest error <= step/2
        step = np.max(np.abs(b)) / 127.0
        assert np.max(np.abs(a - b)) <= step * 0.5 + 1e-7
    # 1 byte/elem + 4-byte scale per leaf
    n_elem = sum(l.size for l in jax.tree.leaves(t))
    assert nbytes == n_elem + 4 * len(jax.tree.leaves(t))


def test_topk_codec_sparsity_bytes_and_full_frac_exact():
    t = _tree()
    dec, nbytes = TopKCodec(frac=0.25, error_feedback=False).roundtrip(t)
    for a, b in zip(jax.tree.leaves(dec), jax.tree.leaves(t)):
        a, b = np.asarray(a), np.asarray(b)
        k = int(np.ceil(0.25 * b.size))
        assert np.count_nonzero(a) <= k
        # kept entries are exact; dropped entries are the smallest-|x|
        kept = a != 0
        np.testing.assert_allclose(a[kept], b[kept], atol=1e-7)
    kept_total = sum(int(np.ceil(0.25 * l.size)) for l in jax.tree.leaves(t))
    assert nbytes == kept_total * 8
    # frac=1.0 keeps everything
    dec_full, _ = TopKCodec(frac=1.0, error_feedback=False).roundtrip(t)
    for a, b in zip(jax.tree.leaves(dec_full), jax.tree.leaves(t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_topk_error_feedback_conserves_mass():
    """decoded + residual == input (+ prior residual): nothing is lost,
    only delayed to a later round."""
    codec = TopKCodec(frac=0.1, error_feedback=True)
    t = _tree()
    dec, _ = codec.roundtrip(t)
    for d, r, x in zip(jax.tree.leaves(dec),
                       jax.tree.leaves(codec._residual),
                       jax.tree.leaves(t)):
        np.testing.assert_allclose(np.asarray(d) + np.asarray(r),
                                   np.asarray(x), atol=1e-6)
    # second round: the residual re-enters selection
    zero = jax.tree.map(jnp.zeros_like, t)
    dec2, _ = codec.roundtrip(zero)
    assert any(np.count_nonzero(np.asarray(l)) for l in jax.tree.leaves(dec2))


def test_int8_codec_zero_range_delta_roundtrips_exact():
    """All-constant (zero-range) deltas — the common case for frozen or
    converged leaves — must round-trip without NaN."""
    zero = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((16,))}
    dec, nbytes = Int8Codec().roundtrip(zero)
    for l in jax.tree.leaves(dec):
        a = np.asarray(l)
        assert np.all(np.isfinite(a))
        np.testing.assert_array_equal(a, np.zeros_like(a))
    assert nbytes == (8 * 8 + 16) + 4 * 2
    # constant nonzero: q = +/-127 exactly, so the round-trip is exact
    const = {"w": jnp.full((8, 8), -0.37), "b": jnp.full((16,), 0.5)}
    dec_c, _ = Int8Codec().roundtrip(const)
    for a, b in zip(jax.tree.leaves(dec_c), jax.tree.leaves(const)):
        assert np.all(np.isfinite(np.asarray(a)))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_topk_codec_k_geq_n_keeps_everything():
    """frac >= 1 (k >= n per leaf) must not IndexError in lax.top_k and
    must be the identity on the delta."""
    t = _tree()
    for frac in (1.0, 1.5, 7.0):
        dec, nbytes = TopKCodec(frac=frac, error_feedback=False).roundtrip(t)
        for a, b in zip(jax.tree.leaves(dec), jax.tree.leaves(t)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-7)
        # k clamps at n: wire bytes never exceed 8 bytes/element
        n_elem = sum(l.size for l in jax.tree.leaves(t))
        assert nbytes == n_elem * 8
    # tiny leaf (n=1) with tiny frac: k clamps up to 1, not 0
    tiny = {"s": jnp.asarray([3.0])}
    dec, nbytes = TopKCodec(frac=1e-6, error_feedback=False).roundtrip(tiny)
    np.testing.assert_allclose(np.asarray(dec["s"]), [3.0])
    assert nbytes == 8


def test_make_codec_factory():
    assert make_codec("none").name == "none"
    assert make_codec("fp16").name == "fp16"
    assert make_codec("int8").name == "int8"
    assert make_codec("topk", topk_frac=0.5).frac == 0.5
    with pytest.raises(ValueError):
        make_codec("gzip")


def test_link_model_and_ledger():
    link = LinkModel(latency_s=0.1, bandwidth_bps=8e6)
    assert link.transfer_time(0) == pytest.approx(0.1)
    assert link.transfer_time(1_000_000) == pytest.approx(0.1 + 1.0)
    led = TrafficLedger()
    led.record("c0", up=10, down=20)
    led.record("c0", up=5)
    led.record("c1", down=7)
    assert led.total_up == 15 and led.total_down == 27


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------

def test_event_queue_orders_by_time_then_insertion():
    q = EventQueue()
    q.push(2.0, FINISH, "b")
    q.push(1.0, FINISH, "a")
    q.push(1.0, ARRIVE, "c")          # same time: insertion order breaks tie
    order = [(e.time, e.client_id) for e in q.drain()]
    assert order == [(1.0, "a"), (1.0, "c"), (2.0, "b")]


def test_bernoulli_availability_deterministic_and_varied():
    tr = BernoulliAvailability(0.5, seed=3)
    draws = [tr.available(f"c{i}", r) for i in range(4) for r in range(8)]
    assert draws == [tr.available(f"c{i}", r)
                     for i in range(4) for r in range(8)]
    assert any(draws) and not all(draws)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def test_fedasync_staleness_discounts_rate():
    pol = FedAsync(alpha=0.5, staleness_power=1.0)
    assert pol.rate(0) == pytest.approx(0.5)
    assert pol.rate(3) == pytest.approx(0.5 / 4)
    g, u = _tree(0), _tree(1)
    mixed, bumped = pol.on_update(g, ClientUpdate("c0", u, 1.0, staleness=0))
    assert bumped
    want = jax.tree.map(lambda a, b: 0.5 * a + 0.5 * b, g, u)
    for a, b in zip(jax.tree.leaves(mixed), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_fedbuff_fires_at_buffer_size_and_flushes():
    pol = FedBuff(buffer_size=2, server_lr=1.0, staleness_power=0.0)
    g = _tree(0)
    g1, bumped1 = pol.on_update(g, ClientUpdate("c0", _tree(1), 1.0))
    assert not bumped1           # buffered, global untouched
    g2, bumped2 = pol.on_update(g1, ClientUpdate("c1", _tree(2), 1.0))
    assert bumped2               # K=2 reached: buffer mean replaces global
    want = jax.tree.map(lambda a, b: (a + b) / 2, _tree(1), _tree(2))
    for a, b in zip(jax.tree.leaves(g2), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # round-end flush of a partial buffer
    g3, _ = pol.on_update(g2, ClientUpdate("c2", _tree(3), 1.0))
    g4 = pol.on_round_end(g3)
    for a, b in zip(jax.tree.leaves(g4), jax.tree.leaves(_tree(3))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# engine + trainer (smoke scale)
# ---------------------------------------------------------------------------

def _cfg(**over):
    base = {"shape.global_batch": 8, "fsl.num_clients": 2,
            "model.dcgan.base_filters": 8}
    base.update(over)
    return get_config("dcgan-mnist").override(base)


@pytest.fixture(scope="module")
def parts():
    imgs, labels = synthetic_mnist(120, seed=0)
    return partition_dirichlet(imgs, labels, 2, alpha=0.5, seed=0)


def test_engine_sync_reproduces_seed_trainer_bit_for_bit(parts):
    ta = FSLGANTrainer(_cfg(), parts, seed=0)
    tb = FSLGANTrainer(_cfg(), parts, seed=0)
    for _ in range(2):
        ma = ta.train_epoch(batches_per_client=2)          # engine path
        mb = tb.train_epoch_sequential(batches_per_client=2)  # seed loop
        for k in ("d_loss", "g_loss", "num_clients"):
            assert ma[k] == mb[k]
    for cid in ta.state.d_params:
        for a, b in zip(jax.tree.leaves(ta.state.d_params[cid]),
                        jax.tree.leaves(tb.state.d_params[cid])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ta.state.g_params),
                    jax.tree.leaves(tb.state.g_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _dead_bias(path) -> bool:
    """Conv biases under batchnorm: BN mean-subtraction cancels them, so
    their gradient is fp cancellation noise that Adam amplifies to O(lr)."""
    keys = [getattr(p, "key", getattr(p, "name", None)) for p in path]
    return (len(keys) == 2 and keys[1] == "b"
            and str(keys[0]).startswith("conv") and keys[0] != "conv0")


def test_vectorized_round_matches_sequential(parts):
    tr = FSLGANTrainer(_cfg(), parts, seed=0)
    st = tr.state
    active = tr._active_clients()
    B, T = tr.batch_size, 2
    reals = jnp.stack([jnp.stack([tr._sample_real(cid, B) for _ in range(T)])
                       for cid in active])
    fakes = jnp.stack([jnp.stack([tr._gen(st.g_params, tr._z(B))
                                  for _ in range(T)]) for cid in active])

    sp = stack_trees([st.d_params[c] for c in active])
    so = stack_trees([st.d_opt[c] for c in active])
    vp, vo, v_losses = tr.program.run_vectorized(sp, so, reals, fakes)
    seq_p, seq_o, s_losses = sequential_d_rounds(
        tr._d_step, [st.d_params[c] for c in active],
        [st.d_opt[c] for c in active], reals, fakes)

    np.testing.assert_allclose(np.asarray(v_losses), np.asarray(s_losses),
                               atol=1e-5, rtol=1e-5)
    for i, cid in enumerate(active):
        got = jax.tree_util.tree_flatten_with_path(
            jax.tree.map(lambda x: x[i], vp))[0]
        want = jax.tree_util.tree_flatten_with_path(seq_p[i])[0]
        for (path, a), (_, b) in zip(got, want):
            if _dead_bias(path):
                continue
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, rtol=5e-5,
                                       err_msg=jax.tree_util.keystr(path))
        # functional equivalence including dead params: BN cancels them
        from repro.models.dcgan import disc_apply
        x = tr._sample_real(active[0], 4)
        np.testing.assert_allclose(
            np.asarray(disc_apply(jax.tree.map(lambda v: v[i], vp), x, tr.c)),
            np.asarray(disc_apply(seq_p[i], x, tr.c)), atol=1e-4, rtol=1e-4)


def test_fedavg_stacked_kernel_matches_host():
    trees = [_tree(i) for i in range(3)]
    stacked = stack_trees(trees)
    w = [1.0, 2.0, 3.0]
    host = fedavg_stacked(stacked, w)
    kern = fedavg_stacked(stacked, w, use_kernel=True, interpret=True)
    for a, b in zip(jax.tree.leaves(host), jax.tree.leaves(kern)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # unstack round-trips
    back = unstack_tree(stacked, 3)
    for t, u in zip(trees, back):
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(u)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_byte_accounting_and_codec_compression(parts):
    t_raw = FSLGANTrainer(_cfg(), parts, seed=0)
    m_raw = t_raw.train_epoch(batches_per_client=1)
    t_int8 = FSLGANTrainer(_cfg(**{"fed.codec": "int8"}), parts, seed=0)
    m_int8 = t_int8.train_epoch(batches_per_client=1)
    # downlink (fakes) identical; uplink ~4x smaller under int8
    assert m_int8["down_mbytes"] == m_raw["down_mbytes"]
    assert m_int8["up_mbytes"] < 0.3 * m_raw["up_mbytes"]
    # raw uplink == native D param bytes x clients
    d_bytes = tree_bytes(t_raw.state.d_params["c0"])
    assert m_raw["up_mbytes"] == pytest.approx(
        2 * d_bytes / 1e6, rel=1e-6)
    # engine's cumulative ledger matches the round report
    assert t_raw.engine.ledger.total_up == int(m_raw["up_mbytes"] * 1e6)


def test_straggler_deadline_drops_updates(parts):
    t = FSLGANTrainer(_cfg(**{"fed.deadline_s": 1.0}), parts, seed=0)
    m = t.train_epoch(batches_per_client=1)
    assert m["num_clients"] == 0.0 and m["stragglers"] == 2.0
    assert m["round_time_s"] == pytest.approx(1.0)


def test_async_modes_train_and_record_staleness(parts):
    for mode in ("fedasync", "fedbuff"):
        t = FSLGANTrainer(_cfg(**{"fed.mode": mode, "fed.async_cycles": 2}),
                          parts, seed=0)
        m = t.train_epoch(batches_per_client=1)
        assert np.isfinite(m["d_loss"]) and np.isfinite(m["g_loss"])
        assert m["num_clients"] == 2.0
        assert m["round_time_s"] > 0.0
        rep_events = t.engine  # 2 clients x 2 cycles = 4 arrivals expected
        assert rep_events.round_idx == 1


def test_availability_trace_gates_participation(parts):
    t = FSLGANTrainer(_cfg(**{"fed.availability": 0.5,
                              "fed.availability_seed": 3}), parts, seed=0)
    ns = [t.train_epoch(batches_per_client=1)["num_clients"]
          for _ in range(4)]
    assert min(ns) < 2.0            # somebody was down at least once


# ---------------------------------------------------------------------------
# client programs: backend x privacy orthogonality (ISSUE 3)
# ---------------------------------------------------------------------------

def _d_param_trees_close(ta, tb, atol=5e-5, rtol=5e-5):
    """Compare per-client D params, skipping BN-cancelled dead biases."""
    for cid in ta.state.d_params:
        got = jax.tree_util.tree_flatten_with_path(ta.state.d_params[cid])[0]
        want = jax.tree_util.tree_flatten_with_path(tb.state.d_params[cid])[0]
        for (path, a), (_, b) in zip(got, want):
            if _dead_bias(path):
                continue
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=atol, rtol=rtol,
                                       err_msg=f"{cid}/"
                                       + jax.tree_util.keystr(path))


def test_engine_vectorized_backend_matches_loop(parts):
    """The engine's batched vectorized dispatch == the loop backend (and
    hence the seed loop) to fp32 tolerance, at fixed seed."""
    ta = FSLGANTrainer(_cfg(), parts, seed=0)
    tb = FSLGANTrainer(_cfg(), parts, seed=0)
    for _ in range(2):
        ma = ta.train_epoch(batches_per_client=2, backend="loop")
        mb = tb.train_epoch(batches_per_client=2, backend="vectorized")
        assert ma["num_clients"] == mb["num_clients"]
        assert ma["up_mbytes"] == mb["up_mbytes"]
        np.testing.assert_allclose(ma["d_loss"], mb["d_loss"],
                                   atol=1e-5, rtol=1e-5)
    _d_param_trees_close(ta, tb)


def test_looped_dp_matches_vectorized_dp_fixed_seed(parts):
    """ISSUE 3 acceptance pin: DP-SGD through the loop backend and through
    the vectorized (vmap/scan, clip+noise inside the scanned step) backend
    draw the same noise and produce the same training to fp32 tolerance."""
    over = {"privacy.enabled": True, "privacy.noise_multiplier": 0.8}
    ta = FSLGANTrainer(_cfg(**over), parts, seed=0)
    tb = FSLGANTrainer(_cfg(**over), parts, seed=0)
    for _ in range(2):
        ma = ta.train_epoch(batches_per_client=2, backend="loop")
        mb = tb.train_epoch(batches_per_client=2, backend="vectorized")
        np.testing.assert_allclose(ma["d_loss"], mb["d_loss"],
                                   atol=1e-5, rtol=1e-5)
        assert ma["dp_epsilon"] == mb["dp_epsilon"]
    assert ta.accountant.steps == tb.accountant.steps == 2 * 2 * 2
    _d_param_trees_close(ta, tb)


MATRIX_BACKENDS = ("loop", "vectorized")
MATRIX_PRIVACY = {
    "none": {},
    "dp_sgd": {"privacy.enabled": True, "privacy.noise_multiplier": 0.5},
    "uplink": {"privacy.enabled": True, "privacy.mode": "uplink",
               "privacy.noise_multiplier": 0.5},
}
MATRIX_CODECS = ("none", "fp16", "int8", "topk")


@pytest.mark.parametrize("backend", MATRIX_BACKENDS)
@pytest.mark.parametrize("privacy", sorted(MATRIX_PRIVACY))
@pytest.mark.parametrize("codec", MATRIX_CODECS)
def test_backend_privacy_codec_matrix(parts, backend, privacy, codec):
    """Every backend x privacy x codec cell trains: finite losses, both
    clients participate, and privacy modes account a positive epsilon.
    Neither NotImplementedError wall exists any more."""
    over = {"fed.codec": codec, "fed.topk_frac": 0.25,
            **MATRIX_PRIVACY[privacy]}
    t = FSLGANTrainer(_cfg(**over), parts, seed=0)
    m = t.train_epoch(batches_per_client=1, backend=backend)
    assert np.isfinite(m["d_loss"]) and np.isfinite(m["g_loss"])
    assert m["num_clients"] == 2.0
    if privacy == "none":
        assert "dp_epsilon" not in m
    else:
        assert 0 < m["dp_epsilon"] < float("inf")


@pytest.mark.parametrize("mode,backend", [("fedasync", "vectorized"),
                                          ("fedbuff", "loop")])
def test_async_scheduling_composes_with_backends_and_dp(parts, mode,
                                                        backend):
    """Scheduling x backend x privacy: async modes execute the program
    per-arrival under either backend, DP-SGD included."""
    t = FSLGANTrainer(_cfg(**{"fed.mode": mode, "fed.async_cycles": 2,
                              "privacy.enabled": True,
                              "privacy.noise_multiplier": 0.5}),
                      parts, seed=0)
    m = t.train_epoch(batches_per_client=1, backend=backend)
    assert np.isfinite(m["d_loss"]) and m["num_clients"] == 2.0
    # 2 clients x 2 cycles x 1 batch DP releases
    assert t.accountant.steps == 4


def test_straggler_drop_commits_no_opt_state(parts):
    """ISSUE 3 regression: a client that RUNS but whose update lands after
    the deadline must leave the trainer's opt state untouched (the old
    ``_local_update_fn`` mutated ``st.d_opt`` as a side effect, leaving it
    ahead of the re-broadcast params)."""
    # c1 runs 3x the batches => strictly the slowest; pick a deadline after
    # its compute finishes but before its uplink lands
    over = {"fed.client_local_steps": {"c1": 3}}
    probe = FSLGANTrainer(_cfg(**over), parts, seed=0)
    eng = probe._ensure_engine(1)
    batch_b = fake_batch_bytes(probe.batch_size, (28, 28, 1))
    # downlink is priced per client: c1's 3-step schedule downloads 3x
    down_t = {cid: eng.downlink.transfer_time(
        eng.specs[cid].local_steps * batch_b) for cid in ("c0", "c1")}
    up_t = {cid: eng.uplink.transfer_time(
        tree_bytes(probe.state.d_params[cid])) for cid in ("c0", "c1")}
    finish = {cid: down_t[cid] + eng.specs[cid].compute_time_s + up_t[cid]
              for cid in ("c0", "c1")}
    assert finish["c1"] > finish["c0"]
    deadline = finish["c1"] - up_t["c1"] / 2
    assert down_t["c1"] + eng.specs["c1"].compute_time_s < deadline
    assert finish["c0"] < deadline

    t = FSLGANTrainer(_cfg(**over, **{"fed.deadline_s": deadline}),
                      parts, seed=0)
    m = t.train_epoch(batches_per_client=1)
    assert m["num_clients"] == 1.0 and m["stragglers"] == 1.0
    # c1 executed (its losses are in the round mean) ...
    assert len(t.state.history["d_loss"]) == 1 and np.isfinite(m["d_loss"])
    # ... but committed nothing: opt state still at initialization, while
    # the survivor advanced
    assert int(t.state.d_opt["c1"]["step"]) == 0
    assert int(t.state.d_opt["c0"]["step"]) == 1
    # and the wire accounting matches the per-client schedule: c1's 3-step
    # round downloaded 3x the fake payload
    assert t.engine.ledger.down_bytes["c1"] == 3 * batch_b
    assert t.engine.ledger.down_bytes["c0"] == batch_b


def test_split_loop_backend_bit_exact_vs_unsplit_sequential(parts):
    """ISSUE 4 acceptance pin: with cfg.split enabled (identity stage) the
    local step executes THROUGH the plan — staged segment forward/backward
    with boundary hand-offs — yet training is bit-for-bit the seed's
    monolithic sequential loop."""
    ta = FSLGANTrainer(_cfg(**{"split.enabled": True}), parts, seed=0)
    tb = FSLGANTrainer(_cfg(), parts, seed=0)
    assert any(ex.num_boundaries > 0 for ex in ta.split_execs.values())
    for _ in range(2):
        ma = ta.train_epoch(batches_per_client=2, backend="loop")
        mb = tb.train_epoch_sequential(batches_per_client=2)
        assert ma["d_loss"] == mb["d_loss"]
        assert ma["g_loss"] == mb["g_loss"]
    for cid in ta.state.d_params:
        for a, b in zip(jax.tree.leaves(ta.state.d_params[cid]),
                        jax.tree.leaves(tb.state.d_params[cid])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_split_vectorized_backend_matches_loop(parts):
    """ISSUE 4 acceptance pin: the split-executed step under the
    vectorized backend (clients grouped per split signature, one jitted
    vmap/scan program per group) == the loop backend to fp32 tolerance."""
    ta = FSLGANTrainer(_cfg(**{"split.enabled": True}), parts, seed=0)
    tb = FSLGANTrainer(_cfg(**{"split.enabled": True}), parts, seed=0)
    # the paper pool gives these two clients DIFFERENT boundary
    # signatures, so this exercises the per-signature grouped dispatch
    sigs = {ta.program.signature_for(cid) for cid in ta._active_clients()}
    assert len(sigs) == 2
    for _ in range(2):
        ma = ta.train_epoch(batches_per_client=2, backend="loop")
        mb = tb.train_epoch(batches_per_client=2, backend="vectorized")
        np.testing.assert_allclose(ma["d_loss"], mb["d_loss"],
                                   atol=1e-5, rtol=1e-5)
        assert ma["lan_mbytes"] == mb["lan_mbytes"] > 0
    _d_param_trees_close(ta, tb)


def test_split_reports_measured_lan_bytes(parts):
    """ISSUE 4 acceptance: train_epoch with cfg.split reports nonzero
    measured LAN boundary bytes equal to tree_bytes of the boundary
    tensors the step actually ships (x steps x clients)."""
    t = FSLGANTrainer(_cfg(**{"split.enabled": True}), parts, seed=0)
    steps = 2
    m = t.train_epoch(batches_per_client=steps)
    expect = 0
    for cid in t._active_clients():
        ex = t.split_execs[cid]
        real = jnp.zeros((t.batch_size, 28, 28, 1))
        rec = ex.shipped_boundaries(t.state.d_params[cid], real, real)
        expect += steps * sum(tree_bytes(x) for d in ("fwd", "bwd")
                              for pair in rec[d] for x in pair)
    assert expect > 0
    assert m["lan_mbytes"] == pytest.approx(expect / 1e6)
    assert t.engine.ledger.total_lan == expect
    assert m["max_device_load"] > 0
    # round time is priced from the measured bytes, not the bare constant
    eng_split = t.engine.specs["c0"].compute_time_s
    t_unsplit = FSLGANTrainer(_cfg(), parts, seed=0)
    t_unsplit.train_epoch(batches_per_client=steps)
    assert eng_split != t_unsplit.engine.specs["c0"].compute_time_s
    # unsplit rounds ship nothing over the LAN
    assert t_unsplit.engine.ledger.total_lan == 0
    assert "lan_mbytes" not in t_unsplit.state.history


@pytest.mark.parametrize("stage,backend", [("int8", "loop"),
                                           ("fp16", "vectorized"),
                                           ("dp", "vectorized")])
def test_split_boundary_stage_matrix(parts, stage, backend):
    """Codec/DP boundary stages compose with both backends: training stays
    finite, the staged round differs from the identity-stage one, and
    codec stages shrink the measured LAN bytes."""
    over = {"split.enabled": True, "split.boundary_stage": stage,
            "split.stage_sigma": 0.3}
    t = FSLGANTrainer(_cfg(**over), parts, seed=0)
    m = t.train_epoch(batches_per_client=1, backend=backend)
    assert np.isfinite(m["d_loss"]) and m["num_clients"] == 2.0
    t0 = FSLGANTrainer(_cfg(**{"split.enabled": True}), parts, seed=0)
    m0 = t0.train_epoch(batches_per_client=1, backend=backend)
    assert m["d_loss"] != m0["d_loss"]
    if stage in ("int8", "fp16"):
        assert 0 < m["lan_mbytes"] < m0["lan_mbytes"]
    else:
        assert m["lan_mbytes"] == m0["lan_mbytes"]


def test_split_stochastic_stage_backends_draw_same_noise(parts):
    """The dp boundary stage's noise keys derive from (round, client,
    exec, batch, boundary), so loop and vectorized backends draw identical
    noise — same pin as DP-SGD, now for the stage."""
    over = {"split.enabled": True, "split.boundary_stage": "dp",
            "split.stage_clip": 5.0, "split.stage_sigma": 0.4}
    ta = FSLGANTrainer(_cfg(**over), parts, seed=0)
    tb = FSLGANTrainer(_cfg(**over), parts, seed=0)
    ma = ta.train_epoch(batches_per_client=2, backend="loop")
    mb = tb.train_epoch(batches_per_client=2, backend="vectorized")
    np.testing.assert_allclose(ma["d_loss"], mb["d_loss"],
                               atol=1e-5, rtol=1e-5)
    _d_param_trees_close(ta, tb)


def test_sequential_reference_refuses_lossy_boundary_stage(parts):
    """train_epoch_sequential trains the monolithic D — identical to the
    split step only under the identity stage.  A lossy stage must be
    refused, not silently diverge from every engine path."""
    t = FSLGANTrainer(_cfg(**{"split.enabled": True,
                              "split.boundary_stage": "int8"}),
                      parts, seed=0)
    with pytest.raises(ValueError, match="identity-stage"):
        t.train_epoch_sequential(batches_per_client=1)
    # identity stage keeps the reference valid (the bit-exact pin)
    t2 = FSLGANTrainer(_cfg(**{"split.enabled": True}), parts, seed=0)
    m = t2.train_epoch_sequential(batches_per_client=1)
    assert np.isfinite(m["d_loss"])


def test_split_composes_with_dp_sgd_and_codec(parts):
    """Split execution x DP-SGD x uplink codec in one round, both
    backends agreeing — the fourth axis joins the matrix instead of
    becoming a divergent path."""
    over = {"split.enabled": True, "fed.codec": "int8",
            "privacy.enabled": True, "privacy.noise_multiplier": 0.5}
    ta = FSLGANTrainer(_cfg(**over), parts, seed=0)
    tb = FSLGANTrainer(_cfg(**over), parts, seed=0)
    ma = ta.train_epoch(batches_per_client=1, backend="loop")
    mb = tb.train_epoch(batches_per_client=1, backend="vectorized")
    np.testing.assert_allclose(ma["d_loss"], mb["d_loss"],
                               atol=1e-5, rtol=1e-5)
    assert ma["dp_epsilon"] == mb["dp_epsilon"] > 0
    assert ma["lan_mbytes"] == mb["lan_mbytes"] > 0
    _d_param_trees_close(ta, tb)


def test_per_client_schedules_thread_through_backends(parts):
    """cfg.fed.client_lr_scales / client_local_steps reach both backends:
    per-client step counts differ, scaling the LR changes training, and
    the two backends agree on the scheduled round."""
    over = {"fed.client_lr_scales": {"c0": 0.25},
            "fed.client_local_steps": {"c1": 3}}
    ta = FSLGANTrainer(_cfg(**over), parts, seed=0)
    tb = FSLGANTrainer(_cfg(**over), parts, seed=0)
    ma = ta.train_epoch(batches_per_client=1, backend="loop")
    mb = tb.train_epoch(batches_per_client=1, backend="vectorized")
    # heterogeneous local_steps: c1 ran 3 batches, c0 ran 1
    assert int(ta.state.d_opt["c1"]["step"]) == 3
    assert int(ta.state.d_opt["c0"]["step"]) == 1
    assert int(tb.state.d_opt["c1"]["step"]) == 3
    np.testing.assert_allclose(ma["d_loss"], mb["d_loss"],
                               atol=1e-5, rtol=1e-5)
    _d_param_trees_close(ta, tb)
    # the lr_scale actually bites: the aggregated model differs from the
    # default-schedule run (losses can't show it — they are evaluated
    # before each step's update)
    tc = FSLGANTrainer(_cfg(**{"fed.client_local_steps": {"c1": 3}}),
                       parts, seed=0)
    tc.train_epoch(batches_per_client=1)
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(tc.state.d_params["c0"]),
                               jax.tree.leaves(ta.state.d_params["c0"])))
    assert diff > 1e-6
