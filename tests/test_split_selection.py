"""FSL split + device-selection: property-based tests (hypothesis) over the
paper's §4 invariants, plus the executed-split layer (SplitExecution):
staged gradients vs monolithic, boundary stages, measured LAN pricing."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.config import DCGANConfig, SplitConfig
from repro.core.devices import Client, Device, make_pool
from repro.core.gan import bce_logits, d_loss_fn
from repro.core.selection import STRATEGIES, make_plan, plan_all_clients
from repro.core.simulate import epoch_time_report, strategy_sweep
from repro.core.split import (BoundaryStage, CodecBoundaryStage,
                              GaussianBoundaryStage, InfeasibleSplit,
                              SplitExecution, SplitPlan, make_boundary_stage,
                              partition_params, plan_segments, split_forward)
from repro.models.dcgan import (disc_apply, disc_init, disc_apply_layer,
                                disc_layer_costs, disc_layer_names)

LAYERS = [("l0", 1.0), ("l1", 2.0), ("l2", 4.0), ("l3", 1.0), ("l4", 0.5)]


def _client(caps, tfs):
    return Client("c0", [Device(f"d{i}", tf, cap)
                         for i, (cap, tf) in enumerate(zip(caps, tfs))])


devices_strategy = st.lists(
    st.tuples(st.integers(min_value=1, max_value=6),
              st.floats(min_value=0.1, max_value=10.0)),
    min_size=1, max_size=8)


@settings(max_examples=50, deadline=None)
@given(devs=devices_strategy,
       strategy=st.sampled_from(STRATEGIES),
       seed=st.integers(min_value=0, max_value=99))
def test_plan_invariants(devs, strategy, seed):
    """Any feasible plan covers the model exactly, in order, within capacity."""
    client = _client([c for c, _ in devs], [t for _, t in devs])
    total_cap = sum(c for c, _ in devs)
    if total_cap < len(LAYERS):
        with pytest.raises(InfeasibleSplit):
            make_plan(client, LAYERS, strategy, seed)
        return
    plan = make_plan(client, LAYERS, strategy, seed)
    # covers model in order
    assert plan.layers_in_order() == [n for n, _ in LAYERS]
    # capacity respected: units assigned to a device <= its capacity
    units = {}
    for p in plan.portions:
        units[p.device_id] = units.get(p.device_id, 0) + len(p.layer_names)
    caps = {d.device_id: d.capacity for d in client.devices}
    for did, u in units.items():
        assert u <= caps[did], (did, u, caps[did])


def test_sorted_multi_prefers_efficient_devices():
    client = _client([4, 4], [0.1, 10.0])   # d0 fast, d1 slow
    plan = make_plan(client, LAYERS[:4], "sorted_multi", seed=0)
    # all four units fit on the efficient device
    assert all(p.device_id == "d0" for p in plan.portions)


def test_single_spreads_multi_concentrates():
    client = _client([5, 5, 5], [1.0, 1.0, 1.0])
    single = make_plan(client, LAYERS, "sorted_single", seed=0)
    multi = make_plan(client, LAYERS, "sorted_multi", seed=0)
    assert single.num_boundaries >= multi.num_boundaries


def test_infeasible_client_dropped_from_round():
    ok = _client([10], [1.0])
    bad = Client("c1", [Device("d0", 1.0, 1)])   # capacity 1 < 5 layers
    plans = plan_all_clients([ok, bad], LAYERS, "sorted_multi")
    assert set(plans) == {"c0"}


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_under_capacity_client_raises_infeasible_split(strategy):
    """Drop rule (paper §4): every strategy refuses a client whose devices
    cannot hold the whole model, via InfeasibleSplit."""
    bad = _client([1, 1], [1.0, 0.5])        # capacity 2 < 5 layer units
    with pytest.raises(InfeasibleSplit):
        make_plan(bad, LAYERS, strategy, seed=0)


def test_plan_all_clients_skips_infeasible_and_keeps_planning():
    """An infeasible client in the middle of the roster is excluded without
    aborting the round — later clients still get plans."""
    ok_a = _client([10], [1.0])
    bad = Client("c_bad", [Device("d0", 1.0, 1)])
    ok_b = Client("c_b", [Device("d0", 2.0, 3), Device("d1", 1.0, 3)])
    plans = plan_all_clients([ok_a, bad, ok_b], LAYERS, "sorted_multi")
    assert set(plans) == {"c0", "c_b"}
    for plan in plans.values():
        assert plan.layers_in_order() == [n for n, _ in LAYERS]


def test_plan_all_clients_all_infeasible_returns_empty():
    bad = [Client(f"c{i}", [Device("d0", 1.0, 1)]) for i in range(3)]
    assert plan_all_clients(bad, LAYERS, "random_multi") == {}


def test_fig2_ordering_paper_pool():
    """The paper's qualitative Fig 2 result: sorted_multi best, random_multi
    worst (compute-dominated regime with slow-but-roomy devices)."""
    pool = make_pool("paper", 5, 4, seed=0)
    c = DCGANConfig()
    costs = disc_layer_costs(c)
    total = sum(costs.values())
    layers = [(n, 4 * costs[n] / total) for n in disc_layer_names(c)]
    res = strategy_sweep(pool, layers, seeds=range(6), compute_unit_s=0.2)
    assert res["sorted_multi"][0] < res["sorted_single"][0]
    assert res["sorted_multi"][0] < res["random_single"][0]
    assert res["random_multi"][0] > res["sorted_multi"][0]
    # random strategies have nonzero variance, sorted_multi is deterministic
    assert res["random_multi"][1] > 0


def test_split_forward_identical_to_monolithic():
    """The paper's split changes WHERE layers run, never WHAT they compute."""
    c = DCGANConfig(base_filters=8)
    key = jax.random.PRNGKey(0)
    params = disc_init(key, c)
    imgs = jax.random.normal(key, (4, 28, 28, 1))
    mono = disc_apply(params, imgs, c)
    costs = disc_layer_costs(c)
    layers = [(n, costs[n]) for n in disc_layer_names(c)]
    client = _client([2, 2], [1.0, 2.0])
    for strategy in STRATEGIES:
        plan = make_plan(client, layers, strategy, seed=3)
        out = split_forward(imgs, plan,
                            lambda name, x: disc_apply_layer(name, params, x, c))
        np.testing.assert_array_equal(np.asarray(mono), np.asarray(out))


def test_time_model_hops_priced():
    client = _client([1, 1, 1, 1, 1], [1.0] * 5)
    plan = make_plan(client, LAYERS, "sorted_single", seed=0)
    from repro.core.simulate import plan_epoch_time
    t_with = plan_epoch_time(plan, client, batches_per_epoch=1,
                             lan_latency_s=0.05, compute_unit_s=0.0)
    assert t_with == pytest.approx(plan.num_boundaries * 2 * 0.05)


def test_time_model_measured_bytes():
    """Measured-bytes LAN pricing: each hop event costs latency +
    serialization; the 50 ms constant stays the no-measurement fallback."""
    client = _client([1, 1, 1, 1, 1], [1.0] * 5)
    plan = make_plan(client, LAYERS, "sorted_single", seed=0)
    from repro.core.simulate import plan_epoch_time
    events = [1_000_000, 250_000, 250_000]        # bytes per hop crossing
    t = plan_epoch_time(plan, client, batches_per_epoch=2,
                        lan_latency_s=0.01, compute_unit_s=0.0,
                        boundary_bytes=events, lan_bandwidth_bps=8e6)
    per_batch = sum(0.01 + 8.0 * b / 8e6 for b in events)
    assert t == pytest.approx(2 * per_batch)
    # empty measurement (0-boundary plan trained split): pure compute
    assert plan_epoch_time(plan, client, batches_per_epoch=1,
                           lan_latency_s=0.05, compute_unit_s=0.0,
                           boundary_bytes=[]) == 0.0
    # fallback unchanged
    assert plan_epoch_time(plan, client, batches_per_epoch=1,
                           lan_latency_s=0.05, compute_unit_s=0.0) \
        == pytest.approx(plan.num_boundaries * 2 * 0.05)


# ---------------------------------------------------------------------------
# executed split: SplitExecution staged value_and_grad + boundary stages
# ---------------------------------------------------------------------------

_C = DCGANConfig(base_filters=4)
_TAILS = (functools.partial(bce_logits, target=1.0),
          functools.partial(bce_logits, target=0.0))


def _exec_fixture(caps, tfs, strategy, seed=3, stage=None):
    costs = disc_layer_costs(_C)
    layers = [(n, costs[n]) for n in disc_layer_names(_C)]
    plan = make_plan(_client(caps, tfs), layers, strategy, seed)
    return SplitExecution(plan, functools.partial(disc_apply_layer, c=_C),
                          _TAILS, stage=stage)


def _batches(n=4, seed=0):
    k = jax.random.PRNGKey(seed)
    real = jax.random.normal(jax.random.fold_in(k, 1), (n, 28, 28, 1))
    fake = jax.random.normal(jax.random.fold_in(k, 2), (n, 28, 28, 1))
    return real, fake


def test_split_value_and_grad_bitexact_monolithic():
    """Tentpole pin: the staged split step IS the monolithic gradient under
    the identity stage — executing through the plan changes where layers
    run and what crosses the LAN, never the math, bit for bit."""
    params = disc_init(jax.random.PRNGKey(0), _C)
    real, fake = _batches()
    mono = jax.jit(lambda p, r, f: jax.value_and_grad(d_loss_fn)(
        p, r, f, _C))
    ml, mg = mono(params, real, fake)
    for strategy in STRATEGIES:
        ex = _exec_fixture([2, 2], [1.0, 2.0], strategy)
        assert ex.num_boundaries >= 1
        sl, sg = jax.jit(ex.value_and_grad)(params, real, fake)
        assert np.asarray(sl) == np.asarray(ml)
        for a, b in zip(jax.tree.leaves(sg), jax.tree.leaves(mg)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=12, deadline=None)
@given(devs=devices_strategy,
       strategy=st.sampled_from(STRATEGIES),
       seed=st.integers(min_value=0, max_value=99))
def test_random_feasible_plans_execute_like_monolithic(devs, strategy, seed):
    """Property: ANY feasible plan over ANY device roster covers the model
    in order AND its staged gradients match the monolithic ones."""
    costs = disc_layer_costs(_C)
    layers = [(n, costs[n]) for n in disc_layer_names(_C)]
    client = _client([c for c, _ in devs], [t for _, t in devs])
    if client.total_capacity() < len(layers):
        with pytest.raises(InfeasibleSplit):
            make_plan(client, layers, strategy, seed)
        return
    plan = make_plan(client, layers, strategy, seed)
    assert plan.layers_in_order() == [n for n, _ in layers]
    ex = SplitExecution(plan, functools.partial(disc_apply_layer, c=_C),
                        _TAILS)
    params = disc_init(jax.random.PRNGKey(0), _C)
    real, fake = _batches(n=2, seed=seed)
    ml, mg = jax.value_and_grad(d_loss_fn)(params, real, fake, _C)
    sl, sg = ex.value_and_grad(params, real, fake)
    np.testing.assert_allclose(np.asarray(sl), np.asarray(ml),
                               atol=1e-6, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(sg), jax.tree.leaves(mg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


def test_plan_segments_and_partition_params():
    ex = _exec_fixture([2, 2], [1.0, 2.0], "sorted_single")
    segs = plan_segments(ex.plan)
    assert len(segs) - 1 == ex.plan.num_boundaries == ex.num_boundaries
    assert [n for _, names in segs for n in names] \
        == ex.plan.layers_in_order()
    params = disc_init(jax.random.PRNGKey(0), _C)
    parts = partition_params(ex.plan, params)
    seen = [n for part in parts for n in part]
    assert seen == ex.plan.layers_in_order()


def test_shipped_boundaries_and_wire_bytes_agree():
    """What `shipped_boundaries` records is what `step_wire_bytes` prices:
    fwd + bwd tensors for both passes, native bytes under identity."""
    ex = _exec_fixture([2, 2], [1.0, 2.0], "sorted_multi")
    params = disc_init(jax.random.PRNGKey(0), _C)
    real, fake = _batches()
    rec = ex.shipped_boundaries(params, real, fake)
    assert len(rec["fwd"]) == len(rec["bwd"]) == ex.num_boundaries
    from repro.fed.transport import tree_bytes
    shipped = sum(tree_bytes(t) for d in ("fwd", "bwd")
                  for pair in rec[d] for t in pair)
    total, per_b = ex.step_wire_bytes(params, real.shape)
    assert total == shipped > 0
    assert len(per_b) == ex.num_boundaries
    # identity fwd tensor == the clean prefix activation
    clean = ex.forward_boundaries(params, real)
    for b in range(ex.num_boundaries):
        np.testing.assert_array_equal(np.asarray(rec["fwd"][b][0]),
                                      np.asarray(clean[b]))


def test_codec_boundary_stages_price_and_transform():
    from repro.fed.transport import make_codec
    shape = (4, 7, 7, 8)
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    n = int(np.prod(shape))
    ident = BoundaryStage()
    assert ident.wire_bytes(shape) == n * 4
    np.testing.assert_array_equal(np.asarray(ident.apply(x)), np.asarray(x))
    fp16 = CodecBoundaryStage(make_codec("fp16"))
    assert fp16.wire_bytes(shape) == n * 2
    assert float(jnp.max(jnp.abs(fp16.apply(x) - x))) < 1e-2
    int8 = CodecBoundaryStage(make_codec("int8"))
    assert int8.wire_bytes(shape) == n + 4
    topk = CodecBoundaryStage(make_codec("topk", topk_frac=0.25,
                                         error_feedback=False))
    assert topk.wire_bytes(shape) == int(np.ceil(0.25 * n)) * 8
    assert np.count_nonzero(np.asarray(topk.apply(x))) \
        <= int(np.ceil(0.25 * n))
    # stateful codecs cannot live inside a jitted step
    with pytest.raises(ValueError):
        CodecBoundaryStage(make_codec("topk", error_feedback=True))


def test_gaussian_boundary_stage_clips_and_noises():
    stage = GaussianBoundaryStage(clip=1.0, sigma=0.0)
    x = 100.0 * jax.random.normal(jax.random.PRNGKey(0), (3, 50))
    y = stage.apply(x, jax.random.PRNGKey(1))
    norms = np.linalg.norm(np.asarray(y).reshape(3, -1), axis=1)
    assert np.all(norms <= 1.0 + 1e-5)
    noisy = GaussianBoundaryStage(clip=1.0, sigma=0.5)
    y1 = noisy.apply(x, jax.random.PRNGKey(1))
    y2 = noisy.apply(x, jax.random.PRNGKey(1))
    y3 = noisy.apply(x, jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert float(jnp.max(jnp.abs(y1 - y3))) > 0.0
    assert noisy.stochastic and not stage.name == "identity"


def test_make_boundary_stage_factory():
    assert make_boundary_stage(SplitConfig()).name == "identity"
    assert make_boundary_stage(
        SplitConfig(boundary_stage="int8")).name == "int8"
    dp = make_boundary_stage(SplitConfig(boundary_stage="dp",
                                         stage_clip=2.0, stage_sigma=0.7))
    assert isinstance(dp, GaussianBoundaryStage)
    assert dp.clip == 2.0 and dp.sigma == 0.7
    with pytest.raises(ValueError):
        make_boundary_stage(SplitConfig(boundary_stage="gzip"))


def test_stage_parameters_are_part_of_the_signature():
    """Regression: the compilation signature must distinguish stages by
    PARAMETERS, not just name — two dp stages with different sigmas (or
    top-k stages with different fracs) must never share a compiled step."""
    from repro.fed.transport import make_codec
    a = _exec_fixture([2, 2], [1.0, 2.0], "sorted_multi",
                      stage=GaussianBoundaryStage(1.0, 0.1))
    b = _exec_fixture([2, 2], [1.0, 2.0], "sorted_multi",
                      stage=GaussianBoundaryStage(1.0, 2.0))
    assert a.signature != b.signature
    ta = _exec_fixture([2, 2], [1.0, 2.0], "sorted_multi",
                       stage=CodecBoundaryStage(make_codec(
                           "topk", topk_frac=0.1, error_feedback=False)))
    tb = _exec_fixture([2, 2], [1.0, 2.0], "sorted_multi",
                       stage=CodecBoundaryStage(make_codec(
                           "topk", topk_frac=0.5, error_feedback=False)))
    assert ta.signature != tb.signature
    # same depths + same stage params => shared program
    c = _exec_fixture([2, 2], [1.0, 2.0], "sorted_multi",
                      stage=GaussianBoundaryStage(1.0, 0.1))
    assert a.signature == c.signature


def test_shipped_prefix_defaults_to_noised_tensors():
    """Regression: probing a stochastic-stage boundary WITHOUT a key must
    still ship noised tensors — a keyless probe that silently dropped the
    noise would overstate the deployed round's leakage."""
    from repro.privacy import make_shipped_prefix_fn
    ex = _exec_fixture([2, 2], [1.0, 2.0], "sorted_multi",
                       stage=GaussianBoundaryStage(5.0, 1.0))
    params = disc_init(jax.random.PRNGKey(0), _C)
    real, _ = _batches()
    noised = make_shipped_prefix_fn(ex, params, 0)(real)
    clean = _exec_fixture([2, 2], [1.0, 2.0], "sorted_multi") \
        .forward_boundaries(params, real)[0]
    assert float(jnp.max(jnp.abs(noised - clean))) > 0.0


def test_split_execution_stage_changes_downstream_compute():
    """A lossy boundary stage feeds the STAGED activation to the next
    segment — the executed round differs from the clean one (that is the
    point: the attack surface and the training numerics are now the same
    tensors)."""
    from repro.fed.transport import make_codec
    stage = CodecBoundaryStage(make_codec("int8"))
    clean = _exec_fixture([2, 2], [1.0, 2.0], "sorted_multi")
    lossy = _exec_fixture([2, 2], [1.0, 2.0], "sorted_multi", stage=stage)
    params = disc_init(jax.random.PRNGKey(0), _C)
    real, fake = _batches()
    lc, gc = clean.value_and_grad(params, real, fake)
    ll, gl = lossy.value_and_grad(params, real, fake)
    assert float(ll) != float(lc)
    assert np.isfinite(float(ll))
