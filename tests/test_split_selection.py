"""FSL split + device-selection: property-based tests (hypothesis) over the
paper's §4 invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.config import DCGANConfig
from repro.core.devices import Client, Device, make_pool
from repro.core.selection import STRATEGIES, make_plan, plan_all_clients
from repro.core.simulate import epoch_time_report, strategy_sweep
from repro.core.split import InfeasibleSplit, SplitPlan, split_forward
from repro.models.dcgan import (disc_apply, disc_init, disc_apply_layer,
                                disc_layer_costs, disc_layer_names)

LAYERS = [("l0", 1.0), ("l1", 2.0), ("l2", 4.0), ("l3", 1.0), ("l4", 0.5)]


def _client(caps, tfs):
    return Client("c0", [Device(f"d{i}", tf, cap)
                         for i, (cap, tf) in enumerate(zip(caps, tfs))])


devices_strategy = st.lists(
    st.tuples(st.integers(min_value=1, max_value=6),
              st.floats(min_value=0.1, max_value=10.0)),
    min_size=1, max_size=8)


@settings(max_examples=50, deadline=None)
@given(devs=devices_strategy,
       strategy=st.sampled_from(STRATEGIES),
       seed=st.integers(min_value=0, max_value=99))
def test_plan_invariants(devs, strategy, seed):
    """Any feasible plan covers the model exactly, in order, within capacity."""
    client = _client([c for c, _ in devs], [t for _, t in devs])
    total_cap = sum(c for c, _ in devs)
    if total_cap < len(LAYERS):
        with pytest.raises(InfeasibleSplit):
            make_plan(client, LAYERS, strategy, seed)
        return
    plan = make_plan(client, LAYERS, strategy, seed)
    # covers model in order
    assert plan.layers_in_order() == [n for n, _ in LAYERS]
    # capacity respected: units assigned to a device <= its capacity
    units = {}
    for p in plan.portions:
        units[p.device_id] = units.get(p.device_id, 0) + len(p.layer_names)
    caps = {d.device_id: d.capacity for d in client.devices}
    for did, u in units.items():
        assert u <= caps[did], (did, u, caps[did])


def test_sorted_multi_prefers_efficient_devices():
    client = _client([4, 4], [0.1, 10.0])   # d0 fast, d1 slow
    plan = make_plan(client, LAYERS[:4], "sorted_multi", seed=0)
    # all four units fit on the efficient device
    assert all(p.device_id == "d0" for p in plan.portions)


def test_single_spreads_multi_concentrates():
    client = _client([5, 5, 5], [1.0, 1.0, 1.0])
    single = make_plan(client, LAYERS, "sorted_single", seed=0)
    multi = make_plan(client, LAYERS, "sorted_multi", seed=0)
    assert single.num_boundaries >= multi.num_boundaries


def test_infeasible_client_dropped_from_round():
    ok = _client([10], [1.0])
    bad = Client("c1", [Device("d0", 1.0, 1)])   # capacity 1 < 5 layers
    plans = plan_all_clients([ok, bad], LAYERS, "sorted_multi")
    assert set(plans) == {"c0"}


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_under_capacity_client_raises_infeasible_split(strategy):
    """Drop rule (paper §4): every strategy refuses a client whose devices
    cannot hold the whole model, via InfeasibleSplit."""
    bad = _client([1, 1], [1.0, 0.5])        # capacity 2 < 5 layer units
    with pytest.raises(InfeasibleSplit):
        make_plan(bad, LAYERS, strategy, seed=0)


def test_plan_all_clients_skips_infeasible_and_keeps_planning():
    """An infeasible client in the middle of the roster is excluded without
    aborting the round — later clients still get plans."""
    ok_a = _client([10], [1.0])
    bad = Client("c_bad", [Device("d0", 1.0, 1)])
    ok_b = Client("c_b", [Device("d0", 2.0, 3), Device("d1", 1.0, 3)])
    plans = plan_all_clients([ok_a, bad, ok_b], LAYERS, "sorted_multi")
    assert set(plans) == {"c0", "c_b"}
    for plan in plans.values():
        assert plan.layers_in_order() == [n for n, _ in LAYERS]


def test_plan_all_clients_all_infeasible_returns_empty():
    bad = [Client(f"c{i}", [Device("d0", 1.0, 1)]) for i in range(3)]
    assert plan_all_clients(bad, LAYERS, "random_multi") == {}


def test_fig2_ordering_paper_pool():
    """The paper's qualitative Fig 2 result: sorted_multi best, random_multi
    worst (compute-dominated regime with slow-but-roomy devices)."""
    pool = make_pool("paper", 5, 4, seed=0)
    c = DCGANConfig()
    costs = disc_layer_costs(c)
    total = sum(costs.values())
    layers = [(n, 4 * costs[n] / total) for n in disc_layer_names(c)]
    res = strategy_sweep(pool, layers, seeds=range(6), compute_unit_s=0.2)
    assert res["sorted_multi"][0] < res["sorted_single"][0]
    assert res["sorted_multi"][0] < res["random_single"][0]
    assert res["random_multi"][0] > res["sorted_multi"][0]
    # random strategies have nonzero variance, sorted_multi is deterministic
    assert res["random_multi"][1] > 0


def test_split_forward_identical_to_monolithic():
    """The paper's split changes WHERE layers run, never WHAT they compute."""
    c = DCGANConfig(base_filters=8)
    key = jax.random.PRNGKey(0)
    params = disc_init(key, c)
    imgs = jax.random.normal(key, (4, 28, 28, 1))
    mono = disc_apply(params, imgs, c)
    costs = disc_layer_costs(c)
    layers = [(n, costs[n]) for n in disc_layer_names(c)]
    client = _client([2, 2], [1.0, 2.0])
    for strategy in STRATEGIES:
        plan = make_plan(client, layers, strategy, seed=3)
        out = split_forward(imgs, plan,
                            lambda name, x: disc_apply_layer(name, params, x, c))
        np.testing.assert_array_equal(np.asarray(mono), np.asarray(out))


def test_time_model_hops_priced():
    client = _client([1, 1, 1, 1, 1], [1.0] * 5)
    plan = make_plan(client, LAYERS, "sorted_single", seed=0)
    from repro.core.simulate import plan_epoch_time
    t_with = plan_epoch_time(plan, client, batches_per_epoch=1,
                             lan_latency_s=0.05, compute_unit_s=0.0)
    assert t_with == pytest.approx(plan.num_boundaries * 2 * 0.05)
