"""The serving invariant: prefill + token-by-token decode reproduces the
teacher-forced forward logits exactly (per family, incl. ring buffers,
MLA latent cache, RWKV/RG-LRU recurrent state)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduce_for_smoke
from repro.configs.registry import get_config
from repro.models.transformer import (lm_apply, lm_decode_step, lm_init,
                                      lm_prefill)

ARCHS = ["qwen3-14b", "rwkv6-1.6b", "olmoe-1b-7b", "deepseek-v2-lite-16b",
         "recurrentgemma-9b", "whisper-base", "chameleon-34b", "granite-20b"]


def _setup(arch, seq=12, batch=2, **over):
    cfg = reduce_for_smoke(get_config(arch, "train_4k"), seq_len=seq,
                           batch=batch)
    if over:
        cfg = cfg.override(over)
    m = cfg.model
    key = jax.random.PRNGKey(0)
    params = lm_init(key, m)
    toks = jax.random.randint(key, (batch, seq), 0, m.vocab_size)
    batch_d = {"tokens": toks}
    if m.encdec.enabled:
        batch_d["enc_embeds"] = 0.1 * jax.random.normal(
            key, (batch, m.encdec.encoder_seq, m.d_model))
    return cfg, m, params, toks, batch_d


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    seq, pre_len = 12, 8
    cfg, m, params, toks, batch = _setup(arch, seq=seq)
    full, _ = lm_apply(params, batch, m, remat="none")
    pre = dict(batch, tokens=toks[:, :pre_len])
    lg, state, idx = lm_prefill(params, pre, m, cache_len=seq,
                                cache_dtype=jnp.float32)
    errs = [float(jnp.max(jnp.abs(lg - full[:, pre_len - 1])))]
    for t in range(pre_len, seq):
        lg, state = lm_decode_step(params, toks[:, t], state,
                                   jnp.asarray(t, jnp.int32), m)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 5e-4, f"{arch}: decode drift {max(errs):.2e}"


def test_sliding_window_ring_wraparound():
    """Ring-buffer decode stays consistent well past the window size."""
    seq, pre_len, window = 24, 6, 5
    cfg, m, params, toks, batch = _setup(
        "qwen3-14b", seq=seq, **{"model.attention": "sliding",
                                 "model.sliding_window": window})
    full, _ = lm_apply(params, batch, m, remat="none")
    pre = dict(batch, tokens=toks[:, :pre_len])
    lg, state, idx = lm_prefill(params, pre, m, cache_len=seq,
                                cache_dtype=jnp.float32)
    errs = [float(jnp.max(jnp.abs(lg - full[:, pre_len - 1])))]
    for t in range(pre_len, seq):
        lg, state = lm_decode_step(params, toks[:, t], state,
                                   jnp.asarray(t, jnp.int32), m)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 5e-4, f"ring drift {max(errs):.2e}"


def test_decode_unrolled_matches_scanned():
    cfg, m, params, toks, batch = _setup("olmoe-1b-7b", seq=8)
    from repro.models.transformer import init_decode_state
    state_a = init_decode_state(m, 2, 8, jnp.float32)
    state_b = init_decode_state(m, 2, 8, jnp.float32)
    la, _ = lm_decode_step(params, toks[:, 0], state_a,
                           jnp.asarray(0, jnp.int32), m, scan_layers=True)
    lb, _ = lm_decode_step(params, toks[:, 0], state_b,
                           jnp.asarray(0, jnp.int32), m, scan_layers=False)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)


def test_forward_unrolled_matches_scanned():
    cfg, m, params, toks, batch = _setup("recurrentgemma-9b", seq=9)
    a, _ = lm_apply(params, batch, m, remat="none", scan_layers=True)
    b, _ = lm_apply(params, batch, m, remat="none", scan_layers=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
