"""Runtime: train_step learns, FSL cadence averages, microbatch invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduce_for_smoke
from repro.configs.registry import get_config
from repro.data import synthetic_lm_batch
from repro.models.transformer import lm_init
from repro.optim import make_optimizer
from repro.runtime import make_fsl_train_step, make_train_step


def _setup(arch="qwen3-14b", seq=32, batch=8, **over):
    cfg = reduce_for_smoke(get_config(arch, "train_4k"), seq_len=seq,
                           batch=batch)
    over.setdefault("optim.warmup_steps", 0)
    over.setdefault("optim.schedule", "constant")
    cfg = cfg.override(over)
    m = cfg.model
    params = lm_init(jax.random.PRNGKey(0), m)
    opt = make_optimizer(cfg.optim)
    return cfg, m, params, opt.init(params)


def test_train_step_reduces_loss():
    cfg, m, params, opt_state = _setup(batch=8)
    step = jax.jit(make_train_step(cfg))
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_lm_batch(8, 32, m.vocab_size, seed=0).items()}
    losses = []
    for i in range(30):
        params, opt_state, metrics = step(params, opt_state, batch,
                                          jnp.asarray(i, jnp.int32))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_microbatch_count_invariance():
    """Same data, nmb=1 vs nmb=4 must give (nearly) identical updates."""
    batch_np = synthetic_lm_batch(8, 32, 256, seed=1)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    outs = {}
    for nmb in (1, 4):
        cfg, m, params, opt_state = _setup(
            batch=8, **{"parallel.microbatches": nmb,
                        "model.vocab_size": 256})
        step = jax.jit(make_train_step(cfg))
        p2, _, metrics = step(params, opt_state, batch,
                              jnp.asarray(0, jnp.int32))
        outs[nmb] = (p2, float(metrics["loss"]))
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-4)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_fsl_step_averages_on_cadence():
    """With local_steps=2: replicas diverge after step 0, equalize after
    step 1 (the FedAvg round)."""
    n_clients = 3
    cfg, m, params, opt_state = _setup(batch=4, **{"fsl.local_steps": 2})
    fsl_step = jax.jit(make_fsl_train_step(cfg, n_clients))
    cparams = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients, *x.shape)), params)
    copt = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients, *x.shape)), opt_state)

    def cbatch(seed):
        b = synthetic_lm_batch(4 * n_clients, 32, m.vocab_size, seed=seed)
        return {k: jnp.asarray(v).reshape(n_clients, 4, -1)
                for k, v in b.items()}

    def spread(t):
        # max over leaves of per-leaf max deviation across clients
        return max(float(jnp.max(jnp.abs(l - l[0:1]))) for l in
                   jax.tree.leaves(t))

    cparams, copt, _ = fsl_step(cparams, copt, cbatch(0),
                                jnp.asarray(0, jnp.int32))
    assert spread(cparams) > 0, "clients should diverge on local step"
    cparams, copt, _ = fsl_step(cparams, copt, cbatch(1),
                                jnp.asarray(1, jnp.int32))
    assert spread(cparams) < 1e-6, "FedAvg round should equalize replicas"


def test_fsl_every_step_equals_sync():
    """local_steps=1 keeps replicas identical at every step."""
    n_clients = 2
    cfg, m, params, opt_state = _setup(batch=4, **{"fsl.local_steps": 1})
    fsl_step = jax.jit(make_fsl_train_step(cfg, n_clients))
    cparams = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients, *x.shape)), params)
    copt = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients, *x.shape)), opt_state)
    b = synthetic_lm_batch(4 * n_clients, 32, m.vocab_size, seed=2)
    cb = {k: jnp.asarray(v).reshape(n_clients, 4, -1) for k, v in b.items()}
    cparams, copt, _ = fsl_step(cparams, copt, cb, jnp.asarray(0, jnp.int32))
    for leaf in jax.tree.leaves(cparams):
        np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                                   np.asarray(leaf[1], np.float32),
                                   atol=1e-6)
