"""MoE dispatch properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MoEConfig
from repro.models.layers import mlp_apply
from repro.models.moe import load_balance_loss, moe_apply, moe_init

KEY = jax.random.PRNGKey(0)


def test_single_expert_topk1_equals_dense_mlp():
    """E=1, k=1 routing reduces exactly to one SwiGLU expert on all tokens."""
    cfg = MoEConfig(num_experts=1, top_k=1, d_ff_expert=32,
                    router_aux_coef=0.0)
    p = moe_init(KEY, 16, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 6, 16))
    y, aux = moe_apply(p, x, cfg)
    dense_p = {"gate": {"w": p["experts"]["gate"][0]},
               "up": {"w": p["experts"]["up"][0]},
               "down": {"w": p["experts"]["down"][0]}}
    want = mlp_apply(dense_p, x, "silu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-5)


def test_moe_finite_and_shape():
    cfg = MoEConfig(num_experts=8, num_shared_experts=1, top_k=2,
                    d_ff_expert=16)
    p = moe_init(KEY, 32, cfg)
    x = jax.random.normal(KEY, (2, 10, 32))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(aux))


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives loss == E * E*(1/E)*(1/E) == 1."""
    e, t = 8, 64
    probs = jnp.full((t, e), 1.0 / e)
    idx = (jnp.arange(t) % e)[:, None]
    val = float(load_balance_loss(probs, idx, e))
    assert val == pytest.approx(1.0, rel=1e-5)


def test_load_balance_loss_penalizes_collapse():
    e, t = 8, 64
    probs = jnp.zeros((t, e)).at[:, 0].set(1.0)
    idx = jnp.zeros((t, 1), jnp.int32)
    collapsed = float(load_balance_loss(probs, idx, e))
    uniform = 1.0
    assert collapsed > 4 * uniform


def test_capacity_drop_keeps_output_finite():
    """Tiny capacity factor forces drops; outputs must stay finite and the
    dropped tokens fall back to (shared-expert or zero) contribution."""
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8,
                    capacity_factor=0.3)
    p = moe_init(KEY, 16, cfg)
    x = jax.random.normal(KEY, (1, 32, 16))
    y, aux = moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(y).all())


def test_router_gradients_flow():
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8)
    p = moe_init(KEY, 16, cfg)
    x = jax.random.normal(KEY, (1, 8, 16))

    def loss(p):
        y, aux = moe_apply(p, x, cfg)
        return jnp.sum(y ** 2) + aux

    g = jax.grad(loss)(p)
    gr = g["router"]["w"]
    assert float(jnp.max(jnp.abs(gr))) > 0, "router got no gradient"
