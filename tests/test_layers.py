"""Core layers: norms, rope, attention equivalences, GQA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def test_rmsnorm_unit_scale_output_norm(key):
    p = L.rmsnorm_init(64)
    x = jax.random.normal(key, (4, 8, 64)) * 5.0
    y = L.rmsnorm_apply(p, x)
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_layernorm_zero_mean(key):
    p = L.layernorm_init(32)
    x = jax.random.normal(key, (2, 5, 32)) + 3.0
    y = L.layernorm_apply(p, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-5)


def test_rope_preserves_norm_and_relative(key):
    x = jax.random.normal(key, (1, 6, 2, 32))
    pos = jnp.arange(6)
    y = L.apply_rope(x, pos, 10000.0)
    # rotation preserves per-head norms
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # dot products depend only on relative distance
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, 32))
    def dot_at(pq, pk):
        qr = L.apply_rope(q, jnp.asarray([pq]), 10000.0)
        kr = L.apply_rope(k, jnp.asarray([pk]), 10000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(7, 5)) < 1e-4


def test_attention_chunked_matches_full(key):
    b, s, h, d = 2, 96, 4, 32
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, 2, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2, d))
    pos = jnp.arange(s)
    full = L.attention_full(q, k, v, pos, pos)
    chunked = L.attention_chunked(q, k, v, pos, pos, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5)


def test_attention_chunked_sliding_window(key):
    b, s, h, d = 1, 64, 2, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    pos = jnp.arange(s)
    full = L.attention_full(q, k, v, pos, pos, window=8)
    chunked = L.attention_chunked(q, k, v, pos, pos, window=8, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=1e-5)


def test_attention_causality(key):
    """Changing future K/V must not change past outputs."""
    b, s, h, d = 1, 32, 2, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d))
    pos = jnp.arange(s)
    out1 = L.attention_full(q, k, v, pos, pos)
    k2 = k.at[:, 20:].set(99.0)
    v2 = v.at[:, 20:].set(-99.0)
    out2 = L.attention_full(q, k2, v2, pos, pos)
    np.testing.assert_allclose(np.asarray(out1[:, :20]),
                               np.asarray(out2[:, :20]), atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, 20:]), np.asarray(out2[:, 20:]))


def test_gqa_kv_repetition_matches_mha(key):
    """GQA with kv groups == explicit repetition."""
    dims = L.AttnDims(d_model=64, num_heads=4, num_kv_heads=2, head_dim=16)
    p = L.gqa_init(key, dims)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 24, 64))
    out, (k, v) = L.gqa_apply(p, x, dims)
    assert out.shape == (2, 24, 64)
    assert k.shape == (2, 24, 2, 16)


def test_mlp_swiglu_shapes(key):
    p = L.mlp_init(key, 32, 64, "silu")
    x = jax.random.normal(key, (2, 5, 32))
    assert L.mlp_apply(p, x, "silu").shape == (2, 5, 32)


def test_sinusoidal_positions_range():
    e = L.sinusoidal_positions(100, 64)
    assert e.shape == (100, 64)
    assert float(jnp.max(jnp.abs(e))) <= 1.0 + 1e-6
