"""Config system: registry resolution, param counts, overrides, smoke
reduction, validation — including construction-time knob-name validation
(codec / boundary stage / selection strategy / modes)."""
import pytest

from repro.config import INPUT_SHAPES, RunConfig, reduce_for_smoke
from repro.configs.registry import (ASSIGNED_ARCHS, SHAPES, SkippedShape,
                                    get_config, iter_pairs, list_archs)

# target param counts (billions) from the assignment, +/- tolerance
EXPECTED_B = {
    "qwen3-14b": (14.8, 1.5),
    "recurrentgemma-9b": (9.6, 1.5),
    "rwkv6-1.6b": (1.6, 0.3),
    "deepseek-v2-lite-16b": (16.2, 2.0),
    "chameleon-34b": (34.3, 3.0),
    "olmoe-1b-7b": (6.9, 0.8),
    "whisper-base": (0.10, 0.05),
    "granite-20b": (28.2, 9.0),   # assignment dims give 28B (see config note)
    "qwen2-72b": (72.7, 4.0),
    "llama3-405b": (405.9, 10.0),
}


def test_all_archs_registered():
    assert len(ASSIGNED_ARCHS) == 10
    assert "dcgan-mnist" in list_archs()
    assert len(SHAPES) == 4


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_counts(arch):
    cfg = get_config(arch)
    n = cfg.model.param_count() / 1e9
    mid, tol = EXPECTED_B[arch]
    assert abs(n - mid) <= tol, f"{arch}: {n:.2f}B vs expected {mid}B"


def test_moe_active_params_smaller():
    for arch in ("deepseek-v2-lite-16b", "olmoe-1b-7b"):
        cfg = get_config(arch)
        assert cfg.model.active_param_count() < 0.5 * cfg.model.param_count()


def test_pairs_matrix_covers_40():
    pairs = list(iter_pairs(include_skipped=True))
    assert len(pairs) == 40
    skipped = [(a, s) for a, s, c in pairs if c is None]
    assert skipped == [("whisper-base", "long_500k")]


def test_long500k_dense_gets_sliding_window():
    cfg = get_config("qwen3-14b", "long_500k")
    assert cfg.model.attention == "sliding"
    assert cfg.model.sliding_window == 4096


def test_long500k_native_for_ssm():
    cfg = get_config("rwkv6-1.6b", "long_500k")
    assert cfg.model.attention == "none"
    cfg = get_config("recurrentgemma-9b", "long_500k")
    assert cfg.model.rglru.enabled


def test_whisper_long_skipped():
    with pytest.raises(SkippedShape):
        get_config("whisper-base", "long_500k")


def test_override_types_and_unknown_key():
    cfg = get_config("qwen3-14b")
    c2 = cfg.override({"model.d_model": "1024", "optim.lr": "0.01"})
    assert c2.model.d_model == 1024 and isinstance(c2.model.d_model, int)
    assert abs(c2.optim.lr - 0.01) < 1e-12
    with pytest.raises(KeyError):
        cfg.override({"model.not_a_key": 1})


def test_roundtrip_dict():
    cfg = get_config("deepseek-v2-lite-16b", "train_4k")
    c2 = RunConfig.from_dict(cfg.to_dict())
    assert c2.to_dict() == cfg.to_dict()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_reduction_bounds(arch):
    cfg = reduce_for_smoke(get_config(arch))
    m = cfg.model
    assert m.num_layers == 2
    assert m.d_model <= 512
    assert m.moe.num_experts <= 4
    assert m.num_heads % max(1, m.num_kv_heads) == 0


def test_validation_rejects_dense_long_decode():
    cfg = get_config("qwen3-14b")
    bad = cfg.override({"shape.mode": "decode", "shape.seq_len": 524288})
    with pytest.raises(ValueError):
        bad.validate()


def test_input_shapes_assignment():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)


# ---------------------------------------------------------------------------
# construction-time knob-name validation (ISSUE 5 satellite): a typo'd
# codec / stage / strategy / mode fails at config construction with the
# valid options listed, not deep inside a jitted program.
# ---------------------------------------------------------------------------

def _dcgan():
    return get_config("dcgan-mnist")


def test_fed_section_validates_names_at_construction():
    with pytest.raises(ValueError, match=r"fed\.codec.*'gzip'.*fp16"):
        _dcgan().override({"fed.codec": "gzip"})
    with pytest.raises(ValueError, match=r"fed\.mode.*fedasink.*fedasync"):
        _dcgan().override({"fed.mode": "fedasink"})
    with pytest.raises(ValueError, match=r"fed\.backend.*vectorised"):
        _dcgan().override({"fed.backend": "vectorised"})
    # aliases of the identity codec stay accepted
    assert _dcgan().override({"fed.codec": "identity"}).fed.codec \
        == "identity"


def test_split_section_validates_names_at_construction():
    with pytest.raises(ValueError,
                       match=r"split\.boundary_stage.*'zstd'.*identity"):
        _dcgan().override({"split.boundary_stage": "zstd"})
    with pytest.raises(ValueError,
                       match=r"split\.strategy.*sorted_multi"):
        _dcgan().override({"split.strategy": "sorted_best"})
    # "" = inherit fsl.selection; "none" = identity stage alias
    cfg = _dcgan().override({"split.boundary_stage": "none"})
    assert cfg.split.strategy == ""


def test_fsl_section_validates_selection_at_construction():
    with pytest.raises(ValueError,
                       match=r"fsl\.selection.*random_single"):
        _dcgan().override({"fsl.selection": "fastest_first"})


def test_privacy_section_validates_mode_at_construction():
    with pytest.raises(ValueError, match=r"privacy\.mode.*dp_sgd.*uplink"):
        _dcgan().override({"privacy.mode": "dp-sgd"})


def test_control_section_validates_names_at_construction():
    with pytest.raises(ValueError, match=r"control\.mode.*frozen"):
        _dcgan().override({"control.mode": "auto"})
    with pytest.raises(ValueError, match=r"control\.controllers.*codec"):
        _dcgan().override({"control.controllers": ["bandit"]})
    with pytest.raises(ValueError,
                       match=r"control\.replan_strategy.*sorted_multi"):
        _dcgan().override({"control.replan_strategy": "best"})
    with pytest.raises(ValueError, match=r"control\.leaky_stage.*dp"):
        _dcgan().override({"control.leaky_stage": "noise"})
    cfg = _dcgan().override({"control.mode": "adaptive",
                             "control.controllers": ["codec", "sigma"]})
    assert cfg.control.controllers == ("codec", "sigma")


def test_health_section_validates_policy_at_construction():
    from repro.config import HealthConfig
    with pytest.raises(ValueError, match=r"obs\.health\.policy.*'panic'"):
        HealthConfig(policy="panic")
    with pytest.raises(ValueError, match=r"obs\.health\.policy"):
        _dcgan().override({"obs.health.policy": "crash"})
    cfg = _dcgan().override({"obs.health.enabled": True,
                             "obs.health.policy": "rollback"})
    assert cfg.obs.health.policy == "rollback"
    assert cfg.to_dict()["obs"]["health"]["enabled"] is True
