"""Optional-hypothesis shim.

The CI image does not ship ``hypothesis`` (see requirements-dev.txt for the
full dev environment). Property-based tests import ``given/settings/st`` from
here: when hypothesis is installed they are the real thing; when it is absent
each ``@given`` test is skipped at run time while every other test in the
module still collects and runs.
"""
from __future__ import annotations

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:            # pragma: no cover - exercised in the CI image
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for hypothesis.strategies: every attribute is callable."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco
