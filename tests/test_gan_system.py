"""End-to-end FSL-GAN system behaviour (paper reproduction at smoke scale)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer, bce_logits
from repro.data import partition_dirichlet, synthetic_mnist


def test_bce_logits_stable_extremes():
    assert float(bce_logits(jnp.asarray([1000.0]), 1.0)) < 1e-3
    assert float(bce_logits(jnp.asarray([-1000.0]), 0.0)) < 1e-3
    assert np.isfinite(float(bce_logits(jnp.asarray([-1000.0]), 1.0)))
    assert float(bce_logits(jnp.asarray([-1000.0]), 1.0)) > 100.0


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("dcgan-mnist").override({
        "shape.global_batch": 16, "fsl.num_clients": 2,
        "model.dcgan.base_filters": 8})
    imgs, labels = synthetic_mnist(200, seed=0)
    parts = partition_dirichlet(imgs, labels, 2, alpha=0.5, seed=0)
    tr = FSLGANTrainer(cfg, parts, seed=0)
    metrics = [tr.train_epoch(batches_per_client=2) for _ in range(3)]
    return tr, metrics


def test_gan_trains_and_improves(trained):
    tr, metrics = trained
    assert metrics[-1]["d_loss"] < metrics[0]["d_loss"]
    assert all(np.isfinite(m["g_loss"]) for m in metrics)


def test_gan_generates_valid_images(trained):
    tr, _ = trained
    gen = tr.generate(4)
    assert gen.shape == (4, 28, 28, 1)
    assert gen.min() >= -1.0 and gen.max() <= 1.0


def test_gan_clients_have_plans(trained):
    tr, _ = trained
    for cid, plan in tr.plans.items():
        names = plan.layers_in_order()
        assert names == ["conv0", "conv1", "conv2", "classifier"]


def test_discriminators_synced_after_round(trained):
    tr, _ = trained
    ids = list(tr.state.d_params)
    for a, b in zip(jax.tree.leaves(tr.state.d_params[ids[0]]),
                    jax.tree.leaves(tr.state.d_params[ids[1]])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_privacy_boundary_server_never_sees_real_data():
    """Structural check: generator update consumes only z and D params."""
    import inspect
    from repro.core import gan
    src = inspect.getsource(gan.FSLGANTrainer._build_steps)
    # the g_step signature has no `real` argument
    assert "def g_step(g_params, g_opt, d_params, z):" in src
