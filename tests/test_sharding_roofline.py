"""Sharding rules + roofline HLO parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.roofline.analysis import collective_bytes_from_hlo
from repro.sharding.specs import (AxisRules, Lg, default_rules, logical_spec,
                                  tree_shardings)


@pytest.fixture(scope="module")
def mesh():
    # container has 1 device; a 1x1 mesh still exercises the rule machinery
    return jax.make_mesh((1, 1), ("data", "model"))


def _mesh_multi():
    """Fake a larger mesh via Mesh of the same device repeated? Not possible;
    use rule-level tests with a synthetic mesh-shape object instead."""


def test_logical_spec_divisibility(mesh):
    rules = default_rules(mesh)
    # 1-sized axes always divide; spec materializes mapped axes
    spec = logical_spec(mesh, rules, (16, 32), ("embed", "mlp"))
    assert isinstance(spec, P)


def test_logical_spec_drops_nondivisible():
    # synthetic rules against a real 1x1 mesh but manual divisibility check
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    rules = AxisRules(rules={"embed": "data", "mlp": "model",
                             "batch": ("pod", "data")})
    spec = logical_spec(FakeMesh(), rules, (30, 64), ("embed", "mlp"))
    # 30 % 16 != 0 -> dropped; 64 % 16 == 0 -> kept
    assert spec == P(None, "model")


def test_logical_spec_no_axis_reuse():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 4}
    rules = AxisRules(rules={"embed": "data", "mlp": "data"})
    spec = logical_spec(FakeMesh(), rules, (8, 8), ("embed", "mlp"))
    assert spec == P("data")  # second use of 'data' dropped (trailing None trimmed)


def test_tree_shardings_structure_mismatch_raises(mesh):
    rules = default_rules(mesh)
    params = {"a": jnp.ones((4, 4))}
    specs = {"b": Lg("embed", "mlp")}
    with pytest.raises((ValueError, KeyError)):
        tree_shardings(mesh, rules, params, specs)


def test_collective_parser_counts_bytes():
    hlo = """
  %ag = bf16[2,1024,512]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[128]{0} all-reduce(%y), to_apply=%add
  %rs = f32[64,32]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = bf16[16,16]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %a2a = f32[8,8]{1,0} all-to-all(%v), dimensions={0}
"""
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 2 * 1024 * 512 * 2
    assert out["all-reduce"] == 128 * 4
    assert out["reduce-scatter"] == 64 * 32 * 4
    assert out["collective-permute"] == 16 * 16 * 2
    assert out["all-to-all"] == 8 * 8 * 4
    assert out["count"] == 5
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_collective_parser_on_real_module():
    """Lower a psum on a 1-device mesh; parser must not crash (0 or more
    collectives depending on optimization)."""
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding

    def f(x):
        return x * 2

    with mesh:
        c = jax.jit(f, in_shardings=NamedSharding(mesh, P("data"))).lower(
            jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    out = collective_bytes_from_hlo(c.as_text())
    assert out["total"] >= 0


def test_roofline_report_dominant():
    from repro.roofline.analysis import RooflineReport
    r = RooflineReport(arch="x", shape="y", mesh="m", chips=256,
                       hlo_flops=1e15, hlo_bytes=1e9, collective_bytes=1e9,
                       model_flops=2.56e17)
    assert r.dominant == "compute"
    assert 0.9 < r.useful_flops_ratio < 1.1
