"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family (2 layers, d_model<=512, <=4 experts) runs one forward /
train step on CPU; output shapes asserted + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduce_for_smoke
from repro.configs.registry import ASSIGNED_ARCHS, get_config
from repro.models.transformer import lm_apply, lm_init, lm_loss
from repro.optim import make_optimizer
from repro.runtime import make_train_step

SEQ, BATCH = 32, 2


def _smoke_cfg(arch):
    cfg = reduce_for_smoke(get_config(arch, "train_4k"), seq_len=SEQ,
                           batch=BATCH)
    return cfg


def _batch(cfg, key):
    m = cfg.model
    toks = jax.random.randint(key, (BATCH, SEQ), 0, m.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if m.encdec.enabled:
        b["enc_embeds"] = 0.1 * jax.random.normal(
            key, (BATCH, m.encdec.encoder_seq, m.d_model))
    return b


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _smoke_cfg(arch)
    m = cfg.model
    key = jax.random.PRNGKey(0)
    params = lm_init(key, m)
    logits, aux = lm_apply(params, _batch(cfg, key), m, remat="none")
    assert logits.shape == (BATCH, SEQ, m.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = _smoke_cfg(arch)
    m = cfg.model
    key = jax.random.PRNGKey(1)
    params = lm_init(key, m)
    opt = make_optimizer(cfg.optim)
    opt_state = opt.init(params)
    step = make_train_step(cfg)
    batch = _batch(cfg, key)
    params2, opt_state2, metrics = jax.jit(step)(
        params, opt_state, batch, jnp.asarray(0, jnp.int32))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, f"{arch}: loss={loss}"
    # params actually changed
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, f"{arch}: optimizer made no update"
    # no NaNs anywhere in the updated params
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), arch


def test_dcgan_smoke():
    """The paper's own model at reduced scale."""
    from repro.config import DCGANConfig
    from repro.models.dcgan import disc_apply, disc_init, gen_apply, gen_init
    c = DCGANConfig(base_filters=8)
    key = jax.random.PRNGKey(0)
    g, d = gen_init(key, c), disc_init(key, c)
    img = gen_apply(g, jax.random.normal(key, (2, c.latent_dim)), c)
    assert img.shape == (2, 28, 28, 1)
    assert bool(jnp.isfinite(img).all())
    logit = disc_apply(d, img, c)
    assert logit.shape == (2, 1)
    assert bool(jnp.isfinite(logit).all())
