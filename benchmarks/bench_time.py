"""Fig 2 reproduction: per-epoch wall time of the slowest discriminator
under the four splitting strategies (paper §5, Time Benchmark).

Methodology mirrors the paper: 5 clients x 4 heterogeneous devices
(Time_Factor / Client_Capacity pools), 24 batches/epoch, 50 ms LAN hops.
``compute_unit_s`` is calibrated so a full model on a reference device
costs ~0.8 s/batch (the paper's compute-dominated regime — P100-scale
conv blocks on phone-class devices).
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.config import DCGANConfig
from repro.core.devices import make_pool
from repro.core.simulate import strategy_sweep
from repro.models.dcgan import disc_layer_costs, disc_layer_names


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    c = DCGANConfig()
    costs = disc_layer_costs(c)
    total = sum(costs.values())
    layers = [(n, 4 * costs[n] / total) for n in disc_layer_names(c)]
    pool = make_pool("paper", 5, 4, seed=0)
    seeds = range(3 if fast else 10)
    t0 = time.time()
    res = strategy_sweep(pool, layers, seeds=seeds, compute_unit_s=0.2,
                         lan_latency_s=0.050, batches_per_epoch=24)
    us = (time.time() - t0) * 1e6 / max(len(seeds) * 4, 1)
    rows = []
    for strat, (mean, std) in res.items():
        rows.append((f"fig2_epoch_time[{strat}]", us,
                     f"slowest_client_s={mean:.2f}+-{std:.2f}"))
    # the paper's ordering claim (sorted_multi best, random_multi worst)
    best = res["sorted_multi"][0] < min(v[0] for k, v in res.items()
                                        if k != "sorted_multi")
    worst = res["random_multi"][0] > max(v[0] for k, v in res.items()
                                         if k != "random_multi")
    rows.append(("fig2_ordering_matches_paper", us,
                 f"sorted_multi_best={best} random_multi_worst={worst}"))
    return rows
