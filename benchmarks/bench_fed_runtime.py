"""Federation runtime benchmarks (fed/ subsystem).

Three questions the runtime makes measurable:

  1. **Dispatch**: the client program's two backends — per-client loop of
     jitted steps vs ONE jitted vmap/scan program (fed/programs.py) —
     against the seed's sequential reference, under the engine.
  2. **Wire**: per-round uplink bytes and virtual round time under each
     codec (none / fp16 / int8 / topk) — what actually crosses the network
     per PS-FedGAN's accounting.
  3. **Scheduling**: sync barrier vs FedAsync vs FedBuff virtual wall-clock
     per round, with and without a straggler deadline.
  4. **Pipeline**: micro-batched split execution — virtual round time vs
     the number of micro-batches K (the 1F1B overlap schedule from
     core/pipeline feeding plan_epoch_time), plus the fused boundary
     stage (kernels/boundary_fuse) against the unfused composition.

Besides CSV rows, writes machine-readable ``BENCH_fed_runtime.json`` next
to this file (gitignored; parity with ``BENCH_privacy.json``) so the
dispatch/wire/scheduling trajectory is trackable across PRs.
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Tuple

import numpy as np

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist

from benchmarks._obs import finish, obs_over

JSON_PATH = os.path.join(os.path.dirname(__file__), "BENCH_fed_runtime.json")


def _cfg(clients: int, **over):
    base = {"shape.global_batch": 16, "fsl.num_clients": clients,
            "model.dcgan.base_filters": 8}
    base.update(over)
    return get_config("dcgan-mnist").override(base)


def _parts(clients: int):
    imgs, labels = synthetic_mnist(200 * clients, seed=0)
    return partition_dirichlet(imgs, labels, clients, alpha=0.5, seed=0)


def _time_epochs(step, reps: int) -> float:
    step()                                   # warm-up / compile
    t0 = time.time()
    for _ in range(reps):
        step()
    return (time.time() - t0) * 1e6 / reps   # us per epoch


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    clients = 3 if fast else 4
    batches = 2 if fast else 4
    reps = 2 if fast else 3
    parts = _parts(clients)
    rows: List[Tuple[str, float, str]] = []
    results = {"config": {"clients": clients, "batches": batches,
                          "reps": reps, "fast": fast}}

    # 1. dispatch: seed reference vs engine loop vs engine vectorized -----
    tr_seq = FSLGANTrainer(_cfg(clients), parts, seed=0)
    us_seq = _time_epochs(
        lambda: tr_seq.train_epoch_sequential(batches_per_client=batches),
        reps)
    tr_loop = FSLGANTrainer(_cfg(clients), parts, seed=0)
    us_loop = _time_epochs(
        lambda: tr_loop.train_epoch(batches_per_client=batches,
                                    backend="loop"), reps)
    tr_vec = FSLGANTrainer(_cfg(clients), parts, seed=0)
    us_vec = _time_epochs(
        lambda: tr_vec.train_epoch(batches_per_client=batches,
                                   backend="vectorized"), reps)
    rows.append(("fed_round_sequential", us_seq,
                 f"clients={clients} batches={batches}"))
    rows.append(("fed_round_engine[loop]", us_loop,
                 "engine sync, per-client jitted steps (bit-exact)"))
    rows.append(("fed_round_engine[vectorized]", us_vec,
                 f"speedup={us_loop / max(us_vec, 1e-9):.2f}x vs loop "
                 "(one jitted vmap program)"))
    # backend="auto": one-shot timed probe on the first round picks the
    # faster dispatch for this host (fixes the vectorized-on-CPU trap)
    tr_auto = FSLGANTrainer(_cfg(clients), parts, seed=0)
    us_auto = _time_epochs(
        lambda: tr_auto.train_epoch(batches_per_client=batches,
                                    backend="auto"), reps)
    auto_fb = next(fb for fb in tr_auto.feedback if fb.backend_probe_us)
    rows.append(("fed_round_engine[auto]", us_auto,
                 f"chose {auto_fb.backend} (probe: "
                 + " ".join(f"{k}={v:.0f}us"
                            for k, v in sorted(
                                auto_fb.backend_probe_us.items())) + ")"))
    results["dispatch"] = {
        "sequential_us": us_seq, "engine_loop_us": us_loop,
        "engine_vectorized_us": us_vec,
        "engine_auto_us": us_auto,
        "auto_choice": auto_fb.backend,
        "auto_probe_us": dict(auto_fb.backend_probe_us),
        "vectorized_speedup_vs_loop": us_loop / max(us_vec, 1e-9),
        "vectorized_speedup_vs_sequential": us_seq / max(us_vec, 1e-9)}

    # 2. codec sweep: uplink bytes + virtual round time --------------------
    results["codecs"] = {}
    for codec in ("none", "fp16", "int8", "topk"):
        tr = FSLGANTrainer(_cfg(clients, **{"fed.codec": codec,
                                            "fed.topk_frac": 0.05}),
                           parts, seed=0)
        t0 = time.time()
        m = tr.train_epoch(batches_per_client=batches)
        us = (time.time() - t0) * 1e6
        rows.append((f"fed_codec[{codec}]", us,
                     f"up_mb={m['up_mbytes']:.4f} "
                     f"down_mb={m['down_mbytes']:.4f} "
                     f"round_s={m['round_time_s']:.1f} "
                     f"d_loss={m['d_loss']:.3f}"))
        results["codecs"][codec] = {
            "us_per_epoch": us, "up_mbytes": m["up_mbytes"],
            "down_mbytes": m["down_mbytes"],
            "round_time_s": m["round_time_s"],
            "d_loss": None if not np.isfinite(m["d_loss"])
            else m["d_loss"]}

    # 3. scheduling: sync vs async vs buffered, straggler deadline ---------
    scenarios = {
        "sync": {},
        "sync_deadline": {"fed.deadline_s": 2.5e4},
        "fedasync": {"fed.mode": "fedasync", "fed.async_cycles": 2},
        "fedbuff": {"fed.mode": "fedbuff", "fed.buffer_size": 2,
                    "fed.async_cycles": 2},
    }
    results["scheduling"] = {}
    for name, over in scenarios.items():
        # each scheduling scenario leaves a recorded trace + metrics run
        # under benchmarks/obs/ (sync barrier vs async event loop spans)
        tr = FSLGANTrainer(_cfg(clients, **over,
                                **obs_over(f"fed_sched_{name}")),
                           parts, seed=0)
        t0 = time.time()
        ms = [tr.train_epoch(batches_per_client=batches)
              for _ in range(2 if fast else 3)]
        m = ms[-1]
        us = (time.time() - t0) * 1e6 / len(ms)
        rows.append((f"fed_sched[{name}]", us,
                     f"round_s={m['round_time_s']:.1f} "
                     f"clients={m['num_clients']:.0f} "
                     f"stragglers={m['stragglers']:.0f} "
                     f"staleness={m['mean_staleness']:.2f} "
                     f"d_loss={m['d_loss']:.3f}"))
        results["scheduling"][name] = {
            "us_per_epoch": us, "round_time_s": m["round_time_s"],
            "num_clients": m["num_clients"],
            "stragglers": m["stragglers"],
            "mean_staleness": m["mean_staleness"],
            "d_loss": None if not np.isfinite(m["d_loss"])
            else m["d_loss"],
            "trace_spans": len(tr.recorder.tracer.spans)}
        finish(tr)

    # 4. pipeline: micro-batched split execution vs K ----------------------
    results["pipeline"] = {}
    pipe_metrics = {}
    for k in (1, 2, 4):
        tr = FSLGANTrainer(_cfg(clients, **{
            "split.enabled": True,
            "split.pipeline_microbatches": k}), parts, seed=0)
        t0 = time.time()
        m = tr.train_epoch(batches_per_client=batches)
        us = (time.time() - t0) * 1e6
        fb = tr.feedback[-1]
        rows.append((f"fed_pipeline[k{k}]", us,
                     f"round_s={m['round_time_s']:.1f} "
                     f"overlap_speedup={fb.pipeline_speedup:.2f} "
                     f"d_loss={m['d_loss']:.3f}"))
        pipe_metrics[k] = m
        results["pipeline"][f"k{k}"] = {
            "us_per_epoch": us, "round_time_s": m["round_time_s"],
            "pipeline_speedup": fb.pipeline_speedup,
            "d_loss": None if not np.isfinite(m["d_loss"])
            else m["d_loss"]}
    r1 = pipe_metrics[1]["round_time_s"]
    r4 = pipe_metrics[4]["round_time_s"]
    d1, d4 = pipe_metrics[1]["d_loss"], pipe_metrics[4]["d_loss"]
    results["pipeline"]["round_speedup_k4_vs_k1"] = r1 / max(r4, 1e-9)
    # acceptance gates: overlap must shorten the virtual round, and the
    # micro-batched loss must track the monolithic one closely
    results["pipeline"]["speedup_ok"] = bool(r4 < r1)
    results["pipeline"]["numerics_ok"] = bool(
        abs(d4 - d1) <= 1e-2 * max(abs(d1), 1e-9))

    # fused boundary stage vs the unfused two-stage composition
    import jax
    import jax.numpy as jnp
    from repro.core.split import ComposedBoundaryStage, FusedBoundaryStage, \
        make_boundary_stage
    from repro.roofline.analysis import fused_boundary_terms
    bsz, feat = 16, 6272          # one conv0 crossing of the smoke model
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (bsz, feat), jnp.float32)
    scfg = _cfg(clients, **{"split.boundary_stage": "int8+dp",
                            "split.stage_clip": 1.0,
                            "split.stage_sigma": 0.5}).split
    fused = make_boundary_stage(scfg, "int8+dp")
    composed = ComposedBoundaryStage(
        [make_boundary_stage(scfg, "int8"), make_boundary_stage(scfg, "dp")])
    assert isinstance(fused, FusedBoundaryStage)

    def _stage_us(stage):
        def step():
            out = stage.apply(x, key)
            jax.block_until_ready(out)
            return out
        out = step()
        t0 = time.time()
        for _ in range(reps):
            step()
        return out, (time.time() - t0) * 1e6 / reps

    out_c, us_c = _stage_us(composed)
    out_f, us_f = _stage_us(fused)
    err = float(jnp.max(jnp.abs(out_c - out_f)))
    rows.append(("fed_boundary_fuse[int8+dp]", us_f,
                 f"composed={us_c:.0f}us speedup={us_c / max(us_f, 1e-9):.2f}x "
                 f"max_err={err:.2e}"))
    results["pipeline"]["boundary_fuse"] = {
        "composed_us": us_c, "fused_us": us_f,
        "fused_speedup": us_c / max(us_f, 1e-9),
        "max_abs_err": err,
        # fma re-association under jit puts the two paths ~1 ulp apart
        "fused_matches": bool(err <= 1e-5),
        "roofline": fused_boundary_terms(bsz, feat, codec="int8")}

    # 5. scale: rounds-per-second vs population + sharded dispatch ---------
    from repro.fed.roster import Roster
    from repro.fed.transport import tree_bytes
    results["config"]["devices"] = len(jax.devices())
    update_b = tree_bytes(
        tr_vec.state.d_params[next(iter(tr_vec.state.d_params))])
    participants, r_cohorts = (8, 4) if fast else (32, 8)
    pops = (100, 10_000, 1_000_000)
    results["scale"] = {"populations": {}, "update_bytes": int(update_b),
                        "participants": participants,
                        "cohorts": r_cohorts,
                        "fan_in": participants / r_cohorts}
    eps_by_pop = []
    for pop in pops:
        r = Roster(pop, participants=participants, cohorts=r_cohorts,
                   seed=0)
        t0 = time.time()
        s = r.sample_round(0)
        sample_us = (time.time() - t0) * 1e6
        flat_rps = r.rounds_per_second(update_b, down_bytes=update_b)
        hier_rps = r.rounds_per_second(update_b, down_bytes=update_b,
                                       hierarchical=True)
        wan_flat = r.wan_bytes_per_round(update_b)
        wan_hier = r.wan_bytes_per_round(update_b, hierarchical=True)
        eps = r.amplified_epsilon(1.1, rounds=100)
        eps_by_pop.append(eps)
        rows.append((f"fed_scale[pop{pop}]", sample_us,
                     f"rps={flat_rps:.4f} hier_rps={hier_rps:.4f} "
                     f"wan_mb={wan_flat / 1e6:.2f}->"
                     f"{wan_hier / 1e6:.2f} eps100={eps:.3f}"))
        results["scale"]["populations"][str(pop)] = {
            "sample_us": sample_us,
            "rounds_per_s_flat": flat_rps,
            "rounds_per_s_hier": hier_rps,
            "wan_bytes_flat": int(wan_flat),
            "wan_bytes_hier": int(wan_hier),
            "amplified_epsilon_100r": eps,
            "deterministic": bool(s == r.sample_round(0))}
    p = results["scale"]["populations"]
    results["scale"]["analytic_wan_cut_ok"] = bool(all(
        v["wan_bytes_flat"] >= results["scale"]["fan_in"]
        * v["wan_bytes_hier"] for v in p.values()))
    results["scale"]["deterministic"] = bool(all(
        v["deterministic"] for v in p.values()))
    # subsampling amplification: epsilon shrinks as the population grows
    results["scale"]["epsilon_monotone_ok"] = bool(
        all(a > b for a, b in zip(eps_by_pop, eps_by_pop[1:])))

    # measured two-tier round at the bench's client count: the hierarchy
    # must cut WAN uplink by >= the cohort fan-in (clients / cohorts)
    e_cohorts = 2
    tr_flat = FSLGANTrainer(_cfg(clients), parts, seed=0)
    m_flat = tr_flat.train_epoch(batches_per_client=batches,
                                 backend="vectorized")
    tr_hier = FSLGANTrainer(
        _cfg(clients, **{"fed.hierarchy_cohorts": e_cohorts}),
        parts, seed=0)
    m_hier = tr_hier.train_epoch(batches_per_client=batches,
                                 backend="vectorized")
    up_flat = tr_flat.engine.ledger.total_up
    up_hier = tr_hier.engine.ledger.total_up
    fan_in = clients / e_cohorts
    rows.append((f"fed_scale[hier_c{e_cohorts}]", 0.0,
                 f"wan_up {up_flat}->{up_hier} "
                 f"cut={up_flat / max(up_hier, 1):.2f}x "
                 f"(fan_in={fan_in:.1f}) "
                 f"edge={tr_hier.engine.ledger.total_edge}"))
    results["scale"]["hier_round"] = {
        "cohorts": e_cohorts,
        "wan_up_bytes_flat": int(up_flat),
        "wan_up_bytes_hier": int(up_hier),
        "edge_bytes": int(tr_hier.engine.ledger.total_edge),
        "wan_cut": up_flat / max(up_hier, 1),
        "fan_in": fan_in,
        "wan_cut_ok": bool(up_flat >= fan_in * up_hier),
        "d_loss_delta": None
        if not (np.isfinite(m_flat["d_loss"])
                and np.isfinite(m_hier["d_loss"]))
        else abs(m_flat["d_loss"] - m_hier["d_loss"])}

    # sharded vs unsharded vectorized dispatch (multi-device only: CPU
    # runs get > 1 device via --xla_force_host_platform_device_count)
    tr_unsh = FSLGANTrainer(_cfg(clients), parts, seed=0)
    us_unsh = _time_epochs(
        lambda: tr_unsh.train_epoch(batches_per_client=batches,
                                    backend="vectorized"), reps)
    tr_sh = FSLGANTrainer(_cfg(clients, **{"fed.shard_clients": True}),
                          parts, seed=0)
    us_sh = _time_epochs(
        lambda: tr_sh.train_epoch(batches_per_client=batches,
                                  backend="vectorized"), reps)
    shards = tr_sh.feedback[-1].shards
    rows.append(("fed_scale[sharded]", us_sh,
                 f"unsharded={us_unsh:.0f}us shards={shards} "
                 f"devices={len(jax.devices())} "
                 f"speedup={us_unsh / max(us_sh, 1e-9):.2f}x"))
    results["scale"]["sharded"] = {
        "devices": len(jax.devices()), "shards": int(shards),
        "unsharded_us": us_unsh, "sharded_us": us_sh,
        "speedup": us_unsh / max(us_sh, 1e-9)}

    # 6. agg: compressed-domain streaming server reduce ---------------------
    # decode-then-fedavg (stage one decoded fp32 tree per client, stack,
    # weighted mean — what server_reduce="decode" pays) vs the streaming
    # fold (fold each int8 wire into one persistent accumulator) vs the
    # batched vmap decode-reduce.  Client counts are FIXED at 4/16/64 in
    # both fast and full mode — the regress gate's boolean rules
    # (numerics_ok, speedup_ok@64) must hold at any size, so only the
    # leaf width shrinks under fast.
    from repro.fed.aggregate import (StreamingAggregator, batched_reduce,
                                     decode_enc)
    from repro.fed.programs import fedavg_stacked, stack_trees
    from repro.fed.transport import make_codec
    from repro.roofline.analysis import agg_fuse_terms
    leaf = (1 << 14) if fast else (1 << 17)
    template = {"w": jnp.zeros((leaf,), jnp.float32),
                "b": jnp.zeros((leaf // 4,), jnp.float32)}
    n_total = sum(l.size for l in jax.tree.leaves(template))
    results["agg"] = {"codec": "int8", "elems": int(n_total),
                      "roofline_c64": agg_fuse_terms(64, n_total,
                                                     codec="int8")}

    def _best_us(fn, reps_):
        fn()                                   # warm-up / compile
        best = float("inf")
        for _ in range(max(1, reps_)):
            t0 = time.time()
            fn()
            best = min(best, time.time() - t0)
        return best * 1e6

    for c in (4, 16, 64):
        akey = jax.random.PRNGKey(c)
        encs, wire_b = [], 0
        for i in range(c):
            ki = jax.random.fold_in(akey, i)
            d = {"w": 0.1 * jax.random.normal(ki, (leaf,), jnp.float32),
                 "b": 0.1 * jax.random.normal(jax.random.fold_in(ki, 1),
                                              (leaf // 4,), jnp.float32)}
            enc, nb = make_codec("int8").encode_tree(d)
            encs.append(enc)
            wire_b += nb
        agg_w = [1.0 + (i % 3) for i in range(c)]    # non-uniform weights

        def _decode_reduce():
            trees = [decode_enc("int8", e, template) for e in encs]
            out = fedavg_stacked(stack_trees(trees), agg_w)
            jax.block_until_ready(out)
            return out

        def _stream():
            agg = StreamingAggregator("int8")
            agg.init(template)
            for e, w in zip(encs, agg_w):
                agg.fold(e, w)
            out = agg.finalize()
            jax.block_until_ready(out)
            return out

        def _batched():
            out = batched_reduce("int8", encs, agg_w, template)
            jax.block_until_ready(out)
            return out

        want = _decode_reduce()
        dec_us = _best_us(_decode_reduce, reps)
        str_us = _best_us(_stream, reps)
        bat_us = _best_us(_batched, reps)

        def _rel(got):
            num = den = 0.0
            for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
                num += float(jnp.sum((a - b) ** 2))
                den += float(jnp.sum(b ** 2))
            return (num ** 0.5) / max(den ** 0.5, 1e-12)

        err_s, err_b = _rel(_stream()), _rel(_batched())
        speedup = dec_us / max(str_us, 1e-9)
        rows.append((f"fed_agg[c{c}]", str_us,
                     f"decode={dec_us:.0f}us batched={bat_us:.0f}us "
                     f"fused_speedup={speedup:.2f}x "
                     f"err={max(err_s, err_b):.2e} "
                     f"trees {c}->1"))
        results["agg"][f"c{c}"] = {
            "decode_us": dec_us, "stream_us": str_us, "batched_us": bat_us,
            "fused_speedup": speedup,
            "speedup_ok": bool(speedup >= 1.2),
            # one weighted mean's reassociation: fma-level
            "numerics_ok": bool(err_s <= 2e-5 and err_b <= 2e-5),
            "rel_err_stream": err_s, "rel_err_batched": err_b,
            "wire_bytes": int(wire_b),
            # peak live decoded fp32 trees at the server: the decode
            # reduce stages one per client, the fold holds one accumulator
            "peak_trees_decode": c, "peak_trees_stream": 1}

    with open(JSON_PATH, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    rows.append(("fed_runtime_json", 0.0, f"wrote {JSON_PATH}"))
    return rows
