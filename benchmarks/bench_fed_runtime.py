"""Federation runtime benchmarks (fed/ subsystem).

Three questions the runtime makes measurable:

  1. **Dispatch**: vectorized (one jitted vmap program) vs sequential
     per-client Python loop for the multi-client D round — the speed
     headline of fed/vectorized.py.
  2. **Wire**: per-round uplink bytes and virtual round time under each
     codec (none / fp16 / int8 / topk) — what actually crosses the network
     per PS-FedGAN's accounting.
  3. **Scheduling**: sync barrier vs FedAsync vs FedBuff virtual wall-clock
     per round, with and without a straggler deadline.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist


def _cfg(clients: int, **over):
    base = {"shape.global_batch": 16, "fsl.num_clients": clients,
            "model.dcgan.base_filters": 8}
    base.update(over)
    return get_config("dcgan-mnist").override(base)


def _parts(clients: int):
    imgs, labels = synthetic_mnist(200 * clients, seed=0)
    return partition_dirichlet(imgs, labels, clients, alpha=0.5, seed=0)


def _time_epochs(step, reps: int) -> float:
    step()                                   # warm-up / compile
    t0 = time.time()
    for _ in range(reps):
        step()
    return (time.time() - t0) * 1e6 / reps   # us per epoch


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    clients = 3 if fast else 4
    batches = 2 if fast else 4
    reps = 2 if fast else 3
    parts = _parts(clients)
    rows: List[Tuple[str, float, str]] = []

    # 1. vectorized vs sequential dispatch ---------------------------------
    tr_seq = FSLGANTrainer(_cfg(clients), parts, seed=0)
    us_seq = _time_epochs(
        lambda: tr_seq.train_epoch_sequential(batches_per_client=batches),
        reps)
    tr_vec = FSLGANTrainer(_cfg(clients), parts, seed=0)
    us_vec = _time_epochs(
        lambda: tr_vec.train_epoch_vectorized(batches_per_client=batches),
        reps)
    rows.append(("fed_round_sequential", us_seq,
                 f"clients={clients} batches={batches}"))
    rows.append(("fed_round_vectorized", us_vec,
                 f"speedup={us_seq / max(us_vec, 1e-9):.2f}x "
                 "(one jitted vmap program)"))

    # 2. codec sweep: uplink bytes + virtual round time --------------------
    for codec in ("none", "fp16", "int8", "topk"):
        tr = FSLGANTrainer(_cfg(clients, **{"fed.codec": codec,
                                            "fed.topk_frac": 0.05}),
                           parts, seed=0)
        t0 = time.time()
        m = tr.train_epoch(batches_per_client=batches)
        rows.append((f"fed_codec[{codec}]", (time.time() - t0) * 1e6,
                     f"up_mb={m['up_mbytes']:.4f} "
                     f"down_mb={m['down_mbytes']:.4f} "
                     f"round_s={m['round_time_s']:.1f} "
                     f"d_loss={m['d_loss']:.3f}"))

    # 3. scheduling: sync vs async vs buffered, straggler deadline ---------
    scenarios = {
        "sync": {},
        "sync_deadline": {"fed.deadline_s": 2.5e4},
        "fedasync": {"fed.mode": "fedasync", "fed.async_cycles": 2},
        "fedbuff": {"fed.mode": "fedbuff", "fed.buffer_size": 2,
                    "fed.async_cycles": 2},
    }
    for name, over in scenarios.items():
        tr = FSLGANTrainer(_cfg(clients, **over), parts, seed=0)
        t0 = time.time()
        ms = [tr.train_epoch(batches_per_client=batches)
              for _ in range(2 if fast else 3)]
        m = ms[-1]
        rows.append((f"fed_sched[{name}]",
                     (time.time() - t0) * 1e6 / len(ms),
                     f"round_s={m['round_time_s']:.1f} "
                     f"clients={m['num_clients']:.0f} "
                     f"stragglers={m['stragglers']:.0f} "
                     f"staleness={m['mean_staleness']:.2f} "
                     f"d_loss={m['d_loss']:.3f}"))
    return rows
