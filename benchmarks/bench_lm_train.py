"""LM substrate benchmark: steps/s + loss trajectory for a reduced arch on
CPU, and FSL-cadence overhead (local_steps=1 vs 4) — the paper's FedAvg
cadence applied to transformer training."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.config import reduce_for_smoke
from repro.configs.registry import get_config
from repro.data import synthetic_lm_batch
from repro.models.transformer import lm_init
from repro.optim import make_optimizer
from repro.runtime import make_fsl_train_step, make_train_step


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    steps = 5 if fast else 15
    rows = []
    cfg = reduce_for_smoke(get_config("qwen3-14b", "train_4k"), seq_len=64,
                           batch=8)
    m = cfg.model
    params = lm_init(jax.random.PRNGKey(0), m)
    opt = make_optimizer(cfg.optim)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg))
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_lm_batch(8, 64, m.vocab_size, seed=0).items()}
    params_, opt_, metrics = step(params, opt_state, batch,
                                  jnp.asarray(0, jnp.int32))  # compile
    t0 = time.time()
    first = float(metrics["loss"])
    for i in range(steps):
        params_, opt_, metrics = step(params_, opt_, batch,
                                      jnp.asarray(i + 1, jnp.int32))
    us = (time.time() - t0) * 1e6 / steps
    rows.append(("lm_train_step[qwen3-smoke]", us,
                 f"loss {first:.3f}->{float(metrics['loss']):.3f}"))

    # FSL cadence: 2 clients, local_steps 1 vs 4
    for ls in (1, 4):
        cfg2 = cfg.override({"fsl.local_steps": ls})
        fstep = jax.jit(make_fsl_train_step(cfg2, 2))
        cp = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (2, *x.shape)),
                          params)
        co = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (2, *x.shape)),
                          opt_state)
        b = synthetic_lm_batch(16, 64, m.vocab_size, seed=1)
        cb = {k: jnp.asarray(v).reshape(2, 8, -1) for k, v in b.items()}
        cp, co, met = fstep(cp, co, cb, jnp.asarray(0, jnp.int32))  # compile
        t0 = time.time()
        for i in range(steps):
            cp, co, met = fstep(cp, co, cb, jnp.asarray(i + 1, jnp.int32))
        us = (time.time() - t0) * 1e6 / steps
        rows.append((f"fsl_train_step[2clients_localsteps{ls}]", us,
                     f"loss={float(met['loss']):.3f}"))
    return rows
