"""Privacy frontier benchmarks (privacy/ subsystem).

Four measured surfaces, mirroring the attack suite:

  1. **Split-depth leakage** — distance correlation between raw inputs and
     the smashed activation at each discriminator depth, plus the boundary
     depths each selection strategy actually exposes (the deeper the first
     LAN hop, the less an on-path device sees).
  2. **DP frontier** — for a sigma sweep: trained d_loss (utility proxy),
     accountant epsilon, and gradient-inversion reconstruction PSNR
     against the uplinked gradient (leakage).  The leakage-vs-accuracy-
     vs-epsilon trade the ROADMAP asks for.
  3. **Shipped-boundary attack** — run an actual split training round
     (``cfg.split`` enabled) per boundary stage and attack the tensors it
     really ships (post-codec, post-DP-noise): per-boundary dCor + decoder
     inversion PSNR + wire bytes.  The executed-split counterpart of (1).
  4. **Kernel** — dp_clip Pallas kernel (interpret) vs its pure-JAX
     reference, like bench_kernels' other entries.

Besides CSV rows, writes machine-readable ``BENCH_privacy.json`` next to
this file (gitignored), same facts keyed for downstream tooling.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import DCGANConfig
from repro.configs.registry import get_config
from repro.core.devices import make_pool
from repro.core.gan import FSLGANTrainer, d_loss_fn
from repro.core.selection import plan_all_clients
from repro.data import partition_dirichlet, synthetic_mnist
from repro.kernels.dp_clip.ops import dp_clip_noise_tree
from repro.kernels.dp_clip.ref import dp_clip_noise_ref
from repro.models.dcgan import disc_init, disc_layer_costs, disc_layer_names
from repro.privacy import (ActivationInversionAttack, best_match_psnr,
                           distance_correlation, invert_gradients,
                           make_prefix_fn, make_shipped_prefix_fn,
                           plan_boundary_depths)

from benchmarks._obs import finish, obs_over

JSON_PATH = os.path.join(os.path.dirname(__file__), "BENCH_privacy.json")


def _cfg(clients: int, **over):
    base = {"shape.global_batch": 8, "fsl.num_clients": clients,
            "model.dcgan.base_filters": 8}
    base.update(over)
    return get_config("dcgan-mnist").override(base)


def _split_depth_leakage(fast: bool):
    """dCor(input, activation) per depth + boundary depths per strategy."""
    c = DCGANConfig(base_filters=8)
    params = disc_init(jax.random.PRNGKey(0), c)
    probe, _ = synthetic_mnist(48 if fast else 96, seed=3)
    probe = jnp.asarray(probe)
    depth_dcor = {}
    for depth in range(1, len(disc_layer_names(c))):
        act = make_prefix_fn(params, c, depth)(probe)
        depth_dcor[depth] = distance_correlation(probe, act)
    costs = disc_layer_costs(c)
    layers = [(n, costs[n]) for n in disc_layer_names(c)]
    pool = make_pool("paper", 4, 4, seed=0)
    strat_depths = {}
    for strategy in ("random_single", "sorted_single", "sorted_multi"):
        plans = plan_all_clients(pool, layers, strategy, seed=0)
        depths = [d for p in plans.values() for d in plan_boundary_depths(p)]
        # min exposed depth == worst case: the shallowest activation any
        # on-path device observes under this strategy
        strat_depths[strategy] = {
            "min_depth": int(min(depths)) if depths else None,
            "mean_depth": float(np.mean(depths)) if depths else None,
            "mean_dcor_exposed": float(np.mean(
                [depth_dcor[min(d, max(depth_dcor))] for d in depths]))
            if depths else None}
    return depth_dcor, strat_depths


def _dp_frontier(clients: int, batches: int, epochs: int, sigmas, parts):
    """Train briefly per sigma; measure utility, epsilon, inversion PSNR."""
    c = DCGANConfig(base_filters=8)
    loss_fn = functools.partial(d_loss_fn, c=c)
    imgs, _ = synthetic_mnist(4, seed=1)
    real = jnp.asarray(imgs[:1])
    points = []
    for sigma in sigmas:
        over = {} if sigma is None else {
            "privacy.enabled": True, "privacy.noise_multiplier": sigma,
            "privacy.clip_norm": 1.0, "privacy.sample_rate": 0.1}
        tr = FSLGANTrainer(_cfg(clients, **over), parts, seed=0)
        t0 = time.time()
        for _ in range(epochs):
            m = tr.train_epoch(batches_per_client=batches)
        train_us = (time.time() - t0) * 1e6 / epochs
        # leakage probe: invert the (privatized) gradient of one real image
        params = tr.state.d_params[tr.client_ids[0]]
        fake = 0.3 * jax.random.normal(jax.random.PRNGKey(3), real.shape)
        if sigma is None:
            g = jax.grad(loss_fn)(params, real, fake)
        else:
            per_ex = jax.vmap(
                lambda r, f: jax.grad(loss_fn)(params, r[None], f[None]),
                in_axes=(0, 0))(real, fake)
            g = dp_clip_noise_tree(per_ex, 1.0, float(sigma),
                                   jax.random.PRNGKey(11), use_kernel=False)
        rec, _ = invert_gradients(loss_fn, params, g, fake, real.shape,
                                  steps=150, key=jax.random.PRNGKey(7))
        points.append({
            "sigma": 0.0 if sigma is None else float(sigma),
            "dp": sigma is not None,
            "d_loss": float(m["d_loss"]),
            "g_loss": float(m["g_loss"]),
            "epsilon": float(m.get("dp_epsilon", float("inf"))),
            "inversion_psnr_db": best_match_psnr(rec, real),
            "train_us_per_epoch": train_us})
    return points


def _split_boundary_attack(fast: bool, parts):
    """Attack the boundary tensors an EXECUTED split round actually ships.

    For each boundary stage, run one real split training round
    (cfg.split enabled), then target the post-stage tensors
    (``make_shipped_prefix_fn``) with dCor + a decoder inversion — the
    leakage of the deployment, not of a separate clean forward."""
    stages = ["identity", "int8"] if fast else ["identity", "fp16", "int8",
                                                "dp"]
    dec_steps = 30 if fast else 80
    probe_n = 32 if fast else 64
    points = []
    for stage in stages:
        over = {"split.enabled": True, "split.boundary_stage": stage,
                "split.stage_clip": 5.0, "split.stage_sigma": 0.5}
        # recorded: the trace carries one span per boundary crossing, so
        # the attacked tensors map 1:1 onto spans in benchmarks/obs/
        tr = FSLGANTrainer(_cfg(2, **over,
                                **obs_over(f"privacy_split_{stage}")),
                           parts, seed=0)
        m = tr.train_epoch(batches_per_client=1)
        finish(tr)
        # deepest-split client => per-boundary rows actually sweep depth
        cid = max(tr._active_clients(),
                  key=lambda c: tr.split_execs[c].num_boundaries)
        ex = tr.split_execs[cid]
        d_params = tr.state.d_params[cid]
        aux, _ = synthetic_mnist(probe_n, seed=5)
        victim, _ = synthetic_mnist(16, seed=9)
        aux, victim = jnp.asarray(aux), jnp.asarray(victim)
        for b in range(ex.num_boundaries):
            prefix = make_shipped_prefix_fn(ex, d_params, b,
                                            key=jax.random.PRNGKey(13))
            atk = ActivationInversionAttack(prefix, (28, 28, 1), width=16)
            atk.train(aux, steps=dec_steps, batch=16)
            rec = atk.reconstruct(victim)
            points.append({
                "stage": stage,
                "boundary": b,
                "depth": ex.boundaries[b].depth,
                # priced at the ROUND's batch size: these rows reconcile
                # with round_lan_mbytes (x 2 directions x passes x steps)
                "wire_bytes": ex.stages[b].wire_bytes(ex.boundary_shapes(
                    d_params, (tr.batch_size,) + victim.shape[1:])[b]),
                "dcor": distance_correlation(victim, prefix(victim)),
                "psnr_db": best_match_psnr(rec, victim),
                "round_lan_mbytes": float(m["lan_mbytes"])})
    return points


def _kernel_rows(reps: int) -> List[Tuple[str, float, str]]:
    b, n = 8, 1 << 16
    x = jax.random.normal(jax.random.PRNGKey(0), (b, n))
    z = jax.random.normal(jax.random.PRNGKey(1), (n,))

    ref = jax.jit(lambda: dp_clip_noise_ref(x, 1.0, 0.5, z))
    ref().block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        ref().block_until_ready()
    us_ref = (time.time() - t0) * 1e6 / reps

    from repro.kernels.dp_clip.kernel import dp_clip_noise_kernel
    kern = jax.jit(lambda: dp_clip_noise_kernel(x, 1.0, 0.5, z,
                                                interpret=True))
    out = kern().block_until_ready()
    err = float(jnp.max(jnp.abs(out - ref())))
    t0 = time.time()
    for _ in range(reps):
        kern().block_until_ready()
    us_k = (time.time() - t0) * 1e6 / reps
    return [("dp_clip_ref", us_ref, f"B={b} N={n}"),
            ("dp_clip_kernel[interpret]", us_k,
             f"max_err={err:.2e} (vs ref)")]


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    clients = 2
    batches = 1 if fast else 2
    epochs = 1 if fast else 2
    sigmas = [None, 1.0] if fast else [None, 0.5, 1.0, 2.0]
    rows: List[Tuple[str, float, str]] = []

    t0 = time.time()
    depth_dcor, strat_depths = _split_depth_leakage(fast)
    rows.append(("privacy_split_leakage", (time.time() - t0) * 1e6,
                 " ".join(f"dcor[d{d}]={v:.3f}"
                          for d, v in sorted(depth_dcor.items()))))
    for s, info in strat_depths.items():
        rows.append((f"privacy_boundary[{s}]", 0.0,
                     f"min_depth={info['min_depth']} "
                     f"mean_depth={info['mean_depth']:.2f} "
                     f"mean_dcor={info['mean_dcor_exposed']:.3f}"))

    imgs, labels = synthetic_mnist(60 * clients, seed=0)
    parts = partition_dirichlet(imgs, labels, clients, alpha=0.5, seed=0)
    frontier = _dp_frontier(clients, batches, epochs, sigmas, parts)
    for p in frontier:
        tag = f"sigma={p['sigma']:.2f}" if p["dp"] else "no_dp"
        rows.append((f"privacy_frontier[{tag}]", p["train_us_per_epoch"],
                     f"eps={p['epsilon']:.2f} d_loss={p['d_loss']:.3f} "
                     f"inv_psnr={p['inversion_psnr_db']:.2f}dB"))

    t0 = time.time()
    boundary_attack = _split_boundary_attack(fast, parts)
    rows.append(("privacy_split_boundary_attack", (time.time() - t0) * 1e6,
                 f"{len(boundary_attack)} (stage, boundary) cells"))
    for p in boundary_attack:
        rows.append((f"privacy_shipped[{p['stage']}/b{p['boundary']}]", 0.0,
                     f"depth={p['depth']} dcor={p['dcor']:.3f} "
                     f"psnr={p['psnr_db']:.2f}dB "
                     f"wire={p['wire_bytes']}B"))

    rows.extend(_kernel_rows(2 if fast else 4))

    with open(JSON_PATH, "w") as f:
        json.dump({"split_depth_dcor": {str(k): v
                                        for k, v in depth_dcor.items()},
                   "strategy_boundaries": strat_depths,
                   "dp_frontier": frontier,
                   "split_boundary_attack": boundary_attack}, f, indent=2)
    rows.append(("privacy_json", 0.0, JSON_PATH))
    return rows
