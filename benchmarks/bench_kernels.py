"""Kernel micro-benchmarks: µs/call + allclose vs oracle.

On this CPU container the Pallas kernels run in interpret mode, so absolute
times are NOT TPU-indicative; the oracle-delta column is the correctness
payload and the timings track interpreter-relative changes only.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.agg_fuse.ops import dequant_reduce_flat, scatter_acc_flat
from repro.kernels.agg_fuse.ref import dequant_reduce_ref, scatter_acc_ref
from repro.kernels.boundary_fuse.ops import fused_boundary_flat
from repro.kernels.boundary_fuse.ref import fused_boundary_ref
from repro.kernels.fedavg.ops import fedavg_flat
from repro.kernels.fedavg.ref import fedavg_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)   # warm/compile
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.time() - t0) / reps * 1e6


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    key = jax.random.PRNGKey(0)
    rows = []

    # flash attention
    b, s, h, d = 1, 256, 4, 64
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, 2, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2, d))
    out, us = _time(flash_attention, q, k, v, interpret=True)
    ref = jnp.swapaxes(attention_ref(jnp.swapaxes(q, 1, 2),
                                     jnp.swapaxes(k, 1, 2),
                                     jnp.swapaxes(v, 1, 2)), 1, 2)
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append((f"kernel_flash_attn[b{b}s{s}h{h}d{d}gqa2]", us,
                 f"max_err_vs_oracle={err:.2e}"))

    # wkv6
    b, t, hh, n = 1, 128, 2, 32
    r = jax.random.normal(key, (b, t, hh, n))
    kk = jax.random.normal(jax.random.fold_in(key, 3), (b, t, hh, n))
    vv = jax.random.normal(jax.random.fold_in(key, 4), (b, t, hh, n))
    w = jnp.exp(-jnp.exp(jax.random.normal(jax.random.fold_in(key, 5),
                                           (b, t, hh, n)) * 0.5))
    u = 0.1 * jax.random.normal(jax.random.fold_in(key, 6), (hh, n))
    (out_w, sT), us = _time(wkv6, r, kk, vv, w, u, interpret=True)
    ref_w, ref_s = wkv6_ref(r, kk, vv, w, u)
    err = float(jnp.max(jnp.abs(out_w - ref_w)))
    rows.append((f"kernel_wkv6[b{b}t{t}h{hh}n{n}]", us,
                 f"max_err_vs_oracle={err:.2e}"))

    # fedavg
    st = jax.random.normal(key, (5, 65536))
    wts = jnp.arange(1.0, 6.0)
    out_f, us = _time(fedavg_flat, st, wts, interpret=True)
    err = float(jnp.max(jnp.abs(out_f - fedavg_ref(st, wts / wts.sum()))))
    rows.append(("kernel_fedavg[c5_n65536]", us,
                 f"max_err_vs_oracle={err:.2e}"))

    # fused boundary stage (codec qdq + per-example clip + noise)
    bb, nn = 8, 4096
    x = jax.random.normal(jax.random.fold_in(key, 7), (bb, nn), jnp.float32)
    noise = jax.random.normal(jax.random.fold_in(key, 8), (bb, nn),
                              jnp.float32)
    clip = jnp.asarray(1.0, jnp.float32)
    scale = jnp.asarray(0.5, jnp.float32)
    out_b, us = _time(fused_boundary_flat, x, clip, scale, noise,
                      codec="int8", use_kernel=True, interpret=True)
    ref_b = fused_boundary_ref(x, clip, scale, noise, codec="int8")
    err = float(jnp.max(jnp.abs(out_b - ref_b)))
    rows.append((f"kernel_boundary_fuse[int8_b{bb}_n{nn}]", us,
                 f"max_err_vs_oracle={err:.2e}"))

    # fused dequant-reduce (compressed-domain server aggregation)
    cc, na = 8, 65536
    wires = jax.random.randint(jax.random.fold_in(key, 9), (cc, na),
                               -127, 128, jnp.int32).astype(jnp.int8)
    scales = jax.random.uniform(jax.random.fold_in(key, 10), (cc,),
                                jnp.float32, 1e-3, 1e-1)
    wts_a = jnp.arange(1.0, cc + 1.0)
    out_a, us = _time(dequant_reduce_flat, wires, scales, wts_a,
                      use_kernel=True, interpret=True)
    wn = wts_a / wts_a.sum()
    ref_a = dequant_reduce_ref(wires, jnp.stack([wn, scales], axis=1))
    err = float(jnp.max(jnp.abs(out_a - ref_a)))
    rows.append((f"kernel_agg_fuse_dense[c{cc}_n{na}]", us,
                 f"max_err_vs_oracle={err:.2e}"))

    # sparse scatter-accumulate (top-k wires into the dense accumulator)
    kk_s, ns = 512, 65536
    acc0 = jax.random.normal(jax.random.fold_in(key, 11), (ns,), jnp.float32)
    sidx = jax.random.randint(jax.random.fold_in(key, 12), (kk_s,), 0, ns,
                              jnp.int32)                 # collisions likely
    svals = jax.random.normal(jax.random.fold_in(key, 13), (kk_s,),
                              jnp.float32)
    # the acc arg is donated — hand the timer a fresh copy per call
    out_s, us = _time(lambda: scatter_acc_flat(jnp.copy(acc0), svals, sidx,
                                               1.5, use_kernel=True,
                                               interpret=True))
    err = float(jnp.max(jnp.abs(out_s - scatter_acc_ref(acc0, svals, sidx,
                                                        1.5))))
    rows.append((f"kernel_agg_fuse_scatter[k{kk_s}_n{ns}]", us,
                 f"max_err_vs_oracle={err:.2e}"))
    return rows
