"""Fig 4 proxy: generated-image quality as a function of training.

The paper shows image grids at epochs 100..500. Offline we report a
quantitative proxy: (a) MSE between the mean generated image and the mean
real image, (b) generated pixel std (mode-collapse detector — collapsed
generators have near-zero std), before and after training.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from benchmarks._obs import finish, obs_over
from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist


def _proxies(gen: np.ndarray, real: np.ndarray):
    mse = float(np.mean((gen.mean(0) - real.mean(0)) ** 2))
    return mse, float(gen.std())


def run(fast: bool = False, epochs: int = 8) -> List[Tuple[str, float, str]]:
    if fast:
        epochs = 3
    imgs, labels = synthetic_mnist(1200, seed=0)
    cfg = get_config("dcgan-mnist").override({
        "shape.global_batch": 32, "fsl.num_clients": 3,
        "model.dcgan.base_filters": 8, **obs_over("images")})
    parts = partition_dirichlet(imgs, labels, 3, alpha=0.5, seed=0)
    tr = FSLGANTrainer(cfg, parts, seed=0)
    g0 = tr.generate(64)
    mse0, std0 = _proxies(g0, imgs)
    t0 = time.time()
    for _ in range(epochs):
        tr.train_epoch(batches_per_client=3)
    secs = time.time() - t0
    finish(tr)
    g1 = tr.generate(64)
    mse1, std1 = _proxies(g1, imgs)
    return [
        ("fig4_mean_image_mse_untrained", 0.0, f"mse={mse0:.4f}"),
        ("fig4_mean_image_mse_trained", secs * 1e6 / epochs,
         f"mse={mse1:.4f} improved={mse1 < mse0}"),
        ("fig4_pixel_std_no_collapse", 0.0,
         f"std={std1:.3f} (untrained {std0:.3f})"),
    ]
