"""Control-plane benchmarks (control/ subsystem).

Three closed loops, measured against their best static competitor:

  1. **Adaptive codec** vs every static codec: total uplink bytes and the
     final measured delta error over a multi-round run.  The acceptance
     frontier (ISSUE 5): adaptive bytes <= the best static codec that
     stays inside the error budget, at equal-or-better final delta error.
  2. **Adaptive sigma** vs the static config sigma: the controller spends
     a total (epsilon, delta) budget over a fixed horizon without ever
     crossing it, where the static sigma either overspends or sandbags.
  3. **Adaptive deadline**: the controller cuts the measured round time by
     dropping the tail of the finish distribution a static (no-deadline)
     run waits for.

Writes machine-readable ``BENCH_control.json`` next to this file
(uploaded with the other BENCH_*.json artifacts in CI).
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import List, Tuple

import numpy as np

from repro.configs.registry import get_config
from repro.core.gan import FSLGANTrainer
from repro.data import partition_dirichlet, synthetic_mnist

from benchmarks._obs import obs_over, replay_ok

JSON_PATH = os.path.join(os.path.dirname(__file__), "BENCH_control.json")

ERROR_BUDGET = 0.05
EPS_BUDGET = 3.0


def _cfg(clients: int, **over):
    base = {"shape.global_batch": 8, "fsl.num_clients": clients,
            "model.dcgan.base_filters": 8}
    base.update(over)
    return get_config("dcgan-mnist").override(base)


def _parts(clients: int):
    imgs, labels = synthetic_mnist(120 * clients, seed=0)
    return partition_dirichlet(imgs, labels, clients, alpha=0.5, seed=0)


def _run_rounds(tr: FSLGANTrainer, rounds: int, batches: int):
    for _ in range(rounds):
        tr.train_epoch(batches_per_client=batches)
    errs = [fb.codec_error for fb in tr.feedback
            if not math.isnan(fb.codec_error)]
    return {
        "up_bytes": int(tr.engine.ledger.total_up),
        "final_codec_error": errs[-1] if errs else 0.0,
        "final_d_loss": tr.feedback[-1].d_loss,
        "codec_trace": [fb.codec for fb in tr.feedback],
    }


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    clients = 2 if fast else 3
    batches = 1 if fast else 2
    rounds = 3 if fast else 5
    parts = _parts(clients)
    rows: List[Tuple[str, float, str]] = []
    results = {"config": {"clients": clients, "batches": batches,
                          "rounds": rounds, "fast": fast,
                          "error_budget": ERROR_BUDGET,
                          "epsilon_budget": EPS_BUDGET}}

    # 1. adaptive codec vs the static frontier -----------------------------
    statics = {}
    for codec in ("none", "fp16", "int8", "topk"):
        tr = FSLGANTrainer(_cfg(clients, **{"fed.codec": codec}), parts,
                           seed=0)
        t0 = time.time()
        statics[codec] = _run_rounds(tr, rounds, batches)
        rows.append((f"control_static[{codec}]",
                     (time.time() - t0) * 1e6 / rounds,
                     f"up={statics[codec]['up_bytes']} "
                     f"err={statics[codec]['final_codec_error']:.4f}"))
    tr = FSLGANTrainer(_cfg(clients, **{
        "control.mode": "adaptive", "control.controllers": ["codec"],
        "control.error_budget": ERROR_BUDGET},
        **obs_over("control_adaptive_codec")), parts, seed=0)
    t0 = time.time()
    adaptive = _run_rounds(tr, rounds, batches)
    us_adaptive = (time.time() - t0) * 1e6 / rounds
    # flight-recorder acceptance on bench data: the recorded feedback
    # JSONL replayed offline reproduces the live codec decisions
    adaptive["replay_ok"] = replay_ok(tr)
    # the frontier comparison: best static = fewest bytes among codecs
    # whose final delta error stays inside the budget
    in_budget = {k: v for k, v in statics.items()
                 if v["final_codec_error"] <= ERROR_BUDGET}
    best_static = min(in_budget, key=lambda k: in_budget[k]["up_bytes"])
    bytes_ok = adaptive["up_bytes"] <= statics[best_static]["up_bytes"]
    err_ok = adaptive["final_codec_error"] <= max(
        statics[best_static]["final_codec_error"], ERROR_BUDGET)
    rows.append(("control_adaptive_codec", us_adaptive,
                 f"up={adaptive['up_bytes']} "
                 f"err={adaptive['final_codec_error']:.4f} "
                 f"trace={'>'.join(adaptive['codec_trace'])} "
                 f"best_static={best_static} frontier_ok={bytes_ok and err_ok} "
                 f"replay_ok={adaptive['replay_ok']}"))
    results["codec"] = {"static": statics, "adaptive": adaptive,
                        "best_static": best_static,
                        "adaptive_bytes_le_best_static": bytes_ok,
                        "adaptive_error_ok": err_ok,
                        "frontier_ok": bytes_ok and err_ok}

    # 2. adaptive sigma: budget spend vs static ----------------------------
    horizon = rounds
    priv = {"privacy.enabled": True, "privacy.mode": "uplink",
            "privacy.noise_multiplier": 1.0}
    tr_static = FSLGANTrainer(_cfg(clients, **priv), parts, seed=0)
    for _ in range(horizon):
        m_static = tr_static.train_epoch(batches_per_client=batches)
    tr_ad = FSLGANTrainer(_cfg(clients, **priv, **{
        "control.mode": "adaptive", "control.controllers": ["sigma"],
        "control.epsilon_budget": EPS_BUDGET,
        "control.horizon_rounds": horizon}), parts, seed=0)
    t0 = time.time()
    for _ in range(horizon):
        m_ad = tr_ad.train_epoch(batches_per_client=batches)
    us_sigma = (time.time() - t0) * 1e6 / horizon
    budget_ok = m_ad["dp_epsilon"] <= EPS_BUDGET * (1 + 1e-9)
    rows.append(("control_adaptive_sigma", us_sigma,
                 f"eps={m_ad['dp_epsilon']:.3f}<=budget={EPS_BUDGET} "
                 f"static_eps={m_static['dp_epsilon']:.3f} "
                 f"sigma_trace={[round(f.sigma, 3) for f in tr_ad.feedback]} "
                 f"budget_ok={budget_ok}"))
    results["sigma"] = {
        "budget": EPS_BUDGET, "horizon": horizon,
        "adaptive_epsilon": m_ad["dp_epsilon"],
        "static_epsilon": m_static["dp_epsilon"],
        "sigma_trace": [fb.sigma for fb in tr_ad.feedback],
        "epsilon_trace": [fb.dp_epsilon for fb in tr_ad.feedback],
        "budget_ok": budget_ok}

    # 3. adaptive deadline vs waiting out the tail -------------------------
    sched = {"fed.client_local_steps": {"c1": 4}}
    tr_wait = FSLGANTrainer(_cfg(clients, **sched), parts, seed=0)
    for _ in range(rounds):
        m_wait = tr_wait.train_epoch(batches_per_client=batches)
    tr_dl = FSLGANTrainer(_cfg(clients, **sched, **{
        "control.mode": "adaptive", "control.controllers": ["deadline"],
        "control.deadline_quantile": 0.5, "control.deadline_slack": 1.1}),
        parts, seed=0)
    t0 = time.time()
    for _ in range(rounds):
        m_dl = tr_dl.train_epoch(batches_per_client=batches)
    us_dl = (time.time() - t0) * 1e6 / rounds
    rows.append(("control_adaptive_deadline", us_dl,
                 f"round_s={m_dl['round_time_s']:.1f} vs "
                 f"wait={m_wait['round_time_s']:.1f} "
                 f"stragglers={m_dl['stragglers']:.0f} "
                 f"deadline={tr_dl.engine.deadline_s:.1f}"))
    results["deadline"] = {
        "adaptive_round_s": m_dl["round_time_s"],
        "static_round_s": m_wait["round_time_s"],
        "deadline_s": tr_dl.engine.deadline_s,
        "deadline_trace": [fb.deadline_s for fb in tr_dl.feedback],
        "stragglers": m_dl["stragglers"],
        "faster": m_dl["round_time_s"] < m_wait["round_time_s"]}

    with open(JSON_PATH, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    rows.append(("control_json", 0.0, f"wrote {JSON_PATH}"))
    return rows
