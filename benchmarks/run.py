"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Set BENCH_FAST=1 for a quick
pass (fewer epochs/seeds).

Every trainer bench records flight-recorder artifacts under
``benchmarks/obs/`` (see _obs.py).  The BENCH_*.json baselines some
benches (re)write are regression-gated: after a bench pass, run

    python -m repro.obs.regress --bench-dir benchmarks --baseline-git HEAD

to compare the fresh numbers against the committed baselines (CI does
this in the bench-regress job and fails on regression).

  bench_time          Fig 2  epoch time vs splitting strategy
  bench_convergence   Fig 3  generator loss vs #discriminators
  bench_images        Fig 4  image-quality proxies
  bench_kernels       —      Pallas kernels vs oracles (+ µs, interpret)
  bench_lm_train      —      LM substrate + FSL cadence
  bench_roofline      —      roofline table from dry-run artifacts
  bench_fed_runtime   —      federation runtime: loop vs vectorized client-
                             program dispatch, codec wire bytes, sync/async
                             rounds; writes BENCH_fed_runtime.json
  bench_privacy       —      privacy frontier: split-depth leakage, DP
                             sigma sweep (eps/utility/inversion PSNR),
                             dp_clip kernel; writes BENCH_privacy.json
  bench_control       —      closed-loop control plane: adaptive codec vs
                             the static frontier, sigma budget spend,
                             deadline retuning; writes BENCH_control.json
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    fast = os.environ.get("BENCH_FAST", "0") == "1"
    from benchmarks import (bench_control, bench_convergence,
                            bench_fed_runtime, bench_heterogeneity,
                            bench_images, bench_kernels, bench_lm_train,
                            bench_privacy, bench_roofline, bench_time)
    modules = [
        ("bench_time", bench_time),
        ("bench_fed_runtime", bench_fed_runtime),
        ("bench_control", bench_control),
        ("bench_privacy", bench_privacy),
        ("bench_kernels", bench_kernels),
        ("bench_lm_train", bench_lm_train),
        ("bench_images", bench_images),
        ("bench_convergence", bench_convergence),
        ("bench_heterogeneity", bench_heterogeneity),
        ("bench_roofline", bench_roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            rows = mod.run(fast=fast)
            for rname, us, derived in rows:
                print(f"{rname},{us:.1f},{derived}", flush=True)
        except Exception as e:
            failures += 1
            traceback.print_exc(file=sys.stderr)
            print(f"{name},0.0,ERROR {type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
