"""Shared flight-recorder wiring for the trainer benches.

Every trainer bench (bench_control, bench_fed_runtime, bench_privacy,
bench_convergence, bench_images) records its runs through ``repro.obs``
so every bench invocation leaves trace + metrics + feedback (+ digests)
JSONL under ``benchmarks/obs/<run_id>/`` — the artifacts CI uploads next
to the BENCH_*.json numbers, and the inputs ``repro.obs.diff`` compares
across invocations.  ``obs/`` is runtime output and stays gitignored;
only the BENCH_*.json summaries are committed as baselines (gated by
``python -m repro.obs.regress``).

``FlightRecorder.flush`` is explicitly idempotent (pinned in
tests/test_obs.py), so ``finish()`` flushing and a caller flushing again
— e.g. ``replay_ok`` after a bench already called ``finish`` — costs one
trace export, not two.
"""
from __future__ import annotations

import os
import shutil
from typing import Dict

OBS_DIR = os.path.join(os.path.dirname(__file__), "obs")


def obs_over(run_id: str) -> Dict[str, object]:
    """Config overrides that point a trainer's recorder at
    ``benchmarks/obs/<run_id>``.  The run dir is wiped first: the JSONL
    sinks append, so a stale dir from a previous local bench invocation
    would splice two knob histories together and break ``replay_ok``
    (fresh CI checkouts never hit this; dirty working trees did)."""
    shutil.rmtree(os.path.join(OBS_DIR, run_id), ignore_errors=True)
    return {"obs.enabled": True, "obs.out_dir": OBS_DIR,
            "obs.run_id": run_id}


def finish(tr) -> str:
    """Flush a recorded trainer's artifacts; returns the run directory."""
    tr.recorder.flush()
    return tr.recorder.run_dir


def record_rows(run_id: str, rows) -> str:
    """Record a trainer-less bench's output rows (``(name, us, notes)``
    tuples) as a metrics JSONL under ``benchmarks/obs/<run_id>`` — the
    same artifact layout the trainer benches leave, so ``repro.obs``
    tooling (load_jsonl, diff) reads artifact-driven benches like
    bench_roofline too."""
    from repro.obs import JsonlSink
    run_dir = os.path.join(OBS_DIR, run_id)
    shutil.rmtree(run_dir, ignore_errors=True)
    os.makedirs(run_dir, exist_ok=True)
    path = os.path.join(run_dir, "metrics.jsonl")
    with JsonlSink(path) as sink:
        for name, us, notes in rows:
            sink.write({"name": name, "us": float(us), "notes": notes})
    return run_dir


def replay_ok(tr) -> bool:
    """Flush and replay the recorded run offline through the pure
    controller fold — True iff the live knob sequence is reproduced
    bit-exactly (the ISSUE 6 acceptance check, run on bench data)."""
    from repro.obs import replay_run
    return replay_run(finish(tr)).matches
